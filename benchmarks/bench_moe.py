"""Beyond-paper benchmark: the PointAcc dispatch paradigm on MoE routing.

Dense one-hot dispatch (G-M-S analogue) vs ranking-based sorted dispatch
(Fetch-on-Demand analogue) on the mixtral / granite-moe reduced configs:
wall time + the structural FLOP ratio E/topk recovered by sorting.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro import configs
from repro.models import moe as MOE


def run(arch: str, tokens: int = 2048):
    cfg = configs.get(arch, reduced=True)
    p = MOE.moe_init(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, tokens, cfg.d_model))
                    .astype(np.float32))

    dense = jax.jit(lambda p, x: MOE.moe_apply_dense(p, cfg, x)[0])
    sort = jax.jit(lambda p, x: MOE.moe_apply_sorted(
        p, cfg, x, capacity_factor=2.0)[0])

    us_d = timeit(dense, p, x)
    us_s = timeit(sort, p, x)
    ratio = cfg.n_experts / cfg.topk
    emit(f"moe/{arch}_dense_t{tokens}", us_d,
         f"experts={cfg.n_experts};topk={cfg.topk}")
    emit(f"moe/{arch}_sorted_t{tokens}", us_s,
         f"speedup={us_d / us_s:.2f}x;flop_ratio={ratio:.0f}x")


def main():
    run("mixtral-8x7b")
    run("granite-moe-1b-a400m")


if __name__ == "__main__":
    main()
