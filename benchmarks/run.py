"""Benchmark harness entry point: one module per paper table/figure.

  bench_mapping   Fig. 17-left   sort-merge vs hash kernel mapping
  bench_convflow  Fig. 17-right  Gather-MatMul-Scatter vs Fetch-on-Demand
  bench_cache     Fig. 18/19     MMU configurable cache: miss rate / DRAM
  bench_fusion    Fig. 20        temporal layer fusion DRAM reduction
  bench_models    Figs. 13/14/16 the 8 paper networks + co-design point
  bench_moe       beyond-paper   PointAcc dispatch on MoE routing

Prints ``name,us_per_call,derived`` CSV.  Roofline terms come from the
dry-run (see launch/dryrun.py + roofline_table.py), not from here — this
container has no TPU to time.
"""

import sys
import traceback

from benchmarks.common import header


def main() -> None:
    header()
    from benchmarks import (bench_cache, bench_convflow, bench_fusion,
                            bench_mapping, bench_models, bench_moe)
    failed = []
    for mod in (bench_mapping, bench_convflow, bench_cache, bench_fusion,
                bench_models, bench_moe):
        try:
            mod.main()
        except Exception:
            failed.append(mod.__name__)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
