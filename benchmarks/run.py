"""Benchmark harness entry point: one module per paper table/figure.

  bench_mapping   Fig. 17-left   sort-merge vs hash kernel mapping
  bench_convflow  Fig. 17-right  Gather-MatMul-Scatter vs Fetch-on-Demand
  bench_cache     Fig. 18/19     MMU configurable cache: miss rate / DRAM
  bench_fusion    Fig. 20        temporal layer fusion DRAM reduction
  bench_models    Figs. 13/14/16 the 8 paper networks + co-design point
  bench_serve     beyond-paper   pipelined serve hot loop vs synchronous
  bench_moe       beyond-paper   PointAcc dispatch on MoE routing

Prints ``name,us_per_call,derived`` CSV and (with --json, default
BENCH_models.json under --smoke) dumps every row as JSON so CI can archive
the perf trajectory.  Roofline terms come from the dry-run (see
launch/dryrun.py + roofline_table.py), not from here — this container has
no TPU to time.
"""

import argparse
import inspect
import sys
import traceback

from benchmarks.common import dump_json, header


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes everywhere (CI)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="dump rows as JSON (default BENCH_models.json "
                         "with --smoke)")
    args = ap.parse_args(argv)
    json_path = args.json or ("BENCH_models.json" if args.smoke else None)

    header()
    from benchmarks import (bench_cache, bench_convflow, bench_fusion,
                            bench_mapping, bench_models, bench_moe,
                            bench_serve)
    failed = []
    for mod in (bench_mapping, bench_convflow, bench_cache, bench_fusion,
                bench_models, bench_serve, bench_moe):
        takes_argv = "argv" in inspect.signature(mod.main).parameters
        try:
            if takes_argv:
                mod.main(["--smoke"] if args.smoke else [])
            else:
                mod.main()
        except Exception:
            failed.append(mod.__name__)
            traceback.print_exc()
    if json_path:
        dump_json(json_path)
        print(f"wrote {json_path}", file=sys.stderr)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
