"""Paper Fig. 17 (left): kernel mapping — mergesort-based (PointAcc) vs
hash-table-based (state-of-the-art GPU baseline).

The paper's finding: on CPU/GPU the mergesort algorithm is *slower* than
hashing, but it parallelises into a 14x-smaller circuit; on TPU the story
repeats as 'sort-based maps onto XLA's native sorting network, hashing
vectorises terribly'.  We measure on synthetic LiDAR scenes:
  * sort      — v1 engine: one lexicographic merge-sort per kernel offset
  * packed_v2 — v2 engine: pack coords to one 62-bit key, sort the cloud
                ONCE, binary-search each offset (timed end-to-end including
                the sort, with a parity assert against the hash baseline)
  * hash      — dict-based point lookup (the CPU implementation of [35])
  * bruteforce — O(N*M) coordinate-equality matching, the naive vector form
"""

from __future__ import annotations

import argparse

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import mapping as M
from repro.data.synthetic import lidar_scene


def hash_kernel_map(coords, mask, out_coords, out_mask, offsets):
    table = {tuple(c): i for i, c in enumerate(coords) if mask[i]}
    n_maps = 0
    for d in offsets:
        for j, q in enumerate(out_coords):
            if out_mask[j]:
                p = (q[0], q[1] + d[0], q[2] + d[1], q[3] + d[2])
                if p in table:
                    n_maps += 1
    return n_maps


def bruteforce_kernel_map(coords, mask, offsets_full):
    # (K, N, M) equality over coordinates, vectorised
    shifted = coords[None] - offsets_full[:, None]           # (K, N, 4)
    eq = (shifted[:, :, None, :] == coords[None, None]).all(-1)
    eq &= (mask[None, :, None] & mask[None, None, :])
    return eq.sum()


def run(n_points: int = 4096):
    coords_np, mask_np, _ = lidar_scene(0, n_points, grid=64)
    pc = M.make_point_cloud(jnp.asarray(coords_np), jnp.asarray(mask_np))

    kmap = jax.jit(lambda c, m: M.kernel_map(
        M.PointCloud(c, m, 1), M.PointCloud(c, m, 1), 3))
    us_sort = timeit(kmap, pc.coords, pc.mask)
    maps = kmap(pc.coords, pc.mask)
    n_maps = int(jnp.sum(maps.valid))
    emit(f"mapping/sort_n{n_points}", us_sort, f"maps={n_maps}")

    # v2: timed end-to-end — the single ranking sort is inside the lambda,
    # so the speedup is the real per-layer cost ratio, not sort-amortised.
    kmap2 = jax.jit(lambda c, m: M.kernel_map_v2(
        M.sort_cloud(M.PointCloud(c, m, 1)), M.PointCloud(c, m, 1), 3))
    us_v2 = timeit(kmap2, pc.coords, pc.mask)
    maps2 = kmap2(pc.coords, pc.mask)
    n_v2 = int(jnp.sum(maps2.valid))
    emit(f"mapping/packed_v2_n{n_points}", us_v2,
         f"maps={n_v2};speedup_vs_sort={us_sort / us_v2:.2f}x")

    offs = M.kernel_offsets(3, 3, 1)
    import time
    t0 = time.perf_counter()
    n_hash = hash_kernel_map(coords_np, mask_np, coords_np, mask_np, offs)
    us_hash = (time.perf_counter() - t0) * 1e6
    emit(f"mapping/hash_n{n_points}", us_hash, f"maps={n_hash}")
    assert n_hash == n_maps, (n_hash, n_maps)
    # parity: the v2 engine finds exactly the hash baseline's map count
    assert n_v2 == n_hash, (n_v2, n_hash)

    if n_points <= 4096:
        offs_full = jnp.asarray(
            np.concatenate([np.zeros((27, 1), np.int32), offs], 1))
        bf = jax.jit(bruteforce_kernel_map)
        us_bf = timeit(bf, pc.coords, pc.mask, offs_full)
        emit(f"mapping/bruteforce_n{n_points}", us_bf,
             f"speedup_vs_bf={us_bf / us_sort:.1f}x")

    emit(f"mapping/summary_n{n_points}", us_sort,
         f"sort_vs_hash={us_hash / us_sort:.2f}x;"
         f"v2_vs_sort={us_sort / us_v2:.2f}x")
    return us_sort, us_v2


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single small size (CI smoke)")
    args = ap.parse_args(argv)
    for n in (1024,) if args.smoke else (1024, 4096, 16384):
        run(n)


if __name__ == "__main__":
    main()
