"""Paper Fig. 17 (left): kernel mapping — mergesort-based (PointAcc) vs
hash-table-based (state-of-the-art GPU baseline).

The paper's finding: on CPU/GPU the mergesort algorithm is *slower* than
hashing, but it parallelises into a 14x-smaller circuit; on TPU the story
repeats as 'sort-based maps onto XLA's native sorting network, hashing
vectorises terribly'.  We measure both on synthetic LiDAR scenes:
  * sort    — repro.core.mapping.kernel_map (lax.sort + adjacent equality)
  * hash    — dict-based point lookup (the CPU implementation of [35])
  * bruteforce — O(N*M) coordinate-equality matching, the naive vector form
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import mapping as M
from repro.data.synthetic import lidar_scene


def hash_kernel_map(coords, mask, out_coords, out_mask, offsets):
    table = {tuple(c): i for i, c in enumerate(coords) if mask[i]}
    n_maps = 0
    for d in offsets:
        for j, q in enumerate(out_coords):
            if out_mask[j]:
                p = (q[0], q[1] + d[0], q[2] + d[1], q[3] + d[2])
                if p in table:
                    n_maps += 1
    return n_maps


def bruteforce_kernel_map(coords, mask, offsets_full):
    # (K, N, M) equality over coordinates, vectorised
    shifted = coords[None] - offsets_full[:, None]           # (K, N, 4)
    eq = (shifted[:, :, None, :] == coords[None, None]).all(-1)
    eq &= (mask[None, :, None] & mask[None, None, :])
    return eq.sum()


def run(n_points: int = 4096):
    coords_np, mask_np, _ = lidar_scene(0, n_points, grid=64)
    pc = M.make_point_cloud(jnp.asarray(coords_np), jnp.asarray(mask_np))

    kmap = jax.jit(lambda c, m: M.kernel_map(
        M.PointCloud(c, m, 1), M.PointCloud(c, m, 1), 3))
    us_sort = timeit(kmap, pc.coords, pc.mask)
    maps = kmap(pc.coords, pc.mask)
    n_maps = int(jnp.sum(maps.valid))
    emit(f"mapping/sort_n{n_points}", us_sort, f"maps={n_maps}")

    offs = M.kernel_offsets(3, 3, 1)
    import time
    t0 = time.perf_counter()
    n_hash = hash_kernel_map(coords_np, mask_np, coords_np, mask_np, offs)
    us_hash = (time.perf_counter() - t0) * 1e6
    emit(f"mapping/hash_n{n_points}", us_hash, f"maps={n_hash}")
    assert n_hash == n_maps, (n_hash, n_maps)

    if n_points <= 4096:
        offs_full = jnp.asarray(
            np.concatenate([np.zeros((27, 1), np.int32), offs], 1))
        bf = jax.jit(bruteforce_kernel_map)
        us_bf = timeit(bf, pc.coords, pc.mask, offs_full)
        emit(f"mapping/bruteforce_n{n_points}", us_bf,
             f"speedup_vs_bf={us_bf / us_sort:.1f}x")

    emit(f"mapping/summary_n{n_points}", us_sort,
         f"sort_vs_hash={us_hash / us_sort:.2f}x")


def main():
    for n in (1024, 4096, 16384):
        run(n)


if __name__ == "__main__":
    main()
