"""Render the §Roofline table (EXPERIMENTS.md) from the dry-run JSON."""

from __future__ import annotations

import json
import sys


def fmt_s(v):
    if v is None:
        return "-"
    if v >= 1.0:
        return f"{v:.2f}s"
    if v >= 1e-3:
        return f"{v * 1e3:.1f}ms"
    return f"{v * 1e6:.0f}us"


PEAK = 197e12


def effective_terms(r):
    """compute term = max(analytic, HLO) per-chip flops: analytic covers
    inner-scan undercount, HLO covers replication redundancy the analytic
    model assumes away (e.g. unshardable-head attention)."""
    comp = max(r["analytic_flops"] / r["chips"],
               r.get("hlo_flops_per_chip", 0.0)) / PEAK
    terms = {"compute_s": comp, "memory_s": r["memory_s"],
             "collective_s": r["collective_s"]}
    dom = max(terms, key=terms.get).replace("_s", "")
    return terms, dom


def render(path="benchmarks/results/dryrun_single_pod.json",
           out=None):
    with open(path) as f:
        rows = json.load(f)
    rows.sort(key=lambda r: (r["arch"], r["shape"]))

    lines = [
        "| arch | shape | compute | memory | collective | dominant | "
        "MODEL_FLOPS/analytic | bytes/chip(params) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("status") == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | skipped | "
                f"{r['reason'][:52]} | — |")
            continue
        if r.get("status") != "ok" or "compute_s" not in r:
            continue
        t, dom = effective_terms(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(t['compute_s'])} | "
            f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
            f"**{dom}** | {r['useful_flops_ratio']:.2f} | "
            f"{r['param_bytes_per_device'] / 1e9:.2f}GB |")
    text = "\n".join(lines)
    if out:
        with open(out, "w") as f:
            f.write(text + "\n")
    return text


def main():
    print(render(*(sys.argv[1:] or [])))


if __name__ == "__main__":
    main()
