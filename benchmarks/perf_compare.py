"""Render the §Perf baseline-vs-optimized comparison table from the two
dry-run sweeps."""

from __future__ import annotations

import json
import sys

from benchmarks.roofline_table import effective_terms, fmt_s


def load(path):
    with open(path) as f:
        return {(r["arch"], r["shape"]): r for r in json.load(f)
                if r.get("status") == "ok" and "compute_s" in r}


def render(base_path="benchmarks/results/dryrun_baseline.json",
           opt_path="benchmarks/results/dryrun_optimized.json"):
    base = load(base_path)
    opt = load(opt_path)
    lines = [
        "| arch | shape | max-term baseline | max-term optimized | "
        "improvement | dominant (b -> o) |",
        "|---|---|---|---|---|---|",
    ]
    gains = []
    for key in sorted(base):
        if key not in opt:
            continue
        tb, db = effective_terms(base[key])
        to, do = effective_terms(opt[key])
        mb = max(tb.values())
        mo = max(to.values())
        gain = mb / mo if mo > 0 else float("inf")
        gains.append(gain)
        lines.append(
            f"| {key[0]} | {key[1]} | {fmt_s(mb)} | {fmt_s(mo)} | "
            f"**{gain:.2f}x** | {db} -> {do} |")
    if gains:
        import statistics
        lines.append(
            f"\ngeometric-mean improvement on the dominant term across "
            f"{len(gains)} cells: "
            f"**{statistics.geometric_mean(gains):.2f}x**")
    return "\n".join(lines)


def main():
    print(render(*(sys.argv[1:] or [])))


if __name__ == "__main__":
    main()
