"""Serve hot loop: the pipelined scheduler vs the synchronous PR-4 path.

PointAcc's thesis is that sparse point-cloud inference is bottlenecked by
data movement, not MACs; our serving telemetry showed the same thing one
level up — per-scene overhead dominated by host-side micro-batch
assembly (per-batch `np.stack` + `tree_map(jnp.stack)` over cached
pyramids) and by the synchronous `block_until_ready` that serialized
assembly against device execution.  This benchmark measures the fix on a
steady-state *repeated-composition* stream (a replayed sensor rig — the
hot loop the AssemblyCache is keyed for), same stream and same bucket
ladder through both paths:

  serve/sync_per_scene   pipeline_depth=0, assembly_cache_entries=0
                         (bit-for-bit the PR-4 scheduler)
  serve/pipe_per_scene   composition-keyed assembly cache + pinned host
                         arenas + double-buffered async dispatch
  serve/speedup          sync / pipelined (acceptance: >= 1.3x, i.e.
                         >= 30% lower steady-state per-scene latency)
  serve/assembly         host assembly time per micro-batch, both paths,
                         + mapping/assembly cache hit rates

The fault-tolerance PR adds two rows on the same stream:

  serve/ft_overhead      no-fault steady-state cost of the guards
                         (admission validation + background watchdog) in
                         % of per-scene latency, measured per component
                         (validation timed directly; watchdog tick cost
                         amortized over its 20Hz rate) — an end-to-end
                         A/B delta is also reported but not asserted,
                         because host drift dwarfs a ~1% effect
                         (acceptance: <= 3%, asserted in the full run)
  serve/recovery         injected mid-stream dispatch failure -> next
                         successful retire (the retry/bisect pipeline
                         restart cost), with the failure counters

The city-scale partition PR adds one more row family on a single
mid-size city scene (chunked predictions asserted bit-identical to the
monolithic path first):

  serve/partition_throughput  one row per chunk budget: steady-state
                         points/s of `segment(partition=)` — octree
                         chunking over packed keys + exact receptive-
                         field halos, every chunk served through the
                         scheduler — with the halo overhead fraction
                         (halo rows / total served rows) and the
                         monolithic points/s as the derived baseline.
                         Smaller budgets mean more chunks and a larger
                         halo fraction: the row quantifies that tax.

The multi-worker router PR adds two more rows:

  serve/router_overhead  single-worker `ServeRouter` vs the bare
                         scheduler it fronts, same stream.  The router
                         adds one routing hop per scene (affinity
                         digest + rendezvous ranking + inbox handoff);
                         like serve/ft_overhead, the asserted number is
                         that hop timed directly against the per-scene
                         latency (an end-to-end A/B delta of a ~1-2%
                         effect drowns in +-20% host drift and is
                         reported informationally only).  Acceptance:
                         <= 5%, asserted in the full run after a
                         bit-identity parity check.
  serve/failover_recovery  2-worker router, one worker killed by an
                         injected fault mid-stream on warm engines:
                         worker death -> last replayed victim completed
                         (the failover + replay pipeline cost, no
                         compile in the path)

The observability PR adds one more row (and upgrades the latency rows:
serve/sync_per_scene and serve/pipe_per_scene now carry p50/p95/p99
from the registry's per-request latency histogram into BENCH_*.json):

  serve/obs_overhead     steady-state cost of the FULL observability
                         stack (span tracer + flight recorder on top of
                         the always-on metrics registry) in % of
                         per-scene latency.  Like ft_overhead /
                         router_overhead, the asserted number is the
                         per-request obs work timed directly — one
                         trace begin/end, the ~8 spans a served request
                         records, the recorder ring appends, and the
                         histogram/counter updates — against the
                         measured per-scene latency; the end-to-end A/B
                         delta is reported informationally.  Parity is
                         asserted first: an obs-enabled scheduler must
                         produce bit-identical predictions to the
                         default (metrics-only) one.  Acceptance:
                         <= 3%, asserted in the full run.

The overload-control PR adds two rows:

  serve/overload_goodput  the SLO-aware controller vs the static
                         max_backlog baseline on the SAME 2x-offered
                         storm-paced stream (FaultPlan.storm_buckets
                         caps the service rate, every request carries
                         deadline_s = the SLO): goodput is completions
                         that are OK *and* within the SLO per second.
                         The uncontrolled path queues until most
                         completions are late; the controller sheds at
                         the Little's-law bound so what it admits
                         finishes on time.  The row value is the
                         goodput ratio (acceptance: >= 1.3x, asserted
                         in the full run).  Parity is asserted first:
                         at nominal load the controller-on scheduler
                         must produce bit-identical predictions.
  serve/overload_overhead  the controller's per-scene hot-path cost at
                         nominal load — the admission gate (rate-
                         limited estimator tick + bound check) and the
                         dispatch-success breaker hook, timed directly
                         against the per-scene latency (ft_overhead
                         discipline; the e2e A/B delta is
                         informational).  Acceptance: <= 3%, asserted
                         in the full run.

Per-request predictions are asserted bit-identical between the paths
before any row is emitted.
"""

from __future__ import annotations

import argparse
import gc
import time

import numpy as np
import jax

from benchmarks.common import emit
from repro.data.synthetic import city_scene, lidar_scene
from repro.models import minkunet as MU
from repro.serve.buckets import BucketLadder
from repro.serve.engine import PointCloudEngine
from repro.serve.scheduler import ServeScheduler


def _stream_once(sched, scenes):
    """One pass: submit every scene (full buckets dispatch on submit),
    flush stragglers, take this pass's results."""
    rids = [sched.submit(c, f, m) for (c, m, f) in scenes]
    sched.flush()
    return sched.take(rids)


def _window_us(sched, scenes, reps):
    """Per-scene latency (us) of one continuous measurement window:
    `reps` repeated-composition passes submitted back to back (full
    buckets dispatch on submit — the pipelined path overlaps pass i+1's
    assembly with pass i's execution), one flush+drain at the end."""
    t0 = time.perf_counter()
    for _ in range(reps):
        for (c, m, f) in scenes:
            sched.submit(c, f, m)
    sched.flush()
    n = len(sched.drain())
    return (time.perf_counter() - t0) * 1e6 / n


def bench_hot_loop(n_points: int, reps: int, windows: int,
                   max_batch: int = 4):
    # narrow trunk on small scenes: the serving shape where host-side
    # assembly is a first-order cost (the regime the pipeline targets)
    params = MU.minkunet_init(jax.random.key(0), c_in=4, n_classes=4,
                              stem=8, enc_planes=(8, 16),
                              dec_planes=(16, 8), blocks_per_stage=1)
    scenes = [lidar_scene(seed=21 + i, n_points=n_points, grid=32)
              for i in range(max_batch)]

    def build(**kw):
        # exact-fit single bucket: measures the hot loop, not padding
        engine = PointCloudEngine(params, n_stages=2, flow="fod",
                                  ladder=BucketLadder((n_points,)),
                                  max_batch=max_batch, mesh=None)
        return ServeScheduler(engine, max_batch=max_batch, mesh=None, **kw)

    sync = build(pipeline_depth=0, assembly_cache_entries=0)
    pipe = build()

    # parity first (doubles as compile + cache warmup): same stream,
    # bit-identical per-request predictions
    ref = _stream_once(sync, scenes)
    got = _stream_once(pipe, scenes)
    for rid in ref:
        np.testing.assert_array_equal(ref[rid].preds, got[rid].preds)

    def _asm_snapshot(sched):
        st = sched.stats()
        batches = sum(b["batches"] for b in st["buckets"].values())
        return st["assembly_time_s"], batches

    # interleaved measurement windows, median per path: host-load drift
    # hits both paths instead of whichever ran second
    asm0 = {"sync": _asm_snapshot(sync), "pipe": _asm_snapshot(pipe)}
    sync_w, pipe_w = [], []
    for _ in range(windows):
        sync_w.append(_window_us(sync, scenes, reps))
        pipe_w.append(_window_us(pipe, scenes, reps))
    sync_us = float(np.median(sync_w))
    pipe_us = float(np.median(pipe_w))
    speedup = sync_us / pipe_us

    def _asm_per_batch_us(sched, name):
        t1, b1 = _asm_snapshot(sched)
        t0, b0 = asm0[name]
        return (t1 - t0) * 1e6 / max(1, b1 - b0)

    asm_sync = _asm_per_batch_us(sync, "sync")
    asm_pipe = _asm_per_batch_us(pipe, "pipe")
    s_sync = sync.stats()
    s_pipe = pipe.stats()
    ac = s_pipe["assembly_cache"]

    def _q(st):
        # per-request latency quantiles from the registry histogram,
        # carried into BENCH_*.json next to the window medians
        q = st["latency_quantiles_s"]
        return {"latency_quantiles_us":
                {k: v * 1e6 for k, v in q.items()}}

    emit("serve/sync_per_scene", sync_us,
         f"scenes_per_pass={max_batch};n={n_points};reps={reps};"
         f"windows={windows};path=pr4_synchronous;"
         f"p50_us={s_sync['latency_quantiles_s']['p50'] * 1e6:.0f};"
         f"p99_us={s_sync['latency_quantiles_s']['p99'] * 1e6:.0f}",
         extra=_q(s_sync))
    emit("serve/pipe_per_scene", pipe_us,
         f"assembly_hit_rate={ac['hit_rate']:.2f};"
         f"map_hit_rate={s_pipe['mapping_cache']['hit_rate']:.2f};"
         f"pipeline_depth={s_pipe['pipeline_depth']};"
         f"p50_us={s_pipe['latency_quantiles_s']['p50'] * 1e6:.0f};"
         f"p99_us={s_pipe['latency_quantiles_s']['p99'] * 1e6:.0f}",
         extra=_q(s_pipe))
    emit("serve/speedup", speedup,
         f"sync_us={sync_us:.0f};pipe_us={pipe_us:.0f};parity=ok;"
         f"latency_cut={(1 - pipe_us / sync_us) * 100:.0f}%;"
         f"speedup={speedup:.2f}x")
    emit("serve/assembly", asm_pipe,
         f"sync_per_batch_us={asm_sync:.0f};"
         f"pipe_per_batch_us={asm_pipe:.0f};"
         f"assembly_hits={ac['hits']};assembly_misses={ac['misses']}")
    assert speedup >= 1.3, (
        f"pipelined serve path must cut steady-state per-scene latency by "
        f">= 30% vs the synchronous scheduler, got {speedup:.2f}x "
        f"({sync_us:.0f}us -> {pipe_us:.0f}us)")
    return speedup


def bench_fault_tolerance(n_points: int, reps: int, windows: int,
                          max_batch: int = 4,
                          assert_overhead: bool = True):
    """serve/ft_overhead + serve/recovery on the repeated-composition
    stream: the guarded path (admission validation + watchdog ticker) vs
    the unguarded PR-5 submit path, and the injected-failure recovery
    latency of the retry/bisect machinery."""
    from repro.serve.faults import FaultPlan, validate_scene

    params = MU.minkunet_init(jax.random.key(0), c_in=4, n_classes=4,
                              stem=8, enc_planes=(8, 16),
                              dec_planes=(16, 8), blocks_per_stage=1)
    scenes = [lidar_scene(seed=21 + i, n_points=n_points, grid=32)
              for i in range(max_batch)]

    def build(fault_plan=None, **kw):
        engine = PointCloudEngine(params, n_stages=2, flow="fod",
                                  ladder=BucketLadder((n_points,)),
                                  max_batch=max_batch, mesh=None)
        return ServeScheduler(engine, max_batch=max_batch, mesh=None,
                              fault_plan=fault_plan, **kw)

    base = build(validate=False, watchdog_s=0)   # PR-5 submit path
    ft = build(validate=True, watchdog_s=0.05)   # guarded steady state

    # parity + warmup: the guarded no-fault path must stay bit-identical
    ref = _stream_once(base, scenes)
    got = _stream_once(ft, scenes)
    for rid in ref:
        np.testing.assert_array_equal(ref[rid].preds, got[rid].preds)

    # The guards' per-scene cost is the admission validation (the
    # watchdog is a fixed-rate lock touch, ~us at 20Hz, amortized below
    # noise) — time it directly against the measured per-scene serve
    # latency.  An end-to-end A/B diff of a ~1% effect is hopeless on a
    # shared host (window drift is +-20%), so the interleaved windows
    # below only provide the latency denominator and an informational
    # end-to-end delta.
    base_w, ft_w = [], []
    for _ in range(max(windows, 8)):
        base_w.append(_window_us(base, scenes, reps))
        ft_w.append(_window_us(ft, scenes, reps))
    base_us = float(np.mean(base_w))
    ft_us = float(np.mean(ft_w))
    e2e_delta = ft_us / base_us - 1.0

    c0, m0, f0 = scenes[0]
    n_val = 1000
    t0 = time.perf_counter()
    for _ in range(n_val):
        validate_scene(c0, f0, m0, ft.ladder)
    val_us = (time.perf_counter() - t0) * 1e6 / n_val
    # amortized watchdog cost: one tick per (watchdog period / per-scene
    # latency) scenes; the tick on a busy scheduler is a lock + deadline
    # check + head-readiness probe
    t0 = time.perf_counter()
    for _ in range(n_val):
        ft._watchdog_tick()
    tick_us = (time.perf_counter() - t0) * 1e6 / n_val
    wd_us = tick_us * (base_us / (0.05 * 1e6))
    overhead = (val_us + wd_us) / base_us
    emit("serve/ft_overhead", overhead * 100,
         f"validate_us={val_us:.1f};watchdog_us={wd_us:.2f};"
         f"per_scene_us={base_us:.0f};e2e_delta_pct={e2e_delta * 100:.1f};"
         f"guards=validate+watchdog;target_pct=3")
    ft.close()

    # recovery latency: one mid-stream dispatch failure; the bisected
    # retries run at the already-compiled shape, so this measures the
    # pipeline restart, not a compile
    plan = FaultPlan(fail_dispatches={2})
    rec = build(fault_plan=plan)
    out = _stream_once(rec, scenes * 4)          # 4 full dispatches
    assert all(r.ok for r in out.values()), "recovery run lost requests"
    st = rec.stats()["faults"]
    assert st["failed_dispatches"] == 1 and st["recovery_s"] is not None
    emit("serve/recovery", st["recovery_s"] * 1e3,
         f"retries={st['retries']};exec_failed={st['exec_failed']};"
         f"failure_to_next_retire_ms={st['recovery_s'] * 1e3:.2f}")

    if assert_overhead:
        assert overhead <= 0.03, (
            f"validation + watchdog must cost <= 3% on the no-fault "
            f"steady state, got {overhead * 100:.1f}% "
            f"({base_us:.0f}us -> {ft_us:.0f}us)")
    return overhead


def bench_partition(n_points: int, budgets: tuple[int, ...],
                    reps: int = 2):
    """serve/partition_throughput: chunk-streamed `segment(partition=)`
    points/s per chunk budget on one city scene that itself fits the
    ladder — so the monolithic path provides both the bit-identity
    reference and the baseline points/s the halo tax is measured
    against."""
    from repro.partition import PartitionPolicy
    from repro.serve.buckets import geometric_ladder

    params = MU.mini_minkunet_init(jax.random.key(0), c_in=4, n_classes=4)
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=geometric_ladder(512, 16384),
                              max_batch=4, mesh=None)
    coords, mask, feats = city_scene(seed=29, n_points=n_points)
    n_valid = int(mask.sum())

    def _time(fn):
        fn()                                      # compile + cache warmup
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn()
        return out, (time.perf_counter() - t0) / reps

    ref, mono_s = _time(lambda: engine.segment(coords, mask, feats)[0])
    mono_pps = n_valid / mono_s
    ref = np.asarray(ref)

    for budget in budgets:
        policy = PartitionPolicy(chunk_budget=budget, force=True)
        got, part_s = _time(lambda: engine.segment(
            coords, mask, feats, partition=policy)[0])
        np.testing.assert_array_equal(ref[mask], np.asarray(got)[mask])
        st = engine.last_partition_stats
        pps = n_valid / part_s
        emit("serve/partition_throughput", pps,
             f"budget={budget};chunks={st['n_chunks']};"
             f"halo_frac={st['halo_fraction']:.2f};"
             f"max_chunk={st['max_chunk_points']};"
             f"mono_pts_per_s={mono_pps:.0f};"
             f"rel_mono={pps / mono_pps:.4f}x;n={n_valid};parity=ok")


def bench_router(n_points: int, reps: int, windows: int,
                 max_batch: int = 4, assert_overhead: bool = True):
    """serve/router_overhead + serve/failover_recovery: the
    digest-affinity router's no-fault cost over the bare scheduler
    (single worker, bit-identity asserted first) and the time a
    worker-kill failover takes to make the stream whole on warm
    engines."""
    import itertools

    from repro.serve.faults import FaultPlan
    from repro.serve.router import ServeRouter

    params = MU.minkunet_init(jax.random.key(0), c_in=4, n_classes=4,
                              stem=8, enc_planes=(8, 16),
                              dec_planes=(16, 8), blocks_per_stage=1)
    scenes = [lidar_scene(seed=21 + i, n_points=n_points, grid=32)
              for i in range(max_batch)]

    def engine():
        return PointCloudEngine(params, n_stages=2, flow="fod",
                                ladder=BucketLadder((n_points,)),
                                max_batch=max_batch, mesh=None)

    # routers cycle a 2-engine pool: workers of one router get distinct
    # engines, successive routers reuse them warm (jit caches persist)
    pool = [engine(), engine()]
    counter = itertools.count()

    def factory():
        return pool[next(counter) % len(pool)]

    bare = ServeScheduler(engine(), max_batch=max_batch, mesh=None)
    router = ServeRouter(factory, 1, max_batch=max_batch, mesh=None)

    # parity first (doubles as warmup): the 1-worker router must be
    # bit-identical to the bare scheduler
    ref = _stream_once(bare, scenes)
    got = router.serve([(c, f, m) for (c, m, f) in scenes])
    for rid, brid in zip(sorted(got), sorted(ref)):
        np.testing.assert_array_equal(ref[brid].preds, got[rid].preds)

    def _router_window_us():
        t0 = time.perf_counter()
        for _ in range(reps):
            for (c, m, f) in scenes:
                router.submit(c, f, m)
        router.flush()
        n = len(router.drain())
        return (time.perf_counter() - t0) * 1e6 / n

    bare_w, rout_w = [], []
    for _ in range(windows):
        bare_w.append(_window_us(bare, scenes, reps))
        rout_w.append(_router_window_us())
    bare_us = float(np.median(bare_w))
    rout_us = float(np.median(rout_w))
    e2e_delta = rout_us / bare_us - 1.0

    # the router's per-scene addition is the routing hop: affinity
    # digest + rendezvous ranking (preview IS that hop; the remaining
    # handoff is a deque append + condition notify).  Time it directly
    # — the e2e A/B delta above is drift-dominated and informational.
    c0, m0, _ = scenes[0]
    n_hop = 300
    t0 = time.perf_counter()
    for _ in range(n_hop):
        router.preview(c0, m0)
    hop_us = (time.perf_counter() - t0) * 1e6 / n_hop
    overhead = hop_us / bare_us
    emit("serve/router_overhead", overhead * 100,
         f"hop_us={hop_us:.1f};bare_us={bare_us:.0f};"
         f"router_us={rout_us:.0f};e2e_delta_pct={e2e_delta * 100:.1f};"
         f"parity=ok;workers=1;target_pct=5")
    router.close()
    bare.close()

    # failover recovery: routing is deterministic, so probe which worker
    # the stream loads most, then kill it on its 2nd request of a fresh
    # (warm-engine) run and measure death -> stream made whole
    probe = ServeRouter(factory, 2, max_batch=max_batch, mesh=None)
    probe.serve([(c, f, m) for (c, m, f) in scenes] * reps)
    name, w = max(probe.stats()["workers"].items(),
                  key=lambda kv: kv[1]["routed"])
    ordinal, routed = w["ordinal"], w["routed"]
    probe.close()
    assert routed >= 2, "stream must load one worker with >= 2 scenes"

    plan = FaultPlan(kill_workers={ordinal: 1})
    chaos = ServeRouter(factory, 2, max_batch=max_batch, mesh=None,
                        fault_plan=plan)
    t0 = time.perf_counter()
    out = chaos.serve([(c, f, m) for (c, m, f) in scenes] * reps)
    drain_ms = (time.perf_counter() - t0) * 1e3
    st = chaos.stats()["faults"]
    assert all(r.error is None for r in out.values()), \
        "failover run lost requests"
    assert st["failovers"] == 1 and st["replayed"] >= 1
    assert st["recovery_s"] is not None
    emit("serve/failover_recovery", st["recovery_s"] * 1e3,
         f"replayed={st['replayed']};stream_ms={drain_ms:.1f};"
         f"death_to_recovered_ms={st['recovery_s'] * 1e3:.2f};"
         f"workers=2->1")
    chaos.close()

    if assert_overhead:
        assert overhead <= 0.05, (
            f"single-worker router must cost <= 5% over the bare "
            f"scheduler, got {overhead * 100:.1f}% "
            f"({bare_us:.0f}us -> {rout_us:.0f}us)")
    return overhead


def bench_obs(n_points: int, reps: int, windows: int,
              max_batch: int = 4, assert_overhead: bool = True):
    """serve/obs_overhead: the full observability stack (span tracer +
    flight recorder) vs the default metrics-only scheduler on the
    repeated-composition stream.  Parity (bit-identical predictions)
    asserted first; the asserted overhead is the per-request obs work
    timed directly against the measured per-scene latency (the same
    direct-measurement discipline as ft_overhead/router_overhead — an
    end-to-end A/B delta of a sub-1% effect is drift noise)."""
    from repro.obs import Observability

    params = MU.minkunet_init(jax.random.key(0), c_in=4, n_classes=4,
                              stem=8, enc_planes=(8, 16),
                              dec_planes=(16, 8), blocks_per_stage=1)
    scenes = [lidar_scene(seed=21 + i, n_points=n_points, grid=32)
              for i in range(max_batch)]

    def build(obs=None):
        engine = PointCloudEngine(params, n_stages=2, flow="fod",
                                  ladder=BucketLadder((n_points,)),
                                  max_batch=max_batch, mesh=None)
        return ServeScheduler(engine, max_batch=max_batch, mesh=None,
                              obs=obs)

    base = build()                           # always-on metrics only
    full = build(obs=Observability.enabled())

    # parity + warmup: tracing must never perturb predictions
    ref = _stream_once(base, scenes)
    got = _stream_once(full, scenes)
    for rid in ref:
        np.testing.assert_array_equal(ref[rid].preds, got[rid].preds)

    base_w, full_w = [], []
    for _ in range(windows):
        base_w.append(_window_us(base, scenes, reps))
        full_w.append(_window_us(full, scenes, reps))
    base_us = float(np.median(base_w))
    full_us = float(np.median(full_w))
    e2e_delta = full_us / base_us - 1.0

    # the tracer+recorder's per-request addition, timed directly: one
    # root begin/end, the span set a served request records (admission,
    # queue_wait, dispatch, assembly + its two children, device_wait,
    # retire event), the recorder ring appends, and the registry updates
    # the request also pays on the metrics-only path
    obs = Observability.enabled()
    tr, rec = obs.tracer, obs.recorder
    h = obs.registry.histogram("bench_latency_seconds", "bench")
    c = obs.registry.counter("bench_requests_total", "bench")
    n_req = 1000
    # GC hygiene: the loop's small allocations otherwise trigger cyclic
    # collections that scan the whole bench-process heap (jax traces,
    # caches) and get billed to the obs work — the serving hot path
    # amortizes those same collections over full scene executions
    gc.collect()
    gc_was_enabled = gc.isenabled()
    gc.disable()
    t0 = time.perf_counter()
    for i in range(n_req):
        tid = f"bench:rid:{i}"
        tr.begin(tid, t=0.0, rid=i, instance="bench")
        tr.span(tid, "admission", t_start=0.0, t_end=0.0,
                bucket=n_points, n_points=n_points)
        q = tr.span(tid, "queue_wait", t_start=0.0)
        tr.end_span(tid, q, t_end=0.0)
        tr.span(tid, "dispatch", t_start=0.0, t_end=0.0,
                dispatch_id=i, bucket=n_points, retries=0)
        a = tr.span(tid, "assembly", t_start=0.0, t_end=0.0,
                    cache_hit=True)
        tr.span(tid, "arena_staging", parent=a, t_start=0.0, t_end=0.0)
        tr.span(tid, "assembly_lookup", parent=a, t_start=0.0,
                t_end=0.0)
        w = tr.span(tid, "device_wait", t_start=0.0)
        tr.end_span(tid, w, t_end=0.0)
        tr.event(tid, "retire", t=0.0, latency_s=0.001)
        tr.end(tid, t=0.0, outcome="ok")
        rec.record("submit", t=0.0, rid=i, bucket=n_points)
        rec.record("dispatch", t=0.0, rids=(i,))
        rec.record("retire", t=0.0, rids=(i,))
        h.observe(0.001)
        h.observe(0.001)
        h.observe(0.001)
        c.inc()
        c.inc()
        c.inc()
    obs_us = (time.perf_counter() - t0) * 1e6 / n_req
    if gc_was_enabled:
        gc.enable()
    overhead = obs_us / base_us
    st = full.stats()
    q = st["latency_quantiles_s"]
    emit("serve/obs_overhead", overhead * 100,
         f"obs_us={obs_us:.1f};per_scene_us={base_us:.0f};"
         f"e2e_delta_pct={e2e_delta * 100:.1f};parity=ok;"
         f"spans_per_req=9;target_pct=3",
         extra={"latency_quantiles_us":
                {k: v * 1e6 for k, v in q.items()},
                "tracer": full.obs.tracer.stats(),
                "recorder": full.obs.recorder.stats()})
    base.close()
    full.close()

    if assert_overhead:
        assert overhead <= 0.03, (
            f"the enabled tracer+recorder must cost <= 3% per scene on "
            f"the steady state, got {overhead * 100:.1f}% "
            f"({obs_us:.1f}us of obs work vs {base_us:.0f}us/scene)")
    return overhead


def bench_overload(n_points: int, reps: int, windows: int,
                   max_batch: int = 4, n_scenes: int = 120,
                   storm_rate: float = 10.0,
                   assert_goodput: bool = True):
    """serve/overload_goodput + serve/overload_overhead: the SLO-aware
    controller vs the static max_backlog baseline on a storm-paced
    stream offered at 2x the (throttled) service rate, plus the
    controller's directly-timed per-scene hot-path cost at nominal load
    (ft_overhead discipline).  Parity is asserted first: at nominal
    load the controller must not perturb predictions."""
    from repro.serve.faults import FaultPlan
    from repro.serve.overload import OverloadPolicy, ServeSLO

    params = MU.minkunet_init(jax.random.key(0), c_in=4, n_classes=4,
                              stem=8, enc_planes=(8, 16),
                              dec_planes=(16, 8), blocks_per_stage=1)
    scenes = [lidar_scene(seed=21 + i, n_points=n_points, grid=32)
              for i in range(max_batch)]

    def build(**kw):
        engine = PointCloudEngine(params, n_stages=2, flow="fod",
                                  ladder=BucketLadder((n_points,)),
                                  max_batch=max_batch, mesh=None)
        return ServeScheduler(engine, max_batch=max_batch, mesh=None,
                              **kw)

    slo_s = 0.25
    policy = OverloadPolicy(slo=ServeSLO(deadline_headroom_s=0.15),
                            tick_s=0.02)

    # parity first (doubles as warmup): the controller-on scheduler at
    # nominal load must produce bit-identical predictions — deferred
    # dispatch reorders nothing when every batch is admitted
    plain = build()
    ctrl = build(overload=policy)
    ref = _stream_once(plain, scenes)
    got = _stream_once(ctrl, scenes)
    for rid in ref:
        np.testing.assert_array_equal(ref[rid].preds, got[rid].preds)

    # the controller's per-scene addition at nominal load is the
    # admission gate (rate-limited estimator tick + effective-backlog
    # check) plus the dispatch-success breaker hook — time it directly
    # against the per-scene latency; the interleaved windows provide
    # the denominator and an informational e2e delta (a sub-1% effect
    # drowns in host drift, same story as ft/router/obs overhead)
    plain_w, ctrl_w = [], []
    for _ in range(windows):
        plain_w.append(_window_us(plain, scenes, reps))
        ctrl_w.append(_window_us(ctrl, scenes, reps))
    base_us = float(np.median(plain_w))
    e2e_delta = float(np.median(ctrl_w)) / base_us - 1.0

    ov = ctrl.overload
    n_adm = 1000
    t0 = time.perf_counter()
    for _ in range(n_adm):
        ov.check_admission_locked(n_points, 1, 0)
        ov.record_dispatch_success(n_points)
    adm_us = (time.perf_counter() - t0) * 1e6 / n_adm
    overhead = adm_us / base_us
    emit("serve/overload_overhead", overhead * 100,
         f"admission_us={adm_us:.2f};per_scene_us={base_us:.0f};"
         f"e2e_delta_pct={e2e_delta * 100:.1f};parity=ok;target_pct=3")
    plain.close()
    ctrl.close()

    # goodput at 2x offered load: storm pacing caps the service rate at
    # storm_rate batches/s, the producer offers scenes at twice that,
    # every request carries deadline_s = the SLO.  goodput counts only
    # completions that are OK *and* within the SLO — the static path
    # queues to max_backlog so most completions land late, the
    # controller sheds at the Little's-law bound so admissions finish
    # on time (and the shed errors tell clients when to retry)
    # the static config is tuned the way burst-absorbing deployments
    # are: deep pipeline, generous backlog — under SUSTAINED 2x load
    # that queue depth is exactly what turns every completion late.
    # The controller runs the same config; its Little's-law bound
    # (service_rate x headroom, ~2 batches here) replaces the static
    # depth as the effective admission limit
    def overloaded_run(overload):
        plan = FaultPlan(storm_buckets={n_points: storm_rate})
        s = build(fault_plan=plan, overload=overload,
                  pipeline_depth=16, max_backlog=64, max_wait_s=0.05)
        for (c, m, f) in scenes:        # un-timed compile/cache warmup
            s.submit(c, f, m)
        s.flush()
        s.drain()
        pace_s = 1.0 / (2.0 * storm_rate * max_batch)
        rids = []
        t0 = time.perf_counter()
        for i in range(n_scenes):
            c, m, f = scenes[i % len(scenes)]
            rids.append(s.submit(c, f, m, deadline_s=slo_s))
            time.sleep(pace_s)
        s.flush()
        out = s.take(rids)
        wall = time.perf_counter() - t0
        st = s.stats()
        ov_st = s.overload.stats() if s.overload is not None else None
        s.close()
        assert st["faults"]["exec_failed"] == 0, \
            "overload must shed, never fail execution"
        good = sum(1 for r in out.values()
                   if r.ok and r.latency_s is not None
                   and r.latency_s <= slo_s)
        return good / wall, st, ov_st, wall

    static_gps, static_st, _, static_wall = overloaded_run(None)
    ctrl_gps, ctrl_st, ov_st, ctrl_wall = overloaded_run(policy)
    # a floor of one good scene per wall keeps the ratio meaningful
    # when the uncontrolled path blows the SLO for every completion
    ratio = ctrl_gps / max(static_gps, 1.0 / static_wall)
    sf, cf = static_st["faults"], ctrl_st["faults"]
    emit("serve/overload_goodput", ratio,
         f"ctrl_good_per_s={ctrl_gps:.1f};"
         f"static_good_per_s={static_gps:.1f};"
         f"capacity_per_s={storm_rate * max_batch:.0f};"
         f"offered_x=2;slo_ms={slo_s * 1e3:.0f};"
         f"ctrl_shed={cf['shed']};ctrl_timeout={cf['timeout']};"
         f"static_shed={sf['shed']};static_timeout={sf['timeout']};"
         f"walls_s={static_wall:.2f}/{ctrl_wall:.2f};parity=ok",
         extra={"controller": ov_st})

    if assert_goodput:
        assert ctrl_gps >= 1.3 * static_gps, (
            f"the controller must deliver >= 1.3x the static baseline's "
            f"within-SLO goodput at 2x offered load, got "
            f"{ctrl_gps:.1f}/s vs {static_gps:.1f}/s")
        assert overhead <= 0.03, (
            f"the controller's admission hot path must cost <= 3% per "
            f"scene at nominal load, got {overhead * 100:.1f}% "
            f"({adm_us:.2f}us vs {base_us:.0f}us/scene)")
    return ratio


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller cloud / fewer reps (CI)")
    args = ap.parse_args(argv)
    if args.smoke:
        bench_hot_loop(n_points=128, reps=3, windows=3)
        bench_fault_tolerance(n_points=128, reps=3, windows=3,
                              assert_overhead=False)
        bench_router(n_points=128, reps=3, windows=3,
                     assert_overhead=False)
        bench_obs(n_points=128, reps=3, windows=3,
                  assert_overhead=False)
        bench_overload(n_points=128, reps=3, windows=3, n_scenes=90,
                       storm_rate=15.0, assert_goodput=False)
        bench_partition(n_points=3000, budgets=(512, 1024), reps=1)
    else:
        bench_hot_loop(n_points=128, reps=6, windows=5)
        bench_fault_tolerance(n_points=128, reps=6, windows=5)
        bench_router(n_points=128, reps=8, windows=5)
        bench_obs(n_points=128, reps=6, windows=5)
        bench_overload(n_points=128, reps=6, windows=5, n_scenes=120,
                       storm_rate=10.0)
        bench_partition(n_points=12000, budgets=(1024, 2048, 4096))


if __name__ == "__main__":
    main()
