"""Paper Figs. 13/14: end-to-end latency across the 8 paper networks.

Without silicon we can't reproduce absolute speedups over a 2080Ti; what we
reproduce is the paper's *relative* story on this host:
  * all 8 benchmarks run end to end through the same framework;
  * for the SparseConv models, the PointAcc flow (FoD + ranking-based maps)
    vs the baseline flow (G-M-S) — the architectural delta the paper
    credits for its gains;
  * the temporal-fusion point (§4.2.4): the streamed fused-epilogue Pallas
    flow (`pallas_fused`) vs the PR-1 whole-array kernel (`pallas`), both
    in CPU interpret parity mode, plus the Fig.-20-style DRAM model of the
    epilogue traffic the fusion eliminates;
  * the Fig. 16 co-design point: MinkowskiUNet vs Mini-MinkowskiUNet
    latency at equal input.
"""

from __future__ import annotations

import argparse

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import mapping as M
from repro.data.synthetic import dense_xyz_batch, lidar_scene
from repro.models import minkunet as MU
from repro.models import pointnets as PN

N, B = 512, 2


def bench_pointnet_family():
    xyz_np, mask_np, _ = dense_xyz_batch(0, 0, B, N)
    xyz, mask = jnp.asarray(xyz_np), jnp.asarray(mask_np)
    key = jax.random.key(0)

    nets = {
        "pointnet": (PN.pointnet_init(key, 40),
                     lambda p: PN.pointnet_apply(p, xyz, mask)),
        "pointnet++(c)": (PN.pointnetpp_cls_init(key, 40),
                          lambda p: PN.pointnetpp_cls_apply(
                              p, xyz, mask, n1=128, n2=32)),
        "pointnet++(s)": (PN.pointnetpp_seg_init(key, 13),
                          lambda p: PN.pointnetpp_seg_apply(
                              p, xyz, mask, n1=128, n2=32)),
        "pointnet++(ps)": (PN.pointnetpp_seg_init(key, 50),
                           lambda p: PN.pointnetpp_seg_apply(
                               p, xyz, mask, n1=128, n2=32)),
        "dgcnn": (PN.dgcnn_init(key, 16),
                  lambda p: PN.dgcnn_apply(p, xyz, mask, k=16)),
        "f-pointnet++": (PN.fpointnetpp_init(key),
                         lambda p: PN.fpointnetpp_apply(p, xyz, mask)),
    }
    for name, (params, fn) in nets.items():
        jfn = jax.jit(fn)
        us = timeit(jfn, params)
        emit(f"models/{name}_n{N}", us,
             f"points_per_s={B * N / (us / 1e6):.0f}")


def bench_minknet(n_points=2048, grid=48):
    coords, mask, feats = lidar_scene(3, n_points, grid=grid)
    pc = M.make_point_cloud(jnp.asarray(coords), jnp.asarray(mask))
    feats = jnp.asarray(feats)
    key = jax.random.key(1)

    full = MU.minkunet_init(key, 4, 13, stem=16, enc_planes=(16, 32, 64),
                            dec_planes=(64, 32, 16), blocks_per_stage=1)
    mini = MU.mini_minkunet_init(key, 4, 13)

    for name, params in [("minknet", full), ("mini-minknet", mini)]:
        times = {}
        for flow in ("gms", "fod", "pallas", "pallas_fused"):
            fn = jax.jit(lambda p, f, flow=flow: MU.minkunet_apply(
                p, pc, f, flow=flow))
            times[flow] = timeit(fn, params, feats)
            emit(f"models/{name}_{flow}", times[flow], "")
        # temporal-fusion acceptance row: fused vs baseline Pallas kernel
        # (interpret parity run), with parity asserted against the fod flow
        ref = jax.jit(lambda p, f: MU.minkunet_apply(p, pc, f, flow="fod"))
        fus = jax.jit(lambda p, f: MU.minkunet_apply(
            p, pc, f, flow="pallas_fused"))
        np.testing.assert_allclose(np.asarray(fus(params, feats)),
                                   np.asarray(ref(params, feats)),
                                   rtol=1e-4, atol=1e-4)
        speedup = times["pallas"] / times["pallas_fused"]
        levels = MU.build_unet_maps(pc, len(params["enc"]))
        unf = MU.epilogue_dram_bytes(params, levels, fused=False)
        fsd = MU.epilogue_dram_bytes(params, levels, fused=True)
        emit(f"models/{name}_fused_speedup", speedup,
             f"pallas_us={times['pallas']:.0f};"
             f"fused_us={times['pallas_fused']:.0f};parity=ok;"
             f"speedup={speedup:.2f}x")
        emit(f"models/{name}_epilogue_dram", float(unf / fsd),
             f"unfused_bytes={unf};fused_bytes={fsd};"
             f"reduction={unf / fsd:.2f}x")

    # Fig. 16 co-design ratio
    t_full = timeit(jax.jit(
        lambda p, f: MU.minkunet_apply(p, pc, f, flow="fod")), full, feats)
    t_mini = timeit(jax.jit(
        lambda p, f: MU.minkunet_apply(p, pc, f, flow="fod")), mini, feats)
    emit("models/codesign_ratio", t_full / t_mini,
         f"mini_speedup={t_full / t_mini:.1f}x (paper: 100x w/ silicon)")


def bench_batched_serving(batch_sizes, n_points=512):
    """Per-scene latency vs batch size through the scheduler-backed
    serving entry point (serve.engine.PointCloudEngine.segment_batch):
    one compiled program segments each micro-batch, amortising dispatch;
    steady state hits the per-scene mapping cache every request."""
    from repro.data.synthetic import point_cloud_batch
    from repro.serve.buckets import BucketLadder
    from repro.serve.engine import PointCloudEngine

    params = MU.mini_minkunet_init(jax.random.key(2), c_in=4, n_classes=2)
    base_per_scene = None
    for bsz in batch_sizes:
        # single exact-fit bucket: measures batching, not padding
        engine = PointCloudEngine(params, n_stages=2, flow="fod",
                                  ladder=BucketLadder((n_points,)),
                                  max_batch=bsz, mesh=None)
        coords, mask, feats, _ = point_cloud_batch(
            seed=1, step=0, batch=bsz, n_points=n_points)
        coords = coords.reshape(bsz, n_points, 4)
        mask = mask.reshape(bsz, n_points)
        feats = feats.reshape(bsz, n_points, -1)

        def serve(f, c=coords, m=mask):
            return engine.segment_batch(c, m, f)[0]

        us = timeit(serve, feats)
        per_scene = us / bsz
        if base_per_scene is None:
            base_per_scene = per_scene
        emit(f"models/minkunet_serve_batch{bsz}", us,
             f"per_scene_us={per_scene:.0f};scenes={bsz};"
             f"scaling_vs_b1={base_per_scene / per_scene:.2f}x")


def bench_mixed_serving(n_scenes=16, n_base=512):
    """Continuous-batching rows: a heterogeneous stream (4 distinct point
    counts) through `ServeScheduler` — bucketed capacities bound the
    compile count while padding overhead, mapping-cache hit rate, and
    per-bucket occupancy land in BENCH_models.json."""
    from repro.data.synthetic import lidar_scene
    from repro.serve.buckets import geometric_ladder
    from repro.serve.engine import PointCloudEngine

    params = MU.mini_minkunet_init(jax.random.key(3), c_in=4, n_classes=2)
    sizes = [int(n_base * s) for s in (0.375, 0.625, 0.875, 1.375)]
    engine = PointCloudEngine(
        params, n_stages=2, flow="fod",
        ladder=geometric_ladder(n_base // 2, 2 * n_base),
        max_batch=4, mesh=None)
    sched = engine.scheduler()
    scenes = [lidar_scene(seed=11 + i % 8, n_points=sizes[i % 4], grid=32)
              for i in range(n_scenes)]

    def stream():
        for c, m, f in scenes:
            sched.submit(c, f, m)
        sched.flush()
        return len(sched.drain())

    us = timeit(stream, warmup=1, iters=3)
    s = sched.stats()
    occ = ";".join(f"occ{cap}={b['occupancy']:.2f}"
                   for cap, b in sorted(s["buckets"].items()))
    emit("models/minkunet_serve_mixed", us / n_scenes,
         f"scenes={n_scenes};sizes={len(set(sizes))};"
         f"padding_overhead={s['padding_overhead']:.2f};"
         f"map_hit_rate={s['mapping_cache']['hit_rate']:.2f};"
         f"compiles_apply={s['compiles']['apply_batch']};"
         f"buckets={len(s['buckets'])};{occ}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller cloud (CI smoke)")
    ap.add_argument("--batch", default="1,2,4", metavar="B1,B2,...",
                    help="batch sizes for the vmapped serving axis")
    args = ap.parse_args(argv)
    bench_pointnet_family()
    bench_minknet(*((1024, 32) if args.smoke else (2048, 48)))
    sizes = [int(b) for b in args.batch.split(",") if b]
    bench_batched_serving(sizes, n_points=256 if args.smoke else 512)
    bench_mixed_serving(n_scenes=16, n_base=256 if args.smoke else 512)


if __name__ == "__main__":
    main()
