"""Paper Fig. 17 (right): convolution computation flow —
Gather-MatMul-Scatter vs Fetch-on-Demand.

Measures wall time of both XLA flows + the two Pallas FoD kernels
(interpret mode): the PR-1 whole-array-resident baseline (`pallas_fod`) and
the streamed + fused-epilogue kernel (`pallas_fused`), with a numerical-
parity assert of the fused kernel against the `fod` flow.  The analytic
traffic model matches paper §4.2.3 / Fig. 11c:
  G-M-S: read features per map entry, write gathered matrix, read it back
         for the GEMM, write psums, read psums for scatter, write output.
  FoD:   read features once per (cached) access, accumulate psums on-chip,
         write output once.
"""

from __future__ import annotations

import argparse

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import mapping as M
from repro.core import sparseconv as SC
from repro.data.synthetic import lidar_scene


def traffic_model(maps, n_points, cin, cout, dtype_bytes=4):
    n_maps = int(jnp.sum(maps.valid))
    feat = cin * dtype_bytes
    psum = cout * dtype_bytes
    gms = (n_maps * feat          # gather reads
           + n_maps * feat        # gathered matrix write
           + n_maps * feat        # GEMM read
           + n_maps * psum * 2    # psum write + scatter read
           + n_points * psum)     # output write
    fod = (n_maps * feat          # fetch-on-demand reads (uncached)
           + n_points * psum)     # output write (psums stay on-chip)
    return gms, fod, n_maps


def run(n_points=4096, cin=64, cout=64):
    coords_np, mask_np, _ = lidar_scene(1, n_points, grid=64)
    pc = M.make_point_cloud(jnp.asarray(coords_np), jnp.asarray(mask_np))
    rng = np.random.default_rng(0)
    feats = jnp.asarray(rng.normal(size=(n_points, cin)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(27, cin, cout)).astype(np.float32))
    # key-sorted cloud: the canonical order the fused flow runs in
    sc = M.sort_cloud(pc)
    pc = M.PointCloud(jnp.take(pc.coords, sc.perm, axis=0),
                      jnp.take(pc.mask, sc.perm), pc.stride)
    maps, out_pc = M.build_conv_maps(pc, 3, 1)

    gms = jax.jit(lambda f, w: SC.gather_matmul_scatter(
        f, maps, w, out_pc.capacity))
    fod = jax.jit(lambda f, w: SC.fetch_on_demand(
        f, maps, w, out_pc.capacity))
    us_gms = timeit(gms, feats, w)
    us_fod = timeit(fod, feats, w)

    from repro.kernels.spconv import ops as spops
    pall = jax.jit(lambda f, w: spops.sparse_conv_fod(
        f, maps, w, out_pc.capacity))
    us_pal = timeit(pall, feats, w)
    fused = jax.jit(lambda f, w: SC.sparse_conv_apply(
        f, maps, w, out_pc.capacity, flow="pallas_fused"))
    us_fus = timeit(fused, feats, w)

    # numerical parity: the fused streamed kernel == the XLA fod flow
    np.testing.assert_allclose(np.asarray(fused(feats, w)),
                               np.asarray(fod(feats, w)),
                               rtol=1e-4, atol=1e-4)

    t_gms, t_fod, n_maps = traffic_model(maps, n_points, cin, cout)
    emit(f"convflow/gms_n{n_points}_c{cin}", us_gms,
         f"dram_bytes={t_gms}")
    emit(f"convflow/fod_n{n_points}_c{cin}", us_fod,
         f"dram_bytes={t_fod};traffic_saving={t_gms / t_fod:.2f}x")
    emit(f"convflow/pallas_fod_n{n_points}_c{cin}", us_pal,
         f"interpret_mode=1;maps={n_maps}")
    emit(f"convflow/pallas_fused_n{n_points}_c{cin}", us_fus,
         f"interpret_mode=1;parity=ok;"
         f"speedup_vs_pallas={us_pal / us_fus:.2f}x")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single small size (CI smoke)")
    args = ap.parse_args(argv)
    if args.smoke:
        run(1024, 32, 32)
        return
    run(2048, 32, 32)
    run(4096, 64, 64)


if __name__ == "__main__":
    main()
