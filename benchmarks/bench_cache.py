"""Paper Fig. 18 + 19: MMU configurable cache — miss rate vs block size /
kernel size / #channels, and per-layer DRAM access reduction.

Faithful model of §4.2.3: the input buffers form a direct-mapped cache whose
block ('memory tile') is `block_rows` consecutive feature rows x the channel
tile.  The access stream is exactly PointAcc's Fetch-on-Demand order: for
each weight offset, map entries sorted by output coordinate.  The tag is the
(first point index, channel tile) of the block — we simulate point-index
tags with a whole-channel tile, matching Fig. 18's c=#channels sweep by
scaling the block byte cost.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import mapping as M
from repro.data.synthetic import lidar_scene


def access_stream(maps) -> np.ndarray:
    """Input-row access sequence in FoD streaming order."""
    in_idx = np.asarray(maps.in_idx)
    valid = np.asarray(maps.valid)
    seq = []
    for k in range(in_idx.shape[0]):
        seq.append(in_idx[k][valid[k]])
    return np.concatenate(seq) if seq else np.zeros(0, np.int64)


def simulate_cache(stream: np.ndarray, n_rows: int, block_rows: int,
                   n_sets: int = 256):
    """Direct-mapped cache over row blocks; returns miss rate."""
    if len(stream) == 0:
        return 0.0
    blocks = stream // block_rows
    tags = np.full(n_sets, -1, np.int64)
    misses = 0
    for b in blocks:
        s = b % n_sets
        if tags[s] != b:
            tags[s] = b
            misses += 1
    return misses / len(stream)


def run(n_points=4096, kernel_size=3, channels=64):
    coords_np, mask_np, _ = lidar_scene(2, n_points, grid=48)
    pc = M.make_point_cloud(jnp.asarray(coords_np), jnp.asarray(mask_np))
    maps, _ = M.build_conv_maps(pc, kernel_size, 1)
    stream = access_stream(maps)
    feat_bytes = channels * 4

    no_cache_bytes = len(stream) * feat_bytes
    for block_rows in (1, 2, 4, 8, 16, 32):
        miss = simulate_cache(stream, n_points, block_rows)
        dram = int(len(stream) * miss * block_rows * feat_bytes
                   + 0.5)
        red = no_cache_bytes / max(dram, 1)
        emit(f"cache/k{kernel_size}_c{channels}_b{block_rows}",
             miss * 100.0,
             f"miss_pct={miss * 100:.1f};dram_reduction={red:.2f}x;"
             f"accesses={len(stream)}")


def main():
    # Fig. 18 sweep: block size x kernel size x channels
    run(4096, 3, 16)
    run(4096, 3, 64)
    run(4096, 2, 64)
    # Fig. 19: per-layer DRAM access with/without caching at the chosen
    # block size is the dram_reduction column above.


if __name__ == "__main__":
    main()
