"""Paper Fig. 20: temporal layer fusion — DRAM access reduction running
PointNet-family FC chains in Fusion Mode vs layer-by-layer.

Uses the paper's own compile-time planner (core.fusion.plan_fusion) on the
real MLP chains of our PointNet/PointNet++ models, plus wall-time of the
fused_mlp Pallas kernel vs per-layer execution (interpret mode).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro import nn
from repro.core import fusion as F
from repro.kernels.fused_mlp import ops as fm


CHAINS = {
    "pointnet_feat": [3, 64, 64, 64, 128, 1024],
    "pointnet_head": [1024, 512, 256, 40],
    "pnpp_sa1": [3, 64, 64, 128],
    "pnpp_sa2": [131, 128, 128, 256],
    "pnpp_fp": [384, 256, 128],
}


def run_chain(name, widths, n_points=8192,
              budget=F.DEFAULT_ONCHIP_BUDGET_BYTES):
    groups = F.plan_fusion(widths, budget_bytes=budget)
    unfused = F.dram_bytes_unfused(n_points, widths)
    fused = F.dram_bytes_fused(n_points, widths, groups)
    emit(f"fusion/{name}_plan", float(len(groups)),
         f"reduction={unfused / fused:.2f}x;groups={len(groups)};"
         f"tiles={[g.tile_points for g in groups]}")
    return unfused / fused


def run_kernel_timing(n_points=2048):
    widths = [64, 256, 256, 64]
    rng = np.random.default_rng(0)
    p = nn.mlp_chain_init(jax.random.key(0), widths)
    x = jnp.asarray(rng.normal(size=(n_points, widths[0]))
                    .astype(np.float32))

    fused = jax.jit(lambda x: fm.fused_mlp_chain(x, p))
    layerwise = jax.jit(lambda x: nn.mlp_chain(p, x))
    emit("fusion/kernel_fused", timeit(fused, x), "interpret_mode=1")
    emit("fusion/xla_layerwise", timeit(layerwise, x), "")


def main():
    reductions = [run_chain(k, v) for k, v in CHAINS.items()]
    emit("fusion/mean_reduction", float(np.mean(reductions)),
         f"paper_range=1.33x-2.8x")
    run_kernel_timing()


if __name__ == "__main__":
    main()
