"""Shared benchmark utilities: timing + CSV/JSON emission."""

from __future__ import annotations

import json
import time
from typing import Callable

import jax
import numpy as np

ROWS = []


def timeit(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time (us) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def header():
    print("name,us_per_call,derived", flush=True)


def dump_json(path: str):
    """Write every emitted row as JSON: {name: {us_per_call, derived}}.
    CI archives the file per commit so the perf trajectory is diffable."""
    with open(path, "w") as f:
        json.dump({name: {"us_per_call": us, "derived": derived}
                   for name, us, derived in ROWS}, f, indent=2,
                  sort_keys=True)
