"""Shared benchmark utilities: timing + CSV/JSON emission."""

from __future__ import annotations

import json
import time
from typing import Callable

import jax
import numpy as np

ROWS = []


def timeit(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time (us) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def emit(name: str, us_per_call: float, derived: str = "",
         extra: dict | None = None):
    """Record one benchmark row.  `derived` stays the compact CSV-field
    summary; `extra` is an optional JSON-native dict (e.g. latency
    quantiles) carried verbatim into `dump_json` — structured data that
    would be lossy squeezed into the derived string."""
    ROWS.append((name, us_per_call, derived, extra))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def header():
    print("name,us_per_call,derived", flush=True)


def dump_json(path: str):
    """Write every emitted row as JSON: {name: {us_per_call, derived,
    **extra}}.  CI archives the file per commit so the perf trajectory
    is diffable."""
    payload = {}
    for name, us, derived, extra in ROWS:
        row = {"us_per_call": us, "derived": derived}
        if extra:
            row.update(extra)
        payload[name] = row
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
