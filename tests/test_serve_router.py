"""Multi-worker serving fabric (serve/router.py): rendezvous digest
affinity, health-checked failover with in-flight replay, elastic pool
membership, graceful shedding, and the single-worker == bare-scheduler
parity contract.

Engine economy: the module shares a POOL of warmed engines that the
router factory cycles through — each router's workers get distinct
engines (private caches, as in production) while the suite pays each
engine's jit compiles exactly once.  Routers built under chaos use
tight liveness policies only AFTER the pool is warm, so a slow first
compile is never mistaken for a hang.
"""

import itertools
import time

import numpy as np
import pytest
import jax

from repro.data.synthetic import lidar_scene
from repro.launch.fault_tolerance import Pulse
from repro.serve import faults as FLT
from repro.serve.buckets import geometric_ladder
from repro.serve.engine import PointCloudEngine
from repro.serve.faults import FaultPlan
from repro.serve.router import (LivenessPolicy, ServeRouter,
                                _rendezvous_score)
from repro.serve.scheduler import ServeScheduler
from tests.test_serve_faults import _mini_params


N_ENGINES = 4


def _scenes(n=10):
    out = []
    for s in range(n):
        c, m, f = lidar_scene(seed=240 + s, n_points=40 + 7 * s, grid=16)
        out.append((c, f, m))
    return out


SCENES = _scenes()


@pytest.fixture(scope="module")
def pool():
    """(factory, reference) — `factory` cycles a pool of N_ENGINES warmed
    engines (distinct per concurrently-live worker, reused across
    routers), `reference` is the bare-scheduler predictions for SCENES
    in submission order (the bit-identity baseline)."""
    jax.clear_caches()
    params = _mini_params()
    engines = [PointCloudEngine(params, n_stages=2, flow="fod",
                                ladder=geometric_ladder(64, 128))
               for _ in range(N_ENGINES)]
    reference = None
    for eng in engines:                 # warm every engine's jit caches
        sched = ServeScheduler(eng, max_batch=2)
        out = sched.serve(SCENES)
        sched.close()
        preds = [np.asarray(out[r].preds) for r in sorted(out)]
        if reference is None:
            reference = preds
        else:                           # engines must be interchangeable
            for a, b in zip(reference, preds):
                assert np.array_equal(a, b)
    counter = itertools.count()

    def factory():
        return engines[next(counter) % N_ENGINES]

    return factory, reference


def _router(factory, n_workers, **kw):
    kw.setdefault("max_batch", 2)
    return ServeRouter(factory, n_workers, **kw)


# ---------------------------------------------------------------------------
# pure units: policy + rendezvous hashing
# ---------------------------------------------------------------------------

def test_liveness_policy_validation():
    p = LivenessPolicy(beat_s=0.1, miss_beats=20)
    assert p.stall_s == pytest.approx(2.0)
    with pytest.raises(ValueError, match="beat_s > 0"):
        LivenessPolicy(beat_s=0.0)
    with pytest.raises(ValueError, match="miss_beats"):
        LivenessPolicy(miss_beats=0)


def test_rendezvous_minimal_reshuffle():
    """The HRW property the elastic pool leans on: removing one worker
    moves ONLY the keys that ranked it first — every other key keeps its
    worker."""
    names3 = ["w0", "w1", "w2"]
    names2 = ["w0", "w1"]
    keys = [f"scene-{i}".encode() for i in range(200)]

    def best(key, names):
        return max(names, key=lambda n: _rendezvous_score(key, n))

    owners3 = {k: best(k, names3) for k in keys}
    owners2 = {k: best(k, names2) for k in keys}
    # all three workers get a share (spread), deterministically
    assert set(owners3.values()) == set(names3)
    for k in keys:
        if owners3[k] != "w2":
            assert owners2[k] == owners3[k]     # survivors keep their keys
    assert {k: best(k, names3) for k in keys} == owners3    # stable


def test_pulse_liveness():
    p = Pulse()
    assert p.age() < 0.5 and not p.stalled(0.5)
    time.sleep(0.06)
    assert p.stalled(0.05)
    p.beat()
    assert not p.stalled(0.05)


# ---------------------------------------------------------------------------
# routing + parity (no faults)
# ---------------------------------------------------------------------------

def test_single_worker_parity_with_bare_scheduler(pool):
    """Acceptance: the 1-worker router is bit-identical to the bare
    scheduler it fronts."""
    factory, reference = pool
    with _router(factory, 1) as r:
        out = r.serve(SCENES)
    assert len(out) == len(SCENES)
    for rid in sorted(out):
        res = out[rid]
        assert res.error is None
        assert np.array_equal(np.asarray(res.preds), reference[rid])
        assert res.n_points == np.asarray(SCENES[rid][0]).shape[0]


def test_digest_affinity_and_spread(pool):
    """Identical geometry keeps landing on the same worker (previewed
    and measured via per-worker routed counters); distinct geometry
    spreads over the pool."""
    factory, reference = pool
    with _router(factory, 3) as r:
        previews = [r.preview(c, m) for c, f, m in SCENES]
        assert all(p is not None for p in previews)
        assert len(set(previews)) > 1               # spread
        out1 = r.serve(SCENES)
        st1 = r.stats()
        routed1 = {n: w["routed"] for n, w in st1["workers"].items()}
        # the preview IS the route taken
        for name in routed1:
            assert routed1[name] == previews.count(name)
        out2 = r.serve(SCENES)                      # same geometry again
        st2 = r.stats()
        routed2 = {n: w["routed"] for n, w in st2["workers"].items()}
        assert routed2 == {n: 2 * c for n, c in routed1.items()}
        # affinity pays: repeat stream hits the workers' mapping caches
        pc = st2["pool_cache"]
        assert pc["mapping_hits"] >= len(SCENES)
    for i, rid in enumerate(sorted(out2)):
        assert np.array_equal(np.asarray(out2[rid].preds), reference[i])
    # results from both streams were completed exactly once each
    assert sorted(out1) != sorted(out2)


# ---------------------------------------------------------------------------
# failover + replay (chaos)
# ---------------------------------------------------------------------------

def _busiest(router_stats):
    name, w = max(router_stats["workers"].items(),
                  key=lambda kv: kv[1]["routed"])
    return name, w["ordinal"], w["routed"]


def test_worker_kill_failover_bit_identical(pool):
    """Acceptance chaos: kill one of 3 workers mid-stream — every
    request completes with predictions, replayed survivors are
    bit-identical to the no-fault run, and a follow-up stream on the
    shrunken pool serves clean."""
    factory, reference = pool
    # probe the (deterministic) routing to target the busiest worker
    with _router(factory, 3) as probe:
        probe.serve(SCENES)
        name, ordinal, routed = _busiest(probe.stats())
    assert routed >= 2, "scene set must load one worker with >= 2 items"

    plan = FaultPlan(kill_workers={ordinal: 1})     # dies on its 2nd item
    r = _router(factory, 3, fault_plan=plan)
    try:
        out = r.serve(SCENES)
        st = r.stats()
        assert plan.stats()["workers_killed"] == 1
        assert st["faults"]["failovers"] == 1
        assert st["faults"]["replayed"] >= 1
        assert st["faults"]["recovery_s"] is not None
        assert st["workers"][name]["state"] == "dead"
        assert "crashed" in st["workers"][name]["reason"]
        assert len(out) == len(SCENES)
        for rid in sorted(out):                     # 0 lost, bit-identical
            assert out[rid].error is None
            assert np.array_equal(np.asarray(out[rid].preds),
                                  reference[rid])
        # follow-up stream on the shrunken pool serves clean
        out2 = r.serve(SCENES)
        assert all(res.error is None for res in out2.values())
        assert r.stats()["n_live"] == 2
    finally:
        r.close()
    assert not any(w["state"] in ("live", "draining")
                   for w in r.stats()["workers"].values())


def test_hung_worker_detected_and_failed_over(pool):
    """A worker that stops beating (injected hang, no crash) is declared
    dead by the liveness policy and its work replays; its late results
    are discarded by the ownership check."""
    factory, reference = pool
    with _router(factory, 2) as probe:
        probe.serve(SCENES)
        name, ordinal, routed = _busiest(probe.stats())
    assert routed >= 2

    plan = FaultPlan(hang_workers={ordinal: 8.0})
    r = _router(factory, 2, fault_plan=plan)
    try:
        # tighten liveness only now: the pool's engines are warm, so the
        # only multi-second stall left is the injected hang
        r.liveness = LivenessPolicy(beat_s=0.05, miss_beats=16)  # 0.8s
        t0 = time.monotonic()
        out = r.serve(SCENES)
        dt = time.monotonic() - t0
        st = r.stats()
        assert plan.stats()["workers_hung"] == 1
        assert st["faults"]["failovers"] == 1
        assert st["workers"][name]["state"] == "dead"
        assert "hung" in st["workers"][name]["reason"]
        assert dt < 8.0, "drain must not wait out the full hang"
        for rid in sorted(out):
            assert out[rid].error is None
            assert np.array_equal(np.asarray(out[rid].preds),
                                  reference[rid])
    finally:
        r.close()


def test_replay_budget_exhaustion_exec_failed(pool):
    """max_replays=0: requests on a killed worker complete with typed
    exec_failed instead of replaying — same taxonomy as the scheduler's
    retry exhaustion."""
    factory, _ = pool
    plan = FaultPlan(kill_workers={0: 0})           # dies on its 1st item
    with _router(factory, 1, fault_plan=plan, max_replays=0) as r:
        out = r.serve(SCENES)
    assert len(out) == len(SCENES)
    codes = {res.error.code for res in out.values() if res.error}
    assert codes and codes <= {FLT.EXEC_FAILED, FLT.SHED}
    assert any(res.error.code == FLT.EXEC_FAILED
               and "replay budget exhausted" in res.error.message
               for res in out.values())


def test_shed_on_empty_and_saturated_pool(pool):
    """Graceful degradation: zero live workers and per-worker backlog
    saturation both complete requests with typed shed results — the
    stream never raises and never queues unbounded."""
    factory, reference = pool
    # zero live workers: the only worker dies on its first item
    plan = FaultPlan(kill_workers={0: 0})
    with _router(factory, 1, fault_plan=plan) as r:
        out = r.serve(SCENES)
        assert all(res.error is not None for res in out.values())
        assert any(res.error.code == FLT.SHED and
                   "no live workers to replay" in res.error.message
                   for res in out.values())
        # admission on the dead pool sheds immediately, typed
        c, f, m = SCENES[0]
        rid = r.submit(c, f, m)
        res = r.poll()
        shed = {x.rid: x for x in res}[rid]
        assert shed.error.code == FLT.SHED
        assert "no live workers in the pool" in shed.error.message

    # saturation: completions only happen on flush, so a second submit
    # against max_backlog=1 finds the worker at its bound and sheds
    with _router(factory, 1, max_backlog=1) as r:
        c0, f0, m0 = SCENES[0]
        c1, f1, m1 = SCENES[1]
        rid0 = r.submit(c0, f0, m0)
        rid1 = r.submit(c1, f1, m1)
        by_rid = {res.rid: res for res in r.drain()}
        assert by_rid[rid0].error is None
        assert np.array_equal(np.asarray(by_rid[rid0].preds), reference[0])
        assert by_rid[rid1].error is not None
        assert by_rid[rid1].error.code == FLT.SHED
        assert "max_backlog" in by_rid[rid1].error.message


# ---------------------------------------------------------------------------
# elastic pool
# ---------------------------------------------------------------------------

def test_elastic_add_remove_with_reaffinity(pool):
    """add_worker(): only the keys that rank the newcomer first move;
    remove_worker() drains-then-leaves and previews revert EXACTLY to
    the pre-join assignment (the rendezvous property, end to end)."""
    factory, reference = pool
    r = _router(factory, 2)
    try:
        r.serve(SCENES)
        before = [r.preview(c, m) for c, f, m in SCENES]
        new = r.add_worker()
        assert r.stats()["n_live"] == 3
        after = [r.preview(c, m) for c, f, m in SCENES]
        for b, a in zip(before, after):
            assert a == b or a == new       # moves only TO the newcomer
        out = r.serve(SCENES)               # shared pool serves clean
        for i, rid in enumerate(sorted(out)):
            assert out[rid].error is None
            assert np.array_equal(np.asarray(out[rid].preds),
                                  reference[i])
        r.remove_worker(new)
        assert r.workers()[new] == "left"
        assert [r.preview(c, m) for c, f, m in SCENES] == before
        out2 = r.serve(SCENES)
        assert all(res.error is None for res in out2.values())
    finally:
        r.close()


def test_router_lifecycle_and_validation(pool):
    factory, _ = pool
    with pytest.raises(ValueError, match="n_workers"):
        ServeRouter(factory, 0)
    with pytest.raises(ValueError, match="max_replays"):
        ServeRouter(factory, 1, max_replays=-1)
    with pytest.raises(ValueError, match="max_backlog"):
        ServeRouter(factory, 1, max_backlog=0)
    r = _router(factory, 1)
    with pytest.raises(KeyError):
        r.remove_worker("nope")
    with pytest.raises(ValueError, match="already exists"):
        r.add_worker("w0")
    r.close()
    r.close()                               # idempotent
    c, f, m = SCENES[0]
    rid = r.submit(c, f, m)                 # post-close: typed rejected
    res = {x.rid: x for x in r.poll()}[rid]
    assert res.error.code == FLT.REJECTED
    with pytest.raises(RuntimeError, match="closed"):
        r.add_worker()


def test_stats_aggregation_shape(pool):
    factory, _ = pool
    with _router(factory, 2) as r:
        r.serve(SCENES)
        st = r.stats()
    assert st["n_workers"] == 2 and st["n_submitted"] == len(SCENES)
    assert st["n_completed"] == len(SCENES) == st["n_ok"]
    assert st["routed_incomplete"] == 0
    pc = st["pool_cache"]
    schedulers = [w["scheduler"] for w in st["workers"].values()]
    assert pc["mapping_misses"] == sum(s["mapping_cache"]["misses"]
                                       for s in schedulers)
    assert pc["assembly_misses"] == sum(s["assembly_cache"]["misses"]
                                        for s in schedulers)
    for w in st["workers"].values():
        assert w["state"] == "live"         # snapshot taken mid-serve
        assert w["scheduler"]["n_ok"] == w["processed"]
    for w in r.stats()["workers"].values():
        assert w["state"] == "left"         # context exit closed the pool
    assert st["liveness"]["stall_s"] == pytest.approx(
        st["liveness"]["beat_s"] * st["liveness"]["miss_beats"])
