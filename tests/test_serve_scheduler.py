"""Continuous-batching serve scheduler: the bucket ladder (capacities,
padding, numerical invariance), the ServeScheduler (queueing, bucketed
micro-batches, out-of-order drain, telemetry), bounded compile counts
through every engine entry point, and mixed-bucket parity with a
per-scene loop across the fod / pallas / pallas_fused flows — plus the
pipelined hot loop: the composition-keyed AssemblyCache (hit / permute /
evict), pre-stacked dummy tails, double-buffered async dispatch +
FIFO retirement, thread-safe submit under concurrent producers,
deadline-aware flush, per-bucket max_batch overrides, and bit-identical
parity with the synchronous (PR-4) path.  The shard_map-sharded executor
is covered on a mocked multi-device mesh in tests/test_distributed.py;
here the same code degrades to the single-device vmapped path.

The fault-tolerance layer (admission rejection, shed, deadline timeout,
retry/bisect isolation, watchdog, close()) has its own unit suite in
tests/test_serve_faults.py; THIS file holds the end-to-end chaos test —
concurrent producers through an injected FaultPlan."""

import threading
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.api import MappingCache, PointAccSession
from repro.core import mapping as M
from repro.data.synthetic import lidar_scene
from repro.models import minkunet as MU
from repro.serve.buckets import (BucketLadder, geometric_ladder,
                                 max_batch_from_occupancy, pad_scene)
from repro.serve.engine import PointCloudEngine
from repro.serve.scheduler import ServeScheduler


def _mini_params(n_classes=2):
    return MU.mini_minkunet_init(jax.random.key(0), c_in=4,
                                 n_classes=n_classes)


def _ref_preds(params, coords, mask, feats, flow="fod"):
    pc = M.make_point_cloud(jnp.asarray(coords), jnp.asarray(mask))
    logits = MU.minkunet_apply(params, pc, jnp.asarray(feats), flow=flow)
    return np.asarray(jnp.argmax(logits, -1))


# ---------------------------------------------------------------------------
# bucket ladder policy
# ---------------------------------------------------------------------------

def test_bucket_ladder_selection_and_bounds():
    ladder = BucketLadder((64, 128, 256))
    assert ladder.n_buckets == 3
    assert ladder.bucket_for(1) == 64
    assert ladder.bucket_for(64) == 64
    assert ladder.bucket_for(65) == 128
    assert ladder.bucket_for(256) == 256
    assert ladder.index_for(200) == 2
    with pytest.raises(ValueError, match="exceeds the bucket ladder"):
        ladder.bucket_for(257)
    assert ladder.padding_fraction(96) == pytest.approx(0.25)


def test_bucket_ladder_validation():
    with pytest.raises(ValueError, match="ascending"):
        BucketLadder((128, 64))
    with pytest.raises(ValueError, match="ascending"):
        BucketLadder((64, 64))
    with pytest.raises(ValueError, match="positive"):
        BucketLadder((0, 64))
    with pytest.raises(ValueError, match="growth"):
        geometric_ladder(64, 256, growth=1.0)


def test_geometric_ladder_growth_bounds_padding():
    ladder = geometric_ladder(128, 4096, growth=2.0)
    caps = ladder.capacities
    assert caps[0] == 128 and caps[-1] >= 4096
    assert all(c % 8 == 0 for c in caps)
    # worst-case padding of a geometric ladder is 1 - 1/growth
    for n in range(129, 4096, 97):
        assert ladder.padding_fraction(n) < 0.5 + 1e-9


def test_pad_scene_sentinels_and_masked_rows():
    rng = np.random.default_rng(0)
    coords = rng.integers(0, 10, size=(5, 4)).astype(np.int32)
    mask = np.array([True, True, False, True, True])
    feats = rng.normal(size=(5, 3)).astype(np.float32)
    c, m, f = pad_scene(coords, mask, feats, 8)
    assert c.shape == (8, 4) and m.shape == (8,) and f.shape == (8, 3)
    np.testing.assert_array_equal(m, list(mask) + [False] * 3)
    # padding rows AND pre-masked rows are sentinel-filled / zeroed
    assert (c[5:] == M.SENTINEL).all() and (c[2] == M.SENTINEL).all()
    assert (f[5:] == 0).all() and (f[2] == 0).all()
    np.testing.assert_array_equal(c[0], coords[0])
    with pytest.raises(ValueError, match="pad.*down"):
        pad_scene(coords, mask, feats, 4)
    # feats=None path (mapping-only padding)
    c2, m2, f2 = pad_scene(coords, mask, None, 8)
    np.testing.assert_array_equal(c2, c)
    assert f2 is None


@pytest.mark.parametrize("flow", ["fod", "pallas_fused"])
def test_bucket_padding_preserves_logits(flow):
    """The core invariant the ladder relies on: padding a scene to a
    bucket capacity leaves the valid rows' logits unchanged (atol 1e-5)
    — sentinel rows sort to the end and never enter a kernel map."""
    coords, mask, feats = lidar_scene(3, 72, grid=16)
    params = _mini_params()
    session = PointAccSession(flow=flow)
    x = session.tensor(jnp.asarray(coords), jnp.asarray(mask),
                       jnp.asarray(feats))
    ref = MU.minkunet_forward(session, params, x)

    session2 = PointAccSession(flow=flow)
    xp = session2.tensor(jnp.asarray(coords), jnp.asarray(mask),
                         jnp.asarray(feats)).padded_to(128)
    assert xp.capacity == 128
    out = MU.minkunet_forward(session2, params, xp)
    np.testing.assert_allclose(np.asarray(out)[:72], np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_padded_to_rejects_shrink_and_is_idempotent():
    coords, mask, feats = lidar_scene(3, 40, grid=12)
    session = PointAccSession()
    x = session.tensor(jnp.asarray(coords), jnp.asarray(mask),
                       jnp.asarray(feats))
    assert x.padded_to(40) is x
    with pytest.raises(ValueError, match="buckets only grow"):
        x.padded_to(16)


# ---------------------------------------------------------------------------
# bucket-aware MappingCache keys
# ---------------------------------------------------------------------------

def test_mapping_cache_extra_distinguishes_buckets():
    cache = MappingCache()
    a = np.zeros(4, np.int32)
    assert cache.get((a,), lambda: "b128", extra=("levels", 128)) \
        == ("b128", False)
    # same bytes, different bucket metadata -> different entry
    assert cache.get((a,), lambda: "b256", extra=("levels", 256)) \
        == ("b256", False)
    assert cache.get((a,), lambda: None, extra=("levels", 128)) \
        == ("b128", True)
    assert MappingCache.digest((a,)) != MappingCache.digest((a,), "tag")
    assert "hit_rate" in cache.stats()


# ---------------------------------------------------------------------------
# acceptance: heterogeneous stream through the scheduler
# ---------------------------------------------------------------------------

def test_scheduler_heterogeneous_stream_acceptance():
    """ISSUE-4 acceptance: >= 16 scenes with >= 4 distinct point counts;
    compilations bounded by #buckets; results match the per-scene loop;
    out-of-order drain; padding / occupancy / hit-rate telemetry."""
    params = _mini_params()
    ladder = geometric_ladder(64, 512)
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=ladder)
    sched = ServeScheduler(engine, max_batch=4, mesh=None)

    sizes = [40, 90, 150, 300]
    scenes = []
    for i in range(16):
        c, m, f = lidar_scene(seed=20 + i % 8, n_points=sizes[i % 4],
                              grid=24)
        scenes.append((c, m, f))
    rids = [sched.submit(c, f, m) for (c, m, f) in scenes]
    assert sched.flush() + sum(len(q) for q in sched._queues.values()) <= 16
    results = sched.drain()
    assert len(results) == 16
    assert sched.drain() == []                        # drained once

    # out-of-order completion: buckets fill at different times
    drained_order = [r.rid for r in results]
    assert sorted(drained_order) == sorted(rids)
    assert drained_order != sorted(drained_order)

    # numerical parity with a per-scene loop, un-padded row counts
    by_rid = {r.rid: r for r in results}
    for rid, (c, m, f) in zip(rids, scenes):
        r = by_rid[rid]
        assert r.n_points == c.shape[0]
        np.testing.assert_array_equal(r.preds, _ref_preds(params, c, m, f))

    # compile bound: one program per bucket per entry point
    n_buckets_used = len({r.bucket for r in results})
    assert n_buckets_used == 4
    comp = engine.compile_stats()
    assert 0 < comp["build"] <= n_buckets_used
    assert 0 < comp["apply_batch"] <= n_buckets_used

    # telemetry: second half of the stream repeats the first's geometry
    stats = sched.stats()
    assert stats["n_completed"] == 16 and stats["queue_depth"] == 0
    assert stats["mapping_cache"]["hits"] == 8
    assert stats["mapping_cache"]["hit_rate"] == pytest.approx(0.5)
    assert stats["padding_overhead"] > 0
    assert stats["n_devices"] == 1                    # CPU degrade path
    for cap, b in stats["buckets"].items():
        assert 0 < b["occupancy"] <= 1.0
        assert b["scenes"] == 4
    # per-request telemetry: repeated geometry reports a mapping hit
    for rid in rids[8:]:
        assert by_rid[rid].mapping_hit
    for rid in rids[:8]:
        assert not by_rid[rid].mapping_hit


def test_scheduler_full_bucket_executes_on_submit():
    """Continuous batching: a bucket that reaches max_batch runs without
    waiting for flush()."""
    params = _mini_params()
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=geometric_ladder(64, 128))
    sched = ServeScheduler(engine, max_batch=2, mesh=None)
    sched.submit(*_scene_cf(0, 40))
    assert len(sched.drain()) == 0
    sched.submit(*_scene_cf(1, 40))                   # fills the bucket
    res = sched.drain()
    assert [r.rid for r in res] == [0, 1]
    assert sched.stats()["queue_depth"] == 0


def _scene_cf(seed, n):
    c, m, f = lidar_scene(seed=40 + seed, n_points=n, grid=16)
    return c, f, m


def test_scheduler_partial_flush_uses_dummy_fill():
    """A straggler still runs (padded with masked dummy scenes) and the
    fill is visible in the occupancy telemetry, not the mapping cache."""
    params = _mini_params()
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=geometric_ladder(64, 64))
    sched = ServeScheduler(engine, max_batch=4, mesh=None)
    c, f, m = _scene_cf(0, 50)
    rid = sched.submit(c, f, m)
    assert sched.flush() == 1
    (res,) = sched.drain()
    assert res.rid == rid
    np.testing.assert_array_equal(res.preds, _ref_preds(params, c, m, f))
    stats = sched.stats()
    assert stats["buckets"][64]["dummy_scenes"] == 3
    assert stats["buckets"][64]["occupancy"] == pytest.approx(0.25)
    # dummy pyramids are cached scheduler-side: cache counts real scenes
    assert stats["mapping_cache"]["misses"] == 1


def test_scheduler_serve_convenience_and_ladder_overflow():
    params = _mini_params()
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=geometric_ladder(64, 128))
    sched = ServeScheduler(engine, max_batch=2, mesh=None)
    out = sched.serve([_scene_cf(i, n) for i, n in enumerate((30, 80))])
    assert set(out) == {0, 1}
    # regression (ISSUE-6 satellite): an oversized scene no longer leaks
    # ValueError out of submit() — it completes as a typed `rejected`
    # result and IS counted as submitted
    rid = sched.submit(*_scene_cf(9, 400))
    res = sched.take([rid])[rid]
    assert not res.ok and res.preds is None
    assert res.error.code == "rejected"
    assert "exceeds the bucket ladder" in res.error.message
    st = sched.stats()
    assert st["n_submitted"] == 3 and st["faults"]["rejected"] == 1
    with pytest.raises(ValueError, match="max_batch"):
        ServeScheduler(engine, max_batch=0, mesh=None)


# ---------------------------------------------------------------------------
# mixed-bucket parity across flows (vmapped pallas/pallas_fused)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("flow", ["pallas", "pallas_fused"])
def test_scheduler_parity_across_flows_mixed_buckets(flow):
    """Satellite: vmapped `pallas`/`pallas_fused` under mixed bucket
    sizes — scheduler results match a per-scene fod loop (exact argmax,
    logits agree at atol 1e-5 per the flow-parity suite), including the
    out-of-order drain path."""
    params = _mini_params()
    engine = PointCloudEngine(params, n_stages=2, flow=flow,
                              ladder=geometric_ladder(48, 96))
    sched = ServeScheduler(engine, max_batch=2, mesh=None)
    sizes = [30, 70, 40, 90]                      # alternating buckets
    scenes = [_scene_cf(i, n) for i, n in enumerate(sizes)]
    rids = [sched.submit(c, f, m) for (c, f, m) in scenes]
    sched.flush()
    results = sched.drain()
    assert sorted(r.rid for r in results) == rids
    by_rid = {r.rid: r for r in results}
    for rid, (c, f, m) in zip(rids, scenes):
        np.testing.assert_array_equal(
            by_rid[rid].preds, _ref_preds(params, c, m, f, flow="fod"))
    assert engine.compile_stats()["apply_batch"] <= 2


# ---------------------------------------------------------------------------
# engine entry points: bounded retraces through the ladder
# ---------------------------------------------------------------------------

def test_engine_segment_bounded_jit_cache_across_sizes():
    """Satellite fix: distinct (B, N) no longer retrace per point count —
    every entry point pads through the ladder, so the jit cache is
    bounded by the number of buckets actually touched."""
    params = _mini_params()
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=geometric_ladder(128, 256))
    refs = {}
    for n in (50, 80, 100, 128):                  # all -> bucket 128
        c, m, f = lidar_scene(seed=60 + n, n_points=n, grid=20)
        preds, hit = engine.segment(c, m, f)
        assert not hit and preds.shape == (n,)
        refs[n] = (np.asarray(preds), c, m, f)
    comp = engine.compile_stats()
    assert comp["build"] == 1 and comp["apply"] == 1

    c, m, f = lidar_scene(seed=61, n_points=200, grid=20)  # bucket 256
    engine.segment(c, m, f)
    comp = engine.compile_stats()
    assert comp["build"] == 2 and comp["apply"] == 2

    # parity: padded serving == per-scene unpadded reference
    for n, (preds, c, m, f) in refs.items():
        np.testing.assert_array_equal(preds, _ref_preds(params, c, m, f))

    # repeated geometry is a cache hit; levels can be passed back in
    c, m, f = refs[80][1:]
    levels, hit = engine.levels_for(c, m)
    assert hit
    preds, hit2 = engine.segment(c, m, f, levels=levels)
    assert hit2 is None
    np.testing.assert_array_equal(np.asarray(preds), refs[80][0])
    assert engine.compile_stats()["apply"] == 2   # still bounded


def test_segment_batch_shares_scheduler_without_stealing_results():
    """A scene submitted directly to the engine's scheduler survives a
    segment_batch call on the same scheduler: the batch flush executes
    it, but its result stays drainable (take() vs drain())."""
    params = _mini_params()
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=geometric_ladder(64, 64),
                              max_batch=2)
    sched = engine.scheduler()
    c, f, m = _scene_cf(0, 40)
    rid = sched.submit(c, f, m)

    bc, bm, bf = [], [], []
    for i in (1, 2):
        sc, sf, sm = _scene_cf(i, 40)
        bc.append(sc), bm.append(sm), bf.append(sf)
    preds, _ = engine.segment_batch(np.stack(bc), np.stack(bm),
                                    np.stack(bf))
    assert preds.shape == (2, 40)
    # the foreign request was executed by the batch's flush, not lost
    res = sched.drain()
    assert [r.rid for r in res] == [rid]
    np.testing.assert_array_equal(res[0].preds,
                                  _ref_preds(params, c, m, f))


def test_segment_batch_ladder_overflow_leaves_no_orphans():
    """A ladder overflow raises BEFORE any scene is admitted, so the
    shared scheduler holds no orphaned queue state."""
    params = _mini_params()
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=geometric_ladder(64, 128))
    scenes = [_scene_cf(i, 160) for i in range(2)]   # > ladder max
    coords = np.stack([c for c, _, _ in scenes])
    feats = np.stack([f for _, f, _ in scenes])
    mask = np.stack([m for _, _, m in scenes])
    with pytest.raises(ValueError, match="exceeds the bucket ladder"):
        engine.segment_batch(coords, mask, feats)
    stats = engine.scheduler().stats()
    assert stats["n_submitted"] == 0 and stats["queue_depth"] == 0


def test_padding_telemetry_counts_valid_rows():
    """padding_frac / padding_overhead count dead rows from pre-masked
    scenes, not just ladder padding."""
    params = _mini_params()
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=geometric_ladder(64, 64))
    sched = ServeScheduler(engine, max_batch=1, mesh=None)
    c, m, f = lidar_scene(seed=80, n_points=64, grid=12)
    assert not m.all()                 # lidar dedupe masks some rows
    rid = sched.submit(c, f, m)
    res = sched.take([rid])[rid]
    expected = 1.0 - m.sum() / 64
    assert res.padding_frac == pytest.approx(expected)
    assert sched.stats()["padding_overhead"] == pytest.approx(
        64 / m.sum() - 1.0)


# ---------------------------------------------------------------------------
# pipelined hot loop: assembly cache, dummy tails, async dispatch, threads
# ---------------------------------------------------------------------------

def test_assembly_cache_repeated_vs_permuted_composition():
    """The composition key is ORDERED per-scene pyramid digests: a
    replayed micro-batch hits (and bypasses the per-scene mapping cache
    wholesale), a permuted one misses the assembly cache but still hits
    the mapping cache scene by scene."""
    params = _mini_params()
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=geometric_ladder(64, 64))
    sched = ServeScheduler(engine, max_batch=2, mesh=None)
    a, b = _scene_cf(0, 40), _scene_cf(1, 50)

    r1 = sched.take([sched.submit(c, f, m) for (c, f, m) in (a, b)])
    ac = sched.stats()["assembly_cache"]
    assert (ac["hits"], ac["misses"]) == (0, 1)

    mc0 = engine.cache_stats()
    r2 = sched.take([sched.submit(c, f, m) for (c, f, m) in (a, b)])
    ac = sched.stats()["assembly_cache"]
    assert (ac["hits"], ac["misses"]) == (1, 1)
    mc = engine.cache_stats()           # mapping cache never consulted
    assert mc["hits"] == mc0["hits"] and mc["misses"] == mc0["misses"]
    assert all(r.mapping_hit for r in r2.values())

    r3 = sched.take([sched.submit(c, f, m) for (c, f, m) in (b, a)])
    ac = sched.stats()["assembly_cache"]
    assert (ac["hits"], ac["misses"]) == (1, 2)
    mc = engine.cache_stats()           # per-scene pyramids still reused
    assert mc["hits"] == mc0["hits"] + 2

    for res, order in ((r1, (a, b)), (r2, (a, b)), (r3, (b, a))):
        for rid, (c, f, m) in zip(sorted(res), order):
            np.testing.assert_array_equal(res[rid].preds,
                                          _ref_preds(params, c, m, f))
    # one bucket, cache on: still one compiled batch program
    assert engine.compile_stats()["apply_batch"] == 1


def test_assembly_cache_lru_eviction_bound():
    params = _mini_params()
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=geometric_ladder(64, 64))
    sched = ServeScheduler(engine, max_batch=1, mesh=None,
                           assembly_cache_entries=1)
    a, b = _scene_cf(0, 40), _scene_cf(1, 50)
    for scene in (a, b, a):             # a evicted by b, then b by a
        (c, f, m) = scene
        sched.take([sched.submit(c, f, m)])
    ac = sched.stats()["assembly_cache"]
    assert ac == {"hits": 0, "misses": 3, "hit_rate": 0.0,
                  "evictions": 2, "entries": 1, "max_entries": 1}
    with pytest.raises(ValueError, match="max_entries"):
        ServeScheduler(engine, mesh=None, assembly_cache_entries=-1)


def test_dummy_tails_prestacked_per_bucket_and_count():
    """Partial micro-batches reuse a pre-stacked dummy pyramid tail per
    (bucket, n_dummies); a replayed straggler composition (same scene,
    same tail length) hits the assembly cache outright."""
    params = _mini_params()
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=geometric_ladder(64, 64))
    sched = ServeScheduler(engine, max_batch=4, mesh=None)
    a, b = _scene_cf(0, 40), _scene_cf(1, 50)

    rid = sched.submit(*a)
    sched.flush()                       # 1 real + 3 dummies
    assert set(sched._dummy_tails) == {(64, 3)}
    sched.submit(*a), sched.submit(*b)
    sched.flush()                       # 2 real + 2 dummies
    assert set(sched._dummy_tails) == {(64, 3), (64, 2)}
    sched.submit(*a)
    sched.flush()                       # same straggler composition
    assert set(sched._dummy_tails) == {(64, 3), (64, 2)}
    assert sched.stats()["assembly_cache"]["hits"] == 1

    res = {r.rid: r for r in sched.drain()}
    (c, f, m) = a
    np.testing.assert_array_equal(res[rid].preds,
                                  _ref_preds(params, c, m, f))
    # dummy pyramids built scheduler-side: cache counts real scenes only
    assert sched.stats()["mapping_cache"]["misses"] == 2


def test_async_dispatch_parks_in_flight_fifo_retirement():
    """Dispatch no longer blocks: a full bucket's micro-batch parks on
    the in-flight FIFO and completes in drain()/poll(); exceeding
    pipeline_depth retires the oldest slot first, so completion order is
    dispatch order."""
    params = _mini_params()
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=geometric_ladder(64, 128))
    sched = ServeScheduler(engine, max_batch=2, mesh=None,
                           pipeline_depth=2)
    for n in (40, 40, 90, 90):          # fills bucket 64, then bucket 128
        sched.submit(*_scene_cf(n, n))
    st = sched.stats()
    assert st["in_flight"] == 2         # both parked, neither retired
    assert st["n_completed"] == 0
    assert [r.rid for r in sched.drain()] == [0, 1, 2, 3]
    assert sched.stats()["in_flight"] == 0

    # depth 1: the third dispatch to one bucket forces the first two out
    sched2 = ServeScheduler(engine, max_batch=1, mesh=None,
                            pipeline_depth=1)
    for i in range(3):
        sched2.submit(*_scene_cf(i, 40))
    st = sched2.stats()
    assert st["in_flight"] == 1 and st["n_completed"] == 2
    with pytest.raises(ValueError, match="pipeline_depth"):
        ServeScheduler(engine, mesh=None, pipeline_depth=-1)


def test_thread_safe_submit_under_concurrent_producers():
    """submit() from several producer threads while earlier micro-batches
    execute: no lost/duplicated rids, telemetry adds up, every result
    matches the per-scene reference."""
    params = _mini_params()
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=geometric_ladder(64, 128))
    sched = ServeScheduler(engine, max_batch=4, mesh=None)
    submitted = []

    def producer(t):
        for j in range(4):
            c, f, m = _scene_cf(4 * t + j, 40 if j % 2 else 90)
            rid = sched.submit(c, f, m)
            submitted.append((rid, (c, f, m)))

    threads = [threading.Thread(target=producer, args=(t,))
               for t in range(4)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    sched.flush()
    results = {r.rid: r for r in sched.drain()}

    assert len(submitted) == 16
    rids = [rid for rid, _ in submitted]
    assert sorted(rids) == list(range(16))      # unique, gap-free
    st = sched.stats()
    assert st["n_submitted"] == 16 and st["n_completed"] == 16
    assert st["queue_depth"] == 0 and st["in_flight"] == 0
    for rid, (c, f, m) in submitted:
        np.testing.assert_array_equal(results[rid].preds,
                                      _ref_preds(params, c, m, f))


@pytest.mark.parametrize("flow", ["pallas", "pallas_fused"])
def test_pipelined_parity_with_synchronous_path(flow):
    """Acceptance: the pipelined path (assembly cache + arenas + async
    dispatch) is bit-identical to the synchronous PR-4 path
    (pipeline_depth=0, assembly_cache_entries=0) on the same repeated
    stream, per flow."""
    params = _mini_params()

    def run(**kw):
        engine = PointCloudEngine(params, n_stages=2, flow=flow,
                                  ladder=geometric_ladder(48, 96))
        sched = ServeScheduler(engine, max_batch=2, mesh=None, **kw)
        base = [_scene_cf(i, n) for i, n in enumerate((30, 70, 40, 90))]
        return sched, sched.serve(base * 2)     # repeat -> assembly hits

    sync_sched, sync_out = run(pipeline_depth=0, assembly_cache_entries=0)
    pipe_sched, pipe_out = run()
    assert sync_sched.stats()["assembly_cache"] is None
    assert pipe_sched.stats()["assembly_cache"]["hits"] >= 2
    assert sorted(sync_out) == sorted(pipe_out)
    for rid in sync_out:
        np.testing.assert_array_equal(sync_out[rid].preds,
                                      pipe_out[rid].preds)


def test_serve_returns_only_own_requests():
    """Satellite fix: serve() on a shared scheduler returns the rids IT
    submitted; a foreign request executed by the same flush stays
    drainable."""
    params = _mini_params()
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=geometric_ladder(64, 64))
    sched = ServeScheduler(engine, max_batch=4, mesh=None)
    c, f, m = _scene_cf(0, 40)
    foreign = sched.submit(c, f, m)
    out = sched.serve([_scene_cf(i, 40) for i in (1, 2)])
    assert set(out) == {1, 2}                   # not the foreign rid
    res = sched.drain()
    assert [r.rid for r in res] == [foreign]
    np.testing.assert_array_equal(res[0].preds,
                                  _ref_preds(params, c, m, f))


def test_deadline_flush_runs_overdue_partial_batch():
    """max_wait_s policy: a partial micro-batch executes once its oldest
    queued request exceeds the deadline (checked in submit()/poll()),
    counted in stats()["deadline_flushes"]."""
    params = _mini_params()
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=geometric_ladder(64, 64))
    # watchdog_s=0 keeps the firing synchronous (in poll()) for a
    # deterministic count; background firing is covered in
    # test_serve_faults.py
    sched = ServeScheduler(engine, max_batch=4, mesh=None,
                           max_wait_s=0.05, watchdog_s=0)
    c, f, m = _scene_cf(0, 40)
    rid = sched.submit(c, f, m)                 # 1/4: queued, not overdue
    assert sched.stats()["deadline_flushes"] == 0
    assert sched.stats()["queue_depth"] == 1
    time.sleep(0.06)
    results = sched.poll()                      # deadline fires here
    assert sched.stats()["deadline_flushes"] == 1
    res = {r.rid: r for r in results + sched.drain()}
    np.testing.assert_array_equal(res[rid].preds,
                                  _ref_preds(params, c, m, f))
    assert sched.stats()["buckets"][64]["dummy_scenes"] == 3


def test_per_bucket_max_batch_overrides_and_ladder_config():
    """Satellite: per-bucket micro-batch widths via a dict override or
    ladder-level config, seeded from occupancy telemetry."""
    params = _mini_params()
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=geometric_ladder(64, 128))
    sched = ServeScheduler(engine, mesh=None,
                           max_batch={64: 2, "default": 4})
    assert sched.max_batch_for(64) == 2 and sched.max_batch_for(128) == 4
    sched.submit(*_scene_cf(0, 40))
    sched.submit(*_scene_cf(1, 40))             # width-2 bucket dispatches
    assert len(sched.drain()) == 2
    st = sched.stats()["buckets"][64]
    assert st["batches"] == 1 and st["dummy_scenes"] == 0
    assert st["max_batch"] == 2
    with pytest.raises(ValueError, match="not on the ladder"):
        ServeScheduler(engine, mesh=None, max_batch={999: 2})

    ladder = BucketLadder((64, 128), max_batch=(1, 2))
    engine2 = PointCloudEngine(params, n_stages=2, flow="fod",
                               ladder=ladder)
    sched2 = ServeScheduler(engine2, mesh=None)
    assert sched2.max_batch_for(64) == 1 and sched2.max_batch_for(128) == 2
    with pytest.raises(ValueError, match="one positive width"):
        BucketLadder((64, 128), max_batch=(2,))

    # occupancy telemetry -> suggested overrides (mean real scenes/batch)
    assert max_batch_from_occupancy(
        {64: {"scenes": 2, "batches": 2}, 128: {"scenes": 7, "batches": 2}},
        default=4) == {64: 1, 128: 4}


# ---------------------------------------------------------------------------
# chaos: concurrent producers through an injected FaultPlan
# ---------------------------------------------------------------------------

def test_chaos_concurrent_producers_with_injected_faults():
    """ISSUE-6 acceptance: concurrent producers stream mixed-size scenes
    through an injected FaultPlan (1 transient dispatch failure + 1
    NaN-corrupted scene + 1 oversized scene).  Every submitted rid
    resolves to predictions or a typed error, no exception escapes
    submit/flush/drain/serve, every surviving prediction is bit-identical
    to the fault-free per-scene reference, and the scheduler serves a
    clean follow-up stream afterwards."""
    from repro.serve.faults import FaultPlan

    # this test compiles several fresh full-model programs late in the
    # suite; drop the session's accumulated executables first so the
    # CPU backend's JIT doesn't run out of code space mid-compile
    jax.clear_caches()
    params = _mini_params()
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=geometric_ladder(64, 128))
    plan = FaultPlan(fail_dispatches={0},   # first micro-batch wait fails
                     corrupt_scenes={5})    # 6th submit gets NaN feats
    sched = ServeScheduler(engine, max_batch=2, mesh=None,
                           fault_plan=plan)
    submitted = []

    def producer(t):
        for j in range(4):
            scene = _scene_cf(4 * t + j, 40 if j % 2 else 90)
            # rid pairs with its scene via locals; list.append is atomic
            submitted.append((sched.submit(*scene), scene))

    threads = [threading.Thread(target=producer, args=(t,))
               for t in range(3)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    # oversized scene last (its submit ordinal can't collide with the
    # corrupt_scenes ordinal, which lands on a producer submit)
    big_rid = sched.submit(*_scene_cf(99, 400))
    submitted.append((big_rid, None))
    sched.flush()
    results = {r.rid: r for r in sched.drain()}

    # every rid completed, exactly once, with preds XOR a typed error
    assert sorted(results) == sorted(rid for rid, _ in submitted)
    errors = {rid: r.error for rid, r in results.items()
              if r.error is not None}
    assert results[big_rid].error.code == "rejected"
    assert len(errors) == 2                 # corrupted + oversized
    assert all(e.code == "rejected" for e in errors.values())
    # surviving predictions are bit-identical to the no-fault reference
    # (per-scene vmap independence: the retried composition can't leak)
    n_ok = 0
    for rid, scene in submitted:
        if rid in errors:
            continue
        c, f, m = scene
        np.testing.assert_array_equal(results[rid].preds,
                                      _ref_preds(params, c, m, f))
        n_ok += 1
    assert n_ok == 11

    st = sched.stats()
    assert st["n_submitted"] == 13 and st["n_completed"] == 13
    assert st["faults"]["rejected"] == 2
    assert st["faults"]["exec_failed"] == 0  # transient failure retried
    assert st["faults"]["failed_dispatches"] == 1
    assert st["faults"]["retries"] >= 1
    assert st["faults"]["recovery_s"] is not None
    assert plan.stats()["failures_injected"] == 1
    assert plan.stats()["scenes_corrupted"] == 1

    # the stream survives: a clean follow-up batch serves normally
    follow = [_scene_cf(200 + i, 40) for i in range(2)]
    out = sched.serve(follow)
    assert len(out) == 2
    for rid, (c, f, m) in zip(sorted(out), follow):
        assert out[rid].ok
        np.testing.assert_array_equal(out[rid].preds,
                                      _ref_preds(params, c, m, f))


def test_engine_batched_levels_cache_per_scene():
    """levels_for(batched=True) stacks per-scene cached pyramids: a new
    batch composition around a repeated scene still hits."""
    params = _mini_params()
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=geometric_ladder(128, 128))
    scenes = [lidar_scene(seed=70 + i, n_points=100, grid=20)
              for i in range(3)]
    coords = np.stack([c for c, _, _ in scenes])
    mask = np.stack([m for _, m, _ in scenes])
    _, hit = engine.levels_for(coords, mask, batched=True)
    assert not hit
    # reversed composition: every scene already cached
    _, hit = engine.levels_for(coords[::-1], mask[::-1], batched=True)
    assert hit
    assert engine.cache_stats()["hits"] == 3
