"""Continuous-batching serve scheduler: the bucket ladder (capacities,
padding, numerical invariance), the ServeScheduler (queueing, bucketed
micro-batches, out-of-order drain, telemetry), bounded compile counts
through every engine entry point, and mixed-bucket parity with a
per-scene loop across the fod / pallas / pallas_fused flows.  The
shard_map-sharded executor is covered on a mocked multi-device mesh in
tests/test_distributed.py; here the same code degrades to the
single-device vmapped path."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.api import MappingCache, PointAccSession
from repro.core import mapping as M
from repro.data.synthetic import lidar_scene
from repro.models import minkunet as MU
from repro.serve.buckets import (BucketLadder, geometric_ladder,
                                 pad_scene)
from repro.serve.engine import PointCloudEngine
from repro.serve.scheduler import ServeScheduler


def _mini_params(n_classes=2):
    return MU.mini_minkunet_init(jax.random.key(0), c_in=4,
                                 n_classes=n_classes)


def _ref_preds(params, coords, mask, feats, flow="fod"):
    pc = M.make_point_cloud(jnp.asarray(coords), jnp.asarray(mask))
    logits = MU.minkunet_apply(params, pc, jnp.asarray(feats), flow=flow)
    return np.asarray(jnp.argmax(logits, -1))


# ---------------------------------------------------------------------------
# bucket ladder policy
# ---------------------------------------------------------------------------

def test_bucket_ladder_selection_and_bounds():
    ladder = BucketLadder((64, 128, 256))
    assert ladder.n_buckets == 3
    assert ladder.bucket_for(1) == 64
    assert ladder.bucket_for(64) == 64
    assert ladder.bucket_for(65) == 128
    assert ladder.bucket_for(256) == 256
    assert ladder.index_for(200) == 2
    with pytest.raises(ValueError, match="exceeds the bucket ladder"):
        ladder.bucket_for(257)
    assert ladder.padding_fraction(96) == pytest.approx(0.25)


def test_bucket_ladder_validation():
    with pytest.raises(ValueError, match="ascending"):
        BucketLadder((128, 64))
    with pytest.raises(ValueError, match="ascending"):
        BucketLadder((64, 64))
    with pytest.raises(ValueError, match="positive"):
        BucketLadder((0, 64))
    with pytest.raises(ValueError, match="growth"):
        geometric_ladder(64, 256, growth=1.0)


def test_geometric_ladder_growth_bounds_padding():
    ladder = geometric_ladder(128, 4096, growth=2.0)
    caps = ladder.capacities
    assert caps[0] == 128 and caps[-1] >= 4096
    assert all(c % 8 == 0 for c in caps)
    # worst-case padding of a geometric ladder is 1 - 1/growth
    for n in range(129, 4096, 97):
        assert ladder.padding_fraction(n) < 0.5 + 1e-9


def test_pad_scene_sentinels_and_masked_rows():
    rng = np.random.default_rng(0)
    coords = rng.integers(0, 10, size=(5, 4)).astype(np.int32)
    mask = np.array([True, True, False, True, True])
    feats = rng.normal(size=(5, 3)).astype(np.float32)
    c, m, f = pad_scene(coords, mask, feats, 8)
    assert c.shape == (8, 4) and m.shape == (8,) and f.shape == (8, 3)
    np.testing.assert_array_equal(m, list(mask) + [False] * 3)
    # padding rows AND pre-masked rows are sentinel-filled / zeroed
    assert (c[5:] == M.SENTINEL).all() and (c[2] == M.SENTINEL).all()
    assert (f[5:] == 0).all() and (f[2] == 0).all()
    np.testing.assert_array_equal(c[0], coords[0])
    with pytest.raises(ValueError, match="pad.*down"):
        pad_scene(coords, mask, feats, 4)
    # feats=None path (mapping-only padding)
    c2, m2, f2 = pad_scene(coords, mask, None, 8)
    np.testing.assert_array_equal(c2, c)
    assert f2 is None


@pytest.mark.parametrize("flow", ["fod", "pallas_fused"])
def test_bucket_padding_preserves_logits(flow):
    """The core invariant the ladder relies on: padding a scene to a
    bucket capacity leaves the valid rows' logits unchanged (atol 1e-5)
    — sentinel rows sort to the end and never enter a kernel map."""
    coords, mask, feats = lidar_scene(3, 72, grid=16)
    params = _mini_params()
    session = PointAccSession(flow=flow)
    x = session.tensor(jnp.asarray(coords), jnp.asarray(mask),
                       jnp.asarray(feats))
    ref = MU.minkunet_forward(session, params, x)

    session2 = PointAccSession(flow=flow)
    xp = session2.tensor(jnp.asarray(coords), jnp.asarray(mask),
                         jnp.asarray(feats)).padded_to(128)
    assert xp.capacity == 128
    out = MU.minkunet_forward(session2, params, xp)
    np.testing.assert_allclose(np.asarray(out)[:72], np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_padded_to_rejects_shrink_and_is_idempotent():
    coords, mask, feats = lidar_scene(3, 40, grid=12)
    session = PointAccSession()
    x = session.tensor(jnp.asarray(coords), jnp.asarray(mask),
                       jnp.asarray(feats))
    assert x.padded_to(40) is x
    with pytest.raises(ValueError, match="buckets only grow"):
        x.padded_to(16)


# ---------------------------------------------------------------------------
# bucket-aware MappingCache keys
# ---------------------------------------------------------------------------

def test_mapping_cache_extra_distinguishes_buckets():
    cache = MappingCache()
    a = np.zeros(4, np.int32)
    assert cache.get((a,), lambda: "b128", extra=("levels", 128)) \
        == ("b128", False)
    # same bytes, different bucket metadata -> different entry
    assert cache.get((a,), lambda: "b256", extra=("levels", 256)) \
        == ("b256", False)
    assert cache.get((a,), lambda: None, extra=("levels", 128)) \
        == ("b128", True)
    assert MappingCache.digest((a,)) != MappingCache.digest((a,), "tag")
    assert "hit_rate" in cache.stats()


# ---------------------------------------------------------------------------
# acceptance: heterogeneous stream through the scheduler
# ---------------------------------------------------------------------------

def test_scheduler_heterogeneous_stream_acceptance():
    """ISSUE-4 acceptance: >= 16 scenes with >= 4 distinct point counts;
    compilations bounded by #buckets; results match the per-scene loop;
    out-of-order drain; padding / occupancy / hit-rate telemetry."""
    params = _mini_params()
    ladder = geometric_ladder(64, 512)
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=ladder)
    sched = ServeScheduler(engine, max_batch=4, mesh=None)

    sizes = [40, 90, 150, 300]
    scenes = []
    for i in range(16):
        c, m, f = lidar_scene(seed=20 + i % 8, n_points=sizes[i % 4],
                              grid=24)
        scenes.append((c, m, f))
    rids = [sched.submit(c, f, m) for (c, m, f) in scenes]
    assert sched.flush() + sum(len(q) for q in sched._queues.values()) <= 16
    results = sched.drain()
    assert len(results) == 16
    assert sched.drain() == []                        # drained once

    # out-of-order completion: buckets fill at different times
    drained_order = [r.rid for r in results]
    assert sorted(drained_order) == sorted(rids)
    assert drained_order != sorted(drained_order)

    # numerical parity with a per-scene loop, un-padded row counts
    by_rid = {r.rid: r for r in results}
    for rid, (c, m, f) in zip(rids, scenes):
        r = by_rid[rid]
        assert r.n_points == c.shape[0]
        np.testing.assert_array_equal(r.preds, _ref_preds(params, c, m, f))

    # compile bound: one program per bucket per entry point
    n_buckets_used = len({r.bucket for r in results})
    assert n_buckets_used == 4
    comp = engine.compile_stats()
    assert 0 < comp["build"] <= n_buckets_used
    assert 0 < comp["apply_batch"] <= n_buckets_used

    # telemetry: second half of the stream repeats the first's geometry
    stats = sched.stats()
    assert stats["n_completed"] == 16 and stats["queue_depth"] == 0
    assert stats["mapping_cache"]["hits"] == 8
    assert stats["mapping_cache"]["hit_rate"] == pytest.approx(0.5)
    assert stats["padding_overhead"] > 0
    assert stats["n_devices"] == 1                    # CPU degrade path
    for cap, b in stats["buckets"].items():
        assert 0 < b["occupancy"] <= 1.0
        assert b["scenes"] == 4
    # per-request telemetry: repeated geometry reports a mapping hit
    for rid in rids[8:]:
        assert by_rid[rid].mapping_hit
    for rid in rids[:8]:
        assert not by_rid[rid].mapping_hit


def test_scheduler_full_bucket_executes_on_submit():
    """Continuous batching: a bucket that reaches max_batch runs without
    waiting for flush()."""
    params = _mini_params()
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=geometric_ladder(64, 128))
    sched = ServeScheduler(engine, max_batch=2, mesh=None)
    sched.submit(*_scene_cf(0, 40))
    assert len(sched.drain()) == 0
    sched.submit(*_scene_cf(1, 40))                   # fills the bucket
    res = sched.drain()
    assert [r.rid for r in res] == [0, 1]
    assert sched.stats()["queue_depth"] == 0


def _scene_cf(seed, n):
    c, m, f = lidar_scene(seed=40 + seed, n_points=n, grid=16)
    return c, f, m


def test_scheduler_partial_flush_uses_dummy_fill():
    """A straggler still runs (padded with masked dummy scenes) and the
    fill is visible in the occupancy telemetry, not the mapping cache."""
    params = _mini_params()
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=geometric_ladder(64, 64))
    sched = ServeScheduler(engine, max_batch=4, mesh=None)
    c, f, m = _scene_cf(0, 50)
    rid = sched.submit(c, f, m)
    assert sched.flush() == 1
    (res,) = sched.drain()
    assert res.rid == rid
    np.testing.assert_array_equal(res.preds, _ref_preds(params, c, m, f))
    stats = sched.stats()
    assert stats["buckets"][64]["dummy_scenes"] == 3
    assert stats["buckets"][64]["occupancy"] == pytest.approx(0.25)
    # dummy pyramids are cached scheduler-side: cache counts real scenes
    assert stats["mapping_cache"]["misses"] == 1


def test_scheduler_serve_convenience_and_ladder_overflow():
    params = _mini_params()
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=geometric_ladder(64, 128))
    sched = ServeScheduler(engine, max_batch=2, mesh=None)
    out = sched.serve([_scene_cf(i, n) for i, n in enumerate((30, 80))])
    assert set(out) == {0, 1}
    with pytest.raises(ValueError, match="exceeds the bucket ladder"):
        sched.submit(*_scene_cf(9, 400))
    with pytest.raises(ValueError, match="max_batch"):
        ServeScheduler(engine, max_batch=0, mesh=None)


# ---------------------------------------------------------------------------
# mixed-bucket parity across flows (vmapped pallas/pallas_fused)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("flow", ["pallas", "pallas_fused"])
def test_scheduler_parity_across_flows_mixed_buckets(flow):
    """Satellite: vmapped `pallas`/`pallas_fused` under mixed bucket
    sizes — scheduler results match a per-scene fod loop (exact argmax,
    logits agree at atol 1e-5 per the flow-parity suite), including the
    out-of-order drain path."""
    params = _mini_params()
    engine = PointCloudEngine(params, n_stages=2, flow=flow,
                              ladder=geometric_ladder(48, 96))
    sched = ServeScheduler(engine, max_batch=2, mesh=None)
    sizes = [30, 70, 40, 90]                      # alternating buckets
    scenes = [_scene_cf(i, n) for i, n in enumerate(sizes)]
    rids = [sched.submit(c, f, m) for (c, f, m) in scenes]
    sched.flush()
    results = sched.drain()
    assert sorted(r.rid for r in results) == rids
    by_rid = {r.rid: r for r in results}
    for rid, (c, f, m) in zip(rids, scenes):
        np.testing.assert_array_equal(
            by_rid[rid].preds, _ref_preds(params, c, m, f, flow="fod"))
    assert engine.compile_stats()["apply_batch"] <= 2


# ---------------------------------------------------------------------------
# engine entry points: bounded retraces through the ladder
# ---------------------------------------------------------------------------

def test_engine_segment_bounded_jit_cache_across_sizes():
    """Satellite fix: distinct (B, N) no longer retrace per point count —
    every entry point pads through the ladder, so the jit cache is
    bounded by the number of buckets actually touched."""
    params = _mini_params()
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=geometric_ladder(128, 256))
    refs = {}
    for n in (50, 80, 100, 128):                  # all -> bucket 128
        c, m, f = lidar_scene(seed=60 + n, n_points=n, grid=20)
        preds, hit = engine.segment(c, m, f)
        assert not hit and preds.shape == (n,)
        refs[n] = (np.asarray(preds), c, m, f)
    comp = engine.compile_stats()
    assert comp["build"] == 1 and comp["apply"] == 1

    c, m, f = lidar_scene(seed=61, n_points=200, grid=20)  # bucket 256
    engine.segment(c, m, f)
    comp = engine.compile_stats()
    assert comp["build"] == 2 and comp["apply"] == 2

    # parity: padded serving == per-scene unpadded reference
    for n, (preds, c, m, f) in refs.items():
        np.testing.assert_array_equal(preds, _ref_preds(params, c, m, f))

    # repeated geometry is a cache hit; levels can be passed back in
    c, m, f = refs[80][1:]
    levels, hit = engine.levels_for(c, m)
    assert hit
    preds, hit2 = engine.segment(c, m, f, levels=levels)
    assert hit2 is None
    np.testing.assert_array_equal(np.asarray(preds), refs[80][0])
    assert engine.compile_stats()["apply"] == 2   # still bounded


def test_segment_batch_shares_scheduler_without_stealing_results():
    """A scene submitted directly to the engine's scheduler survives a
    segment_batch call on the same scheduler: the batch flush executes
    it, but its result stays drainable (take() vs drain())."""
    params = _mini_params()
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=geometric_ladder(64, 64),
                              max_batch=2)
    sched = engine.scheduler()
    c, f, m = _scene_cf(0, 40)
    rid = sched.submit(c, f, m)

    bc, bm, bf = [], [], []
    for i in (1, 2):
        sc, sf, sm = _scene_cf(i, 40)
        bc.append(sc), bm.append(sm), bf.append(sf)
    preds, _ = engine.segment_batch(np.stack(bc), np.stack(bm),
                                    np.stack(bf))
    assert preds.shape == (2, 40)
    # the foreign request was executed by the batch's flush, not lost
    res = sched.drain()
    assert [r.rid for r in res] == [rid]
    np.testing.assert_array_equal(res[0].preds,
                                  _ref_preds(params, c, m, f))


def test_segment_batch_ladder_overflow_leaves_no_orphans():
    """A ladder overflow raises BEFORE any scene is admitted, so the
    shared scheduler holds no orphaned queue state."""
    params = _mini_params()
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=geometric_ladder(64, 128))
    scenes = [_scene_cf(i, 160) for i in range(2)]   # > ladder max
    coords = np.stack([c for c, _, _ in scenes])
    feats = np.stack([f for _, f, _ in scenes])
    mask = np.stack([m for _, _, m in scenes])
    with pytest.raises(ValueError, match="exceeds the bucket ladder"):
        engine.segment_batch(coords, mask, feats)
    stats = engine.scheduler().stats()
    assert stats["n_submitted"] == 0 and stats["queue_depth"] == 0


def test_padding_telemetry_counts_valid_rows():
    """padding_frac / padding_overhead count dead rows from pre-masked
    scenes, not just ladder padding."""
    params = _mini_params()
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=geometric_ladder(64, 64))
    sched = ServeScheduler(engine, max_batch=1, mesh=None)
    c, m, f = lidar_scene(seed=80, n_points=64, grid=12)
    assert not m.all()                 # lidar dedupe masks some rows
    rid = sched.submit(c, f, m)
    res = sched.take([rid])[rid]
    expected = 1.0 - m.sum() / 64
    assert res.padding_frac == pytest.approx(expected)
    assert sched.stats()["padding_overhead"] == pytest.approx(
        64 / m.sum() - 1.0)


def test_engine_batched_levels_cache_per_scene():
    """levels_for(batched=True) stacks per-scene cached pyramids: a new
    batch composition around a repeated scene still hits."""
    params = _mini_params()
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=geometric_ladder(128, 128))
    scenes = [lidar_scene(seed=70 + i, n_points=100, grid=20)
              for i in range(3)]
    coords = np.stack([c for c, _, _ in scenes])
    mask = np.stack([m for _, m, _ in scenes])
    _, hit = engine.levels_for(coords, mask, batched=True)
    assert not hit
    # reversed composition: every scene already cached
    _, hit = engine.levels_for(coords[::-1], mask[::-1], batched=True)
    assert hit
    assert engine.cache_stats()["hits"] == 3
