"""SLO-aware overload control (serve/overload.py): the circuit-breaker
state machine (trip / half-open probe / probe takeover, all on an
injected clock), the rate estimator + Little's-law effective backlog
bound, the brownout ladder's knob mutation and restore, the controller's
admission gate (adaptive shed, priority-lane shed, breaker shed — every
shed carrying a `retry_after_s` hint), deterministic seeded retry
backoff, priority/EDF lane ordering under deferred dispatch, and the
acceptance storm: a multi-producer 2x-capacity overload run whose
accounting conserves every submit, sheds carry retry hints, traces all
close, and the surviving predictions are bit-identical to an unloaded
control run."""

import math
import threading
import time

import numpy as np
import pytest
import jax

from repro.data.synthetic import lidar_scene
from repro.obs import Observability
from repro.obs import metrics as MX
from repro.serve import faults as FLT
from repro.serve import overload as OV
from repro.serve.buckets import geometric_ladder
from repro.serve.engine import PointCloudEngine
from repro.serve.faults import FaultPlan
from repro.serve.overload import (BreakerPolicy, BrownoutPolicy,
                                  CircuitBreaker, OverloadController,
                                  OverloadPolicy, ServeSLO,
                                  resolve_controller)
from repro.serve.router import ServeRouter
from repro.serve.scheduler import ServeScheduler
from tests.test_serve_faults import _mini_params


def _scene(seed, n):
    c, m, f = lidar_scene(seed=940 + seed, n_points=n, grid=16)
    return c, f, m


@pytest.fixture(scope="module")
def served():
    """(params, engine) shared across the module, jit paid once."""
    jax.clear_caches()
    params = _mini_params()
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=geometric_ladder(64, 128))
    return params, engine


# ---------------------------------------------------------------------------
# circuit breaker state machine (no engine, injected clock)
# ---------------------------------------------------------------------------

_BP = BreakerPolicy(k_failures=3, window_s=1.0, cooldown_s=0.5)


def test_breaker_trips_and_recovers():
    br = CircuitBreaker(_BP)
    assert br.state == OV.CLOSED and br.allow(0.0)
    assert not br.record_failure(0.0)
    assert not br.record_failure(0.1)
    assert br.record_failure(0.2)           # k-th failure in window trips
    assert br.state == OV.OPEN and br.n_trips == 1
    assert not br.allow(0.3)                # cooling down
    assert br.retry_after(0.3) == pytest.approx(0.4)
    assert br.allow(0.71)                   # first allow IS the probe
    assert br.state == OV.HALF_OPEN
    br.record_success(0.72)                 # probe succeeded
    assert br.state == OV.CLOSED
    # the failure window was cleared: two fresh failures do not trip
    assert not br.record_failure(0.8)
    assert not br.record_failure(0.9)
    assert br.state == OV.CLOSED


def test_breaker_probe_failure_and_takeover():
    br = CircuitBreaker(_BP)
    for t in (0.0, 0.1, 0.2):
        br.record_failure(t)
    assert br.state == OV.OPEN
    assert br.allow(0.8)                    # probe slot
    assert br.record_failure(0.9)           # failed probe re-trips
    assert br.state == OV.OPEN and br.n_trips == 2
    assert not br.allow(1.0)
    assert br.allow(1.5)                    # next probe
    # probe outstanding: no second admission inside the cooldown...
    assert not br.allow(1.6)
    # ...but a probe that never resolves is taken over after cooldown_s
    assert br.allow(2.1)
    assert br.state == OV.HALF_OPEN


def test_breaker_window_prunes_old_failures():
    br = CircuitBreaker(_BP)
    br.record_failure(0.0)
    br.record_failure(0.1)
    # the first two fall out of the 1s window before the third lands
    assert not br.record_failure(1.5)
    assert br.state == OV.CLOSED


# ---------------------------------------------------------------------------
# policy validation + controller resolution
# ---------------------------------------------------------------------------

def test_policy_validation():
    with pytest.raises(ValueError, match="k_failures"):
        BreakerPolicy(k_failures=0)
    with pytest.raises(ValueError, match="window_s"):
        BreakerPolicy(window_s=0.0)
    with pytest.raises(ValueError, match="deadline_headroom_s"):
        ServeSLO(deadline_headroom_s=0.0)
    with pytest.raises(ValueError, match="wait_shrink"):
        BrownoutPolicy(wait_shrink=0.0)
    with pytest.raises(ValueError, match="escalate"):
        BrownoutPolicy(escalate_after_s=-1.0)
    with pytest.raises(ValueError, match="tick_s"):
        OverloadPolicy(tick_s=0.0)
    with pytest.raises(ValueError, match="ewma_alpha"):
        OverloadPolicy(ewma_alpha=1.5)
    with pytest.raises(ValueError, match="min_backlog"):
        OverloadPolicy(min_backlog=0)


def test_resolve_controller():
    assert resolve_controller(None) is None
    assert resolve_controller(False) is None
    ctrl = resolve_controller(True)
    assert isinstance(ctrl, OverloadController)
    pol = OverloadPolicy(tick_s=0.1)
    assert resolve_controller(pol).policy is pol
    assert resolve_controller(ctrl) is ctrl
    with pytest.raises(TypeError, match="overload="):
        resolve_controller("adaptive")


# ---------------------------------------------------------------------------
# controller units over a fake scheduler (injected clock, no engine)
# ---------------------------------------------------------------------------

class _FakeSched:
    """Just the scheduler surface the controller reads/writes: the obs
    bundle, the latency histogram, the outstanding map, and the knobs
    the brownout ladder mutates.  (Completions reach the estimator via
    `record_dispatch_success`, not through scheduler state.)"""

    def __init__(self, max_backlog=None, max_wait_s=0.2, pipeline_depth=2):
        self.obs = Observability.enabled()
        self.instance = "fake"
        self.max_backlog = max_backlog
        self.max_wait_s = max_wait_s
        self.pipeline_depth = pipeline_depth
        self._h_latency = self.obs.registry.histogram(
            "serve_request_latency_seconds", "",
            ("instance",)).labels(self.instance)
        self._outstanding = {}

    def max_batch_for(self, cap):
        return 1


def _bound_ctrl(sched, **policy_kw):
    now = [0.0]
    ctrl = OverloadController(OverloadPolicy(**policy_kw),
                              clock=lambda: now[0])
    ctrl.bind(sched)
    return ctrl, now


def test_rate_estimation_and_effective_backlog():
    sched = _FakeSched(max_backlog=4)
    ctrl, _ = _bound_ctrl(
        sched, slo=ServeSLO(deadline_headroom_s=0.5), ewma_alpha=0.5)
    ctrl.tick(0.0)                          # snapshot only
    assert ctrl.service_rate(64) is None
    assert ctrl.effective_backlog(64) == 4  # cold start: static bound
    ctrl.record_dispatch_success(64, 10)
    ctrl.tick(1.0)                          # first estimate = 10/s
    assert ctrl.service_rate(64) == pytest.approx(10.0)
    # Little's law: ceil(10 x 0.5) = 5, clamped by the static 4
    assert ctrl.effective_backlog(64) == 4
    ctrl.record_dispatch_success(64, 2)
    ctrl.tick(2.0)                          # EWMA folds in 2/s
    assert ctrl.service_rate(64) == pytest.approx(6.0)
    assert ctrl.effective_backlog(64) == math.ceil(6.0 * 0.5)
    # retry hint: (outstanding - bound + 1) / rate
    assert ctrl.retry_after(64, 8) == pytest.approx((8 - 3 + 1) / 6.0)
    # zero-completion ticks while busy are burstiness, not signal: the
    # estimate (and with it the bound) holds instead of whipsawing
    for t in (3.0, 4.0, 5.0, 6.0, 7.0, 8.0):
        sched._outstanding[64] = 1          # busy, but nothing completes
        ctrl.tick(t)
    assert ctrl.service_rate(64) == pytest.approx(6.0)
    assert ctrl.effective_backlog(64) >= ctrl.policy.min_backlog


def test_idle_bucket_keeps_estimate():
    sched = _FakeSched()
    ctrl, _ = _bound_ctrl(sched)
    ctrl.tick(0.0)
    ctrl.record_dispatch_success(128, 20)
    ctrl.tick(1.0)
    rate = ctrl.service_rate(128)
    assert rate == pytest.approx(20.0)
    # idle (no delta, nothing outstanding): the estimate survives
    ctrl.tick(2.0)
    ctrl.tick(3.0)
    assert ctrl.service_rate(128) == rate


def test_admission_adaptive_shed_carries_retry_hint():
    sched = _FakeSched(max_backlog=10)
    ctrl, now = _bound_ctrl(sched,
                            slo=ServeSLO(deadline_headroom_s=0.1))
    ctrl.tick(0.0)
    ctrl.record_dispatch_success(64, 10)
    now[0] = 1.0
    # rate 10/s -> ceil(10 x 0.1) = 1, floored at 2 full micro-batches
    ctrl.tick(1.0)
    assert ctrl.effective_backlog(64) == 2
    err = ctrl.check_admission_locked(64, outstanding=5, priority=0)
    assert err is not None and err.code == FLT.SHED
    assert "adaptive bound" in err.message
    assert err.retry_after_s == pytest.approx((5 - 2 + 1) / 10.0)
    # under the bound: admitted
    assert ctrl.check_admission_locked(64, outstanding=0,
                                       priority=0) is None


def test_brownout_ladder_escalates_and_recovers():
    sched = _FakeSched(max_backlog=10, max_wait_s=0.2, pipeline_depth=2)
    ctrl, now = _bound_ctrl(
        sched, slo=ServeSLO(deadline_headroom_s=0.1), tick_s=0.01,
        brownout=BrownoutPolicy(escalate_after_s=0.5, recover_after_s=1.0,
                                wait_shrink=0.5, depth_cap=1,
                                shed_below_priority=1))
    ctrl.tick(0.0)
    ctrl.record_dispatch_success(64, 10)
    ctrl.tick(1.0)                          # rate 10/s -> bound 2
    sched._outstanding[64] = 5              # pinned over the bound
    ctrl.record_dispatch_success(64, 1)     # keep the bucket busy
    ctrl.tick(1.1)                          # pressure starts
    for i, t in enumerate((1.7, 2.3, 2.9)):  # one escalation per window
        ctrl.record_dispatch_success(64, 1)
        ctrl.tick(t)
        assert ctrl.level == i + 1
    assert ctrl.level == 3
    assert sched.max_wait_s == pytest.approx(0.1)       # level 1
    assert sched.pipeline_depth == 1                    # level 2
    # level 3: the lane below shed_below_priority is browned out
    now[0] = 2.95
    err = ctrl.check_admission_locked(64, outstanding=0, priority=0)
    assert err is not None and err.code == FLT.SHED
    assert "brownout" in err.message
    assert err.retry_after_s is not None
    assert ctrl.check_admission_locked(64, outstanding=0,
                                       priority=1) is None
    # calm -> stepwise recovery, knobs restored in reverse
    sched._outstanding[64] = 0
    for t in (3.0, 4.1, 5.2, 6.3):
        ctrl.tick(t)
    assert ctrl.level == 0
    assert sched.max_wait_s == pytest.approx(0.2)
    assert sched.pipeline_depth == 2
    assert ctrl.n_transitions == 6
    # every transition was a flight-recorder incident...
    kinds = [d["reason"] for d in sched.obs.recorder.dumps]
    assert kinds.count("brownout") == 6
    # ...and a span event on the controller trace, closed by close()
    ctrl.close()
    trace = sched.obs.tracer.get("fake:overload")
    assert trace is not None and trace.closed
    assert sched.obs.registry.gauge(
        "serve_overload_state",
        labelnames=("instance",)).labels("fake").value == 0


def test_bucket_breaker_sheds_admission():
    sched = _FakeSched()
    ctrl, now = _bound_ctrl(sched, breaker=_BP)
    for t in (0.0, 0.1, 0.2):
        now[0] = t
        ctrl.record_dispatch_failure(64)
    assert ctrl.bucket_breaker(64).state == OV.OPEN
    now[0] = 0.3
    err = ctrl.check_admission_locked(64, outstanding=0, priority=0)
    assert err is not None and err.code == FLT.SHED
    assert "circuit breaker" in err.message
    assert err.retry_after_s == pytest.approx(0.4)
    # a breaker trip is a recorder incident too
    assert any(d["reason"] == "breaker_trip"
               for d in sched.obs.recorder.dumps)
    # cooldown over: the next admission is the half-open probe
    now[0] = 0.8
    assert ctrl.check_admission_locked(64, outstanding=0,
                                       priority=0) is None
    ctrl.record_dispatch_success(64)
    assert ctrl.bucket_breaker(64).state == OV.CLOSED


# ---------------------------------------------------------------------------
# scheduler integration (engine)
# ---------------------------------------------------------------------------

def _backoff_total(engine, seed):
    plan = FaultPlan(poison_rids=frozenset({0}))
    sched = ServeScheduler(engine, max_batch=2, fault_plan=plan,
                           retry_backoff_s=0.001, retry_backoff_seed=seed)
    rids = [sched.submit(*_scene(s, 40)) for s in range(2)]
    sched.flush()
    out = sched.take(rids)
    st = sched.stats()
    sched.close()
    assert out[rids[0]].error is not None           # the poisoned rid
    assert st["faults"]["retries"] > 0
    return st["faults"]["retry_backoff_s"]


def test_seeded_backoff_determinism(served):
    """Satellite: two schedulers built with the same retry_backoff_seed
    draw identical jitter, so their backoff schedules match exactly."""
    _, engine = served
    a = _backoff_total(engine, 123)
    b = _backoff_total(engine, 123)
    c = _backoff_total(engine, 321)
    assert a > 0
    assert a == b                       # same seed: bit-equal schedule
    assert a != c                       # different seed: different jitter


def test_priority_lanes_edf_order(served):
    """With the controller attached, full batches DEFER while the bucket
    is at pipeline depth; the deferred queue is popped highest-priority
    first (EDF within a priority), and per-scene predictions stay
    bit-identical to the plain FIFO scheduler."""
    _, engine = served
    scenes = [_scene(100 + s, 40) for s in range(8)]

    # control run: plain scheduler, no controller
    ref = ServeScheduler(engine, max_batch=2)
    ref_rids = [ref.submit(*sc) for sc in scenes]
    ref.flush()
    ref_out = ref.take(ref_rids)
    ref.close()

    obs = Observability.enabled()
    pol = OverloadPolicy(
        tick_s=10.0,  # keep the estimator/ladder quiet for this test
        brownout=BrownoutPolicy(escalate_after_s=60.0))
    sched = ServeScheduler(engine, max_batch=2, pipeline_depth=1,
                           overload=pol, watchdog_s=0, obs=obs,
                           instance="lane")
    # 2 batches dispatch immediately (fill the depth), the rest defer
    prios = [0, 0, 0, 0, 0, 0, 5, 5]
    rids = [sched.submit(*sc, priority=p)
            for sc, p in zip(scenes, prios)]
    st = sched.stats()
    assert st["queue_depth"] >= 4       # deferred dispatch engaged
    sched.flush()
    out = sched.take(rids)
    sched.close()
    # dispatch order from the recorder: the priority-5 pair (submitted
    # LAST) must run before the deferred priority-0 pair
    order = [tuple(e["rids"]) for e in obs.recorder.events()
             if e["type"] == "dispatch"]
    flat = [rid for batch in order for rid in batch]
    assert flat.index(rids[6]) < flat.index(rids[4])
    assert flat.index(rids[7]) < flat.index(rids[5])
    # per-scene predictions are bit-identical to the FIFO control run
    for r_ref, r in zip(ref_rids, rids):
        assert out[r].ok and ref_out[r_ref].ok
        np.testing.assert_array_equal(np.asarray(out[r].preds),
                                      np.asarray(ref_out[r_ref].preds))


def test_controller_off_bit_identity(served):
    """overload=None serves bit-identically to overload=True for an
    in-capacity stream (the acceptance discipline the bench asserts on
    throughput; here on the predictions themselves)."""
    _, engine = served
    scenes = [_scene(200 + s, 50) for s in range(4)]
    outs = []
    for overload in (None, True):
        sched = ServeScheduler(engine, max_batch=2, overload=overload)
        rids = [sched.submit(*sc) for sc in scenes]
        sched.flush()
        out = sched.take(rids)
        sched.close()
        outs.append([np.asarray(out[r].preds) for r in rids])
    for a, b in zip(*outs):
        np.testing.assert_array_equal(a, b)


def test_scheduler_timeout_carries_retry_hint(served):
    _, engine = served
    sched = ServeScheduler(engine, max_batch=4, overload=True,
                           watchdog_s=0)
    rid = sched.submit(*_scene(300, 40), deadline_s=0.0)
    sched.flush()
    out = sched.take([rid])
    sched.close()
    assert out[rid].error.code == FLT.TIMEOUT
    assert out[rid].error.retry_after_s is not None
    assert out[rid].error.retry_after_s >= 0.0


def test_stats_surface_unified_backlog_names(served):
    _, engine = served
    sched = ServeScheduler(engine, max_batch=2, max_backlog=6)
    st = sched.stats()
    sched.close()
    assert st["scheduler_max_backlog"] == 6
    assert "scheduler_max_backlog" in MX.SCHEDULER_STATS_KEYS
    assert "router_max_backlog" in MX.ROUTER_STATS_KEYS


# ---------------------------------------------------------------------------
# the acceptance storm: conservation at 2x offered load
# ---------------------------------------------------------------------------

def test_storm_conservation_and_bit_identity(served):
    """Satellite: 3 producers at ~2x the storm-paced capacity.  Every
    submit is conserved across ok/rejected/shed/timeout/exec_failed,
    nothing exec-fails, sheds carry retry_after_s, every trace closes,
    and the surviving predictions are bit-identical to an unloaded
    control run of the same scenes."""
    _, engine = served
    n_producers, per_producer = 3, 12
    scenes = {(k, j): _scene(400 + k * per_producer + j, 40)
              for k in range(n_producers) for j in range(per_producer)}

    # control run: same scenes, no storm, no controller
    ref = ServeScheduler(engine, max_batch=2)
    ref_rids = {kj: ref.submit(*sc) for kj, sc in sorted(scenes.items())}
    ref.flush()
    ref_out = ref.take(list(ref_rids.values()))
    ref.close()

    # storm run: the fault plan paces bucket-64 dispatches to 30/s
    # (max_batch=2 -> ~60 scenes/s capacity) while the producers offer
    # ~2x that; the controller sheds the excess instead of queueing it
    plan = FaultPlan(storm_buckets={64: 30.0})
    obs = Observability.enabled()
    sched = ServeScheduler(
        engine, max_batch=2, pipeline_depth=2, max_backlog=8,
        max_wait_s=0.05, fault_plan=plan, obs=obs, instance="storm",
        overload=OverloadPolicy(slo=ServeSLO(deadline_headroom_s=0.2),
                                tick_s=0.02))
    rids: dict = {}
    lock = threading.Lock()
    errs: list = []

    def producer(k):
        try:
            for j in range(per_producer):
                rid = sched.submit(*scenes[(k, j)], deadline_s=1.0,
                                   priority=k)
                with lock:
                    rids[(k, j)] = rid
                # ~40 scenes/s per producer -> ~120/s offered vs 60/s
                time.sleep(0.025)
        except Exception as e:              # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=producer, args=(k,))
               for k in range(n_producers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    sched.flush()
    out = sched.take(list(rids.values()))
    st = sched.stats()
    sched.close()

    n_total = n_producers * per_producer
    ft = st["faults"]
    assert len(out) == n_total
    assert st["n_submitted"] == n_total
    assert st["n_completed"] == n_total
    assert st["n_submitted"] == (st["n_ok"] + ft["rejected"] + ft["shed"]
                                 + ft["timeout"] + ft["exec_failed"])
    assert ft["exec_failed"] == 0
    assert ft["shed"] >= 1                  # the overload bit
    shed_hints = [r.error.retry_after_s for r in out.values()
                  if r.error is not None and r.error.code == FLT.SHED]
    assert shed_hints and all(h is not None and h >= 0
                              for h in shed_hints)
    # every request trace closed (the controller trace closes in close())
    assert obs.tracer.stats()["live"] == 0
    # survivors are bit-identical to the unloaded control run
    n_checked = 0
    for kj, rid in rids.items():
        if out[rid].ok:
            np.testing.assert_array_equal(
                np.asarray(out[rid].preds),
                np.asarray(ref_out[ref_rids[kj]].preds))
            n_checked += 1
    assert n_checked == st["n_ok"] and n_checked >= 1


# ---------------------------------------------------------------------------
# router integration
# ---------------------------------------------------------------------------

def test_router_overload_wiring(served):
    params, _ = served
    factory = PointCloudEngine.factory(params, 2, flow="fod",
                                       ladder=geometric_ladder(64, 128))
    with pytest.raises(TypeError, match="overload="):
        ServeRouter(factory, 1, overload=OverloadController())
    router = ServeRouter(factory, 2, max_batch=2, max_backlog=4,
                         overload=True)
    try:
        # each worker scheduler built its own controller from the policy
        for w in router._workers.values():
            assert w.sched.overload is not None
            assert w.sched.overload.policy is router.overload
        assert set(router._breakers) == set(router._workers)
        rids = [router.submit(*_scene(500 + s, 40), priority=1)
                for s in range(4)]
        router.flush()
        out = router.take(rids)
        assert all(out[r].ok for r in rids)
        st = router.stats()
        assert st["router_max_backlog"] == 4
        assert st["max_backlog"] == 4       # legacy name kept
    finally:
        router.close()
