"""Smoke tests: every paper network runs a forward pass, correct shapes,
no NaNs, masked outputs zeroed."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import mapping as M
from repro.models import pointnets as PN
from repro.models import minkunet as MU
from tests.test_mapping import random_cloud


@pytest.fixture(scope="module")
def cloud():
    rng = np.random.default_rng(0)
    xyz = rng.normal(size=(2, 96, 3)).astype(np.float32)
    mask = np.ones((2, 96), bool)
    mask[1, 80:] = False
    return jnp.asarray(xyz), jnp.asarray(mask)


def _check(x, shape):
    assert x.shape == shape
    assert not np.any(np.isnan(np.asarray(x)))


def test_pointnet(cloud):
    xyz, mask = cloud
    p = PN.pointnet_init(jax.random.key(0), n_classes=40)
    _check(PN.pointnet_apply(p, xyz, mask), (2, 40))


def test_pointnetpp_cls(cloud):
    xyz, mask = cloud
    p = PN.pointnetpp_cls_init(jax.random.key(1), n_classes=40)
    _check(PN.pointnetpp_cls_apply(p, xyz, mask, n1=32, n2=8), (2, 40))


def test_pointnetpp_seg(cloud):
    xyz, mask = cloud
    p = PN.pointnetpp_seg_init(jax.random.key(2), n_classes=13)
    out = PN.pointnetpp_seg_apply(p, xyz, mask, n1=32, n2=8)
    _check(out, (2, 96, 13))
    assert np.all(np.asarray(out)[1, 80:] == 0)


def test_dgcnn(cloud):
    xyz, mask = cloud
    p = PN.dgcnn_init(jax.random.key(3), n_classes=16)
    _check(PN.dgcnn_apply(p, xyz, mask, k=8), (2, 16))


def test_fpointnetpp(cloud):
    xyz, mask = cloud
    p = PN.fpointnetpp_init(jax.random.key(4))
    out = PN.fpointnetpp_apply(p, xyz, mask)
    _check(out["seg"], (2, 96, 2))
    _check(out["center"], (2, 3))
    _check(out["box"], (2, 7))


@pytest.mark.parametrize("flow", ["fod", "gms"])
def test_minkunet(flow):
    rng = np.random.default_rng(5)
    coords, mask = random_cloud(rng, 120, 160, grid=16)
    feats = jnp.asarray(rng.normal(size=(160, 4)).astype(np.float32))
    feats = feats * jnp.asarray(mask)[:, None]
    pc = M.make_point_cloud(jnp.asarray(coords), jnp.asarray(mask))
    p = MU.minkunet_init(jax.random.key(6), c_in=4, n_classes=13,
                         stem=8, enc_planes=(8, 16), dec_planes=(16, 8),
                         blocks_per_stage=1)
    out = MU.minkunet_apply(p, pc, feats, flow=flow)
    assert out.shape == (160, 13)
    assert not np.any(np.isnan(np.asarray(out)))
    assert np.all(np.asarray(out)[~mask] == 0)


def _jaxprs_in(value):
    if hasattr(value, "jaxpr"):                    # ClosedJaxpr
        return [value.jaxpr]
    if hasattr(value, "eqns"):                     # Jaxpr
        return [value]
    if isinstance(value, (list, tuple)):
        return [j for v in value for j in _jaxprs_in(v)]
    return []


def _count_sort_eqns(jaxpr):
    total = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "sort":
            total += 1
        for v in eqn.params.values():
            total += sum(_count_sort_eqns(j) for j in _jaxprs_in(v))
    return total


def test_unet_maps_one_sort_per_level():
    """Acceptance: the packed-key engine ranks each stride level exactly
    once — n_stages+1 `lax.sort` calls for the whole network, versus one
    (plus a compaction sort) per kernel offset per conv in v1."""
    rng = np.random.default_rng(9)
    coords, mask = random_cloud(rng, 100, 128, grid=16)
    n_stages = 2

    def build(c, m):
        levels = MU.build_unet_maps(M.PointCloud(c, m, 1), n_stages)
        return [(l["pc"].coords, l["subm"].in_idx,
                 l.get("down", l["subm"]).in_idx) for l in levels]

    jaxpr = jax.make_jaxpr(build)(jnp.asarray(coords), jnp.asarray(mask))
    n_sorts = _count_sort_eqns(jaxpr.jaxpr)
    assert n_sorts == n_stages + 1, n_sorts

    def build_v1(c, m):
        levels = MU.build_unet_maps(M.PointCloud(c, m, 1), n_stages,
                                    engine="v1")
        return [(l["pc"].coords, l["subm"].in_idx,
                 l.get("down", l["subm"]).in_idx) for l in levels]

    jaxpr1 = jax.make_jaxpr(build_v1)(jnp.asarray(coords), jnp.asarray(mask))
    assert _count_sort_eqns(jaxpr1.jaxpr) > 3 * n_sorts


def test_minkunet_engines_agree():
    """Forward pass is identical whichever mapping engine built the maps."""
    rng = np.random.default_rng(10)
    coords, mask = random_cloud(rng, 60, 96, grid=12)
    feats = jnp.asarray(rng.normal(size=(96, 4)).astype(np.float32))
    feats = feats * jnp.asarray(mask)[:, None]
    pc = M.make_point_cloud(jnp.asarray(coords), jnp.asarray(mask))
    p = MU.mini_minkunet_init(jax.random.key(11))
    lv2 = MU.build_unet_maps(pc, 2)
    lv1 = MU.build_unet_maps(pc, 2, engine="v1")
    a = MU.minkunet_apply(p, pc, feats, levels=lv2)
    b = MU.minkunet_apply(p, pc, feats, levels=lv1)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-3, atol=2e-3)


def test_minkunet_flows_identical():
    rng = np.random.default_rng(7)
    coords, mask = random_cloud(rng, 60, 96, grid=12)
    feats = jnp.asarray(rng.normal(size=(96, 4)).astype(np.float32))
    feats = feats * jnp.asarray(mask)[:, None]
    pc = M.make_point_cloud(jnp.asarray(coords), jnp.asarray(mask))
    p = MU.mini_minkunet_init(jax.random.key(8))
    a = MU.minkunet_apply(p, pc, feats, flow="fod")
    b = MU.minkunet_apply(p, pc, feats, flow="gms")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-3, atol=2e-3)
