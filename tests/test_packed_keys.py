"""Packed-key engine unit tests: bit budget, saturation, ordering, search.

The v2 ranking engine's correctness rests on three properties of
repro.core.packed:

  1. pack/unpack is a bijection on the in-budget coordinate box;
  2. anything outside the budget (or masked) saturates to the sentinel key
     and can NEVER alias a valid key;
  3. (hi, lo) pair order == lexicographic (batch, x, y, z) coordinate order,
     so every sorted structure matches the v1 lexicographic engine bit for
     bit.
"""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import mapping as M
from repro.core import packed as PK


def pack_np(coords, mask):
    hi, lo = PK.pack_coords(jnp.asarray(coords), jnp.asarray(mask))
    return np.asarray(hi), np.asarray(lo)


# ---------------------------------------------------------------------------
# roundtrip + budget edges
# ---------------------------------------------------------------------------

def test_roundtrip_random_including_negative():
    rng = np.random.default_rng(0)
    coords = np.stack([
        rng.integers(0, PK.BATCH_MAX + 1, 512),
        rng.integers(PK.COORD_MIN, PK.COORD_MAX + 1, 512),
        rng.integers(PK.COORD_MIN, PK.COORD_MAX + 1, 512),
        rng.integers(PK.COORD_MIN, PK.COORD_MAX + 1, 512),
    ], axis=1).astype(np.int32)
    mask = np.ones(512, bool)
    hi, lo = pack_np(coords, mask)
    back = np.asarray(PK.unpack_keys(jnp.asarray(hi), jnp.asarray(lo)))
    np.testing.assert_array_equal(back, coords)


def test_roundtrip_budget_corners():
    corners = np.array([
        [0, PK.COORD_MIN, PK.COORD_MIN, PK.COORD_MIN],
        [0, PK.COORD_MAX, PK.COORD_MAX, PK.COORD_MAX],
        [PK.BATCH_MAX, PK.COORD_MAX, PK.COORD_MIN, PK.COORD_MAX],
        [PK.BATCH_MAX, 0, 0, 0],
    ], np.int32)
    hi, lo = pack_np(corners, np.ones(4, bool))
    assert not np.any(hi == PK.KEY_HI_SENTINEL)
    back = np.asarray(PK.unpack_keys(jnp.asarray(hi), jnp.asarray(lo)))
    np.testing.assert_array_equal(back, corners)


@pytest.mark.parametrize("bad", [
    [0, PK.COORD_MAX + 1, 0, 0],          # +x overflow
    [0, 0, PK.COORD_MIN - 1, 0],          # -y overflow
    [0, 0, 0, PK.COORD_MAX + 1],          # +z overflow
    [PK.BATCH_MAX + 1, 0, 0, 0],          # batch overflow
    [-1, 0, 0, 0],                        # negative batch
    [0, 2**29, -2**29, 5],                # far out of budget
    [int(M.SENTINEL), int(M.SENTINEL), int(M.SENTINEL), int(M.SENTINEL)],
])
def test_overflow_saturates_to_sentinel_never_aliases(bad):
    coords = np.array([bad], np.int32)
    hi, lo = pack_np(coords, np.ones(1, bool))
    assert hi[0] == PK.KEY_HI_SENTINEL and lo[0] == PK.KEY_LO_SENTINEL
    # sentinel unpacks to the masked-row convention, not to a coordinate
    back = np.asarray(PK.unpack_keys(jnp.asarray(hi), jnp.asarray(lo)))
    assert np.all(back == M.SENTINEL)


def test_masked_rows_saturate():
    coords = np.zeros((4, 4), np.int32)
    mask = np.array([True, False, True, False])
    hi, _ = pack_np(coords, mask)
    np.testing.assert_array_equal(hi == PK.KEY_HI_SENTINEL, ~mask)


def test_valid_keys_cannot_reach_sentinel():
    """Max valid hi is (BATCH_MAX<<16)|0xFFFF = 2^30-1 < KEY_HI_SENTINEL:
    the sentinel is outside the image of pack on the valid box."""
    top = np.array([[PK.BATCH_MAX, PK.COORD_MAX, PK.COORD_MAX,
                     PK.COORD_MAX]], np.int32)
    hi, lo = pack_np(top, np.ones(1, bool))
    assert hi[0] == 2**30 - 1
    assert hi[0] < PK.KEY_HI_SENTINEL


# ---------------------------------------------------------------------------
# ordering: packed-pair order == lexicographic coordinate order
# ---------------------------------------------------------------------------

def test_pair_order_matches_lexsort():
    rng = np.random.default_rng(1)
    coords = np.stack([
        rng.integers(0, 4, 256),
        rng.integers(-200, 200, 256),
        rng.integers(-200, 200, 256),
        rng.integers(-200, 200, 256),
    ], axis=1).astype(np.int32)
    hi, lo = pack_np(coords, np.ones(256, bool))
    # numpy lexsort keys are last-significant-first
    lex = np.lexsort((coords[:, 3], coords[:, 2], coords[:, 1],
                      coords[:, 0]))
    pair = np.lexsort((lo, hi))
    np.testing.assert_array_equal(
        coords[lex], coords[pair])


def test_sort_cloud_sorts_and_permutes():
    rng = np.random.default_rng(2)
    coords = np.concatenate([
        rng.integers(0, 2, (64, 1)), rng.integers(-30, 30, (64, 3))],
        axis=1).astype(np.int32)
    mask = rng.random(64) < 0.8
    pc = M.make_point_cloud(jnp.asarray(coords), jnp.asarray(mask))
    sc = M.sort_cloud(pc)
    hi, lo = PK.pack_coords(pc.coords, pc.mask)
    hi, lo = np.asarray(hi), np.asarray(lo)
    perm = np.asarray(sc.perm)
    np.testing.assert_array_equal(np.asarray(sc.sorted_hi), hi[perm])
    np.testing.assert_array_equal(np.asarray(sc.sorted_lo), lo[perm])
    # ascending pair order, sentinels last
    s_hi, s_lo = np.asarray(sc.sorted_hi), np.asarray(sc.sorted_lo)
    key = s_hi.astype(np.int64) * 2**32 + s_lo.astype(np.int64)
    assert np.all(np.diff(key) >= 0)


# ---------------------------------------------------------------------------
# quantization in the key domain
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stride", [2, 4, 8, 32])
def test_quantize_keys_matches_quantize_coords(stride):
    rng = np.random.default_rng(3)
    coords = np.stack([
        rng.integers(0, 3, 128),
        rng.integers(-500, 500, 128),
        rng.integers(-500, 500, 128),
        rng.integers(-500, 500, 128),
    ], axis=1).astype(np.int32)
    hi, lo = pack_np(coords, np.ones(128, bool))
    qhi, qlo = PK.quantize_keys(jnp.asarray(hi), jnp.asarray(lo), stride)
    expect_hi, expect_lo = pack_np(
        np.asarray(M.quantize_coords(jnp.asarray(coords), stride)),
        np.ones(128, bool))
    np.testing.assert_array_equal(np.asarray(qhi), expect_hi)
    np.testing.assert_array_equal(np.asarray(qlo), expect_lo)


def test_quantize_keys_preserves_sentinel():
    hi = jnp.asarray(np.array([PK.KEY_HI_SENTINEL], np.int32))
    lo = jnp.asarray(np.array([PK.KEY_LO_SENTINEL], np.uint32))
    qhi, qlo = PK.quantize_keys(hi, lo, 4)
    assert int(qhi[0]) == int(PK.KEY_HI_SENTINEL)
    assert int(qlo[0]) == int(PK.KEY_LO_SENTINEL)


# ---------------------------------------------------------------------------
# binary search
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,nq", [(1, 16), (7, 64), (256, 300), (1000, 50)])
def test_searchsorted_pair_matches_numpy(n, nq):
    rng = np.random.default_rng(4)
    hi = np.sort(rng.integers(0, 50, n)).astype(np.int32)
    lo = rng.integers(0, 2**32, n, dtype=np.uint64).astype(np.uint32)
    # sort lo within equal hi groups to get ascending pairs
    order = np.lexsort((lo, hi))
    hi, lo = hi[order], lo[order]
    q_hi = rng.integers(0, 50, nq).astype(np.int32)
    q_lo = rng.integers(0, 2**32, nq, dtype=np.uint64).astype(np.uint32)
    got = np.asarray(PK.searchsorted_pair(
        jnp.asarray(hi), jnp.asarray(lo), jnp.asarray(q_hi),
        jnp.asarray(q_lo)))
    key = hi.astype(np.uint64) * 2**32 + lo.astype(np.uint64)
    qkey = q_hi.astype(np.uint64) * 2**32 + q_lo.astype(np.uint64)
    expect = np.searchsorted(key, qkey, side="left")
    np.testing.assert_array_equal(got, expect)
