"""Mapping Unit tests: ranking-based ops vs brute-force oracles."""

import numpy as np
import pytest
import jax.numpy as jnp
from tests.hypothesis_compat import given, settings, st

from repro.core import mapping as M
from repro.core import pointops as P


def random_cloud(rng, n_valid, cap, grid=16, batches=2, d=3):
    """Unique random integer coords with batch column, sentinel padded."""
    seen = set()
    pts = []
    while len(pts) < n_valid:
        c = (rng.integers(0, batches),) + tuple(
            int(x) for x in rng.integers(0, grid, size=d))
        if c not in seen:
            seen.add(c)
            pts.append(c)
    coords = np.full((cap, 1 + d), M.SENTINEL, np.int32)
    coords[:n_valid] = np.array(pts, np.int32)
    mask = np.zeros(cap, bool)
    mask[:n_valid] = True
    # shuffle so valid entries are not contiguous
    perm = rng.permutation(cap)
    return coords[perm], mask[perm]


def oracle_kernel_map(coords, mask, out_coords, out_mask, offsets):
    """dict-based (hash-table) reference: the implementation PointAcc
    replaces.  For output q and offset d, input p = q + d."""
    table = {tuple(c): i for i, c in enumerate(coords) if mask[i]}
    per_offset = []
    for d in offsets:
        pairs = set()
        for j, q in enumerate(out_coords):
            if not out_mask[j]:
                continue
            p = (q[0],) + tuple(q[1:] + d)
            if p in table:
                pairs.add((table[p], j))
        per_offset.append(pairs)
    return per_offset


def maps_to_sets(maps):
    k = maps.in_idx.shape[0]
    out = []
    for i in range(k):
        v = np.asarray(maps.valid[i])
        out.append(set(zip(np.asarray(maps.in_idx[i])[v].tolist(),
                           np.asarray(maps.out_idx[i])[v].tolist())))
    return out


# ---------------------------------------------------------------------------
# quantization
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stride", [2, 4, 8])
def test_quantize_matches_floor(stride):
    rng = np.random.default_rng(0)
    coords = np.concatenate(
        [rng.integers(0, 2, (64, 1)),
         rng.integers(-64, 64, (64, 3))], axis=1).astype(np.int32)
    q = np.asarray(M.quantize_coords(jnp.asarray(coords), stride))
    expect = np.floor(coords[:, 1:] / stride).astype(np.int64) * stride
    np.testing.assert_array_equal(q[:, 1:], expect)
    np.testing.assert_array_equal(q[:, 0], coords[:, 0])


def test_quantize_idempotent():
    rng = np.random.default_rng(1)
    coords = np.concatenate(
        [np.zeros((32, 1), np.int32),
         rng.integers(-32, 32, (32, 3)).astype(np.int32)], axis=1)
    q1 = M.quantize_coords(jnp.asarray(coords), 4)
    q2 = M.quantize_coords(q1, 4)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))


# ---------------------------------------------------------------------------
# unique / downsample (output cloud construction)
# ---------------------------------------------------------------------------

def test_unique_coords_matches_numpy():
    rng = np.random.default_rng(2)
    coords, mask = random_cloud(rng, 40, 64, grid=4)  # small grid -> dupes
    coords = np.array(M.quantize_coords(jnp.asarray(coords), 2))
    coords[~mask] = M.SENTINEL
    got_c, got_m = M.unique_coords(jnp.asarray(coords), jnp.asarray(mask))
    got = set(map(tuple, np.asarray(got_c)[np.asarray(got_m)].tolist()))
    expect = set(map(tuple, coords[mask].tolist()))
    assert got == expect
    # compacted: valid entries at the front
    gm = np.asarray(got_m)
    assert not np.any(gm[np.argmin(gm):]) or gm.all()


def test_downsample_halves_resolution():
    rng = np.random.default_rng(3)
    coords, mask = random_cloud(rng, 50, 64, grid=8)
    pc = M.make_point_cloud(jnp.asarray(coords), jnp.asarray(mask), stride=1)
    down = M.downsample(pc, 2)
    assert down.stride == 2
    dc = np.asarray(down.coords)[np.asarray(down.mask)]
    assert np.all(dc[:, 1:] % 2 == 0)
    expect = {tuple([c[0]] + [(x // 2) * 2 for x in c[1:]])
              for c in coords[mask].tolist()}
    assert set(map(tuple, dc.tolist())) == expect


# ---------------------------------------------------------------------------
# kernel mapping: sort-merge intersection vs hash oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kernel_size,stride", [(3, 1), (2, 2), (3, 2)])
def test_kernel_map_vs_oracle(kernel_size, stride):
    rng = np.random.default_rng(4)
    coords, mask = random_cloud(rng, 60, 96, grid=10)
    pc = M.make_point_cloud(jnp.asarray(coords), jnp.asarray(mask), stride=1)
    maps, out_pc = M.build_conv_maps(pc, kernel_size, stride)
    oc, om = np.asarray(out_pc.coords), np.asarray(out_pc.mask)
    expect = oracle_kernel_map(np.asarray(pc.coords), np.asarray(pc.mask),
                               oc, om, maps.offsets)
    got = maps_to_sets(maps)
    for k in range(len(expect)):
        assert got[k] == expect[k], f"offset {maps.offsets[k]}"


def test_kernel_map_submanifold_center_identity():
    """stride-1 center offset must map every valid point to itself."""
    rng = np.random.default_rng(5)
    coords, mask = random_cloud(rng, 30, 48)
    pc = M.make_point_cloud(jnp.asarray(coords), jnp.asarray(mask))
    maps, out_pc = M.build_conv_maps(pc, 3, 1)
    center = int(np.where((maps.offsets == 0).all(1))[0][0])
    got = maps_to_sets(maps)[center]
    assert got == {(i, i) for i in range(48) if mask[i]}


@settings(max_examples=25, deadline=None)
@given(n=st.integers(5, 40), grid=st.integers(3, 12), seed=st.integers(0, 99))
def test_kernel_map_property(n, grid, seed):
    """Property: sort-merge intersection == hash oracle on random clouds."""
    rng = np.random.default_rng(seed)
    cap = n + rng.integers(0, 8)
    coords, mask = random_cloud(rng, n, cap, grid=grid)
    pc = M.make_point_cloud(jnp.asarray(coords), jnp.asarray(mask))
    maps, out_pc = M.build_conv_maps(pc, 3, 1)
    expect = oracle_kernel_map(np.asarray(pc.coords), np.asarray(pc.mask),
                               np.asarray(out_pc.coords),
                               np.asarray(out_pc.mask), maps.offsets)
    got = maps_to_sets(maps)
    assert all(g == e for g, e in zip(got, expect))


# ---------------------------------------------------------------------------
# v2 packed-key engine vs v1 lexicographic engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kernel_size,stride", [(3, 1), (2, 2), (3, 2)])
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_engines_agree(kernel_size, stride, seed):
    """v2 kernel_map must equal v1 up to per-offset ordering, and produce
    bit-identical output clouds, on randomized (shuffled, masked) clouds."""
    rng = np.random.default_rng(100 + seed)
    n = int(rng.integers(20, 90))
    coords, mask = random_cloud(rng, n, n + int(rng.integers(0, 16)),
                                grid=int(rng.integers(4, 14)))
    if seed % 2:
        coords[mask.nonzero()[0], 1:] -= 17          # negative coords too
    pc = M.make_point_cloud(jnp.asarray(coords), jnp.asarray(mask))
    m1, o1 = M.build_conv_maps(pc, kernel_size, stride, engine="v1")
    m2, o2 = M.build_conv_maps(pc, kernel_size, stride, engine="v2")
    np.testing.assert_array_equal(np.asarray(o1.coords),
                                  np.asarray(o2.coords))
    np.testing.assert_array_equal(np.asarray(o1.mask), np.asarray(o2.mask))
    assert o1.stride == o2.stride
    for k, (s1, s2) in enumerate(zip(maps_to_sets(m1), maps_to_sets(m2))):
        assert s1 == s2, f"offset {m1.offsets[k]}: {s1 ^ s2}"


def test_v2_inverse_table_matches_v1_scatter():
    """The v2 engine's free inverse table == scatter-inverting the v1 maps."""
    from repro.kernels.spconv import ops as spconv_ops
    rng = np.random.default_rng(11)
    coords, mask = random_cloud(rng, 70, 96, grid=10)
    pc = M.make_point_cloud(jnp.asarray(coords), jnp.asarray(mask))
    for ks, stride in [(3, 1), (2, 2)]:
        m1, o1 = M.build_conv_maps(pc, ks, stride, engine="v1")
        m2, _ = M.build_conv_maps(pc, ks, stride, engine="v2")
        assert m2.inv is not None
        np.testing.assert_array_equal(
            np.asarray(spconv_ops.invert_maps(m1, o1.capacity)),
            np.asarray(m2.inv))
        # swapped strided maps carry the transposed inverse table (search-
        # built, scatter-free) and it matches scatter-inverting the swapped
        # v1 map lists; submanifold maps still fall back to the scatter
        if stride > 1:
            sw = m2.swap()
            assert sw.inv is not None
            np.testing.assert_array_equal(
                np.asarray(spconv_ops.invert_maps(m1.swap(), pc.capacity)),
                np.asarray(sw.inv))
        else:
            assert m2.swap().inv is None


def test_downsample_sorted_matches_downsample():
    rng = np.random.default_rng(12)
    coords, mask = random_cloud(rng, 60, 80, grid=8)
    pc = M.make_point_cloud(jnp.asarray(coords), jnp.asarray(mask))
    ref = M.downsample(pc, 2)
    got = M.downsample_sorted(M.sort_cloud(pc), 2)
    np.testing.assert_array_equal(np.asarray(ref.coords),
                                  np.asarray(got.pc.coords))
    np.testing.assert_array_equal(np.asarray(ref.mask),
                                  np.asarray(got.pc.mask))
    assert got.pc.stride == ref.stride
    # the downsampled SortedCloud is identity-permuted (already sorted)
    np.testing.assert_array_equal(np.asarray(got.perm), np.arange(80))


def test_kernel_map_v2_explicit_small_cap_compacts():
    rng = np.random.default_rng(13)
    coords, mask = random_cloud(rng, 40, 64, grid=6)
    pc = M.make_point_cloud(jnp.asarray(coords), jnp.asarray(mask))
    full, _ = M.build_conv_maps(pc, 3, 1, engine="v2")
    small, _ = M.build_conv_maps(pc, 3, 1, cap=50, engine="v2")
    assert small.in_idx.shape == (27, 50)
    for sf, ss in zip(maps_to_sets(full), maps_to_sets(small)):
        assert ss <= sf
        # nothing lost when matches fit in cap (40 valid points max)
        assert len(ss) == len(sf)


def test_kernel_map_v2_small_cap_drops_inv():
    """A cap below out-capacity may truncate matches; the inverse table
    must be dropped so the pallas flow can't see matches gms/fod lost."""
    rng = np.random.default_rng(15)
    coords, mask = random_cloud(rng, 40, 64, grid=6)
    pc = M.make_point_cloud(jnp.asarray(coords), jnp.asarray(mask))
    small, _ = M.build_conv_maps(pc, 3, 1, cap=5, engine="v2")
    assert small.inv is None
    full, _ = M.build_conv_maps(pc, 3, 1, engine="v2")
    assert full.inv is not None


def test_v2_out_of_budget_raises_eagerly():
    coords = np.array([[0, 40000, 0, 0], [0, 1, 1, 1]], np.int32)
    pc = M.make_point_cloud(jnp.asarray(coords),
                            jnp.asarray(np.ones(2, bool)))
    with pytest.raises(ValueError, match="packed-key budget"):
        M.build_conv_maps(pc, 3, 1, engine="v2")
    # v1 handles the same cloud
    maps, _ = M.build_conv_maps(pc, 3, 1, engine="v1")
    assert int(np.sum(np.asarray(maps.valid))) >= 2


def test_explicit_v2_raises_for_non_3d_default_falls_back():
    coords = np.array([[0, 1, 2], [0, 3, 4]], np.int32)   # 2 spatial dims
    pc = M.make_point_cloud(jnp.asarray(coords),
                            jnp.asarray(np.ones(2, bool)))
    maps, _ = M.build_conv_maps(pc, 3, 1)                 # default: v1 path
    assert int(np.sum(np.asarray(maps.valid))) == 2
    with pytest.raises(ValueError, match="3 spatial dims"):
        M.build_conv_maps(pc, 3, 1, engine="v2")


def test_build_conv_maps_reuses_cache():
    """A supplied SortedCloud cache must produce the same maps as a fresh
    sort (it IS the same computation, skipped)."""
    rng = np.random.default_rng(14)
    coords, mask = random_cloud(rng, 50, 64, grid=8)
    pc = M.make_point_cloud(jnp.asarray(coords), jnp.asarray(mask))
    sc = M.sort_cloud(pc)
    fresh, _ = M.build_conv_maps(pc, 3, 1)
    cached, _ = M.build_conv_maps(pc, 3, 1, cache=sc)
    np.testing.assert_array_equal(np.asarray(fresh.in_idx),
                                  np.asarray(cached.in_idx))
    np.testing.assert_array_equal(np.asarray(fresh.valid),
                                  np.asarray(cached.valid))


def test_swap_roundtrip():
    rng = np.random.default_rng(6)
    coords, mask = random_cloud(rng, 20, 32)
    pc = M.make_point_cloud(jnp.asarray(coords), jnp.asarray(mask))
    maps, _ = M.build_conv_maps(pc, 2, 2)
    rt = maps.swap().swap()
    np.testing.assert_array_equal(np.asarray(rt.in_idx),
                                  np.asarray(maps.in_idx))
    np.testing.assert_array_equal(rt.offsets, maps.offsets)


# ---------------------------------------------------------------------------
# FPS / kNN / ball query vs numpy oracles
# ---------------------------------------------------------------------------

def test_fps_matches_oracle():
    rng = np.random.default_rng(7)
    xyz = rng.normal(size=(2, 64, 3)).astype(np.float32)
    mask = np.ones((2, 64), bool)
    mask[1, 50:] = False
    got = np.asarray(P.farthest_point_sampling(
        jnp.asarray(xyz), jnp.asarray(mask), 8))

    for b in range(2):
        sel = [int(np.argmax(mask[b]))]
        mind = np.where(mask[b], np.inf, -np.inf)
        for _ in range(7):
            d = ((xyz[b] - xyz[b, sel[-1]]) ** 2).sum(-1)
            d = np.where(mask[b], d, -np.inf)
            mind = np.minimum(mind, d)
            sel.append(int(np.argmax(mind)))
        assert got[b].tolist() == sel


def test_fps_selects_distinct_valid_points():
    rng = np.random.default_rng(8)
    xyz = rng.normal(size=(1, 128, 3)).astype(np.float32)
    mask = np.ones((1, 128), bool)
    mask[0, 100:] = False
    got = np.asarray(P.farthest_point_sampling(
        jnp.asarray(xyz), jnp.asarray(mask), 16))[0]
    assert len(set(got.tolist())) == 16
    assert np.all(got < 100)


@pytest.mark.parametrize("k,chunk", [(4, 1024), (8, 16)])
def test_knn_matches_argsort(k, chunk):
    rng = np.random.default_rng(9)
    q = rng.normal(size=(2, 33, 3)).astype(np.float32)
    r = rng.normal(size=(2, 57, 3)).astype(np.float32)
    qm = np.ones((2, 33), bool)
    rm = np.ones((2, 57), bool)
    rm[0, 40:] = False
    idx, dist = P.knn(jnp.asarray(q), jnp.asarray(qm), jnp.asarray(r),
                      jnp.asarray(rm), k, chunk=chunk)
    idx, dist = np.asarray(idx), np.asarray(dist)
    for b in range(2):
        d = ((q[b][:, None] - r[b][None]) ** 2).sum(-1)
        d[:, ~rm[b]] = 1e10
        expect = np.sort(d, axis=1)[:, :k]
        np.testing.assert_allclose(np.sort(dist[b], axis=1), expect,
                                   rtol=1e-4, atol=1e-4)
        # indices must point at the same distances
        np.testing.assert_allclose(
            np.take_along_axis(d, idx[b], axis=1), dist[b],
            rtol=1e-4, atol=1e-4)


def test_ball_query_radius_and_padding():
    rng = np.random.default_rng(10)
    q = rng.uniform(-1, 1, size=(1, 16, 3)).astype(np.float32)
    r = rng.uniform(-1, 1, size=(1, 64, 3)).astype(np.float32)
    ones_q, ones_r = np.ones((1, 16), bool), np.ones((1, 64), bool)
    radius = 0.5
    idx, valid = P.ball_query(jnp.asarray(q), jnp.asarray(ones_q),
                              jnp.asarray(r), jnp.asarray(ones_r),
                              radius, 8)
    idx, valid = np.asarray(idx), np.asarray(valid)
    d = ((q[0][:, None] - r[0][None]) ** 2).sum(-1)
    for m in range(16):
        inball = idx[0, m][valid[0, m]]
        if len(inball):
            assert np.all(d[m, inball] <= radius ** 2 + 1e-5)
        # padded slots replicate the first neighbour
        if valid[0, m, 0]:
            pad = idx[0, m][~valid[0, m]]
            assert np.all(pad == idx[0, m, 0]) or pad.size == 0
