"""Optional-hypothesis shim: property tests degrade to skips when absent.

`hypothesis` is a dev-only dependency (requirements-dev.txt).  Importing it
at module scope used to error the whole tier-1 collection on machines
without it; importing from this shim instead keeps example-based tests
running and turns @given property tests into explicit skips.
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """strategies.* stand-in: every attribute is a no-op factory."""

        def __getattr__(self, _name):
            def _strategy(*args, **kwargs):
                return None
            return _strategy

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def placeholder():
                pass
            placeholder.__name__ = fn.__name__
            placeholder.__doc__ = fn.__doc__
            return placeholder
        return deco
