"""Fused-epilogue + streamed-feature-tile Pallas FoD conv: parity vs the
unfused flows, the swapped-maps (transposed) path, streaming for clouds
larger than one feature tile, channel/row padding, and the planner."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import fusion as F
from repro.core import mapping as M
from repro.core import sparseconv as SC
from repro.kernels.spconv import ops as spops
from repro.kernels.spconv.ref import spconv_fod_fused_ref, spconv_fod_ref
from repro.kernels.spconv.spconv import (spconv_fod_fused_pallas,
                                         spconv_fod_pallas)
from repro.models import minkunet as MU
from tests.test_mapping import random_cloud

TOL = dict(rtol=1e-4, atol=1e-4)


def _rand_problem(rng, n, m, cin, cout, k, monotone=False):
    feats = jnp.asarray(rng.normal(size=(n, cin)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(k, cin, cout)).astype(np.float32) * 0.2)
    inv = rng.integers(-1, n, size=(k, m)).astype(np.int32)
    if monotone:
        inv = np.sort(inv, axis=1)
    return feats, jnp.asarray(inv), w


def _fused(feats, inv, w, feat_tile, out_tile=64, **epi):
    n = feats.shape[0]
    wmap, nwin = spops.window_schedule(inv, n, out_tile, feat_tile)
    return spconv_fod_fused_pallas(feats, inv, w, wmap, nwin,
                                   feat_tile=feat_tile, out_tile=out_tile,
                                   interpret=True, **epi)


@pytest.mark.parametrize("feat_tile", [256, 64, 32])
@pytest.mark.parametrize("monotone", [True, False])
def test_fused_kernel_streams_any_window_size(feat_tile, monotone):
    """Correctness must not depend on map ordering: every referenced window
    is visited, each row counted exactly once — including clouds many times
    larger than one feature tile."""
    rng = np.random.default_rng(0)
    feats, inv, w = _rand_problem(rng, 256, 128, 16, 32, 9,
                                  monotone=monotone)
    out = _fused(feats, inv, w, feat_tile)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(spconv_fod_ref(feats, inv, w)),
                               **TOL)


def test_fused_kernel_epilogue_vs_unfused_flows():
    """Full epilogue (bias+LN+residual+ReLU+mask) in the kernel flush ==
    the XLA epilogue applied to the fod/gms flow outputs."""
    rng = np.random.default_rng(1)
    n, m, cin, cout, k = 192, 128, 8, 16, 27
    feats, inv, w = _rand_problem(rng, n, m, cin, cout, k)
    bias = jnp.asarray(rng.normal(size=(cout,)).astype(np.float32))
    ln_s = jnp.asarray(rng.normal(size=(cout,)).astype(np.float32))
    ln_b = jnp.asarray(rng.normal(size=(cout,)).astype(np.float32))
    res = jnp.asarray(rng.normal(size=(m, cout)).astype(np.float32))
    mask = jnp.asarray(rng.integers(0, 2, size=(m,)).astype(np.float32))
    epi = SC.Epilogue(bias=bias, ln_scale=ln_s, ln_bias=ln_b, relu=True,
                      mask=mask, residual=res)
    out = _fused(feats, inv, w, feat_tile=64, bias=bias, ln_scale=ln_s,
                 ln_bias=ln_b, residual=res, mask=mask, relu=True)
    ref = spconv_fod_fused_ref(feats, inv, w, epi)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_fused_wrapper_pads_odd_shapes():
    """Odd cin with explicit cin_tile, odd m, odd n: the ops wrapper pads
    them all; results match the reference on the unpadded problem."""
    rng = np.random.default_rng(2)
    coords, mask = random_cloud(rng, 70, 90, grid=10)
    pc = M.make_point_cloud(jnp.asarray(coords), jnp.asarray(mask))
    feats = jnp.asarray(rng.normal(size=(90, 5)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(27, 5, 7)).astype(np.float32))
    maps, out_pc = M.build_conv_maps(pc, 3, 1)
    out = spops.sparse_conv_fused(feats, maps, w, out_pc.capacity,
                                  feat_tile=32, out_tile=16, cin_tile=4)
    ref = spops.sparse_conv_fod_ref(feats, maps, w, out_pc.capacity)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_tile_mismatch_raises_informative_errors():
    rng = np.random.default_rng(3)
    feats, inv, w = _rand_problem(rng, 64, 64, 6, 8, 8)
    with pytest.raises(ValueError, match="cin_tile"):
        spconv_fod_pallas(feats, inv, w, out_tile=32, cin_tile=4,
                          interpret=True)
    with pytest.raises(ValueError, match="out_tile"):
        spconv_fod_pallas(feats, inv, w, out_tile=48, interpret=True)
    wmap, nwin = spops.window_schedule(inv, 64, 32, 32)
    with pytest.raises(ValueError, match="feat_tile"):
        spconv_fod_fused_pallas(feats, inv, w, wmap, nwin, feat_tile=48,
                                out_tile=32, interpret=True)
    with pytest.raises(ValueError, match="ln_scale"):
        spops.sparse_conv_fused(feats, M.KernelMaps(inv, inv, inv >= 0,
                                                    np.zeros((8, 3))), w, 64,
                                epilogue=SC.Epilogue(ln_scale=w[0, 0]))


def test_swapped_maps_carry_inverse_table():
    """Strided v2 maps expose a scatter-free inverse for the transposed
    direction: swap() promotes inv_t, and it equals the scatter-built one."""
    rng = np.random.default_rng(4)
    coords, mask = random_cloud(rng, 100, 128, grid=12)
    pc = M.make_point_cloud(jnp.asarray(coords), jnp.asarray(mask))
    down, out_sc = M.build_conv_maps_cached(M.sort_cloud(pc), 2, 2)
    sw = down.swap()
    assert sw.inv is not None
    scatter = spops.invert_maps(sw._replace(inv=None), pc.capacity)
    assert bool(jnp.all(sw.inv == scatter))


def test_transposed_conv_pallas_fused_matches_fod():
    """Decoder path: transposed conv through the fused kernel on the swapped
    inverse table == the XLA fod flow on the swapped map lists."""
    rng = np.random.default_rng(5)
    coords, mask = random_cloud(rng, 90, 112, grid=10)
    pc = M.make_point_cloud(jnp.asarray(coords), jnp.asarray(mask))
    feats = rng.normal(size=(112, 6)).astype(np.float32)
    feats[~mask] = 0
    w_down = jnp.asarray(rng.normal(size=(8, 6, 12)).astype(np.float32))
    down = SC.sparse_conv(pc, jnp.asarray(feats), w_down, 2, 2)
    w_up = jnp.asarray(rng.normal(size=(8, 12, 5)).astype(np.float32))
    a = SC.sparse_conv_transposed(down.features, down.maps, pc, w_up,
                                  flow="fod")
    b = SC.sparse_conv_transposed(down.features, down.maps, pc, w_up,
                                  flow="pallas_fused")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), **TOL)


@pytest.mark.parametrize("fused_budget", [None, 36_000])
def test_minkunet_pallas_fused_matches_fod(fused_budget):
    """Acceptance: full MinkUNet forward (encoder + decoder with inverse-
    table up-convs) through the fused Pallas flow is numerically identical
    to flow='fod' — also under a tiny VMEM budget, where every cloud is
    larger than one feature tile and the kernel streams windows."""
    rng = np.random.default_rng(6)
    coords, mask = random_cloud(rng, 120, 160, grid=16)
    feats = jnp.asarray(rng.normal(size=(160, 4)).astype(np.float32))
    feats = feats * jnp.asarray(mask)[:, None]
    pc = M.make_point_cloud(jnp.asarray(coords), jnp.asarray(mask))
    p = MU.minkunet_init(jax.random.key(7), c_in=4, n_classes=13, stem=8,
                         enc_planes=(8, 16), dec_planes=(16, 8),
                         blocks_per_stage=1)
    a = MU.minkunet_apply(p, pc, feats, flow="fod")
    b = MU.minkunet_apply(p, pc, feats, flow="pallas_fused",
                          fused_budget=fused_budget)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), **TOL)
    if fused_budget is not None:
        plan = F.plan_conv_epilogue(160, 8, 8, 27,
                                    budget_bytes=fused_budget)
        assert plan.feat_tile < 160      # the tiny budget really streamed


def test_conv_epilogue_planner():
    """Planner picks the largest fitting cache block, shrinks under
    pressure, and declines to fuse only when nothing fits."""
    roomy = F.plan_conv_epilogue(4096, 64, 64, 27)
    assert roomy.fuse and roomy.feat_tile == 4096    # whole cloud resident
    tight = F.plan_conv_epilogue(4096, 64, 64, 27, budget_bytes=900_000)
    assert tight.fuse and tight.feat_tile < 4096
    assert tight.onchip_bytes <= 900_000
    none = F.plan_conv_epilogue(4096, 64, 64, 27, budget_bytes=1)
    assert not none.fuse
    # DRAM model: fusing removes the pre-activation round trip
    unf = F.dram_bytes_conv_epilogue(1000, 64, residual=True, fused=False)
    fus = F.dram_bytes_conv_epilogue(1000, 64, residual=True, fused=True)
    assert fus < unf
    assert unf - fus == 2 * 1000 * 64 * 4


def test_window_schedule_covers_all_references():
    """Every inverse-table entry falls inside one of its tile's scheduled
    windows (and empty tiles schedule nothing)."""
    rng = np.random.default_rng(8)
    inv = rng.integers(-1, 512, size=(9, 256)).astype(np.int32)
    inv[:, :64] = -1                                  # one empty tile
    wmap, nwin = spops.window_schedule(jnp.asarray(inv), 512, 64, 128)
    wmap, nwin = np.asarray(wmap), np.asarray(nwin)
    assert nwin[0] == 0
    for o in range(4):
        blocks = set(wmap[o, :nwin[o]])
        tile = inv[:, o * 64:(o + 1) * 64]
        for v in tile[tile >= 0]:
            assert v // 128 in blocks
