"""Sparse conv flows vs dense lax.conv oracle, and flow cross-equality."""

import numpy as np
import pytest
import jax.numpy as jnp
from tests.hypothesis_compat import given, settings, st

from repro.core import mapping as M
from repro.core import sparseconv as SC
from tests.test_mapping import random_cloud


def to_dense(coords, mask, feats, grid, batches):
    c_in = feats.shape[-1]
    dense = np.zeros((batches, grid, grid, grid, c_in), np.float32)
    for i in range(coords.shape[0]):
        if mask[i]:
            b, x, y, z = coords[i]
            dense[b, x, y, z] = feats[i]
    return dense


def dense_conv(dense, weights, offsets, stride):
    """Direct oracle: out[q] = sum_d in[q + d] w_d, evaluated on the grid."""
    b, gx, gy, gz, cin = dense.shape
    cout = weights.shape[-1]
    og = gx // stride
    out = np.zeros((b, og, og, og, cout), np.float32)
    for k, d in enumerate(offsets):
        for qx in range(og):
            for qy in range(og):
                for qz in range(og):
                    p = (qx * stride + d[0], qy * stride + d[1],
                         qz * stride + d[2])
                    if all(0 <= p[i] < gx for i in range(3)):
                        out[:, qx, qy, qz] += dense[:, p[0], p[1], p[2]] \
                            @ weights[k]
    return out


@pytest.mark.parametrize("flow", ["gms", "fod"])
@pytest.mark.parametrize("kernel_size,stride", [(3, 1), (2, 2)])
def test_sparse_conv_vs_dense_oracle(flow, kernel_size, stride):
    rng = np.random.default_rng(0)
    grid, batches, cin, cout = 8, 2, 5, 7
    coords, mask = random_cloud(rng, 40, 64, grid=grid, batches=batches)
    feats = rng.normal(size=(64, cin)).astype(np.float32)
    feats[~mask] = 0.0
    pc = M.make_point_cloud(jnp.asarray(coords), jnp.asarray(mask))
    k = kernel_size ** 3
    weights = rng.normal(size=(k, cin, cout)).astype(np.float32) * 0.3

    res = SC.sparse_conv(pc, jnp.asarray(feats), jnp.asarray(weights),
                         kernel_size, stride, flow=flow)

    dense_in = to_dense(coords, mask, feats, grid, batches)
    offs = M.kernel_offsets(kernel_size, 3, 1)
    dense_out = dense_conv(dense_in, weights, offs, stride)

    oc, om = np.asarray(res.pc.coords), np.asarray(res.pc.mask)
    of = np.asarray(res.features)
    for i in range(oc.shape[0]):
        if om[i]:
            b, x, y, z = oc[i]
            np.testing.assert_allclose(
                of[i], dense_out[b, x // stride, y // stride, z // stride],
                rtol=1e-4, atol=1e-4)
    # invalid rows must be zero
    assert np.all(of[~om] == 0)


def test_flows_agree():
    rng = np.random.default_rng(1)
    coords, mask = random_cloud(rng, 100, 128, grid=12)
    feats = jnp.asarray(rng.normal(size=(128, 16)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(27, 16, 24)).astype(np.float32))
    pc = M.make_point_cloud(jnp.asarray(coords), jnp.asarray(mask))
    a = SC.sparse_conv(pc, feats, w, 3, 1, flow="gms").features
    b = SC.sparse_conv(pc, feats, w, 3, 1, flow="fod").features
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-4)


def test_transposed_conv_upsamples_onto_cached_cloud():
    """Down conv then transposed conv: output lives on the original cloud and
    matches an explicit dense computation of the swapped maps."""
    rng = np.random.default_rng(2)
    coords, mask = random_cloud(rng, 30, 48, grid=8)
    feats = rng.normal(size=(48, 4)).astype(np.float32)
    feats[~mask] = 0
    pc = M.make_point_cloud(jnp.asarray(coords), jnp.asarray(mask))
    w_down = jnp.asarray(rng.normal(size=(8, 4, 6)).astype(np.float32))
    down = SC.sparse_conv(pc, jnp.asarray(feats), w_down, 2, 2)

    w_up = rng.normal(size=(8, 6, 5)).astype(np.float32)
    up = SC.sparse_conv_transposed(down.features, down.maps, pc,
                                   jnp.asarray(w_up))
    assert up.shape == (48, 5)
    # oracle via the swapped maps directly
    sm = down.maps.swap()
    expect = np.zeros((48, 5), np.float32)
    din = np.asarray(down.features)
    for k in range(8):
        for t in range(sm.in_idx.shape[1]):
            if sm.valid[k, t]:
                expect[int(sm.out_idx[k, t])] += din[int(sm.in_idx[k, t])] \
                    @ w_up[k]
    np.testing.assert_allclose(np.asarray(up), expect, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50), n=st.integers(10, 50))
def test_flows_agree_property(seed, n):
    rng = np.random.default_rng(seed)
    cap = n + 10
    coords, mask = random_cloud(rng, n, cap, grid=6)
    feats = jnp.asarray(rng.normal(size=(cap, 8)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(27, 8, 8)).astype(np.float32))
    pc = M.make_point_cloud(jnp.asarray(coords), jnp.asarray(mask))
    a = SC.sparse_conv(pc, feats, w, 3, 1, flow="gms").features
    b = SC.sparse_conv(pc, feats, w, 3, 1, flow="fod").features
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-3, atol=1e-3)


def test_fusion_planner_respects_budget_and_covers_chain():
    from repro.core import fusion as F
    widths = [64, 256, 256, 512, 512, 128, 13]
    groups = F.plan_fusion(widths, budget_bytes=2 * 1024 * 1024)
    covered = sum(g.n_layers for g in groups)
    assert covered == len(widths) - 1
    for g in groups:
        assert g.onchip_bytes <= 2 * 1024 * 1024
    # fused DRAM traffic must be <= unfused
    fused = F.dram_bytes_fused(4096, widths, groups)
    unfused = F.dram_bytes_unfused(4096, widths)
    assert fused < unfused
