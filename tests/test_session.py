"""PointAccSession / SparseTensor frontend: parity with the legacy call
sites, one-sort-per-level accounting, the stride-pair transposed lookup,
engine fallbacks (D!=3, packed-key budget), the LRU MappingCache, and the
vmapped batched serving entry point."""

import warnings

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.api import MappingCache, PointAccSession
from repro.core import mapping as M
from repro.core import sparseconv as SC
from repro.core.tensor import MapContext, infer_kernel_size
from repro.models import minkunet as MU
from tests.test_mapping import random_cloud
from tests.test_pointcloud_models import _count_sort_eqns


def _scene(seed=7, n=60, cap=96, grid=12, cin=4):
    rng = np.random.default_rng(seed)
    coords, mask = random_cloud(rng, n, cap, grid=grid)
    feats = rng.normal(size=(cap, cin)).astype(np.float32)
    feats[~mask] = 0
    return (jnp.asarray(coords), jnp.asarray(mask), jnp.asarray(feats))


# ---------------------------------------------------------------------------
# acceptance: whole-network parity + sort accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("flow", ["fod", "pallas", "pallas_fused"])
def test_session_minkunet_matches_legacy_apply(flow):
    """Acceptance: a whole-network MinkUNet forward through the session is
    numerically identical (atol 1e-5) to the minkunet_apply path, for all
    three flows."""
    coords, mask, feats = _scene()
    pc = M.make_point_cloud(coords, mask)
    params = MU.mini_minkunet_init(jax.random.key(8))
    legacy = MU.minkunet_apply(params, pc, feats, flow=flow)

    session = PointAccSession(flow=flow)
    x = session.tensor(coords, mask, feats)
    out = MU.minkunet_forward(session, params, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(legacy),
                               rtol=1e-5, atol=1e-5)
    # and every flow agrees with the fod baseline
    ref = MU.minkunet_apply(params, pc, feats, flow="fod")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("flow", ["fod", "pallas_fused"])
def test_session_one_sort_per_stride_level(flow):
    """Acceptance: the session builds exactly one ranking sort per stride
    level for the ENTIRE forward — including the fused flow, whose
    packed-key canonicalisation reuses the level-0 sort instead of adding
    one (the legacy path paid n_stages+2 there)."""
    coords, mask, _ = _scene(seed=9, n=100, cap=128, grid=16)
    params = MU.mini_minkunet_init(jax.random.key(1))
    n_stages = len(params["enc"])

    def fwd(c, m, f):
        session = PointAccSession(flow=flow)
        return MU.minkunet_forward(session, params, session.tensor(c, m, f))

    jaxpr = jax.make_jaxpr(fwd)(coords, mask, jnp.zeros((128, 4)))
    assert _count_sort_eqns(jaxpr.jaxpr) == n_stages + 1


def test_session_conv_matches_sparse_conv():
    """Single conv: session.conv == the legacy sparse_conv layer wrapper."""
    coords, mask, feats = _scene(seed=3, cin=6)
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.normal(size=(27, 6, 8)).astype(np.float32))
    pc = M.make_point_cloud(coords, mask)
    ref = SC.sparse_conv(pc, feats, w, 3, 1, flow="fod")

    session = PointAccSession(flow="fod")
    y = session.conv(session.tensor(coords, mask, feats), w)
    np.testing.assert_allclose(np.asarray(y.feats), np.asarray(ref.features),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(y.coords),
                                  np.asarray(ref.pc.coords))
    assert y.stride == ref.pc.stride


def test_session_conv_maps_memoized_and_stride_pairs_registered():
    coords, mask, feats = _scene(seed=4)
    session = PointAccSession()
    x = session.tensor(coords, mask, feats)
    rng = np.random.default_rng(4)
    w_subm = jnp.asarray(rng.normal(size=(27, 4, 4)).astype(np.float32))
    w_down = jnp.asarray(rng.normal(size=(8, 4, 4)).astype(np.float32))
    h1 = session.conv(x, w_subm)
    session.conv(h1, w_subm)                     # same level: reuse
    d = session.conv(h1, w_down, stride=2)
    assert set(x.context.maps) == {(3, 1, 1), (2, 1, 2)}
    assert d.stride == 2 and d.context is x.context
    # the strided v2 map carries the swapped inverse table for the decoder
    assert x.context.maps[(2, 1, 2)].inv_t is not None


# ---------------------------------------------------------------------------
# transposed convs: stride-pair lookup + inverse-table fallback
# ---------------------------------------------------------------------------

def test_transposed_conv_by_stride_pair_matches_legacy():
    coords, mask, feats = _scene(seed=5, cin=6)
    rng = np.random.default_rng(5)
    w_down = jnp.asarray(rng.normal(size=(8, 6, 12)).astype(np.float32))
    w_up = jnp.asarray(rng.normal(size=(8, 12, 5)).astype(np.float32))

    pc = M.make_point_cloud(coords, mask)
    down = SC.sparse_conv(pc, feats, w_down, 2, 2)
    legacy = SC.sparse_conv_transposed(down.features, down.maps, pc, w_up)

    session = PointAccSession()
    x = session.tensor(coords, mask, feats)
    h = session.conv(x, w_down, stride=2)
    y = session.conv_transposed(h, w_up, stride=2)
    assert y.stride == 1
    np.testing.assert_allclose(np.asarray(y.feats), np.asarray(legacy),
                               rtol=1e-5, atol=1e-5)


def test_transposed_conv_without_forward_maps_raises():
    coords, mask, feats = _scene(seed=6)
    session = PointAccSession()
    x = session.tensor(coords, mask, feats, stride=2)
    w_up = jnp.zeros((8, 4, 4))
    with pytest.raises(ValueError, match="stride pair"):
        session.conv_transposed(x, w_up, stride=2)


def test_swap_require_inverse_raises_for_v1_maps():
    """Satellite fix: the transposed path must not silently assume inv_t.
    v1-built maps raise under require_inverse, warn-and-fall-back on the
    Pallas flows, and stay numerically identical to the fod flow."""
    coords, mask, feats = _scene(seed=2, cin=6)
    rng = np.random.default_rng(2)
    w_down = jnp.asarray(rng.normal(size=(8, 6, 12)).astype(np.float32))
    w_up = jnp.asarray(rng.normal(size=(8, 12, 5)).astype(np.float32))
    pc = M.make_point_cloud(coords, mask)

    m1, _ = M.build_conv_maps(pc, 2, 2, engine="v1")
    with pytest.raises(ValueError, match="no inverse table"):
        m1.swap(require_inverse=True)
    m2, _ = M.build_conv_maps(pc, 2, 2, engine="v2")
    assert m2.swap(require_inverse=True).inv is not None

    down = SC.sparse_conv(pc, feats, w_down, 2, 2, engine="v1")
    ref = SC.sparse_conv_transposed(down.features, down.maps, pc, w_up,
                                    flow="fod")
    with pytest.warns(UserWarning, match="scatter-built inverse"):
        out = SC.sparse_conv_transposed(down.features, down.maps, pc, w_up,
                                        flow="pallas_fused")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    # v2-built maps keep the scatter-free path warning-free
    down2 = SC.sparse_conv(pc, feats, w_down, 2, 2, engine="v2")
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        SC.sparse_conv_transposed(down2.features, down2.maps, pc, w_up,
                                  flow="pallas_fused")

    # the session's transposed path surfaces the same downgrade
    v1s = PointAccSession(engine="v1", flow="pallas_fused")
    h = v1s.conv(v1s.tensor(coords, mask, feats), w_down, stride=2)
    with pytest.warns(UserWarning, match="scatter-built inverse"):
        y = v1s.conv_transposed(h, w_up, stride=2)
    np.testing.assert_allclose(np.asarray(y.feats), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    v2s = PointAccSession(engine="v2", flow="pallas_fused")
    h2 = v2s.conv(v2s.tensor(coords, mask, feats), w_down, stride=2)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        v2s.conv_transposed(h2, w_up, stride=2)


# ---------------------------------------------------------------------------
# engine fallbacks through the session: D != 3 and the packed-key budget
# ---------------------------------------------------------------------------

def test_session_non3d_cloud_falls_back_to_v1_with_parity():
    """D=2 clouds: the default engine falls back to v1 under the session,
    matching an explicit v1 build; explicit v2 still raises."""
    rng = np.random.default_rng(20)
    coords, mask = random_cloud(rng, 40, 64, grid=8, d=2)
    feats = rng.normal(size=(64, 5)).astype(np.float32)
    feats[~mask] = 0
    coords, mask, feats = (jnp.asarray(coords), jnp.asarray(mask),
                           jnp.asarray(feats))
    w = jnp.asarray(rng.normal(size=(9, 5, 7)).astype(np.float32))

    session = PointAccSession()
    x = session.tensor(coords, mask, feats)
    assert x.context.engine == "v1"
    y = session.conv(x, w)
    assert infer_kernel_size(9, 2) == 3

    pc = M.make_point_cloud(coords, mask)
    ref = SC.sparse_conv(pc, feats, w, 3, 1, engine="v1")
    np.testing.assert_allclose(np.asarray(y.feats),
                               np.asarray(ref.features),
                               rtol=1e-5, atol=1e-5)

    strict = PointAccSession(engine="v2")
    with pytest.raises(ValueError, match="3 spatial dims"):
        strict.conv(strict.tensor(coords, mask, feats), w)


def test_session_out_of_budget_raises_eagerly_and_saturates_under_jit():
    """Coordinates outside the 62-bit key budget, reached through the
    session: eager v2 raises with the v1 escape hatch named; engine='v1'
    serves the same cloud; under jit the bad point saturates to the
    sentinel key and silently drops out of every map."""
    coords = jnp.asarray(np.array([[0, 40000, 0, 0], [0, 1, 1, 1],
                                   [0, 1, 1, 2]], np.int32))
    mask = jnp.asarray(np.ones(3, bool))
    feats = jnp.asarray(np.ones((3, 2), np.float32))
    w = jnp.asarray(np.ones((27, 2, 2), np.float32))

    session = PointAccSession()
    with pytest.raises(ValueError, match="packed-key budget"):
        session.conv(session.tensor(coords, mask, feats), w)

    v1 = PointAccSession(engine="v1")
    y1 = v1.conv(v1.tensor(coords, mask, feats), w)
    assert float(jnp.abs(y1.feats[0]).max()) > 0   # v1 maps the far point

    @jax.jit
    def conv_v2(c, m, f):
        s = PointAccSession(engine="v2")
        return s.conv(s.tensor(c, m, f), w).feats

    y2 = conv_v2(coords, mask, feats)
    assert float(jnp.abs(y2[0]).max()) == 0        # saturated -> no maps
    # in-budget rows are unaffected by the saturating neighbour
    np.testing.assert_allclose(np.asarray(y2[1:]), np.asarray(y1.feats[1:]),
                               rtol=1e-5, atol=1e-5)


def test_session_v1_vs_v2_parity_on_3d_cloud():
    """Same cloud, both engines through the session: identical forward."""
    coords, mask, feats = _scene(seed=21)
    params = MU.mini_minkunet_init(jax.random.key(11))
    outs = []
    for engine in ("v1", "v2"):
        session = PointAccSession(engine=engine)
        outs.append(MU.minkunet_forward(
            session, params, session.tensor(coords, mask, feats)))
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[1]),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# MappingCache: LRU bound + counters
# ---------------------------------------------------------------------------

def test_mapping_cache_lru_bound_and_counters():
    cache = MappingCache(max_entries=2)
    a = np.arange(4, dtype=np.int32)
    b = np.arange(4, dtype=np.int32) + 1
    c = np.arange(4, dtype=np.int32) + 2
    builds = []

    def builder(tag):
        def build():
            builds.append(tag)
            return tag
        return build

    assert cache.get((a,), builder("a")) == ("a", False)
    assert cache.get((a,), builder("a")) == ("a", True)       # hit
    assert cache.get((b,), builder("b")) == ("b", False)
    assert cache.get((c,), builder("c")) == ("c", False)      # evicts a
    assert len(cache) == 2
    assert cache.get((a,), builder("a2")) == ("a2", False)    # a was evicted
    assert cache.get((c,), builder("c2")) == ("c", True)      # c survived
    assert cache.stats()["hits"] == 2
    assert cache.stats()["misses"] == 4
    assert builds == ["a", "b", "c", "a2"]


def test_mapping_cache_distinguishes_dtype_and_shape():
    cache = MappingCache()
    a32 = np.zeros(4, np.int32)
    a64 = np.zeros(4, np.int64)
    a2d = np.zeros((2, 2), np.int32)
    cache.get((a32,), lambda: 1)
    _, hit = cache.get((a64,), lambda: 2)
    assert not hit
    _, hit = cache.get((a2d,), lambda: 3)
    assert not hit


def test_geometry_digest_near_duplicates():
    """Near-duplicate scenes, pinned at the digest level.

    A row permutation of the same coordinate SET is a different padded
    scene — kernel maps are row-indexed, so reusing the permuted scene's
    pyramid would scatter predictions to the wrong rows.  The digest
    must differ.  Features, by contrast, are NOT geometry: a re-scored
    frame (same coords+mask, new feats) shares the cached pyramid —
    `PointCloudEngine.scene_key` hashes only (coords, mask, bucket)."""
    coords, mask, _ = _scene(seed=21)
    c, m = np.asarray(coords), np.asarray(mask)
    base = MappingCache.digest((c, m))
    assert MappingCache.digest((c.copy(), m.copy())) == base  # value id

    perm = np.random.default_rng(3).permutation(c.shape[0])
    assert not np.array_equal(c[perm], c)
    assert MappingCache.digest((c[perm], m[perm])) != base

    cache = MappingCache()
    cache.get((c, m), lambda: "pyramid")
    _, hit = cache.get((c[perm], m[perm]), lambda: "permuted")
    assert not hit                        # permuted rows: a new entry
    _, hit = cache.get((c, m), lambda: "unused")
    assert hit                            # feats never entered the key

    # same geometry under a different serving bucket must not collide
    assert MappingCache.digest((c, m), extra=("levels", 64)) \
        != MappingCache.digest((c, m), extra=("levels", 128))


# ---------------------------------------------------------------------------
# batched serving: vmapped entry point == per-scene loop
# ---------------------------------------------------------------------------

def test_vmapped_segment_batch_matches_per_scene_loop():
    """Acceptance: the jax.vmap-over-scenes serving entry point produces
    the same segmentation as looping minkunet_apply scene by scene."""
    from repro.data.synthetic import point_cloud_batch
    from repro.serve.engine import PointCloudEngine

    B, N = 3, 128
    coords, mask, feats, _ = point_cloud_batch(seed=1, step=0, batch=B,
                                               n_points=N, grid=16)
    coords = coords.reshape(B, N, 4)
    mask = mask.reshape(B, N)
    feats = feats.reshape(B, N, -1)

    params = MU.mini_minkunet_init(jax.random.key(0), c_in=4, n_classes=2)
    engine = PointCloudEngine(params, n_stages=2, flow="fod")
    preds, hit = engine.segment_batch(coords, mask, feats)
    assert not hit and preds.shape == (B, N)

    for b in range(B):
        pc = M.make_point_cloud(jnp.asarray(coords[b]), jnp.asarray(mask[b]))
        logits = MU.minkunet_apply(params, pc, jnp.asarray(feats[b]),
                                   flow="fod")
        np.testing.assert_array_equal(np.asarray(preds[b]),
                                      np.asarray(jnp.argmax(logits, -1)))

    # identical geometry: the second request's ORDERED composition
    # repeats, so the scheduler's assembly cache serves the whole stacked
    # batch — the per-scene mapping cache is bypassed, not consulted
    _, hit = engine.segment_batch(coords, mask, feats)
    assert hit
    assert engine.cache_stats()["hits"] == 0
    assert engine.scheduler().stats()["assembly_cache"]["hits"] == 1

    # permuted composition: the assembly key misses, and the per-scene
    # digests take over — every scene's pyramid hits individually
    _, hit = engine.segment_batch(coords[::-1], mask[::-1], feats[::-1])
    assert hit
    assert engine.cache_stats()["hits"] == B


def test_levels_roundtrip_through_context():
    """build_unet_maps -> _context_from_levels -> forward == direct."""
    coords, mask, feats = _scene(seed=10)
    pc = M.make_point_cloud(coords, mask)
    params = MU.mini_minkunet_init(jax.random.key(11))
    ref = MU.minkunet_apply(params, pc, feats)
    for engine in (None, "v1"):
        levels = MU.build_unet_maps(pc, 2, engine=engine)
        out = MU.minkunet_apply(params, pc, feats, levels=levels)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)


def test_infer_kernel_size():
    assert infer_kernel_size(27, 3) == 3
    assert infer_kernel_size(8, 3) == 2
    assert infer_kernel_size(125, 3) == 5
    assert infer_kernel_size(9, 2) == 3
    with pytest.raises(ValueError, match="kernel_size"):
        infer_kernel_size(10, 3)


def test_map_context_rejects_unknown_engine():
    with pytest.raises(ValueError, match="engine"):
        MapContext(engine="v3")
    with pytest.raises(ValueError, match="flow"):
        PointAccSession(flow="warp")
