"""Per-kernel interpret-mode validation: sweep shapes/dtypes, allclose vs
the pure-jnp oracles (ref.py)."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import mapping as M
from repro.core import sparseconv as SC
from repro.kernels.spconv import ops as spconv_ops
from repro.kernels.spconv.ref import spconv_fod_ref
from repro.kernels.spconv.spconv import spconv_fod_pallas
from repro.kernels.fused_mlp import ops as fmlp_ops
from repro.kernels.fused_mlp.ref import fused_mlp_ref
from repro.kernels.grouped_matmul import ops as gmm_ops
from repro.kernels.grouped_matmul.grouped_matmul import grouped_matmul_pallas
from repro.kernels.grouped_matmul.ref import grouped_matmul_ref
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention.ref import attention_ref
from tests.test_mapping import random_cloud


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# spconv fetch-on-demand
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,m,cin,cout,k", [
    (64, 64, 8, 16, 27), (128, 64, 32, 8, 8), (256, 128, 16, 32, 27)])
def test_spconv_kernel_vs_ref(n, m, cin, cout, k, dtype):
    rng = np.random.default_rng(0)
    feats = jnp.asarray(rng.normal(size=(n, cin)), dtype)
    w = jnp.asarray(rng.normal(size=(k, cin, cout)) * 0.2, dtype)
    inv = rng.integers(-1, n, size=(k, m)).astype(np.int32)
    out = spconv_fod_pallas(feats, jnp.asarray(inv), w, out_tile=64,
                            interpret=True)
    ref = spconv_fod_ref(feats, jnp.asarray(inv), w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_spconv_kernel_cin_tiling():
    rng = np.random.default_rng(1)
    feats = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(8, 32, 16)).astype(np.float32))
    inv = jnp.asarray(rng.integers(-1, 64, size=(8, 64)).astype(np.int32))
    a = spconv_fod_pallas(feats, inv, w, out_tile=32, cin_tile=8,
                          interpret=True)
    b = spconv_fod_ref(feats, inv, w)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-4)


def test_spconv_kernel_end_to_end_matches_flows():
    """Full pipeline: maps from the Mapping Unit -> pallas kernel == both
    XLA flows."""
    rng = np.random.default_rng(2)
    coords, mask = random_cloud(rng, 90, 128, grid=12)
    feats = jnp.asarray(rng.normal(size=(128, 16)).astype(np.float32))
    pc = M.make_point_cloud(jnp.asarray(coords), jnp.asarray(mask))
    w = jnp.asarray(rng.normal(size=(27, 16, 24)).astype(np.float32))
    maps, out_pc = M.build_conv_maps(pc, 3, 1)
    a = spconv_ops.sparse_conv_fod(feats, maps, w, out_pc.capacity)
    b = SC.fetch_on_demand(feats, maps, w, out_pc.capacity)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# fused MLP (temporal layer fusion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("widths", [
    [16, 32, 64], [8, 128, 128, 32], [64, 64]])
def test_fused_mlp_vs_ref(widths, dtype):
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(200, widths[0])), dtype)
    ws = [jnp.asarray(rng.normal(size=(widths[i], widths[i + 1])) * 0.2,
                      dtype) for i in range(len(widths) - 1)]
    bs = [jnp.asarray(rng.normal(size=(widths[i + 1],)) * 0.1, dtype)
          for i in range(len(widths) - 1)]
    out = fmlp_ops.fused_mlp(x, ws, bs, tile_points=64)
    ref = fused_mlp_ref(x, ws, bs)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_fused_mlp_chain_matches_nn_chain():
    from repro import nn
    rng = np.random.default_rng(4)
    p = nn.mlp_chain_init(jax.random.key(0), [12, 48, 48, 24])
    x = jnp.asarray(rng.normal(size=(100, 12)).astype(np.float32))
    out = fmlp_ops.fused_mlp_chain(x, p, final_act=False,
                                   budget_bytes=1 << 20)
    ref = nn.mlp_chain(p, x, final_act=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# grouped matmul + sorted MoE dispatch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("e,rt,cin,cout", [(4, 32, 16, 64), (8, 64, 64, 32)])
def test_grouped_matmul_vs_ref(e, rt, cin, cout, dtype):
    rng = np.random.default_rng(5)
    n_tiles = 2 * e
    x = jnp.asarray(rng.normal(size=(n_tiles * rt, cin)), dtype)
    w = jnp.asarray(rng.normal(size=(e, cin, cout)) * 0.2, dtype)
    eid = jnp.asarray(rng.integers(0, e, size=(n_tiles,)).astype(np.int32))
    out = grouped_matmul_pallas(x, eid, w, row_tile=rt, interpret=True)
    ref = grouped_matmul_ref(x, eid, w, row_tile=rt)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_grouped_matmul_cin_cout_tiling():
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(128, 32)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(4, 32, 64)).astype(np.float32))
    eid = jnp.asarray(np.array([0, 3, 1, 2], np.int32))
    from repro.kernels.grouped_matmul.grouped_matmul import \
        grouped_matmul_pallas as gp
    a = gp(x, eid, w, row_tile=32, cin_tile=16, cout_tile=32,
           interpret=True)
    b = grouped_matmul_ref(x, eid, w, row_tile=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-4)


def test_sorted_moe_ffn_matches_dense_dispatch():
    """Sorted (PointAcc) dispatch == dense one-hot dispatch when capacity is
    ample."""
    rng = np.random.default_rng(7)
    t, d, f, e, topk = 96, 16, 32, 4, 2
    x = jnp.asarray(rng.normal(size=(t, d)).astype(np.float32))
    w_in = jnp.asarray(rng.normal(size=(e, d, f)).astype(np.float32) * 0.2)
    w_out = jnp.asarray(rng.normal(size=(e, f, d)).astype(np.float32) * 0.2)
    logits = jnp.asarray(rng.normal(size=(t, e)).astype(np.float32))
    gates, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), topk)

    got = gmm_ops.sorted_moe_ffn(x, idx, gates, w_in, w_out,
                                 capacity_factor=8.0, row_tile=32)
    # dense oracle: every expert on every token, one-hot combine
    h = jnp.einsum("td,edf->tef", x, w_in)
    y = jnp.einsum("tef,efd->ted", jax.nn.silu(h), w_out)
    onehot = jax.nn.one_hot(idx, e) * gates[..., None]        # (t,topk,e)
    expect = jnp.einsum("tke,ted->td", onehot, y)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                               rtol=1e-3, atol=1e-3)


def test_dispatch_capacity_drops_overflow():
    idx = jnp.zeros((64, 1), jnp.int32)          # all tokens -> expert 0
    disp = gmm_ops.make_dispatch(idx, n_experts=4, capacity=32, row_tile=32)
    kept = int(jnp.sum(disp.dest_row >= 0))
    assert kept == 32                             # capacity-clipped
    # dropped tokens marked -1
    assert int(jnp.sum(disp.dest_row < 0)) == 32


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window,softcap", [
    (True, None, None), (True, 64, None), (True, None, 30.0),
    (False, None, None), (True, 32, 50.0)])
def test_flash_attention_vs_ref(causal, window, softcap, dtype):
    rng = np.random.default_rng(8)
    b, hq, hkv, s, d = 2, 4, 2, 256, 32
    q = jnp.asarray(rng.normal(size=(b, hq, s, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype)
    out = fa_ops.flash_attention(q, k, v, causal, window, softcap, 64, True)
    ref = attention_ref(q, k, v, causal=causal, window=window,
                        softcap=softcap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 2e-3,
                               atol=3e-2 if dtype == jnp.bfloat16 else 2e-3)


def test_flash_attention_cross_lengths():
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.normal(size=(1, 2, 128, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 2, 384, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 2, 384, 16)).astype(np.float32))
    out = fa_ops.flash_attention(q, k, v, False, None, None, 64, True)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3,
                               atol=2e-3)


def test_flash_attention_grad_matches_ref_grad():
    rng = np.random.default_rng(10)
    q = jnp.asarray(rng.normal(size=(1, 2, 64, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 1, 64, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 1, 64, 16)).astype(np.float32))

    def loss_kern(q, k, v):
        return jnp.sum(fa_ops.flash_attention(q, k, v, True, None, None,
                                              32, True) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(attention_ref(q, k, v, causal=True) ** 2)

    g1 = jax.grad(loss_kern, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3,
                                   atol=2e-3)
