"""Flash-decode kernel: interpret-mode sweeps vs the jnp oracle, plus
consistency with the model's decode attention path."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels.flash_decode import ops as fd_ops
from repro.kernels.flash_decode.ref import flash_decode_ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hkv,s,hd", [
    (2, 4, 2, 512, 32), (1, 8, 1, 384, 64), (3, 4, 4, 256, 16)])
def test_flash_decode_vs_ref(b, hq, hkv, s, hd, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, hq, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, hd)), dtype)
    lengths = jnp.asarray(rng.integers(1, s + 1, size=(b,)), jnp.int32)
    out = fd_ops.flash_decode(q, k, v, lengths, block_s=128)
    ref = flash_decode_ref(q, k, v, lengths)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_decode_softcap_and_padding():
    rng = np.random.default_rng(1)
    b, hq, hkv, s, hd = 2, 2, 2, 200, 32       # s not a block multiple
    q = jnp.asarray(rng.normal(size=(b, hq, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, hkv, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, hkv, hd)).astype(np.float32))
    lengths = jnp.asarray([200, 7], jnp.int32)
    out = fd_ops.flash_decode(q, k, v, lengths, softcap=30.0, block_s=128)
    ref = flash_decode_ref(q, k, v, lengths, softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_flash_decode_matches_model_decode_attention():
    """Kernel == the XLA decode-attention path used by the models."""
    from repro.models.layers import KVCache, _decode_attention
    rng = np.random.default_rng(2)
    b, hq, hkv, s, hd = 2, 4, 2, 256, 32
    q4 = jnp.asarray(rng.normal(size=(b, 1, hq, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, hkv, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, hkv, hd)).astype(np.float32))
    pos = jnp.asarray([100, 255], jnp.int32)
    valid = jnp.arange(s)[None, :] <= pos[:, None]
    ref = _decode_attention(q4, KVCache(k, v), valid, None,
                            1.0 / np.sqrt(hd))           # (B, 1, Hq*hd)
    out = fd_ops.flash_decode(q4[:, 0], k, v, pos + 1, block_s=128)
    np.testing.assert_allclose(np.asarray(out).reshape(b, -1),
                               np.asarray(ref)[:, 0], rtol=2e-3, atol=2e-3)
