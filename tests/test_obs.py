"""End-to-end serve observability (repro.obs): the unified metrics
registry (counters / gauges / fixed-bucket histograms + the frozen
stats() schema shapes), request span tracing, the bounded flight
recorder with exactly-once incident dumps, and the JSONL / Prometheus
exporters — plus the integration contracts: obs-enabled serving is
bit-identical to the default path, a multi-producer chaos run leaves
the registry arithmetically consistent with every span tree closed,
and a router worker-kill produces one trace spanning original dispatch
-> failover -> replay -> retire with exactly one recorder dump."""

import json
import threading

import numpy as np
import pytest
import jax

from repro.data.synthetic import lidar_scene
from repro.obs import (FlightRecorder, Histogram, MetricsRegistry,
                       Observability, SpanTracer, TraceSchemaError,
                       iter_trace_records, metrics as MX, prometheus_text,
                       validate_trace_jsonl, write_trace_jsonl)
from repro.serve import faults as FLT
from repro.serve.buckets import geometric_ladder
from repro.serve.engine import PointCloudEngine
from repro.serve.faults import FaultPlan
from repro.serve.router import LivenessPolicy, ServeRouter
from repro.serve.scheduler import ServeScheduler
from tests.test_serve_faults import _mini_params


def _scene(seed, n):
    c, m, f = lidar_scene(seed=340 + seed, n_points=n, grid=16)
    return c, f, m


@pytest.fixture(scope="module")
def served():
    """(params, engine) shared across the module, jit paid once."""
    jax.clear_caches()
    params = _mini_params()
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=geometric_ladder(64, 128))
    return params, engine


# ---------------------------------------------------------------------------
# registry units (no engine)
# ---------------------------------------------------------------------------

def test_counter_gauge_basics():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total", "requests")
    c.inc()
    c.inc(3)
    assert c.value == 4
    g = reg.gauge("depth")
    assert g.value is None                    # unset gauge reads None
    g.set(2)
    g.inc()
    g.dec(3)
    assert g.value == 0
    lazy = reg.gauge("lazy_depth")
    backing = [7]
    lazy.labels().set_function(lambda: backing[0])
    assert lazy.value == 7
    backing[0] = 9
    assert lazy.value == 9
    lazy.labels().set_function(lambda: 1 / 0)  # broken fn reads None
    assert lazy.value is None


def test_registry_idempotent_and_mismatch():
    reg = MetricsRegistry()
    a = reg.counter("x_total", "x", labelnames=("instance",))
    b = reg.counter("x_total", "different help", labelnames=("instance",))
    assert a is b                             # get-or-create, help ignored
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x_total")                  # kind mismatch
    with pytest.raises(ValueError, match="already registered"):
        reg.counter("x_total", labelnames=("code",))  # label mismatch
    with pytest.raises(ValueError, match="takes labels"):
        a.labels()                            # arity enforced


def test_family_labels_and_items():
    reg = MetricsRegistry()
    fam = reg.counter("f_total", labelnames=("instance", "code"))
    fam.labels("w0", "shed").inc(2)
    fam.labels("w1", "shed").inc()
    fam.labels("w0", "timeout").inc()
    assert fam.labels("w0", "shed") is fam.labels("w0", "shed")
    only_w0 = fam.items(instance="w0")
    assert [k for k, _ in only_w0] == [("w0", "shed"), ("w0", "timeout")]
    assert sum(c.value for _, c in fam.items(code="shed")) == 3
    with pytest.raises(ValueError, match="no label"):
        fam.items(bucket="64")


def test_histogram_quantiles():
    h = Histogram(bounds=(1.0, 2.0, 4.0))
    assert h.quantile(0.5) == 0.0             # empty
    for v in (0.5, 1.5, 1.5, 3.0):
        h.observe(v)
    assert h.count == 4 and h.sum == pytest.approx(6.5)
    assert h.counts == [1, 2, 1, 0]
    # p50: rank 2 lands in the (1, 2] bucket, interpolated
    assert 1.0 <= h.quantile(0.5) <= 2.0
    h.observe(100.0)                          # +Inf bucket
    assert h.quantile(0.999) == 4.0           # clamped to the last bound
    with pytest.raises(ValueError, match="quantile"):
        h.quantile(1.5)
    q = h.quantiles()
    assert set(q) == {"p50", "p95", "p99"}
    with pytest.raises(ValueError, match="strictly"):
        Histogram(bounds=(2.0, 1.0))


def test_prometheus_text_exposition():
    reg = MetricsRegistry()
    reg.counter("serve_reqs_total", "requests",
                labelnames=("instance",)).labels("w0").inc(3)
    reg.gauge("serve_depth", "queue depth").set(2)
    h = reg.histogram("serve_lat_seconds", "latency", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    text = prometheus_text(reg)
    assert "# HELP serve_reqs_total requests" in text
    assert "# TYPE serve_reqs_total counter" in text
    assert 'serve_reqs_total{instance="w0"} 3' in text
    assert "serve_depth 2" in text
    # cumulative buckets + the implicit +Inf bucket + sum/count
    assert 'serve_lat_seconds_bucket{le="0.1"} 1' in text
    assert 'serve_lat_seconds_bucket{le="1"} 2' in text
    assert 'serve_lat_seconds_bucket{le="+Inf"} 2' in text
    assert "serve_lat_seconds_sum 0.55" in text
    assert "serve_lat_seconds_count 2" in text


# ---------------------------------------------------------------------------
# tracer + recorder units
# ---------------------------------------------------------------------------

def test_tracer_span_tree():
    tr = SpanTracer()
    tr.begin("t1", t=0.0, rid=1)
    tr.begin("t1", t=5.0)                     # idempotent: keeps root t=0
    a = tr.span("t1", "assembly", t_start=1.0, t_end=2.0, cache_hit=True)
    tr.span("t1", "arena_staging", parent=a, t_start=1.0, t_end=1.5)
    w = tr.span("t1", "device_wait", t_start=2.0)
    tr.end_span("t1", w, t_end=3.0, ok=True)
    tr.event("t1", "retire", t=3.0)
    trace = tr.get("t1")
    assert not trace.closed
    assert trace.names() == ["request", "assembly", "arena_staging",
                             "device_wait", "retire"]
    tree = trace.tree()
    assert tree["name"] == "request" and tree["attrs"] == {"rid": 1}
    asm = next(c for c in tree["children"] if c["name"] == "assembly")
    assert [c["name"] for c in asm["children"]] == ["arena_staging"]
    (dw,) = trace.find("device_wait")
    assert dw.t_end == 3.0 and dw.attrs == {"ok": True}
    (rt,) = trace.find("retire")
    assert rt.t_start == rt.t_end == 3.0      # events are instant
    tr.end("t1", t=4.0, outcome="ok")
    trace = tr.get("t1")
    assert trace.closed
    assert trace.spans[trace.root_id].attrs["outcome"] == "ok"
    assert tr.stats() == {"live": 0, "finished": 1, "spans_recorded": 5,
                          "dropped": 0}


def test_tracer_unknown_tid_drops_and_bound():
    tr = SpanTracer(max_finished=2)
    assert tr.span("ghost", "x") is None      # unknown tid no-ops
    tr.end_span("ghost", 0)
    tr.end("ghost")
    assert tr.stats()["dropped"] == 3
    for i in range(5):
        tr.begin(f"t{i}", t=0.0)
        tr.end(f"t{i}", t=1.0)
    assert tr.stats()["finished"] == 2        # bounded deque
    assert tr.get("t0") is None               # evicted
    assert tr.get("t4").closed


def test_flight_recorder_dump_once():
    shipped = []
    rec = FlightRecorder(capacity=3, max_dumps=2, sink=shipped.append)
    for i in range(5):
        rec.record("submit", t=float(i), rid=i)
    assert [e["rid"] for e in rec.events()] == [2, 3, 4]   # ring bound
    d = rec.dump("exec_failed", key=("exec_failed", "s", 4))
    assert d["reason"] == "exec_failed"
    assert [e["rid"] for e in d["events"]] == [2, 3, 4]
    assert rec.dump("exec_failed", key=("exec_failed", "s", 4)) is None
    assert shipped == [d]                     # sink got it exactly once
    st = rec.stats()
    assert st["events"] == 5 and st["ring"] == 3
    assert st["dumps"] == 1 and st["suppressed"] == 1
    bad = FlightRecorder(sink=lambda d: 1 / 0)
    bad.record("x")
    assert bad.dump("r", key="k") is not None  # broken sink swallowed
    with pytest.raises(ValueError, match="capacity"):
        FlightRecorder(capacity=0)


def test_trace_jsonl_roundtrip(tmp_path):
    tr = SpanTracer()
    tr.begin("rid:1", t=0.0, rid=1)
    tr.span("rid:1", "dispatch", t_start=1.0, t_end=2.0,
            n=np.int64(3))                     # numpy attrs must serialize
    tr.end("rid:1", t=3.0, outcome="ok")
    tr.begin("rid:2", t=0.0)                   # still live
    rec = FlightRecorder()
    rec.record("submit", t=0.5, rid=1)
    rec.dump("failover", key="w0")
    path = tmp_path / "trace.jsonl"
    n = write_trace_jsonl(path, tr, recorder=rec)
    kinds = [r["kind"] for r in iter_trace_records(tr, rec)]
    assert n == len(kinds) == 4                # 3 spans + 1 dump
    report = validate_trace_jsonl(path)
    assert report == {"lines": 4, "spans": 3, "dumps": 1, "traces": 2,
                      "closed_traces": 1}
    rows = [json.loads(line) for line in path.read_text().splitlines()]
    disp = next(r for r in rows if r.get("name") == "dispatch")
    assert disp["attrs"]["n"] == 3             # np.int64 -> plain int

    # schema violations are loud
    bad = tmp_path / "bad.jsonl"
    bad.write_text(json.dumps({"kind": "span"}) + "\n")
    with pytest.raises(TraceSchemaError, match="missing"):
        validate_trace_jsonl(bad)
    bad.write_text("not json\n")
    with pytest.raises(TraceSchemaError, match="not valid JSON"):
        validate_trace_jsonl(bad)
    bad.write_text(json.dumps(dict(rows[1], t_end=0.5)) + "\n")
    with pytest.raises(TraceSchemaError, match="t_end"):
        validate_trace_jsonl(bad)


def test_observability_bundle():
    default = Observability()
    assert default.tracer is None and default.recorder is None
    assert isinstance(default.registry, MetricsRegistry)
    on = Observability.enabled(max_finished=8, capacity=4)
    assert on.tracer is not None and on.recorder is not None
    assert on.recorder.capacity == 4


# ---------------------------------------------------------------------------
# stats() schema shapes (satellite: the drifted dicts, frozen)
# ---------------------------------------------------------------------------

def test_scheduler_stats_schema(served):
    _, engine = served
    sched = ServeScheduler(engine, max_batch=2)
    out = sched.serve([_scene(0, 40), _scene(1, 90)])
    assert all(r.ok for r in out.values())
    st = sched.stats()
    assert set(st) == MX.SCHEDULER_STATS_KEYS
    assert set(st["faults"]) == MX.SCHEDULER_FAULT_KEYS
    for b in st["buckets"].values():
        assert set(b) == MX.SCHEDULER_BUCKET_KEYS
    q = st["latency_quantiles_s"]
    assert set(q) == {"p50", "p95", "p99"}
    assert 0.0 < q["p50"] <= q["p95"] <= q["p99"]
    sched.close()


def test_router_stats_schema(served):
    _, engine = served
    router = ServeRouter(lambda: engine, 1, max_batch=2)
    out = router.serve([_scene(0, 40)])
    assert all(r.error is None for r in out.values())
    st = router.stats()
    assert set(st) == MX.ROUTER_STATS_KEYS
    assert set(st["faults"]) == MX.ROUTER_FAULT_KEYS
    assert set(st["latency_quantiles_s"]) == {"p50", "p95", "p99"}
    router.close()


# ---------------------------------------------------------------------------
# scheduler integration: parity, span trees, error-path latencies
# ---------------------------------------------------------------------------

def test_obs_enabled_bit_identical(served):
    _, engine = served
    scenes = [_scene(i, 40 + 10 * i) for i in range(4)]
    plain = ServeScheduler(engine, max_batch=2)
    traced = ServeScheduler(engine, max_batch=2,
                            obs=Observability.enabled())
    ref = plain.serve(scenes)
    got = traced.serve(scenes)
    for rid in ref:
        assert ref[rid].ok and got[rid].ok
        np.testing.assert_array_equal(ref[rid].preds, got[rid].preds)
    # the view over the registry matches the plain path count for count
    a, b = plain.stats(), traced.stats()
    for key in ("n_submitted", "n_completed", "n_ok", "faults",
                "padding_overhead"):
        assert a[key] == b[key]
    plain.close()
    traced.close()


def test_scheduler_request_span_tree(served):
    _, engine = served
    obs = Observability.enabled()
    sched = ServeScheduler(engine, max_batch=2, obs=obs,
                           instance="s0")
    out = sched.serve([_scene(0, 40), _scene(1, 90)])
    assert all(r.ok for r in out.values())
    assert obs.tracer.stats()["live"] == 0    # every tree closed
    for rid in out:
        trace = obs.tracer.get(f"s0:rid:{rid}")
        assert trace is not None and trace.closed
        names = trace.names()
        for stage in ("request", "admission", "queue_wait", "dispatch",
                      "assembly", "arena_staging", "assembly_lookup",
                      "device_wait", "retire"):
            assert stage in names, (rid, names)
        root = trace.spans[trace.root_id]
        assert root.attrs["outcome"] == "ok"
        (qw,) = trace.find("queue_wait")
        (dp,) = trace.find("dispatch")
        assert qw.t_end is not None and qw.t_end <= dp.t_start + 1e-9
    sched.close()


def test_error_latency_separate_histogram(served):
    """Satellite: error-path completions land in the labeled error
    histogram, never in the OK latency histogram the averages use."""
    _, engine = served
    obs = Observability.enabled()
    sched = ServeScheduler(engine, max_batch=2, obs=obs, instance="s1")
    # oversized -> rejected at admission
    big = _scene(7, 300)
    rid_rej = sched.submit(*big)
    # deadline_s=0 -> timeout converted at the next submit/flush tick
    rid_to = sched.submit(*_scene(8, 40), deadline_s=0.0)
    sched.flush()
    out = sched.take([rid_rej, rid_to])
    assert out[rid_rej].error.code == FLT.REJECTED
    assert out[rid_to].error.code == FLT.TIMEOUT
    st = sched.stats()
    assert st["faults"]["rejected"] == 1
    assert st["faults"]["timeout"] == 1
    assert st["latency_avg_s"] == 0.0         # OK histogram untouched
    errlat = obs.registry.histogram(
        "serve_error_latency_seconds",
        labelnames=("instance", "code"))
    assert errlat.labels("s1", FLT.REJECTED).count == 1
    assert errlat.labels("s1", FLT.TIMEOUT).count == 1
    # the error trace is closed with the error code as the outcome
    trace = obs.tracer.get(f"s1:rid:{rid_rej}")
    assert trace.closed
    assert trace.spans[trace.root_id].attrs["outcome"] == FLT.REJECTED
    sched.close()


def test_chaos_registry_reconciles(served):
    """Satellite: concurrent producers under a chaos plan (poisoned rid
    -> exec_failed, corrupted scene -> rejected) leave the registry
    arithmetically consistent and every completed rid's span tree
    closed, with the exec_failed flight-recorder dump emitted once."""
    _, engine = served
    n_producers, per_producer = 3, 4
    n_total = n_producers * per_producer
    plan = FaultPlan(poison_rids=frozenset({1}),
                     corrupt_scenes=frozenset({2}))
    obs = Observability.enabled()
    sched = ServeScheduler(engine, max_batch=2, fault_plan=plan,
                           obs=obs, instance="cx")
    rids, errs = [], []
    lock = threading.Lock()

    def producer(k):
        try:
            for j in range(per_producer):
                rid = sched.submit(*_scene(10 + k * per_producer + j,
                                           40 + 10 * j))
                with lock:
                    rids.append(rid)
        except Exception as e:                # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=producer, args=(k,))
               for k in range(n_producers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    sched.flush()
    out = sched.take(rids)
    st = sched.stats()
    ft = st["faults"]
    # conservation: every submit is accounted for, exactly once
    assert st["n_submitted"] == n_total
    assert st["n_completed"] == n_total
    assert st["n_submitted"] == (st["n_ok"] + ft["rejected"] + ft["shed"]
                                 + ft["timeout"] + ft["exec_failed"])
    assert ft["exec_failed"] == 1             # the poisoned rid
    assert ft["rejected"] == 1                # the corrupted scene
    n_ok = sum(1 for r in out.values() if r.ok)
    assert n_ok == st["n_ok"]
    # every completed rid's span tree is closed with a final outcome
    assert obs.tracer.stats()["live"] == 0
    for rid in rids:
        trace = obs.tracer.get(f"cx:rid:{rid}")
        assert trace is not None and trace.closed, rid
        assert "outcome" in trace.spans[trace.root_id].attrs
    # exec_failed triggered exactly one flight-recorder dump
    assert obs.recorder.stats()["dumps"] == 1
    (dump,) = obs.recorder.dumps
    assert dump["reason"] == "exec_failed"
    sched.close()


# ---------------------------------------------------------------------------
# router chaos: the acceptance trace (dispatch -> failover -> replay ->
# retire) + exactly-once dump
# ---------------------------------------------------------------------------

def test_router_failover_trace(served):
    # the kill must land while the first victim is genuinely in flight:
    # big scenes make the device execution (~tens of ms for a cap-65536
    # micro-batch) outlast failover detection (health tick every 2.5ms
    # spots the dead thread), so neither w0's end-of-iteration publish
    # nor the salvage harvest can retire it — it HAS to be replayed.
    # miss_beats is huge so the cold cap-65536 compile (seconds, inside
    # submit) is never misread as a hang.
    params, _ = served
    factory = PointCloudEngine.factory(params, 2, flow="fod",
                                       ladder=geometric_ladder(1024, 65536))
    liveness = LivenessPolicy(beat_s=0.005, miss_beats=1_000_000,
                              health_s=0.0025)

    def _big(seed):
        c, m, f = lidar_scene(seed=560 + seed, n_points=60_000, grid=64)
        return c, f, m

    # pick scenes the rendezvous digests route to worker w0, so the
    # kill (on w0's 2nd request) strands an in-flight victim
    probe = ServeRouter(factory, 2, max_batch=1)
    victims = []
    for s in range(24):
        c, f, m = _big(s)
        if probe.preview(c, m) == "w0":
            victims.append((c, f, m))
        if len(victims) == 2:
            break
    probe.close()
    assert len(victims) == 2, "seed sweep found no w0-routed scenes"

    obs = Observability.enabled()
    plan = FaultPlan(kill_workers={0: 1})
    router = ServeRouter(factory, 2, max_batch=1, fault_plan=plan,
                         liveness=liveness, obs=obs)
    out = router.serve(victims)
    st = router.stats()
    assert all(r.error is None for r in out.values())
    assert st["faults"]["failovers"] == 1
    assert st["faults"]["replayed"] >= 1
    router.close()

    # the victim's single trace spans both lives of the request; the
    # genuinely in-flight victim dispatched twice (w0 then the
    # survivor) — a victim killed before w0 touched it only once
    replayed = [t for t in obs.tracer.finished()
                if "failover" in t.names()]
    assert replayed, "no trace recorded the failover"
    inflight = [t for t in replayed if t.names().count("dispatch") == 2]
    assert inflight, [t.names() for t in replayed]
    trace = inflight[0]
    assert trace.closed
    assert trace.spans[trace.root_id].attrs["outcome"] == "ok"
    names = trace.names()
    i_disp = names.index("dispatch")
    i_fail = names.index("failover")
    i_replay = names.index("replay")
    i_retire = len(names) - 1 - names[::-1].index("retire")
    assert i_disp < i_fail < i_replay < i_retire, names
    # the replay re-ran admission/dispatch on the survivor
    assert names.count("admission") == 2
    assert names.count("dispatch") == 2
    # one failover incident -> exactly one flight-recorder dump
    assert obs.recorder.stats()["dumps"] == 1
    (dump,) = obs.recorder.dumps
    assert dump["reason"] == "failover"


# ---------------------------------------------------------------------------
# partition fan-out trace
# ---------------------------------------------------------------------------

def test_partition_chunk_trace(served):
    from repro.partition import PartitionPolicy

    params, _ = served
    obs = Observability.enabled()
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=geometric_ladder(64, 128),
                              obs=obs)
    c, m, f = lidar_scene(seed=460, n_points=100, grid=16)
    preds, _ = engine.segment(
        c, m, f, partition=PartitionPolicy(chunk_budget=32, force=True))
    assert int((np.asarray(preds)[m] < 0).sum()) == 0
    part = [t for t in obs.tracer.finished() if t.tid.startswith("partition:")]
    assert len(part) == 1
    trace = part[0]
    assert trace.closed
    assert trace.spans[trace.root_id].attrs["outcome"] == "ok"
    (fan,) = trace.find("chunk_fanout")
    (stitch,) = trace.find("stitch")
    n_chunks = engine.last_partition_stats["n_chunks"]
    assert fan.attrs["n_chunks"] == n_chunks
    assert len(fan.attrs["rids"]) == n_chunks
    assert stitch.attrs["n_errors"] == 0
    # each chunk rid cross-references an ordinary closed request trace
    for rid in fan.attrs["rids"]:
        chunk = obs.tracer.get(f"scheduler:rid:{rid}")
        assert chunk is not None and chunk.closed
