"""Training substrate tests: losses, optimizer, train step, serving engine,
checkpoint manager, fault-tolerance pieces."""

import os
import signal
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.models import registry
from repro.train import losses as LO
from repro.train import optim as OPT
from repro.train.step import TrainConfig, make_train_step


def test_chunked_ce_matches_naive():
    rng = np.random.default_rng(0)
    b, s, d, v = 2, 32, 16, 128
    hidden = jnp.asarray(rng.normal(size=(b, s, d)).astype(np.float32))
    head = jnp.asarray(rng.normal(size=(d, v)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, v, (b, s)))
    logits = hidden @ head
    naive, _ = LO.cross_entropy(logits, labels)
    for n_chunks in (1, 4, 8):
        chunked, _ = LO.chunked_cross_entropy(hidden, head, labels,
                                              n_chunks=n_chunks)
        np.testing.assert_allclose(float(naive), float(chunked), rtol=1e-5)
    # tied-embedding orientation
    chunked_t, _ = LO.chunked_cross_entropy(hidden, head.T, labels,
                                            transpose_head=True)
    np.testing.assert_allclose(float(naive), float(chunked_t), rtol=1e-5)
    # softcap path
    capped = 30.0 * jnp.tanh(logits / 30.0)
    naive_cap, _ = LO.cross_entropy(capped, labels)
    chunked_cap, _ = LO.chunked_cross_entropy(hidden, head, labels,
                                              softcap=30.0)
    np.testing.assert_allclose(float(naive_cap), float(chunked_cap),
                               rtol=1e-5)


def test_chunked_ce_grads_match():
    rng = np.random.default_rng(1)
    b, s, d, v = 2, 16, 8, 64
    hidden = jnp.asarray(rng.normal(size=(b, s, d)).astype(np.float32))
    head = jnp.asarray(rng.normal(size=(d, v)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, v, (b, s)))
    g1 = jax.grad(lambda h: LO.cross_entropy(h @ head, labels)[0])(hidden)
    g2 = jax.grad(lambda h: LO.chunked_cross_entropy(
        h, head, labels, n_chunks=4)[0])(hidden)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4,
                               atol=1e-5)


def test_adamw_minimises_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = OPT.init(params)
    cfg = OPT.AdamWConfig(lr=0.1, warmup_steps=1, total_steps=200,
                          weight_decay=0.0)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, opt, m = OPT.apply_updates(params, opt, grads, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1
    assert float(m["grad_norm"]) >= 0


def test_train_step_decreases_loss():
    cfg = configs.get("qwen1.5-4b", reduced=True)
    model = registry.build(cfg)
    params = model.init(jax.random.key(0))
    opt = OPT.init(params)
    tc = TrainConfig(compute_dtype=jnp.float32, remat=True,
                     use_chunked_ce=False)
    step = jax.jit(make_train_step(model, tc,
                                   OPT.AdamWConfig(lr=1e-3, warmup_steps=2,
                                                   total_steps=50)))
    from repro.data.synthetic import token_batch
    losses = []
    for t in range(20):
        b = token_batch(0, t % 2, 4, 16, cfg.vocab_size)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt, metrics = step(params, opt, b)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_grad_accumulation_matches_full_batch():
    cfg = configs.get("granite-34b", reduced=True)
    model = registry.build(cfg)
    params = model.init(jax.random.key(1))
    from repro.data.synthetic import token_batch
    b = token_batch(1, 0, 8, 16, cfg.vocab_size)
    b = {k: jnp.asarray(v) for k, v in b.items()}
    ocfg = OPT.AdamWConfig()
    tc1 = TrainConfig(compute_dtype=jnp.float32, use_chunked_ce=False,
                      accum_steps=1)
    tc2 = TrainConfig(compute_dtype=jnp.float32, use_chunked_ce=False,
                      accum_steps=4)
    p1, _, m1 = jax.jit(make_train_step(model, tc1, ocfg))(
        params, OPT.init(params), b)
    p2, _, m2 = jax.jit(make_train_step(model, tc2, ocfg))(
        params, OPT.init(params), b)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-4)
    for a, c in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=1e-3, atol=1e-4)


def test_serve_engine_greedy_generation():
    cfg = configs.get("mixtral-8x7b", reduced=True)
    model = registry.build(cfg)
    params = model.init(jax.random.key(2))
    from repro.serve.lm import ServeConfig, ServeEngine
    eng = ServeEngine(model, params, ServeConfig(max_len=32,
                                                 cache_dtype=jnp.float32,
                                                 compute_dtype=jnp.float32))
    prompts = np.arange(12, dtype=np.int32).reshape(2, 6) % cfg.vocab_size
    out = eng.generate(prompts, max_new_tokens=5)
    assert out.shape == (2, 5)
    assert np.all(out >= 0) and np.all(out < cfg.vocab_size)

    # greedy decode must equal argmax over teacher-forced logits
    full = np.concatenate([prompts, out], axis=1)
    s = full.shape[1]
    batch = {"tokens": jnp.asarray(full),
             "positions": jnp.broadcast_to(jnp.arange(s), (2, s))}
    logits, _ = model.train_logits(params, batch)
    expect = np.asarray(jnp.argmax(logits, -1))[:, 5:-1]
    np.testing.assert_array_equal(out, expect)


def test_checkpoint_manager_async(tmp_path):
    from repro.checkpoint.store import CheckpointManager, latest_step
    mgr = CheckpointManager(str(tmp_path), keep=2, interval_steps=2)
    tree = {"w": jnp.ones((4,))}
    for step in range(1, 7):
        mgr.maybe_save(step, tree)
    mgr.close()
    assert latest_step(str(tmp_path)) == 6
    import os
    kept = [n for n in os.listdir(tmp_path) if n.startswith("step_")]
    assert len(kept) <= 2


def test_preemption_handler_and_timer():
    from repro.launch.fault_tolerance import PreemptionHandler, StepTimer
    with PreemptionHandler(signals=(signal.SIGUSR1,)) as p:
        assert not p.should_stop
        os.kill(os.getpid(), signal.SIGUSR1)
        time.sleep(0.05)
        assert p.should_stop
    t = StepTimer(window=10, straggler_factor=2.0)
    for _ in range(6):
        t.start()
        time.sleep(0.01)
        s = t.stop()
    t.start()
    time.sleep(0.08)
    s = t.stop()
    assert s["straggler"]


def test_data_pipeline_deterministic_skip_ahead():
    from repro.data.pipeline import PrefetchIterator
    from repro.data.synthetic import token_batch

    def bf(step):
        return token_batch(0, step, 2, 8, 100)

    it1 = PrefetchIterator(bf, start_step=0)
    seq1 = [next(it1) for _ in range(5)]
    it1.close()
    it2 = PrefetchIterator(bf, start_step=3)      # skip-ahead restart
    s, b = next(it2)
    it2.close()
    assert s == 3
    np.testing.assert_array_equal(b["tokens"], seq1[3][1]["tokens"])
