"""City-scale partitioning (repro.partition): octree range splitting
over the 62-bit packed keys, exact receptive-field halos, chunk-streamed
serving through the scheduler, and the halo-exactness acceptance —
chunked predictions equal the monolithic network's on every valid row,
for all three conv flows.  The border behaviour of the underlying
mapping ops (`downsample_sorted` / `kernel_map_v2` at chunk boundaries,
including stride cells straddling a split) is pinned at the map level."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import mapping as M
from repro.core import packed as PK
from repro.data.synthetic import city_scene, lidar_scene
from repro.models import minkunet as MU
from repro.partition import HaloSpec, PartitionPolicy, plan_partition, \
    split_ranges
from repro.partition.halo import build_pyramid
from repro.partition.octree import rank_keys
from repro.serve import faults as FLT
from repro.serve.buckets import geometric_ladder
from repro.serve.engine import PointCloudEngine


def _mini_params(n_classes=2):
    return MU.mini_minkunet_init(jax.random.key(0), c_in=4,
                                 n_classes=n_classes)


def _ref_preds(params, coords, mask, feats, flow="fod"):
    pc = M.make_point_cloud(jnp.asarray(coords), jnp.asarray(mask))
    logits = MU.minkunet_apply(params, pc, jnp.asarray(feats), flow=flow)
    return np.asarray(jnp.argmax(logits, -1))


def _rand_sorted_keys(rng, n, dup_frac=0.3):
    """Sorted packed keys of random in-budget coords, with deliberate
    duplicates (multi-row sites)."""
    coords = np.concatenate(
        [rng.integers(0, PK.BATCH_MAX + 1, size=(n, 1)),
         rng.integers(PK.COORD_MIN, PK.COORD_MAX + 1, size=(n, 3))],
        axis=1).astype(np.int64)
    n_dup = int(n * dup_frac)
    coords[:n_dup] = coords[rng.integers(n_dup, n, size=n_dup)]
    return np.sort(PK.pack_coords_host(coords))


# ---------------------------------------------------------------------------
# octree range splitting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("budget", [1, 7, 64, 10_000])
def test_split_ranges_invariants(budget):
    """Coverage, ordering, budget bound, and the no-split-equal-keys
    guarantee, on keys with duplicate sites."""
    rng = np.random.default_rng(5)
    keys = _rand_sorted_keys(rng, 400)
    ranges = split_ranges(keys, budget)

    # exact disjoint cover of [0, n) in order
    assert ranges[0][0] == 0 and ranges[-1][1] == keys.shape[0]
    for (s, e), (s2, _) in zip(ranges, ranges[1:]):
        assert s < e and e == s2
    for s, e in ranges:
        # a leaf over budget is only legal when the trie ran out of bits
        # — i.e. every key in the leaf is the same site
        if e - s > budget:
            assert (keys[s:e] == keys[s]).all()
        # equal keys are never separated across a boundary
        if s > 0:
            assert keys[s - 1] != keys[s]


def test_split_ranges_equal_keys_stay_together():
    keys = np.full(17, 12345, np.uint64)
    assert split_ranges(keys, 1) == [(0, 17)]
    assert split_ranges(np.empty(0, np.uint64), 4) == []


def test_rank_keys_orders_valid_rows_first():
    coords, mask, _ = lidar_scene(seed=2, n_points=120, grid=16)
    keys, order, n_valid = rank_keys(coords, mask)
    assert n_valid == int(mask.sum())
    # ascending keys, sentinels (invalid rows) ranked last
    assert (np.diff(keys.astype(np.uint64)) >= 0).all()
    assert (keys[:n_valid] < PK.KEY64_SENTINEL).all()
    assert (keys[n_valid:] == PK.KEY64_SENTINEL).all()
    assert mask[order[:n_valid]].all() and not mask[order[n_valid:]].any()
    # keys really are the packed coords of the ranked rows
    np.testing.assert_array_equal(
        keys[:n_valid], PK.pack_coords_host(coords[order[:n_valid]]))


# ---------------------------------------------------------------------------
# plan: ownership and halo accounting
# ---------------------------------------------------------------------------

def test_every_valid_point_is_interior_to_exactly_one_chunk():
    """Border ownership: wherever the octree cuts, each valid row lands
    in exactly one chunk's interior; halo rows are duplicates on top."""
    coords, mask, feats = city_scene(seed=4, n_points=1500)
    ladder = geometric_ladder(128, 2048)
    plan = plan_partition(coords, mask, feats,
                          spec=HaloSpec.uniform(2, 1), ladder=ladder,
                          policy=PartitionPolicy(chunk_budget=256,
                                                 force=True))
    assert plan.n_chunks > 1
    owned = np.concatenate([c.rows[c.interior] for c in plan.chunks])
    assert owned.shape[0] == int(mask.sum())          # no row lost ...
    assert np.unique(owned).shape[0] == owned.shape[0]  # ... or doubled
    assert set(owned) == set(np.flatnonzero(mask))
    for c in plan.chunks:
        assert c.mask.all() and c.n_points <= ladder.capacities[-1]
        np.testing.assert_array_equal(c.coords, coords[c.rows])
        np.testing.assert_array_equal(c.feats, feats[c.rows])
    assert 0.0 <= plan.halo_fraction < 1.0
    assert plan.stats()["halo_rows"] == sum(c.n_halo for c in plan.chunks)


def test_stitch_marks_failed_chunks_and_invalid_rows():
    coords, mask, feats = lidar_scene(seed=6, n_points=200, grid=16)
    plan = plan_partition(coords, mask, feats,
                          spec=HaloSpec.uniform(2, 1),
                          ladder=geometric_ladder(64, 512),
                          policy=PartitionPolicy(chunk_budget=48,
                                                 force=True))
    assert plan.n_chunks >= 2
    preds = [np.full(c.n_points, 7, np.int32) for c in plan.chunks]
    preds[0] = None                                   # a failed chunk
    out = plan.stitch(preds)
    dead = plan.chunks[0].rows[plan.chunks[0].interior]
    assert (out[dead] == -1).all()
    assert (out[~mask] == -1).all()
    alive = np.concatenate([c.rows[c.interior] for c in plan.chunks[1:]])
    assert (out[alive] == 7).all()


def test_policy_validation_and_unpartitionable_scene():
    coords, mask, feats = lidar_scene(seed=8, n_points=600, grid=12)
    spec = HaloSpec.uniform(2, 1)
    with pytest.raises(ValueError, match="chunk_budget"):
        plan_partition(coords, mask, feats, spec=spec,
                       ladder=geometric_ladder(64, 128),
                       policy=PartitionPolicy(chunk_budget=4096))
    # a dense 12^3 blob's receptive-field ball cannot fit a 128-row top
    # bucket at any budget: planning must fail loudly, not silently drop
    with pytest.raises(ValueError, match="halo outgrows the ladder"):
        plan_partition(coords, mask, feats, spec=spec,
                       ladder=geometric_ladder(64, 128),
                       policy=PartitionPolicy(chunk_budget=64, force=True))


def test_halo_spec_from_params():
    spec = MU.halo_spec(_mini_params())
    assert spec == HaloSpec.uniform(2, 1)
    assert spec.n_stages == 2
    assert spec.dec_rounds == (2, 2) and spec.enc_rounds == (1, 2, 2)


# ---------------------------------------------------------------------------
# mapping ops at chunk borders (downsample_sorted / kernel_map_v2)
# ---------------------------------------------------------------------------

def _subm_neighbor_sets(coords, k=3):
    """{point coord -> frozenset of matched k^3 neighbour coords} via
    kernel_map_v2's inverse table (all rows valid)."""
    mask = np.ones(coords.shape[0], bool)
    pc = M.make_point_cloud(jnp.asarray(coords), jnp.asarray(mask))
    sc = M.sort_cloud(pc)
    inv = np.asarray(M.kernel_map_v2(sc, pc, k).inv)
    cn = np.asarray(pc.coords)
    return {tuple(cn[j]): frozenset(tuple(cn[inv[o, j]])
                                    for o in range(inv.shape[0])
                                    if inv[o, j] >= 0)
            for j in range(coords.shape[0])}


def _down_member_sets(coords):
    """{stride-2 cell coord -> frozenset of its member point coords} via
    downsample_sorted + the k=2 kernel map (all rows valid)."""
    mask = np.ones(coords.shape[0], bool)
    pc = M.make_point_cloud(jnp.asarray(coords), jnp.asarray(mask))
    sc0 = M.sort_cloud(pc)
    sc1 = M.downsample_sorted(sc0)
    inv = np.asarray(M.kernel_map_v2(sc0, sc1.pc, 2).inv)
    c0 = np.asarray(pc.coords)
    c1, m1 = np.asarray(sc1.pc.coords), np.asarray(sc1.pc.mask)
    return {tuple(c1[j]): frozenset(tuple(c0[inv[o, j]])
                                    for o in range(inv.shape[0])
                                    if inv[o, j] >= 0)
            for j in range(c1.shape[0]) if m1[j]}


def test_chunk_border_maps_match_monolithic_on_interior():
    """A straddling-stride split: collinear points along z cut mid cell-
    pair (the octree's lowest split bit is z's bit 0, so stride-2 cell
    partners CAN land in different chunks).  Every boundary point must be
    interior to exactly one chunk, and on interior sites both the k=3
    submanifold map and the stride-2 downsample map of the halo'd chunk
    cloud must match the monolithic cloud's exactly."""
    n = 16
    coords = np.zeros((n, 4), np.int32)
    coords[:, 3] = np.arange(n)                       # a line along z
    mask = np.ones(n, bool)
    feats = np.zeros((n, 4), np.float32)
    plan = plan_partition(coords, mask, feats,
                          spec=HaloSpec.uniform(1, 1),
                          ladder=geometric_ladder(8, 64),
                          policy=PartitionPolicy(chunk_budget=2,
                                                 force=True))
    assert plan.n_chunks == n // 2
    # cell partners {2k, 2k+1} really do straddle chunk boundaries:
    # interiors are 2-point ranges, so every odd z is a border
    interiors = sorted(tuple(sorted(c.coords[c.interior][:, 3]))
                       for c in plan.chunks)
    assert interiors == [(2 * k, 2 * k + 1) for k in range(n // 2)]

    mono_subm = _subm_neighbor_sets(coords)
    mono_down = _down_member_sets(coords)
    for chunk in plan.chunks:
        sub = _subm_neighbor_sets(chunk.coords)
        down = _down_member_sets(chunk.coords)
        for p in map(tuple, chunk.coords[chunk.interior]):
            assert sub[p] == mono_subm[p]
        # every stride-2 cell owned by an interior point is present in
        # the chunk's downsampled cloud with its full member set
        cells = {tuple(q) for q in
                 np.asarray(M.quantize_coords(
                     jnp.asarray(chunk.coords[chunk.interior]), 2))}
        for cell in cells:
            assert down[cell] == mono_down[cell]


def test_build_pyramid_matches_downsample_sorted():
    """The host-side key pyramid the halo walk uses = the device
    `downsample_sorted` pyramid, level by level."""
    coords, mask, _ = lidar_scene(seed=9, n_points=300, grid=16)
    keys, _, n_valid = rank_keys(coords, mask)
    pyr = build_pyramid(np.unique(keys[:n_valid]), n_stages=2)
    sc = M.sort_cloud(M.make_point_cloud(jnp.asarray(coords),
                                         jnp.asarray(mask)))
    for level in range(3):
        cn = np.asarray(sc.pc.coords)[np.asarray(sc.pc.mask)]
        np.testing.assert_array_equal(
            pyr.levels[level], np.sort(PK.pack_coords_host(cn)))
        if level < 2:
            sc = M.downsample_sorted(sc)


# ---------------------------------------------------------------------------
# acceptance: chunked == monolithic, oversized completes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("flow", ["fod", "pallas", "pallas_fused"])
def test_forced_partition_matches_monolithic(flow):
    """Halo exactness end to end: a scene that fits the ladder, served
    monolithically and force-chunked, gives identical class ids on every
    valid row (and -1 on masked rows), for all three conv flows."""
    params = _mini_params()
    engine = PointCloudEngine(params, n_stages=2, flow=flow,
                              ladder=geometric_ladder(128, 512))
    coords, mask, feats = lidar_scene(seed=12, n_points=400, grid=16)
    mono, _ = engine.segment(coords, mask, feats)
    part, _ = engine.segment(
        coords, mask, feats,
        partition=PartitionPolicy(chunk_budget=96, force=True))
    part = np.asarray(part)
    assert engine.last_partition_stats["n_chunks"] > 1
    assert engine.last_partition_stats["chunk_errors"] == 0
    np.testing.assert_array_equal(part[mask], np.asarray(mono)[mask])
    assert (part[~mask] == -1).all()
    np.testing.assert_array_equal(
        part[mask], _ref_preds(params, coords, mask, feats, flow)[mask])


def test_oversized_scene_completes_via_partition():
    """The PR's headline: a scene the seed path rejects — segment()
    raises, the scheduler returns a typed `rejected`/`oversized` result
    whose message carries the ladder max and the packed-key budget —
    completes through segment(partition='auto') and matches the
    reference network output exactly."""
    params = _mini_params()
    ladder = geometric_ladder(256, 2048)
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=ladder)
    coords, mask, feats = city_scene(seed=15, n_points=3000)

    with pytest.raises(ValueError, match="exceeds the bucket ladder"):
        engine.segment(coords, mask, feats)
    sched = engine.scheduler()
    res = sched.take([sched.submit(coords, feats, mask)]).popitem()[1]
    assert res.error is not None
    assert res.error.code == FLT.REJECTED
    assert res.error.detail == FLT.OVERSIZED          # vs "malformed"
    assert str(ladder.capacities[-1]) in res.error.message
    assert "packed-key budget" in res.error.message
    assert "partition" in res.error.message
    # ... while a malformed scene is distinguishable by detail
    bad = feats.copy()
    bad[mask.argmax()] = np.nan
    r2 = sched.take([sched.submit(coords, bad, mask)]).popitem()[1]
    assert r2.error.code == FLT.REJECTED
    assert r2.error.detail == FLT.MALFORMED

    preds, hit = engine.segment(coords, mask, feats, partition="auto")
    preds = np.asarray(preds)
    st = engine.last_partition_stats
    assert st["n_chunks"] > 1 and st["chunk_errors"] == 0
    assert st["max_chunk_points"] <= ladder.capacities[-1]
    np.testing.assert_array_equal(
        preds[mask], _ref_preds(params, coords, mask, feats)[mask])
    assert (preds[~mask] == -1).all()

    # a repeated frame hits the mapping cache chunk by chunk
    again, hit = engine.segment(coords, mask, feats, partition="auto")
    assert hit is True
    np.testing.assert_array_equal(np.asarray(again), preds)
