"""Fault-tolerant serving runtime: the error taxonomy and admission
validation (serve/faults.py), the scheduler's failure-isolation policies
(rejected / shed / timeout / exec_failed results, retry + bisect poison
isolation, bounded backlog, per-request deadlines), the background
watchdog + close() lifecycle (launch/fault_tolerance.py Ticker), and
`segment_batch`'s per-scene error surfacing.  The end-to-end chaos test
(concurrent producers + injected FaultPlan) lives in
tests/test_serve_scheduler.py."""

import threading
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import mapping as M
from repro.core import packed as PK
from repro.data.synthetic import lidar_scene
from repro.launch.fault_tolerance import Heartbeat, Ticker
from repro.models import minkunet as MU
from repro.serve import faults as FLT
from repro.serve.buckets import BucketLadder, geometric_ladder
from repro.serve.engine import PointCloudEngine
from repro.serve.faults import (AdmissionError, FaultPlan, InjectedFault,
                                ServeError, validate_scene)
from repro.serve.scheduler import ServeScheduler


def _mini_params(n_classes=2):
    return MU.mini_minkunet_init(jax.random.key(0), c_in=4,
                                 n_classes=n_classes)


def _ref_preds(params, coords, mask, feats, flow="fod"):
    pc = M.make_point_cloud(jnp.asarray(coords), jnp.asarray(mask))
    logits = MU.minkunet_apply(params, pc, jnp.asarray(feats), flow=flow)
    return np.asarray(jnp.argmax(logits, -1))


def _scene_cf(seed, n):
    c, m, f = lidar_scene(seed=140 + seed, n_points=n, grid=16)
    return c, f, m


@pytest.fixture(scope="module")
def served():
    """(params, engine) shared across the suite — every test builds its
    own ServeScheduler (policy under test) over the same compiled
    programs, so the suite pays the jit cost once."""
    # this module sits late in the full run and compiles fresh full-model
    # programs; drop executables accumulated by earlier modules so the
    # CPU backend's JIT doesn't run out of code space mid-compile
    jax.clear_caches()
    params = _mini_params()
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=geometric_ladder(64, 128))
    return params, engine


# ---------------------------------------------------------------------------
# taxonomy + validation units (no engine)
# ---------------------------------------------------------------------------

def test_serve_error_taxonomy():
    err = ServeError(FLT.EXEC_FAILED, "boom")
    assert str(err) == "[exec_failed] boom"
    with pytest.raises(ValueError, match="unknown serve error code"):
        ServeError("oom", "nope")
    adm = AdmissionError("bad scene")
    assert isinstance(adm, ValueError)
    e = adm.as_error()
    assert e.code == FLT.REJECTED and e.message == "bad scene"
    assert set(FLT.ERROR_CODES) == {"rejected", "timeout", "shed",
                                    "exec_failed"}


def test_validate_scene_rejections():
    ladder = BucketLadder((64, 128))
    c, f, m = _scene_cf(0, 40)

    def reject(match, **kw):
        args = {"coords": c, "feats": f, "mask": m, "ladder": ladder}
        args.update(kw)
        with pytest.raises(AdmissionError, match=match):
            validate_scene(args["coords"], args["feats"], args["mask"],
                           args["ladder"])

    # the happy path round-trips and resolves the bucket
    vc, vm, vf, n, cap = validate_scene(c, f, m, ladder)
    assert (n, cap) == (40, 64)
    np.testing.assert_array_equal(vc, c)

    reject("must be", coords=c[:, 0])                   # 1-D coords
    reject("does not match", feats=f[:-1])              # ragged feats
    reject("does not match", mask=m[:-1])               # ragged mask
    reject("not integer-compatible",
           coords=c.astype(np.complex64))
    reject("NaN/Inf", coords=np.where(c == c[0, 0], np.nan,
                                      c.astype(np.float32)))
    bad_f = f.copy()
    bad_f[np.flatnonzero(m)[0]] = np.nan        # NaN on a VALID row
    reject("NaN/Inf", feats=bad_f)
    # NaN on a MASKED row is fine — the row never enters a kernel
    masked_f = f.copy()
    dead = np.flatnonzero(~m)
    if dead.size:
        masked_f[dead[0]] = np.nan
        validate_scene(c, masked_f, m, ladder)
    reject("exceeds the bucket ladder", coords=np.tile(c, (5, 1)),
           feats=np.tile(f, (5, 1)), mask=np.tile(m, 5))

    # packed-key budget: spatial overflow and batch-index overflow on a
    # VALID row (masked rows are exempt — they never reach a key)
    row = np.flatnonzero(m)[0]
    over = c.astype(np.int64)
    over[row, 1] = PK.COORD_MAX + 1
    with pytest.raises(AdmissionError, match="packed-key budget"):
        validate_scene(over, f, m, ladder)
    bad_batch = c.astype(np.int64)
    bad_batch[row, 0] = PK.BATCH_MAX + 1
    with pytest.raises(AdmissionError, match="packed-key budget"):
        validate_scene(bad_batch, f, m, ladder)
    # ... but the v1 engine has no key budget
    validate_scene(over, f, m, ladder, check_key_budget=False)

    # stream-consistency pins (first-seen widths from the scheduler)
    with pytest.raises(AdmissionError, match="stream"):
        validate_scene(c, f, m, ladder, coord_dim=5)
    with pytest.raises(AdmissionError, match="stream"):
        validate_scene(c, f, m, ladder, feat_shape=(f.shape[1] + 1,))

    # mask=None defaults to all-valid
    _, vm, _, _, _ = validate_scene(c, f, None, ladder)
    assert vm.all() and vm.shape == (40,)


def test_fault_plan_seams():
    plan = FaultPlan(fail_dispatches={1}, poison_rids={7},
                     corrupt_scenes={0}, delay_buckets={64: 0.01})
    c, f, m = _scene_cf(1, 8)
    _, cf, _ = plan.on_submit(c, f, m)          # ordinal 0: corrupted
    assert np.isnan(cf).any() and not np.isnan(f).any()
    _, cf2, _ = plan.on_submit(c, f, m)         # ordinal 1: untouched
    assert not np.isnan(np.asarray(cf2, np.float32)).any()

    plan.check_wait(0, 128, [1, 2])             # clean dispatch
    t0 = time.monotonic()
    with pytest.raises(InjectedFault, match="dispatch 1"):
        plan.check_wait(1, 64, [3])             # planned failure + delay
    assert time.monotonic() - t0 >= 0.01
    with pytest.raises(InjectedFault, match="poisoned"):
        plan.check_wait(5, 128, [6, 7])         # poisoned rid
    assert plan.stats() == {"submits_seen": 2, "scenes_corrupted": 1,
                            "failures_injected": 2, "delays_injected": 1,
                            "workers_killed": 0, "workers_hung": 0,
                            "slowdowns_injected": 0, "storm_paced": 0}


def test_fault_plan_worker_seams():
    """Router chaos seams: a planned kill raises at exactly the planned
    per-worker step; a planned hang blocks once, only on a warm worker
    (step >= 1), and never raises."""
    plan = FaultPlan(kill_workers={0: 2}, hang_workers={1: 0.06})
    plan.on_worker_step(0, 0)
    plan.on_worker_step(0, 1)
    with pytest.raises(InjectedFault, match=r"worker 0, step 2"):
        plan.on_worker_step(0, 2)

    t0 = time.monotonic()
    plan.on_worker_step(1, 0)               # cold worker: no hang yet
    assert time.monotonic() - t0 < 0.05
    t0 = time.monotonic()
    plan.on_worker_step(1, 1)               # warm: hangs for the duration
    assert time.monotonic() - t0 >= 0.06
    t0 = time.monotonic()
    plan.on_worker_step(1, 2)               # fires once only
    assert time.monotonic() - t0 < 0.05
    st = plan.stats()
    assert st["workers_killed"] == 1 and st["workers_hung"] == 1


def test_fault_plan_close_wakes_injected_waits():
    """Satellite: close() wakes planned delays and hangs early, so
    shutdown under chaos is prompt instead of waiting out multi-second
    injected stalls."""
    plan = FaultPlan(delay_buckets={64: 30.0}, hang_workers={0: 30.0})
    done = []

    def waiter():
        plan.check_wait(0, 64, [0])         # 30s delay unless woken
        plan.on_worker_step(0, 1)           # 30s hang unless woken
        done.append(time.monotonic())

    th = threading.Thread(target=waiter)
    t0 = time.monotonic()
    th.start()
    time.sleep(0.05)
    assert not done and not plan.closed     # really waiting
    plan.close()
    th.join(5.0)
    assert done and done[0] - t0 < 5.0 and plan.closed


def test_ticker_and_heartbeat_close_join():
    """Satellite bugfix: close() JOINS the watcher thread — no daemon
    threads leak past their owner."""
    ticks = []
    with Ticker(0.01, lambda: ticks.append(1), name="t-test") as t:
        time.sleep(0.05)
        assert t.alive
    assert not t.alive and len(ticks) >= 1      # joined on exit
    n = len(ticks)
    time.sleep(0.03)
    assert len(ticks) == n                      # really stopped
    with pytest.raises(ValueError, match="interval"):
        Ticker(0.0, lambda: None)

    # a tick that raises is swallowed; the ticker keeps ticking
    boom = []
    t2 = Ticker(0.01, lambda: boom.append(1) or (_ for _ in ()).throw(
        RuntimeError("tick boom")))
    time.sleep(0.05)
    t2.close()
    assert len(boom) >= 2 and not t2.alive

    stalls = []
    hb = Heartbeat(stall_s=0.04, on_stall=stalls.append)
    time.sleep(0.08)                            # no beat() -> stall fires
    assert stalls and stalls[0] > 0.04
    hb.beat()
    hb.close()
    assert not hb._ticker.alive                 # joined, not abandoned


# ---------------------------------------------------------------------------
# scheduler failure policies
# ---------------------------------------------------------------------------

def test_submit_rejects_malformed_scenes_without_raising(served):
    """Admission control: NaN feats, ragged shapes, oversized scenes and
    mixed stream widths all complete as `rejected` results; the stream
    keeps serving and every submit is counted."""
    params, engine = served
    sched = ServeScheduler(engine, max_batch=2, mesh=None)
    c, f, m = _scene_cf(2, 40)

    bad_f = f.copy()
    bad_f[m.argmax()] = np.nan                  # NaN on a VALID row
    r1 = sched.take([sched.submit(c, bad_f, m)]).popitem()[1]
    assert r1.error.code == "rejected" and "NaN" in r1.error.message
    r2 = sched.take([sched.submit(c, f[:-1], m)]).popitem()[1]
    assert r2.error.code == "rejected"
    r3 = sched.take([sched.submit(*_scene_cf(3, 4000))]).popitem()[1]
    assert "exceeds the bucket ladder" in r3.error.message

    # a good scene pins the stream widths ...
    good = sched.submit(c, f, m)
    sched.flush()
    ok = sched.take([good])[good]
    assert ok.ok and ok.error is None
    np.testing.assert_array_equal(ok.preds, _ref_preds(params, c, m, f))
    # ... and a different-width scene is now refused
    r4 = sched.take([sched.submit(c[:, :3], f, m)]).popitem()[1]
    assert r4.error.code == "rejected" and "stream" in r4.error.message

    st = sched.stats()
    assert st["n_submitted"] == 5 and st["n_completed"] == 5
    assert st["n_ok"] == 1 and st["faults"]["rejected"] == 4


def test_shed_policy_bounds_per_bucket_backlog(served):
    """max_backlog: a submit into a backed-up bucket completes with a
    `shed` result (reject-newest); completions free the budget."""
    params, engine = served
    sched = ServeScheduler(engine, max_batch=2, mesh=None,
                           pipeline_depth=2, max_backlog=2)
    a, b, cst = _scene_cf(4, 40), _scene_cf(5, 40), _scene_cf(6, 40)
    r1 = sched.submit(*a)
    r2 = sched.submit(*b)                       # fills the bucket: parked
    r3 = sched.submit(*cst)                     # backlog 2 >= 2: shed
    out = sched.take([r1, r2, r3])
    assert out[r1].ok and out[r2].ok
    assert out[r3].error.code == "shed"
    assert "max_backlog" in out[r3].error.message
    np.testing.assert_array_equal(out[r1].preds,
                                  _ref_preds(params, *a[::2], a[1]))
    # budget freed: the same scene is admitted now
    r4 = sched.submit(*cst)
    sched.flush()
    assert sched.take([r4])[r4].ok
    st = sched.stats()
    assert st["faults"]["shed"] == 1 and st["n_ok"] == 3


def test_deadline_s_times_out_overdue_queued_requests(served):
    """Per-request deadline_s: still queued past its deadline -> a
    `timeout` result; peers without a deadline keep waiting."""
    params, engine = served
    sched = ServeScheduler(engine, max_batch=4, mesh=None, watchdog_s=0)
    a, b = _scene_cf(7, 40), _scene_cf(8, 40)
    r1 = sched.submit(*a, deadline_s=0.01)
    r2 = sched.submit(*b)                       # no deadline
    time.sleep(0.03)
    polled = {r.rid: r for r in sched.poll()}   # expiry runs here
    st = sched.stats()
    assert st["faults"]["timeout"] == 1 and st["queue_depth"] == 1
    sched.flush()
    out = {**polled, **sched.take([r1, r2])}
    assert out[r1].error.code == "timeout"
    assert "deadline_s" in out[r1].error.message
    assert out[r2].ok
    np.testing.assert_array_equal(out[r2].preds,
                                  _ref_preds(params, b[0], b[2], b[1]))


def test_transient_dispatch_failure_retries_bit_identical(served):
    """A one-shot dispatch failure is retried transparently: the FIFO is
    NOT poisoned, every request completes with predictions bit-identical
    to the fault-free reference, and the fault counters record it."""
    params, engine = served
    plan = FaultPlan(fail_dispatches={0})
    sched = ServeScheduler(engine, max_batch=2, mesh=None,
                           fault_plan=plan)
    scenes = [_scene_cf(i, 40) for i in (9, 10)]
    out = sched.serve(scenes)
    assert all(r.ok for r in out.values())
    for rid, (c, f, m) in zip(sorted(out), scenes):
        np.testing.assert_array_equal(out[rid].preds,
                                      _ref_preds(params, c, m, f))
    st = sched.stats()["faults"]
    assert st["failed_dispatches"] == 1 and st["exec_failed"] == 0
    assert st["retries"] == 2                   # bisected into singles
    assert st["recovery_s"] is not None and st["recovery_s"] >= 0
    assert plan.stats()["failures_injected"] == 1


def test_retry_backoff_jittered_counted_and_off_by_default(served):
    """Satellite: retry dispatches back off (jittered exponential) so a
    transiently sick device isn't hammered; results stay bit-identical,
    the waited time is counted, and the default (0) preserves the
    immediate-retry timing."""
    params, engine = served
    scenes = [_scene_cf(i, 40) for i in (9, 10)]
    sched = ServeScheduler(engine, max_batch=2, mesh=None,
                           fault_plan=FaultPlan(fail_dispatches={0}),
                           retry_backoff_s=0.05)
    t0 = time.monotonic()
    out = sched.serve(scenes)
    dt = time.monotonic() - t0
    sched.close()
    assert all(r.ok for r in out.values())
    for rid, (c, f, m) in zip(sorted(out), scenes):
        np.testing.assert_array_equal(out[rid].preds,
                                      _ref_preds(params, c, m, f))
    backed = sched.stats()["faults"]["retry_backoff_s"]
    assert backed >= 0.025                  # base * 2^0 * jitter in [0.5, 1.5)
    assert dt >= 0.025                      # ... and it was really slept

    sched2 = ServeScheduler(engine, max_batch=2, mesh=None,
                            fault_plan=FaultPlan(fail_dispatches={0}))
    out2 = sched2.serve(scenes)
    sched2.close()
    assert all(r.ok for r in out2.values())
    assert sched2.stats()["faults"]["retry_backoff_s"] == 0.0

    with pytest.raises(ValueError, match="retry_backoff_s"):
        ServeScheduler(engine, retry_backoff_s=-0.1)


def test_poison_scene_isolated_by_bisect(served):
    """A scene that fails EVERY dispatch containing it is bisected away:
    its batch peers complete with bit-identical predictions, the poison
    request itself completes `exec_failed` after exhausting max_retries,
    and the scheduler serves the next stream cleanly."""
    params, engine = served
    # rids are scheduler-local and start at 0: poison the second request
    plan = FaultPlan(poison_rids={1})
    sched = ServeScheduler(engine, max_batch=4, mesh=None,
                           fault_plan=plan)
    scenes = [_scene_cf(20 + i, 40) for i in range(4)]
    out = sched.serve(scenes)
    assert out[1].error.code == "exec_failed"
    assert "injected" in out[1].error.message
    for rid, (c, f, m) in zip(sorted(out), scenes):
        if rid == 1:
            continue
        assert out[rid].ok
        np.testing.assert_array_equal(out[rid].preds,
                                      _ref_preds(params, c, m, f))
    st = sched.stats()["faults"]
    # batch fails, [0,1] half fails, [1] single fails -> dead
    assert st["exec_failed"] == 1
    assert st["failed_dispatches"] == 3
    assert st["retries"] == 4                   # 2 halves + 2 singles
    # the follow-up stream is clean (no poisoned rid outstanding)
    follow = _scene_cf(30, 40)
    out2 = sched.serve([follow])
    (res,) = out2.values()
    assert res.ok
    np.testing.assert_array_equal(
        res.preds, _ref_preds(params, follow[0], follow[2], follow[1]))


def test_retry_disabled_completes_exec_failed(served):
    """max_retries=0: a failed slot's requests complete immediately as
    `exec_failed` — no retry dispatches at all."""
    _, engine = served
    plan = FaultPlan(fail_dispatches={0})
    sched = ServeScheduler(engine, max_batch=2, mesh=None,
                           fault_plan=plan, max_retries=0)
    out = sched.serve([_scene_cf(i, 40) for i in (11, 12)])
    assert all(r.error.code == "exec_failed" for r in out.values())
    st = sched.stats()["faults"]
    assert st["retries"] == 0 and st["exec_failed"] == 2
    with pytest.raises(ValueError, match="max_retries"):
        ServeScheduler(engine, mesh=None, max_retries=-1)
    with pytest.raises(ValueError, match="max_backlog"):
        ServeScheduler(engine, mesh=None, max_backlog=0)


def test_watchdog_background_completion_and_join(served):
    """The watchdog (auto-enabled with max_wait_s) fires the deadline
    flush and retires the slot with NOBODY calling poll(); close() joins
    the ticker thread."""
    params, engine = served
    sched = ServeScheduler(engine, max_batch=4, mesh=None,
                           max_wait_s=0.05)
    assert sched.stats()["watchdog"]
    c, f, m = _scene_cf(13, 40)
    rid = sched.submit(c, f, m)
    deadline = time.monotonic() + 60.0          # ample for a cold compile
    while sched.stats()["n_completed"] < 1:     # stats() never executes
        assert time.monotonic() < deadline, "watchdog never completed it"
        time.sleep(0.02)
    st = sched.stats()
    assert st["deadline_flushes"] >= 1 and st["in_flight"] == 0
    res = sched.take([rid])[rid]
    np.testing.assert_array_equal(res.preds, _ref_preds(params, c, m, f))
    wd = sched._watchdog
    assert wd.alive
    sched.close()
    assert not wd.alive and sched._watchdog is None


def test_close_context_manager_drains_and_rejects_late_submits(served):
    """close()/__exit__: queued scenes execute, in-flight work retires,
    results stay drainable; a submit after close completes `rejected`;
    close is idempotent."""
    params, engine = served
    with ServeScheduler(engine, max_batch=4, mesh=None,
                        max_wait_s=5.0) as sched:
        c, f, m = _scene_cf(14, 40)
        rid = sched.submit(c, f, m)             # partial: still queued
    st = sched.stats()
    assert st["closed"] and st["queue_depth"] == 0 and st["in_flight"] == 0
    res = sched.take([rid])[rid]                # drainable after close
    assert res.ok
    np.testing.assert_array_equal(res.preds, _ref_preds(params, c, m, f))
    late = sched.submit(c, f, m)
    out = sched.take([late])[late]
    assert out.error.code == "rejected" and "closed" in out.error.message
    sched.close()                               # idempotent


# ---------------------------------------------------------------------------
# engine surface
# ---------------------------------------------------------------------------

def test_segment_batch_surfaces_per_scene_errors():
    """PointCloudEngine.segment_batch: on_error='partial' returns the
    typed error per failed scene with -1-filled rows; the default raises
    a RuntimeError naming the scenes; the engine-level fault_plan reaches
    the internal scheduler."""
    params = _mini_params()
    # ordinals are plan-global: corrupt the 2nd scene of BOTH calls
    plan = FaultPlan(corrupt_scenes={1, 3})
    engine = PointCloudEngine(params, n_stages=2, flow="fod",
                              ladder=geometric_ladder(64, 64),
                              max_batch=2, fault_plan=plan)
    scenes = [lidar_scene(seed=160 + i, n_points=40, grid=16)
              for i in range(2)]
    coords = np.stack([c for c, _, _ in scenes])
    mask = np.stack([m for _, m, _ in scenes])
    feats = np.stack([f for _, _, f in scenes])

    preds, hit, errors = engine.segment_batch(coords, mask, feats,
                                              on_error="partial")
    assert set(errors) == {1} and errors[1].code == "rejected"
    assert (np.asarray(preds[1]) == -1).all()
    c, m, f = scenes[0]
    np.testing.assert_array_equal(np.asarray(preds[0]),
                                  _ref_preds(params, c, m, f))

    with pytest.raises(RuntimeError, match="scene 1.*rejected"):
        engine.segment_batch(coords, mask, feats)
    with pytest.raises(ValueError, match="on_error"):
        engine.segment_batch(coords, mask, feats, on_error="ignore")
