"""Distributed-correctness tests on an 8-device host-platform mesh.

Each test runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_
device_count=8 so the main pytest process keeps its single-device view.
"""

import os
import subprocess
import sys
import textwrap


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_sharded_train_step_matches_single_device():
    """One train step on the debug mesh == the same step single-device."""
    run_sub("""
        import jax, numpy as np, jax.numpy as jnp
        from repro import configs
        from repro.models import registry
        from repro.distributed import sharding as SH
        from repro.launch.mesh import make_debug_mesh
        from repro.train import optim as OPT
        from repro.train.step import TrainConfig, make_train_step

        cfg = configs.get("gemma2-2b", reduced=True)
        model = registry.build(cfg)
        params = model.init(jax.random.key(0))
        opt = OPT.init(params)
        rng = np.random.default_rng(0)
        B, S = 4, 16
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))),
            "positions": jnp.broadcast_to(jnp.arange(S), (B, S)),
        }
        tc = TrainConfig(compute_dtype=jnp.float32, remat=True,
                         use_chunked_ce=False)
        ocfg = OPT.AdamWConfig()

        # single device
        step1 = make_train_step(model, tc, ocfg, sc=None)
        p1, o1, m1 = jax.jit(step1)(params, opt, batch)

        # debug mesh
        mesh = make_debug_mesh()
        sc = SH.ShardingConfig(mesh, fsdp=True, seq_parallel=True)
        step2 = make_train_step(model, tc, ocfg, sc=sc)
        p_sh = SH.params_shardings(jax.eval_shape(lambda: params), sc)
        opt_sh = OPT.OptState(step=SH.replicated(sc), m=p_sh, v=p_sh)
        b_sh = SH.batch_specs(jax.eval_shape(lambda: batch), sc)
        params2 = jax.device_put(params, p_sh)
        opt2 = jax.device_put(opt, opt_sh)
        batch2 = jax.device_put(batch, b_sh)
        p2, o2, m2 = jax.jit(step2, in_shardings=(p_sh, opt_sh, b_sh),
                             out_shardings=(p_sh, opt_sh, None))(
                                 params2, opt2, batch2)

        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=1e-4)
        for a, b in zip(jax.tree_util.tree_leaves(p1),
                        jax.tree_util.tree_leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=2e-3)
        print("OK")
    """)


def test_moe_ep_matches_sorted_local():
    """shard_map EP MoE == local sorted dispatch (ample capacity)."""
    run_sub("""
        import jax, numpy as np, jax.numpy as jnp
        from repro import configs
        from repro.models import moe as MOE
        from repro.launch.mesh import make_debug_mesh

        cfg = configs.get("granite-moe-1b-a400m", reduced=True)  # 8e top4
        key = jax.random.key(0)
        p = MOE.moe_init(key, cfg)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(8, 16, cfg.d_model))
                        .astype(np.float32))

        ref, aux_ref = MOE.moe_apply_sorted(p, cfg, x,
                                            capacity_factor=32.0)
        mesh = make_debug_mesh()        # data=2, model=4 -> ep=4, epl=2
        got, aux = jax.jit(lambda p, x: MOE.moe_apply_ep(
            p, cfg, x, mesh=mesh, capacity_factor=32.0))(p, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)
        # aux is meaned per data shard in EP (GShard convention) vs global
        # in the local path: equal in expectation, not bitwise
        np.testing.assert_allclose(float(aux), float(aux_ref), rtol=5e-2)
        print("OK")
    """)


def test_moe_ep_replicated_experts():
    """ep > E path (mixtral-style): experts replicated across shards."""
    run_sub("""
        import jax, numpy as np, jax.numpy as jnp
        from repro import configs
        from repro.models import moe as MOE
        from repro.launch.mesh import make_debug_mesh

        cfg = configs.get("mixtral-8x7b", reduced=True)
        cfg = cfg.replace(n_experts=2, topk=2)   # ep=4 > E=2 -> r=2
        p = MOE.moe_init(jax.random.key(1), cfg)
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(4, 8, cfg.d_model))
                        .astype(np.float32))
        ref, _ = MOE.moe_apply_sorted(p, cfg, x, capacity_factor=32.0)
        mesh = make_debug_mesh()
        got, _ = jax.jit(lambda p, x: MOE.moe_apply_ep(
            p, cfg, x, mesh=mesh, capacity_factor=32.0))(p, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)
        print("OK")
    """)


def test_compressed_psum_error_feedback():
    """int8 cross-pod psum: bounded per-step error, error feedback keeps
    the RUNNING SUM exact to quantisation precision."""
    run_sub("""
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.distributed import compression as C
        from repro.launch.mesh import make_debug_mesh

        mesh = make_debug_mesh(multi_pod=True)   # pod=2
        rng = np.random.default_rng(0)

        def one_round(x, err):
            def local(xl, e):
                m, e2 = C.compressed_psum(xl[0], "pod", e[0])
                return m[None], e2[None]
            return compat.shard_map(local, mesh=mesh, axis_names={"pod"},
                                    in_specs=(P("pod"), P("pod")),
                                    out_specs=(P("pod"), P("pod")))(x, err)

        shape = (2, 1, 300)                  # (pod, local_rows, dim)
        err = jnp.zeros(shape, jnp.float32)
        true_sum = np.zeros((1, 300), np.float32)
        got_sum = np.zeros((1, 300), np.float32)
        for t in range(20):
            x = rng.normal(size=shape).astype(np.float32)
            mean, err = one_round(jnp.asarray(x), err)
            mean = np.asarray(mean)
            # both pods must hold the identical exchanged mean
            np.testing.assert_array_equal(mean[0], mean[1])
            true_sum += x.mean(axis=0)
            got_sum += mean[0]
        # running sums track closely thanks to error feedback
        denom = np.abs(true_sum).mean()
        drift = np.abs(got_sum - true_sum).mean() / denom
        assert drift < 0.02, drift
        print("OK", drift)
    """)


def test_hierarchical_grads_compression():
    """Full wrapper: per-pod grads + compressed exchange ~= exact global
    grads; error buffers keep the optimizer trajectory on track."""
    run_sub("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.distributed import compression as C
        from repro.launch.mesh import make_debug_mesh

        mesh = make_debug_mesh(multi_pod=True)
        rng = np.random.default_rng(0)
        W = jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32))
        batch = {"x": jnp.asarray(rng.normal(size=(16, 8))
                                  .astype(np.float32)),
                 "y": jnp.asarray(rng.normal(size=(16, 4))
                                  .astype(np.float32))}

        def grad_fn(w, b):
            def loss(w):
                return jnp.mean((b["x"] @ w - b["y"]) ** 2)
            l, g = jax.value_and_grad(loss)(w)
            return g, {"loss": l}

        exact, _ = grad_fn(W, batch)
        err = C.init_error_buffers(jax.eval_shape(lambda: W), n_pods=2)
        got, err2, metrics = jax.jit(
            lambda W, b, e: C.hierarchical_grads(grad_fn, mesh, W, b, e)
        )(W, batch, err)
        rel = float(jnp.max(jnp.abs(got - exact)) /
                    (jnp.max(jnp.abs(exact)) + 1e-9))
        assert rel < 0.02, rel        # int8 quantisation noise only
        print("OK", rel)
    """)


def test_pipeline_matches_sequential():
    """2-stage GPipe over 'pod' == plain scan over all bodies."""
    run_sub("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.distributed import pipeline as PP
        from repro.launch.mesh import make_debug_mesh

        mesh = make_debug_mesh(multi_pod=True)   # pod=2
        rng = np.random.default_rng(0)
        n_bodies, d = 4, 16
        W = jnp.asarray(rng.normal(size=(n_bodies, d, d))
                        .astype(np.float32) * 0.3)
        x = jnp.asarray(rng.normal(size=(8, 4, d)).astype(np.float32))

        def body_fn(w, h):
            return jnp.tanh(h @ w)

        def seq(x, W):
            def sb(h, w):
                return body_fn(w, h), None
            y, _ = jax.lax.scan(sb, x, W)
            return y

        ref = seq(x, W)
        got = jax.jit(lambda W, x: PP.pipelined_forward(
            body_fn, W, x, mesh, n_micro=4))(W, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)

        # and it differentiates (GPipe backward through the schedule)
        g = jax.grad(lambda W: jnp.sum(PP.pipelined_forward(
            body_fn, W, x, mesh, n_micro=4) ** 2))(W)
        gref = jax.grad(lambda W: jnp.sum(seq(x, W) ** 2))(W)
        np.testing.assert_allclose(np.asarray(g), np.asarray(gref),
                                   rtol=1e-3, atol=1e-3)
        print("OK")
    """)


def test_sharded_serve_scheduler_matches_per_scene_loop():
    """shard_map-sharded scene-axis serving on the 8-device host mesh:
    the continuous-batching scheduler rounds max_batch up to the device
    count, shards micro-batches with shard_over_scenes, and produces the
    same segmentation as a single-device per-scene loop."""
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import mapping as M
        from repro.data.synthetic import lidar_scene
        from repro.distributed import sharding as SH
        from repro.models import minkunet as MU
        from repro.serve.buckets import geometric_ladder
        from repro.serve.engine import PointCloudEngine
        from repro.serve.scheduler import ServeScheduler

        assert len(jax.devices()) == 8
        mesh = SH.make_scene_mesh()
        assert mesh is not None and mesh.shape["scene"] == 8

        params = MU.mini_minkunet_init(jax.random.key(0), c_in=4,
                                       n_classes=2)
        engine = PointCloudEngine(params, n_stages=2, flow="fod",
                                  ladder=geometric_ladder(64, 128))
        sched = ServeScheduler(engine, max_batch=6, mesh="auto")
        assert sched.mesh is not None
        assert sched.max_batch == 8      # rounded up to the device count

        sizes = [40, 90, 60, 120] * 4    # 16 scenes, 2 buckets
        scenes = [lidar_scene(seed=5 + i % 8, n_points=n, grid=20)
                  for i, n in enumerate(sizes)]
        rids = [sched.submit(c, f, m) for (c, m, f) in scenes]
        sched.flush()
        by_rid = {r.rid: r for r in sched.drain()}
        assert sorted(by_rid) == rids

        for rid, (c, m, f) in zip(rids, scenes):
            pc = M.make_point_cloud(jnp.asarray(c), jnp.asarray(m))
            logits = MU.minkunet_apply(params, pc, jnp.asarray(f),
                                       flow="fod")
            np.testing.assert_array_equal(
                by_rid[rid].preds, np.asarray(jnp.argmax(logits, -1)))

        stats = sched.stats()
        assert stats["n_devices"] == 8
        assert stats["n_completed"] == 16
        assert len(stats["buckets"]) == 2
        print("OK")
    """)


def test_checkpoint_roundtrip_and_elastic(tmp_path):
    """Save on one mesh, restore on a different mesh; atomic commit."""
    run_sub(f"""
        import os, jax, numpy as np, jax.numpy as jnp
        from repro.checkpoint import store
        from repro.distributed import sharding as SH
        from repro.launch.mesh import make_debug_mesh

        root = {str(tmp_path)!r}
        tree = {{"a": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                "b": {{"c": jnp.ones((4, 16), jnp.bfloat16)}}}}
        store.save(root, 5, tree)
        store.save(root, 7, tree)
        assert store.latest_step(root) == 7
        # uncommitted dir is ignored
        os.makedirs(os.path.join(root, "step_00000009"), exist_ok=True)
        assert store.latest_step(root) == 7

        like = jax.eval_shape(lambda: tree)
        mesh = make_debug_mesh()
        sc = SH.ShardingConfig(mesh, fsdp=True)
        sh = jax.tree_util.tree_map(
            lambda l: jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec("data")), like)
        out = store.restore(root, 7, like, sh)
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(tree["a"]))
        assert out["a"].sharding.spec == jax.sharding.PartitionSpec("data")
        print("OK")
    """)
