"""Launcher-level fault-tolerance: checkpoint/restart continuity and the
end-to-end train loop."""

import os
import subprocess
import sys

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_train(args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train"] + args,
        capture_output=True, text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def parse_losses(stdout):
    out = {}
    for line in stdout.splitlines():
        if line.startswith("step "):
            parts = line.split()
            out[int(parts[1])] = float(parts[3])
    return out


def test_train_restart_continuity(tmp_path):
    """Run 20 steps with checkpoints, then restart: the resumed run must
    continue from the checkpointed step with the identical data stream and
    produce the same losses as an uninterrupted 30-step run."""
    ck1 = str(tmp_path / "a")
    common_args = ["--arch", "xlstm-125m", "--reduced", "--batch", "4",
                   "--seq", "32", "--log-every", "1",
                   "--lr-total-steps", "30"]   # schedule fixed across runs
    full = run_train(common_args + ["--steps", "30",
                      "--ckpt-dir", str(tmp_path / "ref"),
                      "--ckpt-every", "1000"])
    losses_full = parse_losses(full)

    run_train(common_args + ["--steps", "20", "--ckpt-dir", ck1,
                             "--ckpt-every", "10"])
    resumed = run_train(common_args + ["--steps", "30", "--ckpt-dir", ck1,
                                       "--ckpt-every", "10"])
    assert "[resume] step 20" in resumed
    losses_res = parse_losses(resumed)
    # steps 20.. must match the uninterrupted run closely
    common = sorted(set(losses_full) & set(losses_res))
    assert common and min(common) >= 20
    for s in common:
        np.testing.assert_allclose(losses_full[s], losses_res[s],
                                   rtol=2e-3, atol=2e-3)


def test_train_loss_improves():
    out = run_train(["--arch", "qwen1.5-4b", "--reduced", "--steps", "40",
                     "--batch", "8", "--seq", "32", "--lr", "1e-3",
                     "--log-every", "5"])
    assert "improved" in out and "NOT improved" not in out
