"""Launcher-level fault-tolerance: checkpoint/restart continuity and the
end-to-end train loop."""

import os
import subprocess
import sys

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_train(args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train"] + args,
        capture_output=True, text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def parse_losses(stdout):
    out = {}
    for line in stdout.splitlines():
        if line.startswith("step "):
            parts = line.split()
            out[int(parts[1])] = float(parts[3])
    return out


def test_train_restart_continuity(tmp_path):
    """Run 20 steps with checkpoints, then restart: the resumed run must
    continue from the checkpointed step with the identical data stream and
    produce the same losses as an uninterrupted 30-step run."""
    ck1 = str(tmp_path / "a")
    common_args = ["--arch", "xlstm-125m", "--reduced", "--batch", "4",
                   "--seq", "32", "--log-every", "1",
                   "--lr-total-steps", "30"]   # schedule fixed across runs
    full = run_train(common_args + ["--steps", "30",
                      "--ckpt-dir", str(tmp_path / "ref"),
                      "--ckpt-every", "1000"])
    losses_full = parse_losses(full)

    run_train(common_args + ["--steps", "20", "--ckpt-dir", ck1,
                             "--ckpt-every", "10"])
    resumed = run_train(common_args + ["--steps", "30", "--ckpt-dir", ck1,
                                       "--ckpt-every", "10"])
    assert "[resume] step 20" in resumed
    losses_res = parse_losses(resumed)
    # steps 20.. must match the uninterrupted run closely
    common = sorted(set(losses_full) & set(losses_res))
    assert common and min(common) >= 20
    for s in common:
        np.testing.assert_allclose(losses_full[s], losses_res[s],
                                   rtol=2e-3, atol=2e-3)


def test_train_loss_improves():
    out = run_train(["--arch", "qwen1.5-4b", "--reduced", "--steps", "40",
                     "--batch", "8", "--seq", "32", "--lr", "1e-3",
                     "--log-every", "5"])
    assert "improved" in out and "NOT improved" not in out


# ---------------------------------------------------------------------------
# fault-tolerance runtime units (launch/fault_tolerance.py)
# ---------------------------------------------------------------------------

def test_preemption_handler_catches_sigterm_and_restores():
    """SIGTERM inside the context flips should_stop (finish the step,
    checkpoint, exit clean); the previous handler is restored on exit."""
    import signal
    from repro.launch.fault_tolerance import PreemptionHandler

    seen = []
    prev = signal.signal(signal.SIGTERM, lambda s, f: seen.append(s))
    try:
        with PreemptionHandler() as p:
            assert not p.should_stop
            os.kill(os.getpid(), signal.SIGTERM)
            assert p.should_stop          # caught, not fatal
        assert not seen                   # ... and not leaked through
        os.kill(os.getpid(), signal.SIGTERM)
        assert seen == [signal.SIGTERM]   # original handler restored
    finally:
        signal.signal(signal.SIGTERM, prev)


def test_step_timer_flags_stragglers():
    """A step >> the rolling median is flagged — the single-process
    analogue of cross-host straggler mitigation — but only once enough
    history exists to trust the median."""
    import time
    from repro.launch.fault_tolerance import StepTimer

    t = StepTimer(window=10, straggler_factor=2.0)
    t.start()
    first = t.stop()
    assert first["step_s"] >= 0 and not first["straggler"]
    for _ in range(6):                    # build history: ~1ms steps
        t.start()
        time.sleep(0.001)
        rec = t.stop()
        assert not rec["straggler"]
    t.start()
    time.sleep(0.03)                      # 30x the median
    slow = t.stop()
    assert slow["straggler"] and slow["step_s"] > 2.0 * slow["median_s"]
    t.start()                             # recovery: normal step unflagged
    time.sleep(0.001)
    assert not t.stop()["straggler"]
