"""Per-architecture smoke tests (deliverable f): reduced config of the same
family, one forward + one train-grad step on CPU, asserting output shapes
and no NaNs."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import configs
from repro.models import registry

LM_ARCHS = [
    "gemma2-2b", "granite-34b", "qwen1.5-4b", "qwen1.5-32b",
    "jamba-v0.1-52b", "xlstm-125m", "seamless-m4t-medium",
    "granite-moe-1b-a400m", "mixtral-8x7b", "qwen2-vl-72b",
]

B, S = 2, 16


def make_batch(cfg, rng):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))),
    }
    if cfg.family == "vlm":
        s_img = 4
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, s_img, cfg.d_model)).astype(np.float32))
        pos_t = np.arange(S + s_img)
        batch["positions"] = jnp.asarray(
            np.broadcast_to(pos_t[None, :, None], (B, S + s_img, 3)).copy())
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S + s_img)))
    elif cfg.family == "audio":
        batch["frame_embeds"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)).astype(np.float32))
        batch["enc_positions"] = jnp.broadcast_to(jnp.arange(S), (B, S))
        batch["positions"] = jnp.broadcast_to(jnp.arange(S), (B, S))
    else:
        batch["positions"] = jnp.broadcast_to(jnp.arange(S), (B, S))
    return batch


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = configs.get(arch, reduced=True)
    model = registry.build(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = make_batch(cfg, rng)
    logits, aux = model.train_logits(params, batch)
    s_total = batch["labels"].shape[1]
    assert logits.shape == (B, s_total, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_train_grad_step(arch):
    cfg = configs.get(arch, reduced=True)
    model = registry.build(cfg)
    params = model.init(jax.random.key(1))
    rng = np.random.default_rng(1)
    batch = make_batch(cfg, rng)

    def loss_fn(p):
        logits, aux = model.train_logits(p, batch)
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        ll = jnp.take_along_axis(lp, batch["labels"][..., None], -1)
        return -jnp.mean(ll) + 0.01 * aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32)))
               for g in leaves)
    # at least one nonzero gradient
    assert any(float(jnp.sum(jnp.abs(g))) > 0 for g in leaves)


@pytest.mark.parametrize("arch", ["gemma2-2b", "mixtral-8x7b",
                                  "jamba-v0.1-52b", "xlstm-125m",
                                  "seamless-m4t-medium"])
def test_prefill_decode_consistency(arch):
    """Prefill S tokens, then decode token S: decode logits must match the
    train-mode logits at the same position (teacher forcing)."""
    cfg = configs.get(arch, reduced=True)
    if cfg.family == "audio":
        pytest.skip("cross-cache prefill->decode covered in serve tests")
    model = registry.build(cfg)
    params = model.init(jax.random.key(2))
    rng = np.random.default_rng(2)
    s = 8
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, s + 1)))
    positions = jnp.broadcast_to(jnp.arange(s + 1), (1, s + 1))

    full_batch = {"tokens": tokens, "positions": positions}
    logits_full, _ = model.train_logits(params, full_batch)

    # prefill on the first s tokens
    pre_batch = {"tokens": tokens[:, :s], "positions": positions[:, :s]}
    logits_pre, states, _ = model.prefill(params, pre_batch)
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, -1]), np.asarray(logits_full[:, s - 1]),
        rtol=1e-3, atol=1e-3)

    # pad prefill states out to max_len and decode one step
    max_len = 16
    init = model.init_state(1, max_len, dtype=jnp.float32)

    def place(dst, src):
        if src.shape == dst.shape:
            return src.astype(dst.dtype)
        # KV caches: copy the first s slots
        pad = [(0, d - s_) for d, s_ in zip(dst.shape, src.shape)]
        return jnp.pad(src.astype(dst.dtype), pad)

    states = jax.tree_util.tree_map(place, init, states)
    dec_batch = {
        "tokens": tokens[:, s:s + 1],
        "positions": positions[:, s:s + 1],
        "cache_pos": jnp.array([s], jnp.int32),
    }
    logits_dec, _, _ = model.decode(params, dec_batch, states)
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0]), np.asarray(logits_full[:, s]),
        rtol=2e-3, atol=2e-3)
