"""Inject the final dry-run tables into EXPERIMENTS.md.

Run after the baseline/optimized/multi-pod sweeps complete:
  PYTHONPATH=src:. python scripts/finalize_experiments.py
"""

import json
import sys

sys.path.insert(0, ".")

from benchmarks.perf_compare import render as render_compare
from benchmarks.roofline_table import render as render_roofline


def summarize_multi_pod(path):
    try:
        with open(path) as f:
            rows = json.load(f)
    except FileNotFoundError:
        return None
    ok = sum(1 for r in rows if r.get("status") == "ok")
    skip = sum(1 for r in rows if r.get("status") == "skipped")
    err = sum(1 for r in rows if r.get("status") == "error")
    return f"{ok} ok / {skip} skipped (noted) / {err} errors"


def main():
    with open("EXPERIMENTS.md") as f:
        text = f.read()

    roof = render_roofline("benchmarks/results/dryrun_baseline.json")
    text = text.replace("<!-- ROOFLINE_TABLE -->", roof)

    comp = render_compare()
    text = text.replace("<!-- OPTIMIZED_TABLE -->", comp)

    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print("EXPERIMENTS.md updated")
    mp = summarize_multi_pod(
        "benchmarks/results/dryrun_multi_pod_final.json")
    if mp:
        print("multi-pod final:", mp)


if __name__ == "__main__":
    main()
