"""Deterministic synthetic data: token streams and LiDAR-like point clouds.

Everything is a pure function of (seed, step, host) — the property the
fault-tolerance layer relies on: any host can regenerate any batch, so
restarts and elastic resharding never skip or repeat data.
"""

from __future__ import annotations

import numpy as np


def token_batch(seed: int, step: int, batch: int, seq: int,
                vocab: int, host: int = 0, n_hosts: int = 1) -> dict:
    """Markov-ish synthetic token stream (not uniform noise: the LM has
    structure to learn, so example train losses actually decrease)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, step, host]))
    b_loc = batch // n_hosts
    base = rng.integers(0, vocab, size=(b_loc, 1))
    steps = rng.integers(1, 17, size=(b_loc, seq + 1))
    toks = (base + np.cumsum(steps, axis=1)) % vocab
    tokens = toks[:, :-1].astype(np.int32)
    labels = toks[:, 1:].astype(np.int32)
    positions = np.broadcast_to(np.arange(seq, dtype=np.int32),
                                (b_loc, seq)).copy()
    return {"tokens": tokens, "labels": labels, "positions": positions}


def lidar_scene(seed: int, n_points: int, grid: int = 64,
                n_objects: int = 8, batch_idx: int = 0):
    """Sparse voxelised scene: ground plane + box-like objects.
    Returns (coords (N, 4) int32 with batch col, mask (N,), feats (N, 4))."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, batch_idx]))
    pts = []
    # ground plane
    n_ground = n_points // 3
    g = np.stack([rng.integers(0, grid, n_ground),
                  rng.integers(0, grid, n_ground),
                  np.zeros(n_ground, np.int64)], axis=1)
    pts.append(g)
    # objects
    remaining = n_points - n_ground
    per = max(1, remaining // n_objects)
    for _ in range(n_objects):
        c = rng.integers(4, grid - 4, size=3)
        size = rng.integers(2, 6, size=3)
        p = c + rng.integers(-size, size + 1, size=(per, 3))
        pts.append(np.clip(p, 0, grid - 1))
    pts = np.concatenate(pts, axis=0)[:n_points]

    # dedupe (point clouds are coordinate sets)
    uniq = np.unique(pts, axis=0)
    n = uniq.shape[0]
    coords = np.full((n_points, 4), 2**30 - 1, np.int32)
    coords[:n, 0] = batch_idx
    coords[:n, 1:] = uniq
    mask = np.zeros(n_points, bool)
    mask[:n] = True
    feats = np.zeros((n_points, 4), np.float32)
    feats[:n, :3] = uniq / grid - 0.5
    feats[:n, 3] = rng.random(n)          # intensity channel
    return coords, mask, feats


def city_scene(seed: int, n_points: int, extent: int | None = None,
               batch_idx: int = 0):
    """City-block scale LiDAR mock: a large-extent ground sheet plus
    towers, with roughly `n_points` UNIQUE voxels — `lidar_scene`'s
    default 64^3 grid saturates near ~40k unique sites, so city-scale
    partition tests need the extent to grow with the point budget.
    Returns the same (coords (N, 4) int32, mask, feats (N, 4)) layout;
    valid rows are the unique voxels actually produced (>= ~0.95 N for
    the default extent)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, batch_idx]))
    if extent is None:
        # ground sheet capacity ~2.5x the ask so collisions stay rare
        extent = int(np.ceil(np.sqrt(n_points * 2.5)))
    m_ground = int(n_points * 1.1)
    ground = np.stack([rng.integers(0, extent, m_ground),
                       rng.integers(0, extent, m_ground),
                       rng.integers(0, 2, m_ground)], axis=1)
    towers = []
    n_towers = max(4, n_points // 4000)
    per = max(16, n_points // (4 * n_towers))
    for _ in range(n_towers):
        c = rng.integers(8, max(9, extent - 8), size=2)
        w = rng.integers(3, 9)
        h = rng.integers(6, 30)
        t = np.stack([c[0] + rng.integers(0, w, per),
                      c[1] + rng.integers(0, w, per),
                      rng.integers(0, h, per)], axis=1)
        towers.append(t)
    pts = np.concatenate([ground, *towers], axis=0)
    uniq = np.unique(np.clip(pts, 0, extent - 1), axis=0)
    uniq = uniq[rng.permutation(uniq.shape[0])[:n_points]]
    n = uniq.shape[0]
    coords = np.full((n_points, 4), 2**30 - 1, np.int32)
    coords[:n, 0] = batch_idx
    coords[:n, 1:] = uniq
    mask = np.zeros(n_points, bool)
    mask[:n] = True
    feats = np.zeros((n_points, 4), np.float32)
    feats[:n, :3] = uniq / extent - 0.5
    feats[:n, 3] = rng.random(n)
    return coords, mask, feats


def point_cloud_batch(seed: int, step: int, batch: int, n_points: int,
                      grid: int = 64):
    """Batched scenes flattened into one masked cloud + per-point labels
    (synthetic semantic task: ground vs object by height)."""
    cs, ms, fs = [], [], []
    for b in range(batch):
        c, m, f = lidar_scene(seed + step * 1000, n_points, grid,
                              batch_idx=b)
        cs.append(c)
        ms.append(m)
        fs.append(f)
    coords = np.concatenate(cs, axis=0)
    mask = np.concatenate(ms, axis=0)
    feats = np.concatenate(fs, axis=0)
    labels = (coords[:, 3] > 0).astype(np.int32)     # object if z > 0
    labels[~mask] = 0
    return coords, mask, feats, labels


def dense_xyz_batch(seed: int, step: int, batch: int, n_points: int):
    """(B, N, 3) float clouds + masks + class labels for PointNet-family."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    labels = rng.integers(0, 8, size=batch).astype(np.int32)
    xyz = np.zeros((batch, n_points, 3), np.float32)
    for b in range(batch):
        # class-dependent ellipsoid
        ax = 0.3 + 0.1 * (labels[b] % 4)
        raw = rng.normal(size=(n_points, 3)).astype(np.float32)
        raw /= np.linalg.norm(raw, axis=1, keepdims=True) + 1e-6
        r = rng.random((n_points, 1)).astype(np.float32) ** (1 / 3)
        xyz[b] = raw * r * np.array([ax, 0.4, 1.0 - ax], np.float32)
    mask = np.ones((batch, n_points), bool)
    return xyz, mask, labels
