"""Host-side data pipeline: deterministic skip-ahead + double-buffered
prefetch.

The iterator is a pure function of step number (data/synthetic.py), so
`start_step` restores any position instantly — no epoch bookkeeping to
checkpoint, and a replacement host after a failure regenerates exactly the
batches it owes (the straggler/elastic story in DESIGN.md §4).
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator

import jax


class PrefetchIterator:
    """Wraps batch_fn(step) -> pytree with a background producer thread and
    a bounded queue (double buffering: host builds batch t+1 while device
    runs step t)."""

    def __init__(self, batch_fn: Callable[[int], dict], start_step: int = 0,
                 buffer: int = 2, device_put: bool = False, shardings=None):
        self.batch_fn = batch_fn
        self.step = start_step
        self.buffer = buffer
        self.device_put = device_put
        self.shardings = shardings
        self._q: queue.Queue = queue.Queue(maxsize=buffer)
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._produce, daemon=True)
        self._t.start()

    def _produce(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.batch_fn(step)
            if self.device_put:
                batch = jax.device_put(batch, self.shardings)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.5)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self._q.get()
        return step, batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
