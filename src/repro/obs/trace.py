"""Request span tracing for the serving stack.

One serve request crosses many stages — admission, bucket queue,
assembly, device dispatch, retire, and (multi-worker / city-scale) a
router hop, failover replay, or partition fan-out — and the aggregate
metrics can say *that* p99 moved without saying *where*.  `SpanTracer`
answers "where did this request's 40 ms go": every request id owns a
**span tree** under one trace, each span carrying monotonic start/end
timestamps and small attribute dicts, so a single trace reconstructs the
request's whole path:

    request (root)                          rid=3 instance=w1
    ├─ route          0.00ms → 0.04ms       worker=w1      (router only)
    ├─ admission      0.04ms → 0.21ms       bucket=512
    ├─ queue_wait     0.21ms → 3.90ms       bucket=512
    ├─ dispatch       3.90ms → 5.10ms       dispatch_id=7 retries=0
    │  └─ assembly    3.90ms → 4.60ms       cache_hit=True
    │     ├─ arena_staging     3.90 → 4.1
    │     └─ assembly_lookup   4.1  → 4.2
    ├─ device_wait    5.10ms → 38.7ms
    └─ retire         38.7ms                (instant)

Failure paths appear as spans too: `dispatch_failed`, `failover`
(attrs: dead worker + reason), `replay` (attrs: surviving worker) — so a
chaos-run trace shows original dispatch → failover → replay → retire in
one tree.

Design constraints (the ≤3% overhead gate in `bench_serve
serve/obs_overhead` is asserted against this implementation):

  * recording a span is one dict + one list append under a leaf lock —
    no I/O, no string formatting on the hot path;
  * the tracer is OPTIONAL: every seam in the scheduler/router is gated
    on `tracer is not None`, and the disabled path is bit-identical;
  * finished traces park in a bounded deque (`max_finished`) — a
    long-running server never grows without bound; exporters drain or
    snapshot them (`repro.obs.export.write_trace_jsonl`).

Trace ids are plain strings.  The component that BEGINS a trace owns
its root (and ends it); components handed a `trace_id` (a router's
worker scheduler, a partition plan's chunk submits) attach child spans
to the existing tree without touching the root.
"""

from __future__ import annotations

import threading
import time
from collections import deque

DEFAULT_MAX_FINISHED = 4096


class Span:
    __slots__ = ("span_id", "parent_id", "name", "t_start", "t_end",
                 "attrs")

    def __init__(self, span_id, parent_id, name, t_start, t_end=None,
                 attrs=None):
        self.span_id = span_id
        self.parent_id = parent_id
        self.name = name
        self.t_start = t_start
        self.t_end = t_end
        self.attrs = attrs or {}

    def as_dict(self) -> dict:
        return {"span_id": self.span_id, "parent_id": self.parent_id,
                "name": self.name, "t_start": self.t_start,
                "t_end": self.t_end, "attrs": dict(self.attrs)}


class Trace:
    """One request's span tree: a root span plus children, keyed by
    span id.  `closed` means the root ended — the request completed
    (with predictions or a typed error) and the tree is final."""

    __slots__ = ("tid", "spans", "root_id", "_order")

    def __init__(self, tid: str):
        self.tid = tid
        self.spans: dict[int, Span] = {}
        self.root_id: int | None = None
        self._order: list[int] = []

    @property
    def closed(self) -> bool:
        root = self.spans.get(self.root_id)
        return root is not None and root.t_end is not None

    def span_list(self) -> list[Span]:
        return [self.spans[i] for i in self._order]

    def names(self) -> list[str]:
        """Span names in record order (test/assertion convenience)."""
        return [s.name for s in self.span_list()]

    def find(self, name: str) -> list[Span]:
        return [s for s in self.span_list() if s.name == name]

    def tree(self) -> dict:
        """Nested {name, t_start, t_end, attrs, children: [...]} from
        the root (None when the trace has no root yet)."""
        kids: dict[int | None, list[Span]] = {}
        for s in self.span_list():
            kids.setdefault(s.parent_id, []).append(s)

        def build(s: Span) -> dict:
            d = s.as_dict()
            d["children"] = [build(c) for c in kids.get(s.span_id, [])]
            return d

        root = self.spans.get(self.root_id)
        return build(root) if root is not None else None


class SpanTracer:
    """Bounded, thread-safe span recorder (see module docstring).

    All methods tolerate unknown trace ids by no-op'ing (a worker may
    publish a span for a request the router already finalized after a
    failover race — dropping it is correct: ownership of the result was
    already decided)."""

    def __init__(self, max_finished: int = DEFAULT_MAX_FINISHED):
        self._lock = threading.Lock()
        self._live: dict[str, Trace] = {}
        self._finished: deque[Trace] = deque(maxlen=max_finished)
        self._next_span = 0
        self.n_dropped = 0          # spans for unknown/finished traces

    # -- recording --------------------------------------------------------

    def begin(self, tid: str, name: str = "request", t: float = None,
              **attrs) -> str:
        """Open a trace with a root span; idempotent per tid."""
        t = time.monotonic() if t is None else t
        with self._lock:
            if tid in self._live:
                return tid
            tr = Trace(tid)
            sid = self._next_span
            self._next_span += 1
            tr.spans[sid] = Span(sid, None, name, t, None, attrs)
            tr.root_id = sid
            tr._order.append(sid)
            self._live[tid] = tr
        return tid

    def span(self, tid: str, name: str, parent: int = None,
             t_start: float = None, t_end: float = None,
             **attrs) -> int | None:
        """Record a span under `parent` (default: the root).  Pass
        `t_end` to record an already-finished span in one call; leave it
        None and `end_span` later for an open one.  Returns the span id
        (None when the trace is unknown — see class docstring)."""
        t_start = time.monotonic() if t_start is None else t_start
        with self._lock:
            tr = self._live.get(tid)
            if tr is None:
                self.n_dropped += 1
                return None
            sid = self._next_span
            self._next_span += 1
            parent = tr.root_id if parent is None else parent
            tr.spans[sid] = Span(sid, parent, name, t_start, t_end, attrs)
            tr._order.append(sid)
            return sid

    def event(self, tid: str, name: str, t: float = None,
              **attrs) -> int | None:
        """An instant (zero-duration) span — markers like `retire`,
        `failover`, `replay`."""
        t = time.monotonic() if t is None else t
        return self.span(tid, name, t_start=t, t_end=t, **attrs)

    def end_span(self, tid: str, span_id: int | None,
                 t_end: float = None, **attrs) -> None:
        if span_id is None:
            return
        t_end = time.monotonic() if t_end is None else t_end
        with self._lock:
            tr = self._live.get(tid)
            s = tr.spans.get(span_id) if tr is not None else None
            if s is None:
                self.n_dropped += 1
                return
            if s.t_end is None:
                s.t_end = t_end
            if attrs:
                s.attrs.update(attrs)

    def end(self, tid: str, t: float = None, **attrs) -> None:
        """Close the trace: end the root span (folding `attrs` — e.g.
        outcome=ok / outcome=exec_failed — into it) and park the trace
        on the bounded finished deque."""
        t = time.monotonic() if t is None else t
        with self._lock:
            tr = self._live.pop(tid, None)
            if tr is None:
                self.n_dropped += 1
                return
            root = tr.spans.get(tr.root_id)
            if root is not None:
                if root.t_end is None:
                    root.t_end = t
                root.attrs.update(attrs)
            self._finished.append(tr)

    # -- reading ----------------------------------------------------------

    def get(self, tid: str) -> Trace | None:
        """The live or (most recent) finished trace for `tid`."""
        with self._lock:
            tr = self._live.get(tid)
            if tr is not None:
                return tr
            for tr in reversed(self._finished):
                if tr.tid == tid:
                    return tr
        return None

    def finished(self) -> list[Trace]:
        with self._lock:
            return list(self._finished)

    def live(self) -> list[Trace]:
        with self._lock:
            return list(self._live.values())

    def stats(self) -> dict:
        with self._lock:
            return {"live": len(self._live),
                    "finished": len(self._finished),
                    "spans_recorded": self._next_span,
                    "dropped": self.n_dropped}
