"""Unified metrics registry for the serving stack: labeled counters,
gauges, and fixed-bucket histograms behind every `stats()` dict.

PointAcc's design method is measurement-first: the paper's CPU/GPU
bottleneck breakdown is what motivates the mapping-kernel and fusion
hardware.  Our serving stack needs the same discipline one level up —
but until this module, each component (scheduler, router, fault plan,
engine) accumulated its own ad-hoc `_latency_sum`/`_n_*` fields with
overlapping-but-drifting names, averages hid tail latency, and nothing
could be exported.  `MetricsRegistry` replaces those fields:

  * every serve-side telemetry value is a **Counter**, **Gauge**, or
    **Histogram** registered under one canonical name with explicit
    labels (`instance` distinguishes schedulers/routers/workers sharing
    one registry; `bucket`/`code` label per-capacity and per-error-code
    series);
  * the legacy `stats()` dicts are now *views* over the registry —
    bit-compatible key for key, value for value (float accumulation
    order preserved), so nothing downstream changes;
  * histograms carry fixed bucket bounds + exact sum/count, so p50/p95/
    p99 come from `Histogram.quantile` instead of averages-only, and the
    whole registry snapshots to Prometheus text exposition
    (`repro.obs.export.prometheus_text`).

Thread-safety: child creation is locked; child *mutation* (`inc`,
`set`, `observe`) is plain attribute arithmetic and must happen under
the owning component's lock — exactly where the ad-hoc fields were
mutated before — or from a single thread.  Components sharing a
registry bind disjoint label sets (distinct `instance` values), so
their children never alias.

Canonical serve metric schema (the one source of truth — the README
"Observability" table renders this list):

  counter  serve_requests_submitted_total{instance}
  counter  serve_requests_completed_total{instance}
  counter  serve_requests_ok_total{instance}
  counter  serve_faults_total{instance,code}      code in ERROR_CODES
  counter  serve_scenes_total{instance,bucket}    real scenes executed
  counter  serve_batches_total{instance,bucket}   micro-batches executed
  counter  serve_dummy_scenes_total{instance,bucket}
  counter  serve_points_real_total{instance}      valid caller rows
  counter  serve_rows_issued_total{instance}      bucket rows to device
  counter  serve_deadline_flushes_total{instance}
  counter  serve_failed_dispatches_total{instance}
  counter  serve_retries_total{instance}
  counter  serve_retry_backoff_seconds_total{instance}
  counter  serve_failovers_total{instance}        router only
  counter  serve_replays_total{instance}          router only
  gauge    serve_queue_depth{instance}            lazy (set_function)
  gauge    serve_inflight_batches{instance}       lazy (set_function)
  gauge    serve_recovery_seconds{instance}       last failure->recovered
  gauge    serve_overload_state{instance}         brownout level (0=nominal)
  gauge    serve_effective_backlog{instance,bucket}  adaptive shed bound
  gauge    serve_breaker_state{instance,target}   0 closed/1 half-open/2 open
  histo    serve_request_latency_seconds{instance}   OK results only
  histo    serve_error_latency_seconds{instance,code} submit->typed error
  histo    serve_assembly_seconds{instance}       per micro-batch
  histo    serve_queue_wait_seconds{instance}     admission->dispatch

The legacy `stats()` keys map onto it 1:1 (`SCHEDULER_STATS_KEYS` /
`ROUTER_STATS_KEYS` below freeze the dict shapes; a schema-shape test
keeps future keys from silently forking the two views again):

  n_submitted       = serve_requests_submitted_total
  n_completed       = serve_requests_completed_total
  n_ok              = serve_requests_ok_total
  latency_avg_s     = latency histogram sum / count   (OK only — error
                      paths land in serve_error_latency_seconds, which
                      the averages silently dropped before)
  faults.<code>     = serve_faults_total{code=<code>}
  buckets.<cap>.*   = serve_{scenes,batches,dummy_scenes}_total{bucket}
  padding_overhead  = rows_issued / points_real - 1
  assembly_time_s   = serve_assembly_seconds sum
"""

from __future__ import annotations

import bisect
import threading

COUNTER = "counter"
GAUGE = "gauge"
HISTOGRAM = "histogram"

# seconds; spans ~0.1 ms .. 10 s — the serve latency range from a warm
# micro-batch on small buckets up to a cold compile
DEFAULT_LATENCY_BUCKETS = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025,
    0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)

# -- frozen stats() shapes (schema-shape tests import these) ---------------

SCHEDULER_STATS_KEYS = frozenset({
    "n_submitted", "n_completed", "n_ok", "queue_depth", "in_flight",
    "padding_overhead", "mapping_cache", "assembly_cache",
    "assembly_time_s", "assembly_time_per_batch_s", "deadline_flushes",
    "buckets", "max_batch", "max_batch_overrides",
    "scheduler_max_backlog", "pipeline_depth",
    "n_devices", "compiles", "latency_avg_s", "latency_quantiles_s",
    "faults", "watchdog", "closed",
})
SCHEDULER_BUCKET_KEYS = frozenset({
    "scenes", "batches", "dummy_scenes", "occupancy", "max_batch",
})
SCHEDULER_FAULT_KEYS = frozenset({
    "rejected", "shed", "timeout", "exec_failed", "failed_dispatches",
    "retries", "retry_backoff_s", "recovery_s",
})
ROUTER_STATS_KEYS = frozenset({
    "n_workers", "n_live", "workers", "n_submitted", "n_completed",
    "n_ok", "routed_incomplete", "latency_avg_s", "latency_quantiles_s",
    "pool_cache", "faults", "liveness", "max_replays", "max_backlog",
    "router_max_backlog", "closed",
})
ROUTER_FAULT_KEYS = frozenset({
    "rejected", "shed", "timeout", "exec_failed", "failovers",
    "replayed", "recovery_s",
})
# the quantile view every latency-reporting stats() exposes
LATENCY_QUANTILES = (0.5, 0.95, 0.99)


class Counter:
    """Monotonic sum.  `inc` under the owning component's lock."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n=1):
        self.value += n


class Gauge:
    """Point-in-time value; `set_function` makes it lazily evaluated at
    snapshot time (queue depths and similar derived lengths)."""

    __slots__ = ("_value", "_fn")

    def __init__(self):
        self._value = None
        self._fn = None

    def set(self, v):
        self._value = v

    def inc(self, n=1):
        self._value = (self._value or 0) + n

    def dec(self, n=1):
        self.inc(-n)

    def set_function(self, fn):
        self._fn = fn

    @property
    def value(self):
        if self._fn is not None:
            try:
                return self._fn()
            except Exception:
                return None
        return self._value


class Histogram:
    """Fixed-bucket histogram with exact sum/count.

    `bounds` are inclusive upper bucket bounds; an implicit +Inf bucket
    catches the tail.  `sum` accumulates observations in arrival order,
    so a legacy `_x_sum += v` field replaced by `observe(v)` stays
    bit-identical.  `quantile(q)` linearly interpolates inside the
    owning bucket (the standard Prometheus `histogram_quantile`
    estimate): resolution is the bucket width, which the default serve
    bounds keep within ~2.5x at any latency decade.
    """

    __slots__ = ("bounds", "counts", "sum", "count")

    def __init__(self, bounds=DEFAULT_LATENCY_BUCKETS):
        self.bounds = tuple(float(b) for b in bounds)
        if list(self.bounds) != sorted(set(self.bounds)):
            raise ValueError(f"histogram bounds must be strictly "
                             f"increasing, got {bounds}")
        self.counts = [0] * (len(self.bounds) + 1)   # last = +Inf
        self.sum = 0.0
        self.count = 0

    def observe(self, v):
        v = float(v)
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.sum += v
        self.count += 1

    def quantile(self, q: float) -> float:
        """Estimated q-quantile (0..1); 0.0 on an empty histogram."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        if self.count == 0:
            return 0.0
        rank = q * self.count
        acc = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if acc + c >= rank:
                if i >= len(self.bounds):        # +Inf bucket: clamp
                    return self.bounds[-1] if self.bounds else 0.0
                lo = self.bounds[i - 1] if i else 0.0
                hi = self.bounds[i]
                return lo + (hi - lo) * max(0.0, rank - acc) / c
            acc += c
        return self.bounds[-1] if self.bounds else 0.0

    def quantiles(self, qs=LATENCY_QUANTILES) -> dict:
        return {f"p{int(q * 100)}": self.quantile(q) for q in qs}


_KINDS = {COUNTER: Counter, GAUGE: Gauge, HISTOGRAM: Histogram}


class Family:
    """One named metric family: a child per label-value tuple.

    `labels(*values)` returns (creating on first use) the child for one
    label-value tuple; an unlabeled family has exactly one child at the
    empty tuple, and proxies `inc`/`set`/`observe` straight to it.
    """

    def __init__(self, kind: str, name: str, help: str = "",
                 labelnames=(), buckets=DEFAULT_LATENCY_BUCKETS):
        self.kind = kind
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self.buckets = tuple(buckets)
        self._children: dict = {}
        self._lock = threading.Lock()
        if not self.labelnames:
            self.labels()               # eager default child

    def _make_child(self):
        if self.kind == HISTOGRAM:
            return Histogram(self.buckets)
        return _KINDS[self.kind]()

    def labels(self, *values):
        if len(values) != len(self.labelnames):
            raise ValueError(
                f"{self.name} takes labels {self.labelnames}, got "
                f"{values}")
        key = tuple(values)
        child = self._children.get(key)
        if child is None:
            with self._lock:
                child = self._children.setdefault(key, self._make_child())
        return child

    def items(self, **match):
        """[(label_values_tuple, child)] sorted by labels; `match`
        filters on named label positions (e.g. instance='w0')."""
        idx = {n: i for i, n in enumerate(self.labelnames)}
        for name in match:
            if name not in idx:
                raise ValueError(f"{self.name} has no label {name!r}")
        out = [(k, c) for k, c in sorted(self._children.items(),
                                         key=lambda kv: str(kv[0]))
               if all(k[idx[n]] == v for n, v in match.items())]
        return out

    # unlabeled-family conveniences
    def inc(self, n=1):
        self.labels().inc(n)

    def dec(self, n=1):
        self.labels().dec(n)

    def set(self, v):
        self.labels().set(v)

    def observe(self, v):
        self.labels().observe(v)

    @property
    def value(self):
        return self.labels().value


class MetricsRegistry:
    """Get-or-create registry of metric families.

    Re-registering a name is idempotent when the kind/labelnames agree
    (components sharing a registry declare the same families) and a
    loud error when they do not — the schema cannot silently fork.
    """

    def __init__(self):
        self._families: dict[str, Family] = {}
        self._lock = threading.Lock()

    def _get(self, kind, name, help, labelnames, buckets):
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = Family(kind, name, help, labelnames, buckets)
                self._families[name] = fam
                return fam
        if fam.kind != kind or fam.labelnames != tuple(labelnames):
            raise ValueError(
                f"metric {name!r} already registered as {fam.kind} with "
                f"labels {fam.labelnames}; cannot re-register as {kind} "
                f"with labels {tuple(labelnames)}")
        return fam

    def counter(self, name, help="", labelnames=()):
        return self._get(COUNTER, name, help, labelnames, ())

    def gauge(self, name, help="", labelnames=()):
        return self._get(GAUGE, name, help, labelnames, ())

    def histogram(self, name, help="", labelnames=(),
                  buckets=DEFAULT_LATENCY_BUCKETS):
        return self._get(HISTOGRAM, name, help, labelnames, buckets)

    def collect(self):
        """Families in registration order (export + schema tests)."""
        with self._lock:
            return list(self._families.values())

    def snapshot(self) -> dict:
        """{name: {labels_tuple_repr: value-or-histogram-dict}} — a
        plain-data view for JSON dumps and assertions."""
        out = {}
        for fam in self.collect():
            series = {}
            for lv, child in fam.items():
                key = ",".join(f"{n}={v}" for n, v in
                               zip(fam.labelnames, lv)) or ""
                if fam.kind == HISTOGRAM:
                    series[key] = {"sum": child.sum, "count": child.count,
                                   "buckets": dict(zip(
                                       [*map(str, child.bounds), "+Inf"],
                                       child.counts))}
                else:
                    series[key] = child.value
            out[fam.name] = {"kind": fam.kind, "series": series}
        return out
