"""Serve observability: metrics registry, span tracing, flight
recorder, exporters.

The serving stack always runs its counters/histograms through a
`MetricsRegistry` (the per-component `stats()` dicts are bit-compatible
views over it).  Span tracing and the flight recorder are opt-in —
construct an `Observability` bundle with `Observability.enabled()` and
hand it to `ServeScheduler(obs=...)` / `ServeRouter(obs=...)`; every
tracing seam is gated on `obs.tracer is not None`, so the default
(metrics-only) path stays bit-identical to a build without this
package.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.obs.export import (
    TraceSchemaError,
    iter_trace_records,
    prometheus_text,
    validate_trace_jsonl,
    write_prometheus,
    write_trace_jsonl,
)
from repro.obs.metrics import (
    COUNTER,
    GAUGE,
    HISTOGRAM,
    LATENCY_QUANTILES,
    Counter,
    Family,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.recorder import FlightRecorder
from repro.obs.trace import Span, SpanTracer, Trace

__all__ = [
    "COUNTER",
    "GAUGE",
    "HISTOGRAM",
    "LATENCY_QUANTILES",
    "Counter",
    "Family",
    "FlightRecorder",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Observability",
    "Span",
    "SpanTracer",
    "Trace",
    "TraceSchemaError",
    "iter_trace_records",
    "prometheus_text",
    "validate_trace_jsonl",
    "write_prometheus",
    "write_trace_jsonl",
]


@dataclass
class Observability:
    """One bundle the serving components share: a registry (always), a
    tracer and flight recorder (optional).  A router passes the same
    bundle into its worker schedulers so one registry/tracer covers the
    whole pool and cross-worker traces (failover replay) land in one
    tree."""

    registry: MetricsRegistry = field(default_factory=MetricsRegistry)
    tracer: SpanTracer | None = None
    recorder: FlightRecorder | None = None

    @classmethod
    def metrics_only(cls) -> "Observability":
        """Registry only — the default wiring; zero tracing overhead."""
        return cls()

    @classmethod
    def enabled(cls, max_finished: int = None, capacity: int = None,
                sink=None) -> "Observability":
        """Full stack: registry + tracer + flight recorder."""
        tkw = {} if max_finished is None else {"max_finished": max_finished}
        rkw = {"sink": sink}
        if capacity is not None:
            rkw["capacity"] = capacity
        return cls(tracer=SpanTracer(**tkw),
                   recorder=FlightRecorder(**rkw))
