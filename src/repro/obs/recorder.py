"""Flight recorder: a bounded ring of recent structured serve events,
dumped automatically on the incidents worth a post-mortem.

Aggregate metrics say a request `exec_failed`; a span tree says where
*that request's* time went; neither says what the runtime was doing
*around* the failure — which dispatches were in flight, what the
watchdog flushed, which worker went quiet.  The flight recorder keeps
the last `capacity` structured events (submit / dispatch / retire /
failure / failover / shed, each a `(t, type, fields)` triple, appended
lock-cheap from inside the serving hot path) and snapshots the whole
ring **exactly once per incident** when one of the dump triggers fires:

  * a request completes `exec_failed` (retry/bisect budget exhausted),
  * a router failover (worker declared dead, work replayed),
  * a watchdog-fired `max_wait_s` deadline flush.

Dumps are keyed: the caller passes an incident key (rid, worker name,
flush ordinal) and a repeated key is a no-op — a failover that strands
ten requests produces ONE dump, not ten.  `max_dumps` bounds retained
snapshots (oldest dropped); an optional `sink` callable ships each dump
out as it happens (the JSONL exporter wires one in).  Like the tracer,
the recorder is optional: every seam is gated on `recorder is not None`
and the disabled path stays bit-identical.
"""

from __future__ import annotations

import threading
import time
from collections import deque

DEFAULT_CAPACITY = 512
DEFAULT_MAX_DUMPS = 16


class FlightRecorder:
    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 max_dumps: int = DEFAULT_MAX_DUMPS, sink=None):
        if capacity < 1:
            raise ValueError("flight recorder capacity must be >= 1")
        self.capacity = int(capacity)
        self.sink = sink
        self._lock = threading.Lock()
        self._ring: deque = deque(maxlen=self.capacity)
        self._dumped_keys: set = set()
        self.dumps: deque = deque(maxlen=max(1, int(max_dumps)))
        self.n_events = 0
        self.n_dumps = 0
        self.n_suppressed = 0       # repeat-key triggers ignored

    def record(self, etype: str, t: float = None, **fields) -> None:
        """Append one structured event to the ring."""
        t = time.monotonic() if t is None else t
        with self._lock:
            self._ring.append((t, etype, fields))
            self.n_events += 1

    def dump(self, reason: str, key=None) -> dict | None:
        """Snapshot the ring for one incident; `key` dedupes — the same
        incident key dumps once, ever.  Returns the dump dict (also
        retained on `self.dumps` and shipped to `sink`), or None when
        the key was already dumped."""
        with self._lock:
            if key is not None:
                if key in self._dumped_keys:
                    self.n_suppressed += 1
                    return None
                self._dumped_keys.add(key)
            d = {"t": time.monotonic(), "reason": reason,
                 "key": repr(key) if key is not None else None,
                 "events": [{"t": t, "type": e, **f}
                            for t, e, f in self._ring]}
            self.dumps.append(d)
            self.n_dumps += 1
        if self.sink is not None:
            try:
                self.sink(d)
            except Exception:
                pass                # a broken sink must not kill serving
        return d

    def events(self) -> list:
        """Current ring contents (newest last) as plain dicts."""
        with self._lock:
            return [{"t": t, "type": e, **f} for t, e, f in self._ring]

    def stats(self) -> dict:
        with self._lock:
            return {"events": self.n_events, "ring": len(self._ring),
                    "capacity": self.capacity, "dumps": self.n_dumps,
                    "suppressed": self.n_suppressed}
