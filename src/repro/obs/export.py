"""Exporters: Prometheus text exposition for the metrics registry, and
JSONL streams for span traces + flight-recorder dumps.

Two formats, both file-first (this repo serves from a CLI/CI world, not
a long-lived daemon — a scrape endpoint would wrap `prometheus_text`
in a dozen lines):

  * **Prometheus text exposition** (`prometheus_text` /
    `write_prometheus`): every family in the registry as
    `# HELP` / `# TYPE` + samples; histograms expand to cumulative
    `_bucket{le=...}` series plus `_sum`/`_count`, so
    `histogram_quantile()` works server-side exactly as the in-process
    `Histogram.quantile` does.
  * **Trace JSONL** (`write_trace_jsonl` / `iter_trace_records`): one
    JSON object per line — `{"kind": "span", ...}` rows reconstruct
    every finished (and optionally still-open) span tree;
    `{"kind": "dump", ...}` rows carry flight-recorder snapshots.
    `validate_trace_jsonl` is the schema gate CI runs on the artifact:
    it re-parses every line, checks required keys, types, parent-pointer
    resolution and span time ordering, and returns a summary dict
    (raising `TraceSchemaError` on any violation).
"""

from __future__ import annotations

import json
import math

from repro.obs import metrics as MX

TRACE_KINDS = ("span", "dump")
SPAN_REQUIRED = ("kind", "trace", "span_id", "parent_id", "name",
                 "t_start", "t_end", "attrs")
DUMP_REQUIRED = ("kind", "reason", "t", "events")


class TraceSchemaError(ValueError):
    """A trace JSONL line violated the schema (see
    `validate_trace_jsonl`)."""


# -- Prometheus text exposition --------------------------------------------

def _fmt(v) -> str:
    if v is None:
        return "NaN"
    f = float(v)
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    return repr(f) if not f.is_integer() else str(int(f))


def _labels(names, values, extra=()) -> str:
    pairs = [f'{n}="{v}"' for n, v in zip(names, values)]
    pairs += [f'{n}="{v}"' for n, v in extra]
    return "{" + ",".join(pairs) + "}" if pairs else ""


def prometheus_text(registry: MX.MetricsRegistry) -> str:
    """The whole registry in Prometheus text exposition format."""
    lines = []
    for fam in registry.collect():
        if fam.help:
            lines.append(f"# HELP {fam.name} {fam.help}")
        lines.append(f"# TYPE {fam.name} {fam.kind}")
        for lv, child in fam.items():
            if fam.kind == MX.HISTOGRAM:
                acc = 0
                for bound, c in zip([*fam.buckets, float("inf")],
                                    child.counts):
                    acc += c
                    le = _labels(fam.labelnames, lv,
                                 [("le", _fmt(bound))])
                    lines.append(f"{fam.name}_bucket{le} {acc}")
                base = _labels(fam.labelnames, lv)
                lines.append(f"{fam.name}_sum{base} {_fmt(child.sum)}")
                lines.append(f"{fam.name}_count{base} {child.count}")
            else:
                base = _labels(fam.labelnames, lv)
                lines.append(f"{fam.name}{base} {_fmt(child.value)}")
    return "\n".join(lines) + "\n"


def write_prometheus(path: str, registry: MX.MetricsRegistry) -> None:
    with open(path, "w") as f:
        f.write(prometheus_text(registry))


# -- trace JSONL ------------------------------------------------------------

def _span_rows(trace):
    for s in trace.span_list():
        yield {"kind": "span", "trace": trace.tid, "span_id": s.span_id,
               "parent_id": s.parent_id, "name": s.name,
               "t_start": s.t_start, "t_end": s.t_end,
               "attrs": {k: _jsonable(v) for k, v in s.attrs.items()}}


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    try:
        return v.item()             # numpy scalars
    except AttributeError:
        return repr(v)


def iter_trace_records(tracer, recorder=None, include_live: bool = True):
    """Every exportable record: spans of finished traces (then live
    ones, open spans with t_end=null), then flight-recorder dumps."""
    if tracer is not None:
        for tr in tracer.finished():
            yield from _span_rows(tr)
        if include_live:
            for tr in tracer.live():
                yield from _span_rows(tr)
    if recorder is not None:
        for d in list(recorder.dumps):
            yield {"kind": "dump", "reason": d["reason"], "t": d["t"],
                   "key": d["key"],
                   "events": [{k: _jsonable(v) for k, v in e.items()}
                              for e in d["events"]]}


def write_trace_jsonl(path: str, tracer, recorder=None,
                      include_live: bool = True) -> int:
    """Write the trace/dump stream as JSONL; returns lines written."""
    n = 0
    with open(path, "w") as f:
        for rec in iter_trace_records(tracer, recorder, include_live):
            f.write(json.dumps(rec, sort_keys=True) + "\n")
            n += 1
    return n


def validate_trace_jsonl(path: str) -> dict:
    """Schema-check one trace JSONL file (the CI artifact gate).

    Raises `TraceSchemaError` naming the first offending line; returns
    {"lines", "spans", "dumps", "traces", "closed_traces"} on success.
    """
    spans_by_trace: dict = {}
    n_dumps = 0
    n_lines = 0
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            n_lines += 1
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise TraceSchemaError(
                    f"{path}:{lineno}: not valid JSON: {e}")
            if not isinstance(rec, dict) or \
                    rec.get("kind") not in TRACE_KINDS:
                raise TraceSchemaError(
                    f"{path}:{lineno}: 'kind' must be one of "
                    f"{TRACE_KINDS}, got {rec.get('kind')!r}")
            if rec["kind"] == "span":
                missing = [k for k in SPAN_REQUIRED if k not in rec]
                if missing:
                    raise TraceSchemaError(
                        f"{path}:{lineno}: span missing keys {missing}")
                if not isinstance(rec["attrs"], dict):
                    raise TraceSchemaError(
                        f"{path}:{lineno}: span attrs must be an object")
                t0, t1 = rec["t_start"], rec["t_end"]
                if not isinstance(t0, (int, float)):
                    raise TraceSchemaError(
                        f"{path}:{lineno}: t_start must be a number")
                if t1 is not None and (not isinstance(t1, (int, float))
                                       or t1 < t0):
                    raise TraceSchemaError(
                        f"{path}:{lineno}: t_end {t1!r} precedes "
                        f"t_start {t0!r}")
                spans_by_trace.setdefault(rec["trace"], []).append(rec)
            else:
                missing = [k for k in DUMP_REQUIRED if k not in rec]
                if missing:
                    raise TraceSchemaError(
                        f"{path}:{lineno}: dump missing keys {missing}")
                if not isinstance(rec["events"], list):
                    raise TraceSchemaError(
                        f"{path}:{lineno}: dump events must be a list")
                n_dumps += 1
    closed = 0
    for tid, spans in spans_by_trace.items():
        ids = {s["span_id"] for s in spans}
        roots = [s for s in spans if s["parent_id"] is None]
        if len(roots) != 1:
            raise TraceSchemaError(
                f"{path}: trace {tid!r} has {len(roots)} root spans "
                f"(exactly 1 required)")
        for s in spans:
            if s["parent_id"] is not None and s["parent_id"] not in ids:
                raise TraceSchemaError(
                    f"{path}: trace {tid!r} span {s['span_id']} has "
                    f"dangling parent {s['parent_id']}")
        if roots[0]["t_end"] is not None:
            closed += 1
    return {"lines": n_lines,
            "spans": sum(len(v) for v in spans_by_trace.values()),
            "dumps": n_dumps, "traces": len(spans_by_trace),
            "closed_traces": closed}
