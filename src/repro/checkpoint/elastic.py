"""Elastic restart: resume a run on a different mesh than it was saved on.

Checkpoints store full (unsharded) logical arrays, so resharding is just
`device_put` onto the new mesh's NamedShardings — the restore path in
store.py already does that.  What this module adds is the policy layer:

  * pick the newest committed step;
  * rebuild shardings for the *surviving* mesh (e.g. 512 -> 256 chips after
    losing a pod, or 256 -> 512 when capacity returns);
  * rescale the data pipeline offset so no batch is skipped or repeated
    (global step x global batch is mesh-independent);
  * validate divisibility (global batch % new data-parallel size).

At 1000+ nodes the same flow runs per-host against a shared filesystem /
object store; only `_gather_for_save`/restore IO changes (per-host shard
files), not this logic.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax

from repro.checkpoint import store
from repro.distributed import sharding as SH
from repro.train import optim as OPT


def resume_or_init(root: str, init_fn, sc: SH.ShardingConfig,
                   global_batch: int) -> Tuple[Any, Any, int]:
    """Returns (params, opt_state, start_step); initialises fresh if no
    committed checkpoint exists."""
    if global_batch % sc.n_data != 0:
        raise ValueError(
            f"global batch {global_batch} not divisible by the new mesh's "
            f"data-parallel size {sc.n_data}; choose a compatible mesh")

    step = store.latest_step(root)
    param_shapes = jax.eval_shape(init_fn)
    opt_shapes = jax.eval_shape(OPT.init, param_shapes)
    p_sh = SH.params_shardings(param_shapes, sc)
    opt_sh = OPT.OptState(step=SH.replicated(sc), m=p_sh, v=p_sh)

    if step is None:
        params = jax.jit(init_fn, out_shardings=p_sh)()
        opt_state = jax.jit(OPT.init, out_shardings=opt_sh)(params)
        return params, opt_state, 0

    params = store.restore(root, step, param_shapes, p_sh)
    opt_state = store.restore(
        root + "/opt", step, opt_shapes, opt_sh) \
        if store.latest_step(root + "/opt") == step else \
        jax.jit(OPT.init, out_shardings=opt_sh)(params)
    return params, opt_state, step


def save_state(root: str, step: int, params, opt_state,
               extra: Optional[dict] = None):
    store.save(root, step, params, extra)
    store.save(root + "/opt", step, opt_state, extra)
