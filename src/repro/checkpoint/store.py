"""Sharded checkpointing with atomic commit and async write.

Layout:
    <root>/step_<N>/
        manifest.json          # tree structure, shapes, dtypes, mesh info
        <flat-key>.npy         # one file per leaf
        COMMIT                 # written last -> marks the step complete

Fault-tolerance contract:
  * a checkpoint is valid iff COMMIT exists (partial writes from a killed
    process are ignored and garbage-collected on the next save);
  * `latest_step()` finds the newest valid step, so restart-after-crash is
    `restore(latest_step())`;
  * saves run on a background thread (training never blocks on disk);
  * restore accepts a different mesh/sharding than save used: arrays are
    `device_put` onto the new sharding (elastic restart — see elastic.py).

Multi-host note: on a real cluster each host writes only the shards it
addresses (`arr.addressable_shards`) into per-host subdirs and host 0 writes
the manifest; this single-process implementation writes full arrays, and the
multi-host path is isolated in `_gather_for_save` for the cluster port.
"""

from __future__ import annotations

import json
import os
import queue
import re
import shutil
import threading
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "__"


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat[0]:
        keys = []
        for k in path:
            if hasattr(k, "key"):
                keys.append(str(k.key))
            elif hasattr(k, "name"):
                keys.append(str(k.name))
            elif hasattr(k, "idx"):
                keys.append(str(k.idx))
        out[_SEP.join(keys)] = leaf
    return out, flat[1]


def _gather_for_save(x) -> np.ndarray:
    """Single-process: full array.  Multi-host port: write
    x.addressable_shards per host instead."""
    return np.asarray(jax.device_get(x))


def save(root: str, step: int, tree: Any, extra: Optional[dict] = None):
    """Synchronous atomic save."""
    step_dir = os.path.join(root, f"step_{step:08d}")
    tmp_dir = step_dir + ".tmp"
    if os.path.exists(tmp_dir):
        shutil.rmtree(tmp_dir)
    os.makedirs(tmp_dir, exist_ok=True)

    flat, _ = _flatten(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": {}}
    for key, leaf in flat.items():
        arr = _gather_for_save(leaf)
        dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or dtype not in np.sctypeDict:
            # ml_dtypes (bfloat16, fp8...) don't survive np.save/np.load:
            # store raw bits, record the logical dtype in the manifest
            arr = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
        np.save(os.path.join(tmp_dir, key + ".npy"), arr)
        manifest["leaves"][key] = {"shape": list(arr.shape),
                                   "dtype": dtype}
    with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    # atomic commit: rename then marker
    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.rename(tmp_dir, step_dir)
    with open(os.path.join(step_dir, "COMMIT"), "w") as f:
        f.write(str(time.time()))
    return step_dir


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    best = None
    for name in os.listdir(root):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(root, name, "COMMIT")):
            best = max(best or -1, int(m.group(1)))
    return best


def restore(root: str, step: int, like: Any, shardings: Any = None) -> Any:
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs).  If `shardings` is given, leaves are device_put onto
    it — this is what makes restarts elastic across mesh changes."""
    step_dir = os.path.join(root, f"step_{step:08d}")
    if not os.path.exists(os.path.join(step_dir, "COMMIT")):
        raise FileNotFoundError(f"no committed checkpoint at {step_dir}")
    flat_like, treedef = _flatten(like)
    flat_sh = _flatten(shardings)[0] if shardings is not None else {}

    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    leaves = {}
    for key, leaf in flat_like.items():
        arr = np.load(os.path.join(step_dir, key + ".npy"))
        logical = manifest["leaves"].get(key, {}).get("dtype")
        if logical and str(arr.dtype) != logical:
            arr = arr.view(np.dtype(logical))      # bf16/fp8 raw bits
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        if key in flat_sh:
            leaves[key] = jax.device_put(arr, flat_sh[key])
        else:
            leaves[key] = jnp.asarray(arr)
    ordered = [leaves[k] for k in flat_like]
    return jax.tree_util.tree_unflatten(treedef, ordered)


def read_manifest(root: str, step: int) -> dict:
    with open(os.path.join(root, f"step_{step:08d}", "manifest.json")) as f:
        return json.load(f)


class CheckpointManager:
    """Async, bounded-retention checkpoint writer."""

    def __init__(self, root: str, keep: int = 3, interval_steps: int = 100):
        self.root = root
        self.keep = keep
        self.interval = interval_steps
        self._q: queue.Queue = queue.Queue(maxsize=1)
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()
        self._last_saved = -1

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree, extra = item
            save(self.root, step, tree, extra)
            self._gc()

    def _gc(self):
        steps = sorted(
            int(m.group(1)) for m in
            (re.fullmatch(r"step_(\d+)", n)
             for n in os.listdir(self.root)) if m)
        for s in steps[:-self.keep]:
            d = os.path.join(self.root, f"step_{s:08d}")
            if os.path.exists(os.path.join(d, "COMMIT")):
                shutil.rmtree(d, ignore_errors=True)

    def maybe_save(self, step: int, tree: Any, extra: Optional[dict] = None,
                   force: bool = False):
        if not force and (step % self.interval or step == self._last_saved):
            return False
        # snapshot to host BEFORE queuing (donated buffers may be reused)
        host_tree = jax.tree_util.tree_map(_gather_for_save, tree)
        try:
            self._q.put_nowait((step, host_tree, extra))
        except queue.Full:
            self._q.get()      # drop the older pending save
            self._q.put((step, host_tree, extra))
        self._last_saved = step
        return True

    def wait(self):
        self._q.join() if False else None
        while not self._q.empty():
            time.sleep(0.05)

    def close(self):
        self.wait()
        self._q.put(None)
        self._worker.join(timeout=10)
