"""Octree chunking over packed keys: recursive range splitting of the
level-0 ranking order into budget-bounded, spatially-local chunks.

The 62-bit packed key (repro.core.packed: batch | x | y | z, biased
fields) is itself a space-filling ordering — ascending key order is the
raster-scan curve over (batch, x, y, z).  Every prefix of the key bits
therefore names a contiguous KEY RANGE: descending the key's bit trie is
the raster-order analogue of descending an octree (batch planes first,
then x halves, then y, then z), and a trie cell is exactly one contiguous
slice of the already-sorted key array.  Splitting is therefore pure
binary search over the one level-0 ranking pass the planner already ran —
no re-sorting, no data movement, and equal keys (duplicate voxels) can
never be separated because they share every bit.

`split_ranges` is the whole algorithm: descend the trie, emit a leaf as
soon as its population fits the point budget, keep splitting otherwise.
Degenerate ranges that exhaust all 62 bits (every key identical) are
emitted as-is — the plan's capacity check catches them loudly rather than
this module splitting a voxel in half silently.
"""

from __future__ import annotations

import numpy as np

from repro.core import packed as PK

# Highest bit of the logical key (bit 61: top of the 14-bit batch field).
_TOP_BIT = PK.KEY64_BITS - 1


def split_ranges(keys_sorted: np.ndarray, budget: int) -> list[tuple[int, int]]:
    """Split an ascending uint64 key array into contiguous ranges of at
    most `budget` points each, along packed-key trie (octree) cell
    boundaries.

    Returns [(start, end), ...] half-open index ranges, ascending and
    exactly covering [0, len(keys_sorted)).  Equal keys always land in
    the same range; a range whose keys are ALL equal is emitted even when
    it exceeds the budget (the caller decides whether an over-populated
    single voxel is an error).
    """
    keys_sorted = np.asarray(keys_sorted, np.uint64)
    n = int(keys_sorted.shape[0])
    if budget < 1:
        raise ValueError(f"chunk budget must be >= 1, got {budget}")
    if n == 0:
        return []
    out: list[tuple[int, int]] = []
    stack = [(0, n, _TOP_BIT)]
    while stack:
        s, e, bit = stack.pop()
        if e - s <= budget or bit < 0:
            out.append((s, e))
            continue
        # keys in [s, e) share every bit above `bit`; the boundary between
        # the bit=0 and bit=1 halves of this trie cell is one binary search
        one = np.uint64(1) << np.uint64(bit)
        prefix = keys_sorted[s] & ~(one | (one - np.uint64(1)))
        mid = s + int(np.searchsorted(keys_sorted[s:e], prefix | one,
                                      side="left"))
        if mid == s or mid == e:
            stack.append((s, e, bit - 1))
        else:
            stack.append((mid, e, bit - 1))
            stack.append((s, mid, bit - 1))
    out.sort()
    return out


def rank_keys(coords, mask) -> tuple[np.ndarray, np.ndarray, int]:
    """The planner's one level-0 ranking pass, on the host.

    Returns `(keys_sorted, order, n_valid)`: uint64 packed keys in
    ascending order (sentinels at the end), the stable permutation
    original-row -> sorted position inverse (`order[i]` = original row at
    sorted position i), and the count of valid (non-sentinel) keys.
    Everything downstream — trie splitting, halo searches, the stride
    pyramid — reuses this single sort.
    """
    keys = PK.pack_coords_host(coords, mask)
    order = np.argsort(keys, kind="stable").astype(np.int64)
    keys_sorted = keys[order]
    n_valid = int(np.searchsorted(keys_sorted, PK.KEY64_SENTINEL,
                                  side="left"))
    return keys_sorted, order, n_valid
