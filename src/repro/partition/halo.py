"""Exact halo computation: which input points does a chunk need so its
conv outputs match the monolithic network bit-for-bit on interior points?

Sparse convolution influence only flows through PRESENT sites: a
submanifold conv at level l gathers the (at most 27) present neighbours
of each site, a stride-2 down conv gathers the (at most 8) present fine
sites of each coarse cell, and the transposed decoder conv gathers
exactly the cell a fine site lives in.  The needed-input set of a chunk's
interior is therefore computable EXACTLY — no conservative bounding box —
by walking the network's conv sites backward over the full cloud's stride
pyramid and propagating "needed" marks along those present-site edges:

    marks[level 0] = chunk interior
    decoder (reversed):  dilate by that stage's submanifold stencil,
                         then lift marks fine -> coarse (cell members);
    encoder (reversed):  dilate at each level (skip-join marks included —
                         the decoder concatenates the encoder output, so
                         its needs flow into the encoder backward pass),
                         then drop marks coarse -> fine (cell lookup);
    stem:                one final dilation at level 0.

Every edge lookup is a binary search of shifted/quantized packed keys
against a level's sorted keys — the `kernel_map_v2` machinery, run
host-side (numpy searchsorted over the composed uint64 keys) because
chunk populations are dynamic shapes.  Marks for ALL chunks propagate in
one pass as an (n_sites, n_chunks) boolean matrix: the neighbour tables
are chunk-independent, so the fan-out costs gathers + ORs, not repeated
searches.

Exactness argument (the headline invariant): by induction over the
backward walk, every site marked needed at a level has (a) its full fine
support marked at the level below, so the chunk's own downsample
reconstructs the site with the monolithic feature, and (b) every present
neighbour its convs gather marked needed too, so no partially-supported
border cell ever contributes to an interior output.  Chunk clouds are
subsets of the monolithic cloud, so no extra sites appear either.
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np

from repro.core import packed as PK


class HaloSpec(NamedTuple):
    """Receptive-field description of a MinkUNet-style stride pyramid.

    `dec_rounds[l]` — submanifold dilation rounds the decoder runs at
    level l (two per residual block of the stage that PRODUCES level l);
    `enc_rounds[l]` — rounds the encoder runs at level l (the stem at
    level 0, two per block for levels 1..n_stages).
    """

    n_stages: int
    dec_rounds: tuple[int, ...]   # length n_stages     (levels 0..S-1)
    enc_rounds: tuple[int, ...]   # length n_stages + 1 (levels 0..S)

    @classmethod
    def uniform(cls, n_stages: int, blocks_per_stage: int) -> "HaloSpec":
        r = 2 * blocks_per_stage
        return cls(n_stages, (r,) * n_stages,
                   (1,) + (r,) * n_stages)


class KeyPyramid(NamedTuple):
    """The full cloud's stride pyramid as sorted unique uint64 key arrays
    (level l at stride 2**l), plus the map from level-0 unique sites back
    to unique-site ids of the ranking order."""

    levels: tuple[np.ndarray, ...]   # level l: ascending unique uint64 keys


def build_pyramid(keys0_unique: np.ndarray, n_stages: int) -> KeyPyramid:
    """Coarsen the (already unique, ascending, sentinel-free) level-0
    keys through `n_stages` stride doublings — quantization happens in
    the key domain (clear low bits per field), dedup is np.unique on the
    host: the partition-planner analogue of `mapping.downsample_sorted`.
    """
    levels = [np.asarray(keys0_unique, np.uint64)]
    for l in range(1, n_stages + 1):
        levels.append(np.unique(PK.quantize_key64(levels[-1], 2 ** l)))
    return KeyPyramid(tuple(levels))


def _lookup(level_keys: np.ndarray, queries: np.ndarray) -> np.ndarray:
    """Index of each query key in a level's sorted keys, -1 on miss
    (including sentinel queries from out-of-budget shifts)."""
    idx = np.searchsorted(level_keys, queries)
    n = level_keys.shape[0]
    safe = np.clip(idx, 0, max(n - 1, 0))
    hit = (idx < n) & (queries != PK.KEY64_SENTINEL)
    if n:
        hit &= level_keys[safe] == queries
    return np.where(hit, safe, -1).astype(np.int64)


def subm_table(level_keys: np.ndarray, stride: int) -> np.ndarray:
    """(27, n) neighbour table for the k=3 submanifold stencil at
    `stride`: row k holds the level index of site + offset_k (-1 when
    absent).  Offsets go through unpack -> shift -> repack so border
    sites that would leave the coordinate budget saturate to a miss
    instead of aliasing another field."""
    coords = PK.unpack_key64(level_keys)
    tables = []
    for dx in (-stride, 0, stride):
        for dy in (-stride, 0, stride):
            for dz in (-stride, 0, stride):
                shifted = coords + np.array([0, dx, dy, dz], np.int32)
                tables.append(_lookup(level_keys,
                                      PK.pack_coords_host(shifted)))
    return np.stack(tables)


def up_table(fine_keys: np.ndarray, coarse_keys: np.ndarray,
             fine_stride: int) -> np.ndarray:
    """(8, n_coarse) table: fine-level indices of each coarse cell's
    members (the k=2 down-conv support; -1 where the fine site is
    absent).  Cell-member fields never overflow, so the shift happens
    directly in the key domain."""
    s = np.uint64(fine_stride)
    tables = []
    for dx in (np.uint64(0), s):
        for dy in (np.uint64(0), s):
            for dz in (np.uint64(0), s):
                q = coarse_keys + ((dx << np.uint64(32))
                                   | (dy << np.uint64(16)) | dz)
                tables.append(_lookup(fine_keys, q))
    return np.stack(tables)


def cell_table(fine_keys: np.ndarray, coarse_keys: np.ndarray,
               coarse_stride: int) -> np.ndarray:
    """(n_fine,) table: coarse-level index of each fine site's cell
    (always present — the cell was built from its members)."""
    return _lookup(coarse_keys, PK.quantize_key64(fine_keys, coarse_stride))


def _or_gather(src_marks: np.ndarray, table: np.ndarray) -> np.ndarray:
    """(n_src, C) marks gathered through a (K, n_dst) index table into
    (n_dst, C) marks: dst |= src[table[k]] over the K stencil rows."""
    n_dst = table.shape[1]
    out = np.zeros((n_dst, src_marks.shape[1]), bool)
    for k in range(table.shape[0]):
        idx = table[k]
        ok = idx >= 0
        out[ok] |= src_marks[idx[ok]]
    return out


def _dilate(marks: np.ndarray, table: np.ndarray, rounds: int) -> np.ndarray:
    for _ in range(rounds):
        marks = _or_gather(marks, table)
    return marks


def needed_marks(pyramid: KeyPyramid, spec: HaloSpec,
                 interior: np.ndarray) -> np.ndarray:
    """(n_level0_sites, n_chunks) needed-input marks from (same-shaped)
    interior marks: the backward walk described in the module docstring.
    The returned marks are a superset of the interior (influence includes
    the identity path), so `needed & ~interior` is exactly the halo."""
    S = spec.n_stages
    if len(pyramid.levels) != S + 1:
        raise ValueError(f"pyramid has {len(pyramid.levels)} levels, spec "
                         f"wants {S + 1}")
    subm = [subm_table(pyramid.levels[l], 2 ** l) for l in range(S + 1)]
    m = [None] * (S + 1)
    m[0] = np.asarray(interior, bool).copy()
    # decoder, reversed: level l marks dilate through the stage's blocks,
    # then lift onto the transposed conv's coarse input
    for l in range(S):
        m[l] = _dilate(m[l], subm[l], spec.dec_rounds[l])
        m[l + 1] = _or_gather(
            m[l], up_table(pyramid.levels[l], pyramid.levels[l + 1], 2 ** l))
    # encoder, reversed: skip-join marks are already in m[l]; dilate, then
    # drop every needed cell's full fine support onto the level below
    for l in range(S, 0, -1):
        m[l] = _dilate(m[l], subm[l], spec.enc_rounds[l])
        cells = cell_table(pyramid.levels[l - 1], pyramid.levels[l], 2 ** l)
        m[l - 1] |= m[l][cells]
    return _dilate(m[0], subm[0], spec.enc_rounds[0])
