"""City-scale scene partitioning: octree chunking over packed keys with
exact halo exchange.

One huge point cloud becomes a stream of bucket-sized, spatially-local
chunks that flow through the existing serve stack as ordinary scenes; the
plan stitches per-chunk predictions back into scene order with halo rows
dropped, and chunked output equals the monolithic output exactly on every
interior point (the subsystem's headline invariant).

  * `octree`  — recursive packed-key range splitting of the level-0
    ranking order into budget-bounded chunks (FractalCloud-style, on the
    62-bit key trie — no extra sort beyond the one ranking pass);
  * `halo`    — per-chunk needed-input sets from the kernel receptive
    field across the stride pyramid (binary searches against each
    level's packed keys — the `kernel_map_v2` machinery, host-side);
  * `plan`    — `PartitionPlan`: chunks onto the `BucketLadder`, through
    `ServeScheduler`/`ServeRouter` submit/flush/take, gather + stitch.
"""

from repro.partition.halo import HaloSpec  # noqa: F401
from repro.partition.octree import split_ranges  # noqa: F401
from repro.partition.plan import (  # noqa: F401
    PartitionPlan, PartitionPolicy, plan_partition)
