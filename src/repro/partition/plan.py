"""PartitionPlan: octree chunks, halo'd, served as ordinary scenes.

`plan_partition` turns one oversized scene into a `PartitionPlan`:

  1. one host ranking pass (`octree.rank_keys` — the level-0 sort every
     downstream structure reuses);
  2. trie range splitting into interior chunks of at most
     `chunk_budget` points (`octree.split_ranges`);
  3. exact needed-input marks for every chunk in one propagation pass
     (`halo.needed_marks` over the full cloud's stride pyramid);
  4. chunk assembly: each chunk's rows = its needed points in packed-key
     order (interior + halo), small enough for the bucket ladder — a
     chunk whose halo overflows the top bucket halves the budget and
     replans.

The plan then `run`s against anything with the serve submit/flush/take
surface (`ServeScheduler`, `ServeRouter`): chunks are admitted as
ordinary scenes — they pad to ladder buckets, micro-batch with their
peers, hit the mapping/assembly caches by geometry digest (repeated
chunks keep their warm worker under digest-affinity routing) — and the
per-chunk predictions are stitched back into the caller's row order with
every halo row dropped.  Interior outputs are exact (see `halo`), so the
stitched result equals the monolithic network's output on every valid
row.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.partition import halo as HL
from repro.partition import octree as OC


@dataclasses.dataclass(frozen=True)
class PartitionPolicy:
    """Partition policy knobs for `PointCloudEngine.segment(partition=)`.

    chunk_budget — target INTERIOR points per chunk (halo rides on top);
                   None derives half the ladder's top bucket, leaving the
                   other half as halo headroom.
    force        — partition even when the scene fits the ladder (parity
                   tests and benchmarks chunk small scenes on purpose).
    max_attempts — budget halvings allowed when a chunk's interior+halo
                   overflows the top bucket before planning fails loudly.
    """

    chunk_budget: int | None = None
    force: bool = False
    max_attempts: int = 6


@dataclasses.dataclass
class Chunk:
    """One bucket-sized scene cut from the big cloud (valid rows only,
    in packed-key order: interior + halo interleaved by key)."""

    coords: np.ndarray      # (m, 4) int32
    mask: np.ndarray        # (m,) bool, all True
    feats: np.ndarray       # (m, C)
    rows: np.ndarray        # (m,) original scene row of each chunk row
    interior: np.ndarray    # (m,) bool — False rows are halo, dropped

    @property
    def n_points(self) -> int:
        return int(self.coords.shape[0])

    @property
    def n_halo(self) -> int:
        return int((~self.interior).sum())


@dataclasses.dataclass
class PartitionPlan:
    """Chunks plus the stitch back into scene order."""

    chunks: list[Chunk]
    n_rows: int             # original scene row count
    n_valid: int
    budget: int             # interior budget the final split used
    spec: HL.HaloSpec

    @property
    def n_chunks(self) -> int:
        return len(self.chunks)

    @property
    def halo_rows(self) -> int:
        return sum(c.n_halo for c in self.chunks)

    @property
    def halo_fraction(self) -> float:
        total = sum(c.n_points for c in self.chunks)
        return self.halo_rows / total if total else 0.0

    def stats(self) -> dict:
        sizes = [c.n_points for c in self.chunks]
        return {"n_chunks": self.n_chunks, "n_valid": self.n_valid,
                "budget": self.budget, "halo_rows": self.halo_rows,
                "halo_fraction": self.halo_fraction,
                "max_chunk_points": max(sizes, default=0),
                "chunk_points": sizes}

    def stitch(self, preds_by_chunk) -> np.ndarray:
        """Per-chunk predictions -> (n_rows,) scene-order class ids.
        Halo rows are dropped; rows no chunk owned (invalid/masked rows,
        or chunks that failed) stay -1 — never a valid class id."""
        out = np.full(self.n_rows, -1, np.int32)
        for chunk, preds in zip(self.chunks, preds_by_chunk):
            if preds is None:
                continue
            preds = np.asarray(preds)
            sel = chunk.interior
            out[chunk.rows[sel]] = preds[sel]
        return out

    def run(self, target, tracer=None, trace_id=None):
        """Serve every chunk through `target` (a `ServeScheduler` or
        `ServeRouter`: anything with submit/flush/take) and stitch.

        Returns `(preds, mapping_hit, errors)`: scene-order predictions
        (-1 on rows of failed chunks), whether every completed chunk's
        pyramid came from the mapping cache, and {chunk_index:
        ServeError} for chunks that completed with a typed error.

        With a `repro.obs.SpanTracer` and a begun `trace_id`, the
        fan-out and stitch phases land as spans on that trace (each
        chunk additionally owns an ordinary per-request trace in the
        target's scheduler; the fan-out span carries their rids for
        cross-referencing).
        """
        tr = tracer if trace_id is not None else None
        t0 = time.monotonic()
        rids = [target.submit(c.coords, c.feats, c.mask)
                for c in self.chunks]
        if tr is not None:
            tr.span(trace_id, "chunk_fanout", t_start=t0,
                    t_end=time.monotonic(), n_chunks=len(self.chunks),
                    rids=list(rids))
        target.flush()
        by_rid = target.take(rids)
        errors = {i: by_rid[r].error for i, r in enumerate(rids)
                  if by_rid[r].error is not None}
        t1 = time.monotonic()
        preds = self.stitch([None if i in errors
                             else by_rid[r].preds
                             for i, r in enumerate(rids)])
        if tr is not None:
            tr.span(trace_id, "stitch", t_start=t1,
                    t_end=time.monotonic(), n_errors=len(errors))
        hit = all(by_rid[r].mapping_hit for i, r in enumerate(rids)
                  if i not in errors) if len(errors) < len(rids) else False
        return preds, hit, errors


def plan_partition(coords, mask, feats, *, spec: HL.HaloSpec, ladder,
                   policy: PartitionPolicy | None = None) -> PartitionPlan:
    """Build a `PartitionPlan` for one (coords, mask, feats) scene."""
    policy = policy or PartitionPolicy()
    coords = np.asarray(coords)
    feats = np.asarray(feats)
    n_rows = coords.shape[0]
    mask = np.ones(n_rows, bool) if mask is None else np.asarray(mask, bool)
    if coords.ndim != 2 or coords.shape[1] != 4:
        raise ValueError("partitioning needs (N, 4) coords (batch + 3 "
                         f"spatial dims), got {coords.shape}")

    keys_sorted, order, n_valid = OC.rank_keys(coords, mask)
    if n_valid == 0:
        return PartitionPlan([], n_rows, 0, 0, spec)
    valid_keys = keys_sorted[:n_valid]
    ukeys, uinv = np.unique(valid_keys, return_inverse=True)
    pyramid = HL.build_pyramid(ukeys, spec.n_stages)

    top = ladder.capacities[-1]
    budget = policy.chunk_budget if policy.chunk_budget is not None \
        else max(1, top // 2)
    if budget > top:
        raise ValueError(f"chunk_budget {budget} exceeds the ladder's top "
                         f"bucket ({top}); halo needs headroom below it")

    for attempt in range(policy.max_attempts):
        ranges = OC.split_ranges(valid_keys, budget)
        # equal keys never split, so unique-site ranges partition cleanly
        interior = np.zeros((ukeys.shape[0], len(ranges)), bool)
        for c, (s, e) in enumerate(ranges):
            interior[uinv[s]:uinv[e - 1] + 1, c] = True
        needed = HL.needed_marks(pyramid, spec, interior)

        chunks = []
        for c, (s, e) in enumerate(ranges):
            positions = np.flatnonzero(needed[uinv, c])
            if positions.shape[0] > top:
                chunks = None
                break
            rows = order[positions]
            chunks.append(Chunk(
                coords=np.ascontiguousarray(coords[rows]),
                mask=np.ones(positions.shape[0], bool),
                feats=np.ascontiguousarray(feats[rows]),
                rows=rows,
                interior=(positions >= s) & (positions < e)))
        if chunks is not None:
            return PartitionPlan(chunks, n_rows, n_valid, budget, spec)
        if budget == 1:
            break
        budget = max(1, budget // 2)
    raise ValueError(
        f"could not partition the scene into chunks fitting the top "
        f"bucket ({top}) within {policy.max_attempts} budget halvings — "
        f"the receptive-field halo outgrows the ladder; extend the "
        f"ladder or shrink the network's receptive field")
