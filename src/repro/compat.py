"""Version-compatibility shims for jax APIs that moved between releases.

The codebase targets current jax but must run on the pinned runtime image
(jax 0.4.37).  Import the moved names from here instead of guessing.
"""

from __future__ import annotations

import jax
import jax.experimental.pallas.tpu as pltpu

# Pallas TPU compiler params: TPUCompilerParams (<= 0.4.x) was renamed to
# CompilerParams in newer releases.
CompilerParams = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))

# shard_map graduated from jax.experimental.shard_map to jax.shard_map, and
# renamed kwargs along the way: axis_names (manual axes) replaced `auto` (its
# complement), check_vma replaced check_rep.
# lax.pcast(..., to="varying") feeds the VMA type system of new shard_map;
# older releases spell it lax.pvary or (0.4.x) have no VMA tracking at all,
# where marking is a no-op.
if hasattr(jax.lax, "pcast"):
    pcast_varying = lambda x, axes: jax.lax.pcast(x, axes, to="varying")
elif hasattr(jax.lax, "pvary"):
    pcast_varying = jax.lax.pvary
else:
    pcast_varying = lambda x, axes: x


# lax.axis_size(name) is newer API; psum of a literal 1 is the classic
# spelling and constant-folds to the same static size.
if hasattr(jax.lax, "axis_size"):
    axis_size = jax.lax.axis_size
else:
    def axis_size(axis_name):
        return jax.lax.psum(1, axis_name)


if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, mesh, in_specs, out_specs, axis_names=None,
                  check_vma=None, **kwargs):
        # axis_names (partial-manual) would map onto old shard_map's `auto`
        # complement, but 0.4.x lowers that through PartitionId, which the
        # CPU SPMD partitioner rejects.  Treating every axis as manual is
        # equivalent here: specs leave the non-manual axes unmentioned, so
        # those inputs are replicated and the body computes identically
        # across them.  The old replication checker can't see that, so it
        # stays off (it's a static check only).
        del axis_names, check_vma
        kwargs.setdefault("check_rep", False)
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kwargs)
