"""Roofline-term extraction from compiled dry-run artifacts.

Hardware model (TPU v5e):
  peak bf16 compute   197 TFLOP/s per chip
  HBM bandwidth       819 GB/s per chip
  ICI link bandwidth  ~50 GB/s per link

Terms per cell:
  compute    = HLO_FLOPs   / (chips * peak)
  memory     = HLO_bytes   / (chips * hbm_bw)
  collective = coll_bytes  / (chips * link_bw)

`cost_analysis()` counts a `lax.scan` body ONCE (verified experimentally),
so whole-model costs are reconstructed by two-point extrapolation: lower the
same step at depth = 1 body and 2 bodies; per-body cost is the delta and
  total = c(1) + (n_bodies - 1) * (c(2) - c(1)).
The same extrapolation applies to collective bytes parsed from the
post-SPMD HLO text (collectives inside the scanned while body also appear
once).  Embed/head/optimizer costs cancel in the delta and are captured by
the depth-1 base term.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tuple": 0, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes of every collective op in (post-SPMD) HLO text.

    Note: these are per-SHARD shapes (post-partitioning), i.e. bytes moved
    per device — which is what the roofline term wants.
    """
    out = {k: 0 for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.search(r"=\s+(\S+)\s+([\w-]+)\(", line)
        if not m:
            continue
        op = m.group(2)
        kind = next((k for k in _COLL_KINDS if op == k or
                     op.startswith(k + ".")), None)
        if kind is None:
            continue
        # operand types appear inside the call parens
        args = line[m.end():line.rfind(")")]
        b = _shape_bytes(args)
        if b == 0:                       # fallback: output type
            b = _shape_bytes(m.group(1))
        out[kind] += b
    return out


@dataclasses.dataclass
class CellCost:
    """All values are PER-DEVICE: XLA cost analysis runs on the partitioned
    per-device module, and collective shapes in post-SPMD HLO are
    per-shard."""
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device bytes accessed
    coll_bytes_per_chip: float   # per-chip collective bytes on the wire
    coll_by_kind: Dict[str, float]

    def terms(self, analytic_flops_per_chip: Optional[float] = None
              ) -> Dict[str, float]:
        f = analytic_flops_per_chip if analytic_flops_per_chip else \
            self.flops
        return {
            "compute_s": f / PEAK_FLOPS,
            "memory_s": self.hbm_bytes / HBM_BW,
            "collective_s": self.coll_bytes_per_chip / ICI_BW,
        }


def extract_cost(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis() or {}
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
    }


def extrapolate(c1: Dict, c2: Dict, coll1: Dict, coll2: Dict,
                n_bodies: int) -> CellCost:
    """Two-point depth extrapolation (see module docstring)."""
    flops = c1["flops"] + (n_bodies - 1) * max(
        0.0, c2["flops"] - c1["flops"])
    byts = c1["bytes"] + (n_bodies - 1) * max(
        0.0, c2["bytes"] - c1["bytes"])
    per_kind = {}
    total_coll = 0.0
    for k in _COLL_KINDS:
        v = coll1.get(k, 0) + (n_bodies - 1) * max(
            0, coll2.get(k, 0) - coll1.get(k, 0))
        per_kind[k] = float(v)
        total_coll += v
    return CellCost(flops, byts, total_coll, per_kind)


def model_flops(cfg, shape, n_active_params: int,
                total_tokens: Optional[int] = None) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) for train;
    2*N*D for a forward-only (prefill/decode) step."""
    if total_tokens is None:
        total_tokens = shape.batch * (shape.seq if shape.kind != "decode"
                                      else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active_params * total_tokens
