"""Training launcher: config -> mesh -> sharded train loop with
fault tolerance (checkpoint/restart, preemption, heartbeat, stragglers).

CPU-scale usage (runs in this container):
  PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --reduced \
      --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/run1

Cluster usage (TPU pods): drop --reduced/--debug-mesh; the same script
builds the 16x16 or 2x16x16 production mesh, enables FSDP for >3B params
and resumes from the newest committed checkpoint automatically.
"""

from __future__ import annotations

import argparse

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--lr-total-steps", type=int, default=None,
                    help="schedule horizon (defaults to --steps); set it "
                         "explicitly when a run will be resumed so the "
                         "schedule is invariant to segmentation")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", choices=["none", "debug", "pod", "multipod"],
                    default="none")
    ap.add_argument("--moe-impl", default=None)
    ap.add_argument("--compute-dtype", default="float32")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.checkpoint import elastic, store
    from repro.data.pipeline import PrefetchIterator
    from repro.data.synthetic import token_batch
    from repro.distributed import sharding as SH
    from repro.launch.fault_tolerance import (Heartbeat, PreemptionHandler,
                                              StepTimer)
    from repro.launch.mesh import make_debug_mesh, make_production_mesh
    from repro.models import registry
    from repro.train import optim as OPT
    from repro.train.step import TrainConfig, make_train_step

    cfg = configs.get(args.arch, reduced=args.reduced)
    model = registry.build(cfg)

    sc = None
    if args.mesh != "none":
        mesh = {"debug": lambda: make_debug_mesh(),
                "pod": lambda: make_production_mesh(),
                "multipod": lambda: make_production_mesh(multi_pod=True)
                }[args.mesh]()
        n_p = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(
            jax.eval_shape(model.init, jax.random.key(0))))
        sc = SH.ShardingConfig(mesh, fsdp=n_p > 3e9, seq_parallel=True)

    tc = TrainConfig(compute_dtype=getattr(jnp, args.compute_dtype),
                     remat=True, accum_steps=args.accum,
                     use_chunked_ce=cfg.vocab_size >= 8192)
    horizon = args.lr_total_steps or args.steps
    ocfg = OPT.AdamWConfig(lr=args.lr, total_steps=horizon,
                           warmup_steps=max(1, horizon // 20))
    step_fn = make_train_step(model, tc, ocfg, sc)

    # ---- init or resume ---------------------------------------------------
    start_step = 0
    if args.ckpt_dir and sc is not None:
        params, opt_state, start_step = elastic.resume_or_init(
            args.ckpt_dir, lambda: model.init(jax.random.key(args.seed)),
            sc, args.batch)
    else:
        params = model.init(jax.random.key(args.seed))
        opt_state = OPT.init(params)
        if args.ckpt_dir:
            last = store.latest_step(args.ckpt_dir)
            if last is not None:
                params = store.restore(args.ckpt_dir, last,
                                       jax.eval_shape(lambda: params))
                opt_state = store.restore(
                    args.ckpt_dir + "/opt", last,
                    jax.eval_shape(lambda: opt_state))
                start_step = last
                print(f"[resume] step {last}")

    if sc is not None:
        p_sh = SH.params_shardings(jax.eval_shape(lambda: params), sc)
        opt_sh = OPT.OptState(step=SH.replicated(sc), m=p_sh, v=p_sh)
        jit_step = jax.jit(step_fn, in_shardings=(p_sh, opt_sh, None),
                           out_shardings=(p_sh, opt_sh, None),
                           donate_argnums=(0, 1))
    else:
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    def batch_fn(step):
        return token_batch(args.seed, step, args.batch, args.seq,
                           cfg.vocab_size)

    data = PrefetchIterator(batch_fn, start_step=start_step)
    timer = StepTimer()
    hb = Heartbeat(stall_s=1800)
    losses = []

    with PreemptionHandler() as pre:
        for step, batch in data:
            if step >= args.steps or pre.should_stop:
                break
            timer.start()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt_state, metrics = jit_step(params, opt_state, batch)
            stats = timer.stop()
            hb.beat()
            losses.append(float(metrics["loss"]))
            if step % args.log_every == 0 or stats["straggler"]:
                print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"{stats['step_s']:.2f}s"
                      + (" [straggler]" if stats["straggler"] else ""),
                      flush=True)
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                store.save(args.ckpt_dir, step + 1, params)
                store.save(args.ckpt_dir + "/opt", step + 1, opt_state)

        if pre.should_stop and args.ckpt_dir:
            print("[preempt] saving final checkpoint")
            store.save(args.ckpt_dir, step, params)
            store.save(args.ckpt_dir + "/opt", step, opt_state)

    data.close()
    hb.close()
    if len(losses) >= 10:
        first, last = np.mean(losses[:5]), np.mean(losses[-5:])
        print(f"loss {first:.4f} -> {last:.4f} "
              f"({'improved' if last < first else 'NOT improved'})")
    return losses


if __name__ == "__main__":
    main()
