"""Assigned input-shape sets and ShapeDtypeStruct stand-ins per cell.

Every (arch x shape) pair is a dry-run "cell".  `input_specs()` returns
weak-type-correct, shardable ShapeDtypeStructs — no device allocation —
including the stubbed modality-frontend embeddings for [audio]/[vlm].

Skip rules (per assignment):
  * long_500k needs sub-quadratic attention -> only archs with
    cfg.subquadratic (gemma2 local/global, jamba, xlstm, mixtral SWA);
    skipped with a note for pure full-attention archs.
  * decode shapes are skipped for encoder-only archs (none in this pool;
    seamless-m4t is enc-dec and DOES decode).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

I32 = jnp.int32
BF16 = jnp.bfloat16


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# [vlm]: patch embeddings prepended to the text stream
VLM_PATCH_TOKENS = 1024
# [audio]: decoder length as a fraction of the encoder frame count
AUDIO_DEC_FRACTION = 4


def cell_supported(cfg: ArchConfig, shape: ShapeSpec) -> Optional[str]:
    """None if runnable; else a human-readable skip reason."""
    if cfg.family == "pointcloud":
        return "point-cloud arch: LM shapes n/a (see paper benchmarks)"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return ("pure full-attention arch: long_500k needs sub-quadratic "
                "attention (skip noted in DESIGN.md)")
    return None


def _positions(cfg: ArchConfig, b: int, s: int):
    if cfg.mrope:
        return jax.ShapeDtypeStruct((b, s, 3), I32)
    return jax.ShapeDtypeStruct((b, s), I32)


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """Batch ShapeDtypeStructs for the step function of this cell."""
    b, s = shape.batch, shape.seq
    if shape.kind == "decode":
        batch = {
            "tokens": jax.ShapeDtypeStruct((b, 1), I32),
            "positions": _positions(cfg, b, 1),
            "cache_pos": jax.ShapeDtypeStruct((b,), I32),
        }
        return batch

    if cfg.family == "audio":
        s_dec = max(128, s // AUDIO_DEC_FRACTION)
        batch = {
            "frame_embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), BF16),
            "enc_positions": jax.ShapeDtypeStruct((b, s), I32),
            "tokens": jax.ShapeDtypeStruct((b, s_dec), I32),
            "positions": jax.ShapeDtypeStruct((b, s_dec), I32),
        }
        if shape.kind == "train":
            batch["labels"] = jax.ShapeDtypeStruct((b, s_dec), I32)
        return batch

    if cfg.family == "vlm":
        s_img = min(VLM_PATCH_TOKENS, s // 4)
        s_txt = s - s_img
        batch = {
            "patch_embeds": jax.ShapeDtypeStruct((b, s_img, cfg.d_model),
                                                 BF16),
            "tokens": jax.ShapeDtypeStruct((b, s_txt), I32),
            "positions": _positions(cfg, b, s),
        }
        if shape.kind == "train":
            batch["labels"] = jax.ShapeDtypeStruct((b, s), I32)
        return batch

    batch = {
        "tokens": jax.ShapeDtypeStruct((b, s), I32),
        "positions": _positions(cfg, b, s),
    }
    if shape.kind == "train":
        batch["labels"] = jax.ShapeDtypeStruct((b, s), I32)
    return batch


def decode_state_specs(model, cfg: ArchConfig, shape: ShapeSpec):
    """ShapeDtypeStructs for the decode-state pytree of this cell."""
    return jax.eval_shape(
        lambda: model.init_state(shape.batch, shape.seq, BF16))
