"""Analytic FLOP model per architecture x shape.

Why analytic: XLA's `cost_analysis()` counts every `while` (scan) body
exactly once.  The dry-run corrects the *layer* scan by two-point depth
extrapolation, but inner scans (mamba/mLSTM chunk scans, sLSTM time steps,
chunked-CE vocab chunks) are still undercounted.  The compute roofline term
therefore uses this analytic model; the HLO-derived number is reported
alongside as a cross-check/lower bound.

Conventions: multiply-accumulate = 2 FLOPs; training = 3x forward
(fwd + 2x bwd); `remat` adds one extra forward (+1x).  Attention score
FLOPs use the average attended length under causal masking.
"""

from __future__ import annotations

from repro.configs.base import ArchConfig
from repro.models.lm import body_layout


def _attn_len(seq_q: int, kv_len: int, window, causal=True) -> float:
    """Average attended kv length per query."""
    if window is not None:
        kv_len = min(kv_len, window)
        # causal + window: ramps up to w then flat
        if causal and seq_q > 1:
            w = kv_len
            ramp = min(seq_q, w)
            avg = (ramp * (ramp + 1) / 2 + max(0, seq_q - w) * w) / seq_q
            return avg
        return kv_len
    if causal and seq_q > 1:
        return (kv_len + 1) / 2
    return kv_len


def attn_flops(cfg: ArchConfig, seq_q: int, kv_len: int, window) -> float:
    """Per-sequence forward FLOPs of one attention layer."""
    d, h, hkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    proj = 2 * seq_q * d * (h + 2 * hkv) * hd + 2 * seq_q * h * hd * d
    L = _attn_len(seq_q, kv_len, window)
    scores = 2 * seq_q * L * h * hd * 2        # qk^T and pv
    return proj + scores


def mlp_flops(cfg: ArchConfig, seq: int, d_ff=None) -> float:
    f = d_ff or cfg.d_ff
    n_mats = 3 if cfg.gated_mlp else 2
    return 2 * seq * cfg.d_model * f * n_mats


def moe_flops(cfg: ArchConfig, seq: int) -> float:
    router = 2 * seq * cfg.d_model * cfg.n_experts
    return router + cfg.topk * mlp_flops(cfg, seq)


def mamba_flops(cfg: ArchConfig, seq: int) -> float:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.d_state
    dtr = max(1, -(-d // 16))
    proj = 2 * seq * d * 2 * di + 2 * seq * di * (dtr + 2 * n) \
        + 2 * seq * dtr * di + 2 * seq * di * d
    conv = 2 * seq * cfg.d_conv * di
    scan = seq * di * n * 10          # da/u build + assoc-scan + readout
    return proj + conv + scan


def mlstm_flops(cfg: ArchConfig, seq: int) -> float:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    hh = cfg.n_heads
    dk = di // hh
    proj = 2 * seq * d * 2 * di + 3 * 2 * seq * di * di \
        + 2 * seq * di * 2 * hh + 2 * seq * di * d
    cell = seq * hh * dk * dk * 6     # kv outer + C update + readout
    return proj + cell


def slstm_flops(cfg: ArchConfig, seq: int) -> float:
    d = cfg.d_model
    return 2 * seq * d * 4 * d * 2 + seq * d * 12


def head_flops(cfg: ArchConfig, seq: int) -> float:
    return 2 * seq * cfg.d_model * cfg.vocab_size


def forward_flops(cfg: ArchConfig, seq_q: int, kv_len: int,
                  with_head: bool = True) -> float:
    """Per-sequence forward FLOPs of the whole stack (decode: seq_q=1,
    kv_len = context length)."""
    total = 0.0
    if cfg.family == "audio":
        s_enc = kv_len            # caller passes encoder length via kv_len
        decode = seq_q == 1
        if not decode:            # decode reuses the cached encoder pass
            for _ in range(cfg.encoder_layers):
                total += attn_flops(cfg, s_enc, s_enc, None)
                total += mlp_flops(cfg, s_enc)
        s_dec = seq_q
        d, h = cfg.d_model, cfg.n_heads
        hd = cfg.resolved_head_dim
        for _ in range(cfg.n_layers):
            total += attn_flops(cfg, s_dec, s_dec if not decode else
                                kv_len, None)                   # self
            # cross attention: q/out proj + scores vs the cached enc kv;
            # the enc kv projection itself is cached at prefill
            total += 2 * s_dec * d * 2 * h * hd                 # q + out
            total += 2 * s_dec * s_enc * h * hd * 2             # scores+pv
            if not decode:
                total += 2 * s_enc * d * 2 * h * hd             # cross kv
            total += mlp_flops(cfg, s_dec)
        if with_head:
            total += head_flops(cfg, s_dec)
        return total

    specs = body_layout(cfg)
    n_bodies = cfg.n_layers // cfg.block_pattern
    body = 0.0
    for spec in specs:
        if spec.kind == "attn":
            body += attn_flops(cfg, seq_q, kv_len, spec.window)
        elif spec.kind == "mamba":
            body += mamba_flops(cfg, seq_q)
        elif spec.kind == "mlstm":
            body += mlstm_flops(cfg, seq_q)
        elif spec.kind == "slstm":
            body += slstm_flops(cfg, seq_q)
        if spec.ffn == "dense":
            body += mlp_flops(cfg, seq_q)
        elif spec.ffn == "moe":
            body += moe_flops(cfg, seq_q)
    total = body * n_bodies
    if with_head:
        total += head_flops(cfg, seq_q)
    return total


def cell_flops(cfg: ArchConfig, shape, remat: bool = True) -> dict:
    """Global FLOPs for one dry-run cell (whole step, all chips)."""
    b, s = shape.batch, shape.seq
    if cfg.family == "audio" and shape.kind != "decode":
        s_dec = max(128, s // 4)
        fwd = b * forward_flops(cfg, s_dec, s)
    elif cfg.family == "vlm" and shape.kind != "decode":
        fwd = b * forward_flops(cfg, s, s)
    elif shape.kind == "decode":
        fwd = b * forward_flops(cfg, 1, s)
    else:
        fwd = b * forward_flops(cfg, s, s)

    if shape.kind == "train":
        mult = 3.0 + (1.0 if remat else 0.0)
        total = fwd * mult
    else:
        total = fwd
    return {"forward": fwd, "total": total}
