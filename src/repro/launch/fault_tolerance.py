"""Fault-tolerance runtime pieces for the training launcher.

  * PreemptionHandler — SIGTERM/SIGINT -> finish the in-flight step, force a
    checkpoint, exit cleanly (what a TPU maintenance event sends).
  * Heartbeat — per-step wall-time log with a stall watchdog; at cluster
    scale the same records feed the coordinator's straggler detection
    (slowest-k host report).
  * step_timer — rolling step-time stats; flags straggler steps
    (> k x median), the single-process analogue of cross-host straggler
    mitigation.

Design notes for 1000+ nodes (documented, exercised here single-process):
  * jax.distributed coordinator with
    --coordinator_timeout / heartbeat flags handles hard node failures: the
    job restarts from the last committed checkpoint (store.py atomicity).
  * slice-swap / elastic downsize is resharding-on-restore (elastic.py).
  * data skip-ahead is deterministic (data/synthetic.py is stateless in
    (seed, step)), so any replacement host resumes mid-epoch exactly.
"""

from __future__ import annotations

import collections
import signal
import statistics
import threading
import time
from typing import Callable, Optional


class PreemptionHandler:
    """Install with `with PreemptionHandler() as p:` and poll
    `p.should_stop` once per step."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._signals = signals
        self._orig = {}
        self.should_stop = False

    def _handle(self, signum, frame):
        self.should_stop = True

    def __enter__(self):
        for s in self._signals:
            self._orig[s] = signal.signal(s, self._handle)
        return self

    def __exit__(self, *exc):
        for s, h in self._orig.items():
            signal.signal(s, h)
        return False


class Heartbeat:
    """Background watchdog: if no beat() within `stall_s`, invoke
    on_stall (default: log loudly).  The cluster version reports to the
    coordinator instead."""

    def __init__(self, stall_s: float = 600.0,
                 on_stall: Optional[Callable] = None):
        self.stall_s = stall_s
        self.on_stall = on_stall or (lambda dt: print(
            f"[heartbeat] STALL: no step completed in {dt:.0f}s",
            flush=True))
        self._last = time.time()
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._watch, daemon=True)
        self._t.start()

    def beat(self):
        self._last = time.time()

    def _watch(self):
        while not self._stop.wait(self.stall_s / 4):
            dt = time.time() - self._last
            if dt > self.stall_s:
                self.on_stall(dt)

    def close(self):
        self._stop.set()


class StepTimer:
    """Rolling step-time tracker with straggler flagging."""

    def __init__(self, window: int = 50, straggler_factor: float = 2.0):
        self.times = collections.deque(maxlen=window)
        self.factor = straggler_factor
        self._t0 = None

    def start(self):
        self._t0 = time.time()

    def stop(self) -> dict:
        dt = time.time() - self._t0
        med = statistics.median(self.times) if self.times else dt
        straggler = len(self.times) >= 5 and dt > self.factor * med
        self.times.append(dt)
        return {"step_s": dt, "median_s": med, "straggler": straggler}
