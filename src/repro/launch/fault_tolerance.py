"""Fault-tolerance runtime pieces for the training launcher.

  * PreemptionHandler — SIGTERM/SIGINT -> finish the in-flight step, force a
    checkpoint, exit cleanly (what a TPU maintenance event sends).
  * Ticker — joinable daemon ticker (the primitive under Heartbeat, the
    serve scheduler's background watchdog, and the serve router's health
    checker): on_tick() every interval_s, close() joins so threads never
    leak past their owner.
  * Pulse — lock-free liveness record: the worked thread beat()s, a
    watcher reads age()/stalled(stall_s).  The primitive under Heartbeat
    and the serve router's per-worker liveness policy (a worker whose
    pulse goes stale is declared hung and failed over).
  * Heartbeat — per-step wall-time log with a stall watchdog; at cluster
    scale the same records feed the coordinator's straggler detection
    (slowest-k host report).
  * step_timer — rolling step-time stats; flags straggler steps
    (> k x median), the single-process analogue of cross-host straggler
    mitigation.

Design notes for 1000+ nodes (documented, exercised here single-process):
  * jax.distributed coordinator with
    --coordinator_timeout / heartbeat flags handles hard node failures: the
    job restarts from the last committed checkpoint (store.py atomicity).
  * slice-swap / elastic downsize is resharding-on-restore (elastic.py).
  * data skip-ahead is deterministic (data/synthetic.py is stateless in
    (seed, step)), so any replacement host resumes mid-epoch exactly.
"""

from __future__ import annotations

import collections
import signal
import statistics
import threading
import time
from typing import Callable, Optional


class PreemptionHandler:
    """Install with `with PreemptionHandler() as p:` and poll
    `p.should_stop` once per step."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._signals = signals
        self._orig = {}
        self.should_stop = False

    def _handle(self, signum, frame):
        self.should_stop = True

    def __enter__(self):
        for s in self._signals:
            self._orig[s] = signal.signal(s, self._handle)
        return self

    def __exit__(self, *exc):
        for s, h in self._orig.items():
            signal.signal(s, h)
        return False


class Ticker:
    """Generic daemon ticker: invoke `on_tick()` every `interval_s`
    until `close()`.  `close()` joins the thread, so a closed ticker
    never outlives its owner — test runs and scheduler shutdown don't
    leak daemon threads.  Exceptions from a tick are reported and
    swallowed (a watchdog must not die of the condition it watches);
    use as a context manager for scoped lifetimes."""

    def __init__(self, interval_s: float, on_tick: Callable[[], None],
                 name: str = "ticker"):
        if interval_s <= 0:
            raise ValueError(f"Ticker interval must be > 0, got "
                             f"{interval_s}")
        self.interval_s = interval_s
        self.on_tick = on_tick
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._run, daemon=True,
                                   name=name)
        self._t.start()

    def _run(self):
        while not self._stop.wait(self.interval_s):
            try:
                self.on_tick()
            except Exception as e:      # noqa: BLE001 — keep ticking
                print(f"[{self._t.name}] tick failed: {e!r}", flush=True)

    @property
    def alive(self) -> bool:
        return self._t.is_alive()

    def close(self, timeout: float = 5.0):
        """Stop ticking and JOIN the thread (`_run` exits on the next
        event check, so this returns promptly even mid-interval)."""
        self._stop.set()
        self._t.join(timeout)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


class Pulse:
    """Lock-free liveness record shared between one worked thread and a
    watcher: the worker `beat()`s whenever it makes progress, the watcher
    reads `age()` / `stalled(stall_s)`.  A bare monotonic float store —
    atomic under the GIL, no lock on the hot path — so beating from a
    serving loop costs one clock read."""

    def __init__(self):
        self._last = time.monotonic()

    def beat(self) -> None:
        self._last = time.monotonic()

    def age(self) -> float:
        """Seconds since the last beat."""
        return time.monotonic() - self._last

    def stalled(self, stall_s: float) -> bool:
        return self.age() > stall_s


class Heartbeat:
    """Background watchdog: if no beat() within `stall_s`, invoke
    on_stall (default: log loudly).  The cluster version reports to the
    coordinator instead.  `close()` joins the watcher thread."""

    def __init__(self, stall_s: float = 600.0,
                 on_stall: Optional[Callable] = None):
        self.stall_s = stall_s
        self.on_stall = on_stall or (lambda dt: print(
            f"[heartbeat] STALL: no step completed in {dt:.0f}s",
            flush=True))
        self._pulse = Pulse()
        self._ticker = Ticker(stall_s / 4, self._check, name="heartbeat")

    def beat(self):
        self._pulse.beat()

    def _check(self):
        dt = self._pulse.age()
        if dt > self.stall_s:
            self.on_stall(dt)

    def close(self):
        self._ticker.close()


class StepTimer:
    """Rolling step-time tracker with straggler flagging."""

    def __init__(self, window: int = 50, straggler_factor: float = 2.0):
        self.times = collections.deque(maxlen=window)
        self.factor = straggler_factor
        self._t0 = None

    def start(self):
        self._t0 = time.time()

    def stop(self) -> dict:
        dt = time.time() - self._t0
        med = statistics.median(self.times) if self.times else dt
        straggler = len(self.times) >= 5 and dt > self.factor * med
        self.times.append(dt)
        return {"step_s": dt, "median_s": med, "straggler": straggler}
