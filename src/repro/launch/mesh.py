"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialisation.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the leading axis maps
    onto the slower inter-pod (DCN-class) links, carrying only DP gradient
    reduction (or PP boundary activations with --pipeline)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(*, multi_pod: bool = False):
    """Tiny mesh with the same axis names for CI-scale distributed tests
    (8 host devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)
