import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input shape x mesh) cell on the production mesh, print
memory/cost analysis, and extract the roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b \
      --shape train_4k [--multi-pod] [--out results.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

The XLA_FLAGS line above MUST stay the first statement: jax locks the
device count on first initialisation.  (Tests may pre-set DRYRUN_DEVICES to
shrink the placeholder device pool before importing this module.)
"""

import sys

if os.environ.get("DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["DRYRUN_DEVICES"])

import argparse
import json
import time
import traceback

import numpy as np


def _build_argparser():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None,
                   choices=[None, "train_4k", "prefill_32k", "decode_32k",
                            "long_500k"])
    p.add_argument("--all", action="store_true")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--out", default="benchmarks/results/dryrun.json")
    p.add_argument("--debug-mesh", action="store_true",
                   help="tiny 8-device mesh (needs DRYRUN_DEVICES=8)")
    p.add_argument("--skip-full", action="store_true",
                   help="skip the full-depth compile (cost terms only)")
    p.add_argument("--no-cost", action="store_true",
                   help="full compile only (no depth-1/2 roofline "
                        "compiles) — used for the multi-pod pass")
    p.add_argument("--fsdp-min-params", type=float, default=3e9,
                   help="enable FSDP above this param count (H2: lower it "
                        "to turn grad all-reduce into reduce-scatter)")
    p.add_argument("--grad-bf16", action="store_true",
                   help="H2: cast grads to bf16 before the DP reduction")
    p.add_argument("--no-sp", action="store_true",
                   help="H2: disable Megatron-SP boundary sharding")
    p.add_argument("--baseline", action="store_true",
                   help="paper-faithful baseline: disable the §Perf "
                        "optimizations (flash-decode cache sharding, "
                        "token-sharded EP, pinned embed lookup)")
    return p


LM_ARCHS = [
    "gemma2-2b", "granite-34b", "qwen1.5-4b", "qwen1.5-32b",
    "jamba-v0.1-52b", "xlstm-125m", "seamless-m4t-medium",
    "granite-moe-1b-a400m", "mixtral-8x7b", "qwen2-vl-72b",
]
ALL_SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def main(argv=None):
    args = _build_argparser().parse_args(argv)

    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.distributed import sharding as SH

    if args.baseline:
        from repro.models import lm as _lm
        from repro.models import moe as _moe
        _lm.PINNED_EMBED_DEFAULT = False
        _moe.TOKEN_SHARDED_DEFAULT = False
    from repro.launch import roofline as RL
    from repro.launch.mesh import make_debug_mesh, make_production_mesh
    from repro.launch.shapes import (SHAPES, cell_supported,
                                     decode_state_specs, input_specs)
    from repro.models import registry
    from repro.serve.lm import (ServeConfig, make_decode_step,
                                make_prefill_step)
    from repro.train import optim as OPT
    from repro.train.step import TrainConfig, make_train_step

    REP = None  # placeholder; set per-mesh below

    def n_params(shapes_tree) -> int:
        return sum(int(np.prod(l.shape)) for l in
                   jax.tree_util.tree_leaves(shapes_tree))

    def n_active_params(cfg, shapes_tree) -> int:
        """6*N_active*D accounting: MoE experts scaled by topk/E."""
        total, expert = 0, 0
        def walk(path, leaf):
            nonlocal total, expert
            n = int(np.prod(leaf.shape))
            total += n
            names = SH._path_names(path)
            if names and names[-1] in ("w_in", "w_gate", "w_out"):
                expert += n
            return leaf
        jax.tree_util.tree_map_with_path(walk, shapes_tree)
        if cfg.n_experts:
            return total - expert + expert * cfg.topk / cfg.n_experts
        return total

    def device_bytes(shapes_tree, shardings_tree, mesh) -> float:
        """Analytic per-device bytes of a sharded tree."""
        leaves = jax.tree_util.tree_leaves(shapes_tree)
        shards = jax.tree_util.tree_leaves(
            shardings_tree, is_leaf=lambda x: hasattr(x, "spec"))
        total = 0.0
        for l, s in zip(leaves, shards):
            n = int(np.prod(l.shape)) * l.dtype.itemsize
            div = 1
            for ax in s.spec:
                if ax is None:
                    continue
                for a in (ax if isinstance(ax, tuple) else (ax,)):
                    div *= mesh.shape[a]
            total += n / div
        return total

    def make_cell_fns(cfg, shape, mesh, sc):
        """Returns (lower_fn, aux_info).  lower_fn() -> jax.stages.Lowered"""
        model = registry.build(cfg)
        param_shapes = jax.eval_shape(model.init, jax.random.key(0))
        p_sh = SH.params_shardings(param_shapes, sc)
        batch = input_specs(cfg, shape)
        b_sh = SH.batch_specs(batch, sc)
        rep = SH.replicated(sc)

        if shape.kind == "train":
            opt_shapes = jax.eval_shape(OPT.init, param_shapes)
            opt_sh = OPT.OptState(step=rep, m=p_sh, v=p_sh)
            tc = TrainConfig(
                grad_reduce_dtype=jnp.bfloat16 if args.grad_bf16 else None)
            step = make_train_step(model, tc, OPT.AdamWConfig(), sc)
            metrics_sh = {k: rep for k in
                          ("loss", "aux", "n_tokens", "grad_norm", "lr")}
            jitted = jax.jit(step, in_shardings=(p_sh, opt_sh, b_sh),
                             out_shardings=(p_sh, opt_sh, metrics_sh),
                             donate_argnums=(0, 1))
            def lower():
                return jitted.lower(param_shapes, opt_shapes, batch)
        elif shape.kind == "prefill":
            svc = ServeConfig(max_len=shape.seq)
            step = make_prefill_step(model, svc, sc)
            out_states = jax.eval_shape(
                lambda p, b: step(p, b)[1], param_shapes, batch)
            st_sh = SH.state_specs(out_states, sc)
            tok_sh = SH.batch_specs(
                {"t": jax.ShapeDtypeStruct((shape.batch,), jnp.int32)},
                sc)["t"]
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh),
                             out_shardings=(tok_sh, st_sh))
            def lower():
                return jitted.lower(param_shapes, batch)
        else:  # decode
            svc = ServeConfig(max_len=shape.seq)
            step = make_decode_step(model, svc, sc)
            states = decode_state_specs(model, cfg, shape)
            st_sh = SH.state_specs(states, sc)
            tok_sh = SH.batch_specs(
                {"t": jax.ShapeDtypeStruct((shape.batch,), jnp.int32)},
                sc)["t"]
            jitted = jax.jit(step, in_shardings=(p_sh, st_sh, b_sh),
                             out_shardings=(tok_sh, st_sh),
                             donate_argnums=(1,))
            def lower():
                return jitted.lower(param_shapes, states, batch)

        info = {
            "n_params": n_params(param_shapes),
            "n_active_params": n_active_params(cfg, param_shapes),
            "param_bytes_per_device": device_bytes(param_shapes, p_sh,
                                                   mesh),
        }
        return lower, info

    def run_cell(arch: str, shape_name: str, multi_pod: bool,
                 skip_full: bool = False) -> dict:
        cfg = configs.get(arch)
        shape = SHAPES[shape_name]
        mesh_name = "2x16x16" if multi_pod else "16x16"
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name}

        reason = cell_supported(cfg, shape)
        if reason:
            rec.update(status="skipped", reason=reason)
            return rec

        if args.debug_mesh:
            mesh = make_debug_mesh(multi_pod=multi_pod)
        else:
            mesh = make_production_mesh(multi_pod=multi_pod)
        chips = int(np.prod(list(mesh.shape.values())))
        model0 = registry.build(cfg)
        n_p = n_params(jax.eval_shape(model0.init, jax.random.key(0)))
        sc = SH.ShardingConfig(
            mesh,
            fsdp=(n_p > args.fsdp_min_params and shape.kind == "train"),
            seq_parallel=(shape.kind != "decode" and not args.no_sp),
            shard_seq_over_data=(shape.kind == "decode"),
            kv_seq_over_model=not args.baseline)

        t0 = time.time()
        try:
            # ---- full-depth compile: THE dry-run proof --------------------
            if not skip_full:
                lower_full, info = make_cell_fns(cfg, shape, mesh, sc)
                lowered = lower_full()
                compiled = lowered.compile()
                try:
                    ma = compiled.memory_analysis()
                    rec["memory_analysis"] = {
                        k: int(getattr(ma, k))
                        for k in ("argument_size_in_bytes",
                                  "output_size_in_bytes",
                                  "temp_size_in_bytes",
                                  "generated_code_size_in_bytes")
                        if hasattr(ma, k)} if ma is not None else None
                except Exception as e:  # CPU backend may not support it
                    rec["memory_analysis"] = f"unavailable: {e}"
                rec["compile_s_full"] = round(time.time() - t0, 1)
            else:
                _, info = make_cell_fns(cfg, shape, mesh, sc)

            if args.no_cost:
                rec.update(status="ok", chips=chips,
                           n_params=info["n_params"],
                           total_s=round(time.time() - t0, 1))
                return rec

            # ---- depth-1/2 compiles for scan-corrected cost terms ---------
            # cost mode unrolls the layer/CE scans so per-layer costs are
            # visible to cost_analysis (see repro.costmode)
            from repro import costmode
            n_bodies = max(1, cfg.n_layers // cfg.block_pattern)
            costs, colls = [], []
            for k in (1, 2):
                ckw = {"n_layers": cfg.block_pattern * k}
                if cfg.encoder_layers:
                    ckw["encoder_layers"] = k
                cfg_k = cfg.replace(**ckw)
                lf, _ = make_cell_fns(cfg_k, shape, mesh, sc)
                with costmode.enable():
                    comp_k = lf().compile()
                costs.append(RL.extract_cost(comp_k))
                colls.append(RL.collective_bytes(comp_k.as_text()))
            cell = RL.extrapolate(costs[0], costs[1], colls[0], colls[1],
                                  n_bodies)
            # analytic compute model (inner scans undercounted by HLO)
            from repro.launch import flops as FL
            af = FL.cell_flops(cfg, shape, remat=(shape.kind == "train"))
            mf = RL.model_flops(cfg, shape, info["n_active_params"])
            terms = cell.terms(af["total"] / chips)
            dominant = max(terms, key=terms.get)
            rec.update(
                status="ok",
                chips=chips,
                n_params=info["n_params"],
                n_active_params=info["n_active_params"],
                param_bytes_per_device=round(
                    info["param_bytes_per_device"]),
                analytic_flops=af["total"],
                hlo_flops_per_chip=cell.flops,
                hlo_bytes_per_chip=cell.hbm_bytes,
                coll_bytes_per_chip=cell.coll_bytes_per_chip,
                coll_by_kind=cell.coll_by_kind,
                **{k: float(f"{v:.6g}") for k, v in terms.items()},
                dominant=dominant.replace("_s", ""),
                model_flops=mf,
                useful_flops_ratio=float(f"{mf / max(af['total'], 1):.4g}"),
                hlo_vs_analytic=float(
                    f"{cell.flops * chips / max(af['total'], 1):.4g}"),
                total_s=round(time.time() - t0, 1),
            )
        except Exception as e:
            rec.update(status="error", error=f"{type(e).__name__}: {e}",
                       traceback=traceback.format_exc()[-2000:],
                       total_s=round(time.time() - t0, 1))
        return rec

    # ------------------------------------------------------------------
    cells = []
    archs = [args.arch] if args.arch else LM_ARCHS
    shapes = [args.shape] if args.shape else ALL_SHAPES
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results
            if r.get("status") in ("ok", "skipped")}

    for mp in meshes:
        mesh_name = "2x16x16" if mp else "16x16"
        for arch in archs:
            for shape_name in shapes:
                key = (arch, shape_name, mesh_name)
                if key in done:
                    print(f"[cached] {key}")
                    continue
                print(f"[run] {key}", flush=True)
                rec = run_cell(arch, shape_name, mp,
                               skip_full=args.skip_full)
                print(json.dumps(
                    {k: v for k, v in rec.items() if k != "traceback"},
                    indent=None), flush=True)
                results = [r for r in results
                           if (r["arch"], r["shape"], r["mesh"]) != key]
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)

    n_ok = sum(1 for r in results if r.get("status") == "ok")
    n_err = sum(1 for r in results if r.get("status") == "error")
    n_skip = sum(1 for r in results if r.get("status") == "skipped")
    print(f"\ndry-run summary: {n_ok} ok, {n_skip} skipped (noted), "
          f"{n_err} errors -> {args.out}")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    sys.exit(main())
