"""Minimal pure-JAX NN substrate: parameter init + functional layers.

No flax/haiku on this box — parameters are plain pytrees (nested dicts of
jnp arrays), applied by pure functions.  Every layer used anywhere in the
framework lives here so sharding rules (distributed/sharding.py) can pattern
-match on parameter tree paths.
"""

from __future__ import annotations

import math
from typing import Any, Callable, Dict, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _fan_in_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    scale = 1.0 / math.sqrt(max(1, fan_in))
    return jax.random.uniform(key, shape, dtype, -scale, scale)


def dense_init(key, d_in: int, d_out: int, use_bias: bool = True,
               dtype=jnp.float32) -> Params:
    kw, kb = jax.random.split(key)
    p = {"w": _fan_in_init(kw, (d_in, d_out), dtype)}
    if use_bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def embedding_init(key, vocab: int, d: int, dtype=jnp.float32) -> Params:
    return {"emb": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def embed(p: Params, ids: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["emb"], ids, axis=0)


def layernorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + eps)
    return (y * p["scale"]).astype(x.dtype)


def mlp_chain_init(key, widths: Sequence[int], use_bias: bool = True,
                   dtype=jnp.float32) -> Params:
    """A chain of FC layers (the paper's fusable dense blocks)."""
    keys = jax.random.split(key, len(widths) - 1)
    return {f"fc{i}": dense_init(keys[i], widths[i], widths[i + 1],
                                 use_bias, dtype)
            for i in range(len(widths) - 1)}


def mlp_chain(p: Params, x: jnp.ndarray,
              act: Callable = jax.nn.relu,
              final_act: bool = True) -> jnp.ndarray:
    n = len(p)
    for i in range(n):
        x = dense(p[f"fc{i}"], x)
        if i < n - 1 or final_act:
            x = act(x)
    return x


def count_params(params) -> int:
    return sum(int(np.prod(x.shape))
               for x in jax.tree_util.tree_leaves(params))


def cast_floating(tree, dtype):
    """Cast floating leaves to dtype (mixed-precision helper)."""
    def f(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree_util.tree_map(f, tree)


import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def cotangent_cast(x, dtype):
    """Identity forward; casts the COTANGENT to `dtype` in backward.

    The loss-side f32 ops (logsumexp, softcap) make every upstream
    cotangent f32 by dtype propagation; inserting this barrier right after
    the backbone's hidden states keeps the whole backward pass — including
    every SP/TP collective on activation cotangents — in bf16 (§Perf H2).
    Parameter gradients still land in f32 via the param-cast transpose.
    """
    return x


def _cotangent_cast_fwd(x, dtype):
    return x, None


def _cotangent_cast_bwd(dtype, _, g):
    return (g.astype(dtype),)


cotangent_cast.defvjp(_cotangent_cast_fwd, _cotangent_cast_bwd)
