"""Sparse convolution on point clouds: the two computation flows.

Paper §4.2.3 / Fig. 17-right contrasts:

  * Gather-MatMul-Scatter (G-M-S): the GPU flow.  Gather all input rows for
    every weight offset into one contiguous (K, cap, Cin) tensor, one big
    GEMM, then scatter-add partial sums.  Maximum GEMM efficiency, maximum
    memory traffic (features read up to 27x, psums written to DRAM).

  * Fetch-on-Demand (FoD): the PointAcc flow.  Stream over weight offsets
    (weight-stationary), fetch only the rows needed for the current tile,
    multiply immediately, accumulate output-stationary partial sums that
    never leave on-chip memory.

Here the FoD flow has two realisations:
  - an XLA realisation (`flow="fod"`): `lax.scan` over offsets with a carried
    output accumulator — peak memory is K-times smaller than G-M-S because
    the gathered tensor is never materialised across offsets;
  - a Pallas TPU kernel (`repro.kernels.spconv`) where scalar-prefetched map
    indices drive the BlockSpec index_map, so rows move HBM->VMEM exactly
    once per compute tile (the paper's configurable cache block) — see
    kernels/spconv/spconv.py.

Both flows are numerically identical; tests cross-check them against a dense
`lax.conv_general_dilated` oracle.
"""

from __future__ import annotations

import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import nn
from repro.core.mapping import (KernelMaps, PointCloud, SortedCloud,
                                build_conv_maps)


class Epilogue(NamedTuple):
    """Post-conv ops a sparse conv layer wants applied to its accumulator
    (paper §4.2.4 temporal fusion, extended from FC chains to conv blocks).

    Applied in this fixed order: +bias -> layernorm -> +residual -> ReLU ->
    *mask.  Every field is optional (None / False = skip).  The XLA flows
    apply it as ordinary post-ops (`apply_epilogue`); the fused Pallas flow
    folds it into the kernel's flush so the pre-activation accumulator never
    round-trips HBM.
    """

    bias: jnp.ndarray | None = None        # (Cout,)
    ln_scale: jnp.ndarray | None = None    # (Cout,)
    ln_bias: jnp.ndarray | None = None     # (Cout,)
    relu: bool = False
    mask: jnp.ndarray | None = None        # (M,) bool/float row validity
    residual: jnp.ndarray | None = None    # (M, Cout) VMEM-resident skip


def apply_epilogue(out: jnp.ndarray, epi: Epilogue | None) -> jnp.ndarray:
    """Reference (XLA) realisation of `Epilogue` — the unfused path, and the
    parity oracle for the fused kernel's flush."""
    if epi is None:
        return out
    if (epi.ln_scale is None) != (epi.ln_bias is None):
        raise ValueError("Epilogue.ln_scale and ln_bias must come together")
    if epi.bias is not None:
        out = out + epi.bias[None, :]
    if epi.ln_scale is not None:
        out = nn.layernorm({"scale": epi.ln_scale, "bias": epi.ln_bias}, out)
    if epi.residual is not None:
        out = out + epi.residual
    if epi.relu:
        out = jax.nn.relu(out)
    if epi.mask is not None:
        out = out * epi.mask.astype(out.dtype)[:, None]
    return out


def gather_matmul_scatter(features: jnp.ndarray, maps: KernelMaps,
                          weights: jnp.ndarray, out_cap: int) -> jnp.ndarray:
    """Baseline GPU flow (paper Fig. 4).

    features: (N, Cin); weights: (K, Cin, Cout) -> (out_cap, Cout).
    """
    k, cap = maps.in_idx.shape
    cout = weights.shape[-1]
    gathered = features[jnp.clip(maps.in_idx, 0), :]          # (K, cap, Cin)
    gathered = gathered * maps.valid[..., None]
    psums = jnp.einsum("kmc,kcd->kmd", gathered, weights,
                       preferred_element_type=jnp.float32)
    out = jnp.zeros((out_cap, cout), psums.dtype)
    scatter_idx = jnp.where(maps.valid, maps.out_idx, out_cap)  # OOB -> drop
    out = out.at[scatter_idx.reshape(-1)].add(
        psums.reshape(-1, cout), mode="drop")
    return out.astype(features.dtype)


def fetch_on_demand(features: jnp.ndarray, maps: KernelMaps,
                    weights: jnp.ndarray, out_cap: int) -> jnp.ndarray:
    """PointAcc flow, XLA realisation.

    Weight-stationary scan over kernel offsets; the output accumulator is the
    scan carry (output-stationary — partial sums never spill).  Peak live
    gathered tensor is (cap, Cin) instead of (K, cap, Cin).
    """
    cout = weights.shape[-1]

    def step(out, inputs):
        in_idx, out_idx, valid, w = inputs
        rows = features[jnp.clip(in_idx, 0), :] * valid[:, None]
        psum = jnp.dot(rows, w, preferred_element_type=jnp.float32)
        idx = jnp.where(valid, out_idx, out_cap)
        out = out.at[idx].add(psum, mode="drop")
        return out, None

    out0 = jnp.zeros((out_cap, cout), jnp.float32)
    out, _ = lax.scan(step, out0,
                      (maps.in_idx, maps.out_idx, maps.valid, weights))
    return out.astype(features.dtype)


def sparse_conv_apply(features: jnp.ndarray, maps: KernelMaps,
                      weights: jnp.ndarray, out_cap: int,
                      flow: str = "fod",
                      epilogue: Epilogue | None = None,
                      plan=None) -> jnp.ndarray:
    """One sparse conv + optional fused epilogue.

    flow selects the computation realisation:
      gms / fod      — XLA flows; the epilogue runs as ordinary post-ops.
      pallas         — baseline whole-array-resident Pallas kernel
                       (epilogue as XLA post-ops): the PR-1 fast path, kept
                       as the comparison baseline.
      pallas_fused   — streamed + fused Pallas kernel: feature tiles stream
                       through VMEM and the epilogue runs in the kernel's
                       flush.  `plan` (core.fusion.ConvFusionPlan) sets the
                       cache-block size; when the planner declines to fuse
                       (plan.fuse False) the conv still streams but the
                       epilogue falls back to XLA post-ops.
    """
    if flow == "gms":
        return apply_epilogue(
            gather_matmul_scatter(features, maps, weights, out_cap), epilogue)
    if flow == "fod":
        return apply_epilogue(
            fetch_on_demand(features, maps, weights, out_cap), epilogue)
    if flow == "pallas":
        from repro.kernels.spconv import ops as spconv_ops
        return apply_epilogue(
            spconv_ops.sparse_conv_fod(features, maps, weights, out_cap),
            epilogue)
    if flow == "pallas_fused":
        from repro.core import fusion as F
        from repro.kernels.spconv import ops as spconv_ops
        if plan is None:
            plan = F.plan_conv_epilogue(
                features.shape[0], features.shape[1], weights.shape[-1],
                weights.shape[0],
                residual=epilogue is not None
                and epilogue.residual is not None)
        epi = epilogue if plan.fuse else None
        out = spconv_ops.sparse_conv_fused(
            features, maps, weights, out_cap, epilogue=epi,
            feat_tile=plan.feat_tile, out_tile=plan.out_tile)
        return out if plan.fuse else apply_epilogue(out, epilogue)
    raise ValueError(f"unknown flow {flow!r}")


class SparseConvResult(NamedTuple):
    features: jnp.ndarray
    pc: PointCloud
    maps: KernelMaps


def sparse_conv(pc: PointCloud, features: jnp.ndarray, weights: jnp.ndarray,
                kernel_size: int, stride: int = 1, flow: str = "fod",
                cap: int | None = None, engine: str | None = None,
                cache: SortedCloud | None = None) -> SparseConvResult:
    """Full sparse conv layer: mapping (MPU) + streaming GEMM (MMU+MXU).

    `cache` is an optional pre-sorted cloud of `pc` (v2 engine): layers that
    share a stride level pass the same SortedCloud so the ranking sort runs
    once per level, not once per layer.
    """
    maps, out_pc = build_conv_maps(pc, kernel_size, stride, cap=cap,
                                   engine=engine, cache=cache)
    out = sparse_conv_apply(features, maps, weights, out_pc.capacity, flow)
    out = out * out_pc.mask[:, None]
    return SparseConvResult(out, out_pc, maps)


def sparse_conv_transposed(features: jnp.ndarray, maps: KernelMaps,
                           out_pc: PointCloud, weights: jnp.ndarray,
                           flow: str = "fod",
                           epilogue: Epilogue | None = None,
                           plan=None) -> jnp.ndarray:
    """Transposed (up-sampling) conv: reuse the encoder's maps with in/out
    roles swapped (MinkowskiEngine semantics; paper §2.1.1 'upsampling is the
    inverse of the corresponding downsampling').  v2-built maps carry the
    swapped inverse table, so the Pallas flows stay scatter-free here too.

    Maps without a transposed inverse table (v1 engine, capped v2 builds)
    still work on every flow, but the Pallas flows must rebuild the inverse
    with a scatter pass — that downgrade is surfaced with a warning rather
    than assumed away (use `maps.swap(require_inverse=True)` directly for a
    hard error).

    With an explicit epilogue the caller owns masking (Epilogue.mask);
    without one the legacy `* mask` post-op is kept."""
    swapped = maps.swap()
    if flow in ("pallas", "pallas_fused") and swapped.inv is None:
        warnings.warn(
            "transposed conv on maps without an inverse table (built with "
            "engine='v1' or an explicit cap): the Pallas flow falls back "
            "to a scatter-built inverse — rebuild the maps with "
            "engine='v2' for the scatter-free path", stacklevel=2)
    out = sparse_conv_apply(features, swapped, weights, out_pc.capacity,
                            flow, epilogue=epilogue, plan=plan)
    if epilogue is None:
        out = out * out_pc.mask[:, None]
    return out
