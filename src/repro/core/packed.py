"""Packed coordinate keys: the v2 ranking engine's scalar key domain.

PointAcc's Mapping Unit (paper §4.1) ranks *coordinates*; the v1 software
realisation sorted 4-6 parallel int32 columns lexicographically for every
kernel offset.  This module collapses a (batch, x, y, z) coordinate into one
62-bit packed key so each ranking op touches a single logical scalar:

    bit 61..48   batch  (14 bits, unsigned,  0 .. 16383)
    bit 47..32   x+2^15 (16 bits, biased,   -32768 .. 32767)
    bit 31..16   y+2^15 (16 bits, biased)
    bit 15..0    z+2^15 (16 bits, biased)

The key is stored as an (int32 hi, uint32 lo) word pair — hi carries
(batch | x), lo carries (y | z) — because int64 is a second-class citizen in
32-bit-default JAX and on TPU, where XLA would emulate it as an i32 pair
anyway.  Lexicographic (hi, lo) order over the pair IS ascending order of the
logical 62-bit key, which in turn IS the lexicographic (batch, x, y, z) order
the v1 engine used: the per-axis bias is monotone, so every downstream
structure (sorted clouds, deduped output clouds) is bit-identical to v1's.

Invalid/overflowing coordinates saturate to the sentinel key
(KEY_HI_SENTINEL is unreachable by any in-range coordinate: the max valid hi
is (16383<<16)|65535 = 2^30-1 < 2^31-1), so an out-of-budget coordinate can
never alias a valid key — it sorts to the end and fails every equality test.

Quantization (paper §2.1.1, "clearing the lowest log2(ts) bits") works
directly in the key domain: the bias 2^15 is divisible by every power-of-two
stride <= 2^15, so clearing the low log2(ts) bits of each 16-bit field is
exactly quantize-then-pack.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from jax import lax

# Coordinate-domain sentinel (shared with repro.core.mapping.SENTINEL).
COORD_SENTINEL = np.int32(2**30 - 1)

BATCH_BITS = 14
SPATIAL_BITS = 16
BIAS = 1 << (SPATIAL_BITS - 1)              # 32768
COORD_MIN = -BIAS                           # -32768
COORD_MAX = BIAS - 1                        # 32767
BATCH_MAX = (1 << BATCH_BITS) - 1           # 16383

KEY_HI_SENTINEL = np.int32(2**31 - 1)
KEY_LO_SENTINEL = np.uint32(2**32 - 1)

_LO16 = np.uint32(0xFFFF)


def pack_coords(coords: jnp.ndarray, mask: jnp.ndarray | None = None):
    """(..., 4) int32 coords -> (hi int32, lo uint32) packed key words.

    Rows that are masked out, or whose batch/coordinate falls outside the
    per-field bit budget, saturate to the sentinel key — never to an aliased
    valid key.
    """
    b = coords[..., 0]
    x = coords[..., 1]
    y = coords[..., 2]
    z = coords[..., 3]
    ok = (b >= 0) & (b <= BATCH_MAX)
    for c in (x, y, z):
        ok = ok & (c >= COORD_MIN) & (c <= COORD_MAX)
    if mask is not None:
        ok = ok & mask
    # Out-of-range lanes may wrap below; `ok` discards them.
    hi = (b << SPATIAL_BITS) | (x + BIAS)
    lo = ((y + BIAS).astype(jnp.uint32) << SPATIAL_BITS) \
        | (z + BIAS).astype(jnp.uint32)
    hi = jnp.where(ok, hi, KEY_HI_SENTINEL)
    lo = jnp.where(ok, lo, KEY_LO_SENTINEL)
    return hi, lo


def is_sentinel_key(hi: jnp.ndarray) -> jnp.ndarray:
    """Valid keys have hi <= 2^30-1, so the hi word alone identifies them."""
    return hi == KEY_HI_SENTINEL


def unpack_keys(hi: jnp.ndarray, lo: jnp.ndarray) -> jnp.ndarray:
    """Inverse of pack_coords: (hi, lo) -> (..., 4) int32 coords.

    Sentinel keys unpack to all-COORD_SENTINEL rows (the masked-row
    convention of repro.core.mapping).
    """
    valid = ~is_sentinel_key(hi)
    b = hi >> SPATIAL_BITS
    x = (hi & np.int32(0xFFFF)) - BIAS
    y = (lo >> SPATIAL_BITS).astype(jnp.int32) - BIAS
    z = (lo & _LO16).astype(jnp.int32) - BIAS
    coords = jnp.stack([b, x, y, z], axis=-1)
    return jnp.where(valid[..., None], coords, COORD_SENTINEL)


def quantize_keys(hi: jnp.ndarray, lo: jnp.ndarray, stride: int):
    """Clear the low log2(stride) bits of every spatial field, in place in
    the key domain.  Sentinel keys are preserved (clearing their bits would
    fabricate a valid-looking key)."""
    if stride == 1:
        return hi, lo
    k = int(np.log2(stride))
    if 2 ** k != stride:
        raise ValueError(f"stride must be a power of two, got {stride}")
    if k > SPATIAL_BITS - 1:
        raise ValueError(f"stride {stride} exceeds the per-axis bit budget")
    low = stride - 1
    qhi = hi & np.int32(~low)
    qlo = lo & np.uint32(~((low << SPATIAL_BITS) | low) & 0xFFFFFFFF)
    sent = is_sentinel_key(hi)
    return (jnp.where(sent, KEY_HI_SENTINEL, qhi),
            jnp.where(sent, KEY_LO_SENTINEL, qlo))


# -- host-side (numpy) key helpers ------------------------------------------
#
# The partition planner (repro.partition) ranks and range-splits CITY-SCALE
# clouds on the host, where shapes are dynamic and a device round-trip per
# binary search would dominate.  These mirror pack/quantize exactly in a
# single uint64 word: the packed 62-bit key fits uint64 with bits 63..62
# zero, so unsigned uint64 order == lexicographic (hi signed-nonnegative,
# lo unsigned) order == logical key order.

KEY64_BITS = BATCH_BITS + 3 * SPATIAL_BITS          # 62
KEY64_SENTINEL = np.uint64(
    (np.uint64(np.uint32(KEY_HI_SENTINEL)) << np.uint64(32))
    | np.uint64(KEY_LO_SENTINEL))


def compose_key64(hi, lo) -> np.ndarray:
    """(hi int32, lo uint32) word pairs -> one uint64 key, order-preserving
    (valid hi is never negative, so the unsigned composition keeps the
    lexicographic pair order)."""
    return ((np.asarray(hi).astype(np.int64).astype(np.uint64)
             << np.uint64(32))
            | np.asarray(lo, np.uint32).astype(np.uint64))


def pack_coords_host(coords, mask=None) -> np.ndarray:
    """Host mirror of `pack_coords`, composed to uint64: (..., 4) int32
    coords -> (...,) uint64 keys with out-of-budget / masked rows saturated
    to KEY64_SENTINEL."""
    coords = np.asarray(coords)
    b = coords[..., 0].astype(np.int64)
    x = coords[..., 1].astype(np.int64)
    y = coords[..., 2].astype(np.int64)
    z = coords[..., 3].astype(np.int64)
    ok = (b >= 0) & (b <= BATCH_MAX)
    for c in (x, y, z):
        ok = ok & (c >= COORD_MIN) & (c <= COORD_MAX)
    if mask is not None:
        ok = ok & np.asarray(mask, bool)
    key = ((b << (3 * SPATIAL_BITS))
           | ((x + BIAS) << (2 * SPATIAL_BITS))
           | ((y + BIAS) << SPATIAL_BITS)
           | (z + BIAS)).astype(np.uint64)
    return np.where(ok, key, KEY64_SENTINEL)


def unpack_key64(keys) -> np.ndarray:
    """Inverse of `pack_coords_host`: (...,) uint64 -> (..., 4) int32
    coords; sentinel keys unpack to all-COORD_SENTINEL rows."""
    keys = np.asarray(keys, np.uint64)
    k = keys.astype(np.int64)
    b = k >> (3 * SPATIAL_BITS)
    x = ((k >> (2 * SPATIAL_BITS)) & 0xFFFF) - BIAS
    y = ((k >> SPATIAL_BITS) & 0xFFFF) - BIAS
    z = (k & 0xFFFF) - BIAS
    coords = np.stack([b, x, y, z], axis=-1).astype(np.int32)
    return np.where((keys == KEY64_SENTINEL)[..., None],
                    np.int32(COORD_SENTINEL), coords)


def quantize_key64(keys, stride: int) -> np.ndarray:
    """Host mirror of `quantize_keys` on composed keys: clear the low
    log2(stride) bits of each 16-bit spatial field; sentinels preserved."""
    if stride == 1:
        return np.asarray(keys, np.uint64)
    k = int(np.log2(stride))
    if 2 ** k != stride:
        raise ValueError(f"stride must be a power of two, got {stride}")
    if k > SPATIAL_BITS - 1:
        raise ValueError(f"stride {stride} exceeds the per-axis bit budget")
    low = stride - 1
    clear = np.uint64((low << (2 * SPATIAL_BITS)) | (low << SPATIAL_BITS)
                      | low)
    keys = np.asarray(keys, np.uint64)
    q = keys & ~clear
    return np.where(keys == KEY64_SENTINEL, KEY64_SENTINEL, q)


def searchsorted_pair(s_hi: jnp.ndarray, s_lo: jnp.ndarray,
                      q_hi: jnp.ndarray, q_lo: jnp.ndarray) -> jnp.ndarray:
    """side='left' binary search of query keys in an ascending key array.

    The sorted operands are the (hi, lo) words of a key array ordered by
    lexicographic (hi signed, lo unsigned) comparison — i.e. by the logical
    62-bit key.  Queries may have any shape; returns int32 positions in
    [0, n].  This is the paper's log-depth comparison network: ceil(log2 n)
    rounds of vectorised gather + compare, no data movement.
    """
    n = s_hi.shape[0]
    lo_i = jnp.zeros(q_hi.shape, jnp.int32)
    hi_i = jnp.full(q_hi.shape, n, jnp.int32)

    def step(_, carry):
        lo_i, hi_i = carry
        active = lo_i < hi_i
        mid = (lo_i + hi_i) >> 1
        midc = jnp.clip(mid, 0, n - 1)
        m_hi = s_hi[midc]
        m_lo = s_lo[midc]
        less = (m_hi < q_hi) | ((m_hi == q_hi) & (m_lo < q_lo))
        lo_i = jnp.where(active & less, mid + 1, lo_i)
        hi_i = jnp.where(active & ~less, mid, hi_i)
        return lo_i, hi_i

    n_steps = max(1, int(np.ceil(np.log2(n + 1))) + 1)
    lo_i, _ = lax.fori_loop(0, n_steps, step, (lo_i, hi_i))
    return lo_i
