"""Ranking-based mapping operations (PointAcc Mapping Unit, paper §4.1).

PointAcc's key insight: every mapping operation a point-cloud network needs
(kernel mapping, k-nearest-neighbours, ball query, farthest-point sampling,
coordinate quantization) can be expressed through *ranking* primitives —
MergeSort / TopK / Max over coordinate or distance keys — instead of hash
tables.  Hash tables need random parallel SRAM access (an O(N^2) crossbar in
silicon); sorting networks are log-depth and fully parallel.  The same
trade-off holds on TPU: XLA has no efficient random-access hash path, but its
bitonic `lax.sort` *is* a sorting network.  This module is therefore a direct
software embodiment of the paper's Mapping Unit:

  * kernel mapping  -> sort-merge intersection of the (-delta)-shifted input
                       cloud with the output cloud (paper Fig. 9), realised as
                       one lexicographic `lax.sort` + adjacent-equality
                       detection (paper's DetectIntersection stage).
  * quantization    -> clearing the low log2(ts) bits of the coordinates
                       (paper §2.1.1), i.e. arithmetic shift right then left.
  * unique (output cloud construction) -> sort + adjacent-dedup + re-sort
                       (compaction without dynamic shapes).

All functions are jit-friendly: point clouds are fixed-capacity arrays with
validity masks; invalid slots hold SENTINEL coordinates which sort to the end.

Coordinate convention: `coords` is (N, 1+D) int32 with the batch index in
column 0 and D spatial dims after it.  `stride` (the paper's tensor stride
`ts`) is a static python int and always a power of two.

Two ranking engines coexist:

  * v1 ("lex"): one full lexicographic merge-sort of both clouds per kernel
    offset (the original, any spatial dimensionality).
  * v2 ("packed", default for D=3): bit-pack each coordinate into one 62-bit
    key (repro.core.packed), sort every cloud ONCE into a `SortedCloud`
    cache, and realise each kernel offset as a vectorised binary search of
    the shifted output keys against the sorted input keys — K merge-sorts
    collapse to 1 sort + K searches, and the sorted cloud is reused by every
    mapping op at the same stride (all submanifold convs of a network level
    share one sort; `downsample_sorted` dedups the already-packed keys).
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import packed as PK

# Large-but-safe sentinel: room to add kernel offsets without int32 overflow.
SENTINEL = PK.COORD_SENTINEL

# Engine used when callers don't pass one explicitly.  "v2" is the packed-key
# engine; "v1" is the per-offset lexicographic merge-sort kept for
# cross-checking and for spatial dimensionalities != 3.
DEFAULT_ENGINE = "v2"


class PointCloud(NamedTuple):
    """A fixed-capacity, masked, sparse voxel point cloud."""

    coords: jnp.ndarray  # (N, 1+D) int32; invalid rows = SENTINEL
    mask: jnp.ndarray    # (N,) bool
    stride: int          # static tensor stride (power of two)

    @property
    def capacity(self) -> int:
        return self.coords.shape[0]

    @property
    def ndim_spatial(self) -> int:
        return self.coords.shape[1] - 1

    def num_valid(self) -> jnp.ndarray:
        return jnp.sum(self.mask.astype(jnp.int32))


class KernelMaps(NamedTuple):
    """Input/output maps for one sparse convolution (paper's map tuples).

    For each kernel offset k (the weight index w_n), row k lists the matched
    (input index, output index) pairs, padded with -1 / valid=False.

    `inv` is the inverse table inv[k, j] = input index feeding output j under
    offset k (-1 if none).  The v2 engine emits it for free — its binary
    search is indexed by output row, so the hit positions ARE the inverse
    table — letting the Pallas FoD kernel skip the scatter pass that v1
    needed (kernels/spconv/ops.invert_maps).  None on the v1 path.

    `inv_t` is the same table for the *swapped* maps: inv_t[k, i] = output
    index feeding input i when the maps are used transposed (decoder
    up-convolution).  The v2 engine computes it with one extra binary search
    per offset (mapping.match_table) so `swap()` hands the Pallas kernel a
    ready inverse table — the decoder never falls back to a scatter pass.
    """

    in_idx: jnp.ndarray   # (K, cap) int32, -1 padded
    out_idx: jnp.ndarray  # (K, cap) int32, -1 padded
    valid: jnp.ndarray    # (K, cap) bool
    offsets: np.ndarray   # (K, D) static numpy offsets (units of input stride)
    inv: jnp.ndarray | None = None    # (K, out_cap) int32, -1 = no map
    inv_t: jnp.ndarray | None = None  # (K, in_cap) int32, -1 = no map

    def swap(self, require_inverse: bool = False) -> "KernelMaps":
        """Transpose the maps: used for transposed (up-sampling) convolution.

        MinkowskiEngine-style: an upsample conv from coarse->fine reuses the
        maps of the corresponding fine->coarse conv with in/out roles swapped
        (and mirrored weight offsets).  The inverse tables swap roles with
        them, so a v2-built map keeps its scatter-free Pallas path in both
        directions.

        Maps built by the v1 engine (or a v2 build whose explicit `cap`
        dropped the tables) carry NO transposed inverse table: the Pallas
        flows then rebuild one with a scatter pass (numerically identical,
        just not scatter-free).  Pass `require_inverse=True` to make that
        silent downgrade a loud error instead.
        """
        if require_inverse and self.inv_t is None:
            raise ValueError(
                "swapped maps carry no inverse table (inv_t is None): the "
                "maps were built by the v1 engine or with an explicit cap "
                "that dropped them.  The Pallas flows would fall back to a "
                "scatter-built inverse; rebuild the maps with engine='v2' "
                "and the default cap for the scatter-free transposed path")
        return KernelMaps(self.out_idx, self.in_idx, self.valid,
                          -self.offsets, inv=self.inv_t, inv_t=self.inv)


def make_point_cloud(coords: jnp.ndarray, mask: jnp.ndarray,
                     stride: int = 1) -> PointCloud:
    """Normalise a raw (coords, mask) pair: sentinel-fill invalid rows."""
    coords = jnp.where(mask[:, None], coords.astype(jnp.int32), SENTINEL)
    return PointCloud(coords, mask, stride)


# ---------------------------------------------------------------------------
# Coordinate quantization (paper §2.1.1: "clearing the lowest log2(ts) bits")
# ---------------------------------------------------------------------------

def quantize_coords(coords: jnp.ndarray, stride: int) -> jnp.ndarray:
    """q = floor(p / ts) * ts for ts a power of two, batch col untouched.

    Arithmetic shift right then left implements floor-division semantics for
    negative coordinates too (two's complement), exactly the paper's
    "clearing the lowest log2(ts) bits" hardware trick.
    """
    if stride == 1:
        return coords
    k = int(np.log2(stride))
    if 2 ** k != stride:
        raise ValueError(f"stride must be a power of two, got {stride}")
    spatial = (coords[:, 1:] >> k) << k
    return jnp.concatenate([coords[:, :1], spatial], axis=1)


# ---------------------------------------------------------------------------
# Lexicographic sort helpers (the MergeSort stage of the Mapping Unit)
# ---------------------------------------------------------------------------

def _lex_sort(columns: Sequence[jnp.ndarray], num_keys: int):
    """Stable lexicographic sort of parallel 1-D arrays on the first
    `num_keys` columns.  This is the software analogue of the paper's
    merge-sorting network (stage MS)."""
    return lax.sort(tuple(columns), dimension=0, num_keys=num_keys,
                    is_stable=True)


def unique_coords(coords: jnp.ndarray, mask: jnp.ndarray):
    """Deduplicate a masked coordinate set without dynamic shapes.

    Ranking-based: sort lexicographically, mark first occurrences (adjacent
    inequality), overwrite duplicates with SENTINEL, re-sort to compact valid
    entries to the front.  Two passes through the sorting network — the same
    dataflow PointAcc uses for output-cloud construction during
    downsampling.
    """
    n, d = coords.shape
    coords = jnp.where(mask[:, None], coords, SENTINEL)
    cols = tuple(coords[:, i] for i in range(d))
    sorted_cols = _lex_sort(cols, num_keys=d)
    sorted_coords = jnp.stack(sorted_cols, axis=1)
    prev = jnp.roll(sorted_coords, 1, axis=0)
    is_first = jnp.any(sorted_coords != prev, axis=1)
    is_first = is_first.at[0].set(True)
    new_mask = is_first & jnp.all(sorted_coords != SENTINEL, axis=1)
    deduped = jnp.where(new_mask[:, None], sorted_coords, SENTINEL)
    # compaction pass: invalids (SENTINEL) sort to the end
    cols2 = tuple(deduped[:, i] for i in range(d))
    compact_cols = _lex_sort(cols2, num_keys=d)
    compact = jnp.stack(compact_cols, axis=1)
    out_mask = jnp.all(compact != SENTINEL, axis=1)
    return compact, out_mask


def downsample(pc: PointCloud, factor: int = 2) -> PointCloud:
    """Output point cloud construction for a strided sparse conv.

    Quantize to the coarser stride then deduplicate (both ranking-based).
    """
    new_stride = pc.stride * factor
    q = quantize_coords(pc.coords, new_stride)
    q = jnp.where(pc.mask[:, None], q, SENTINEL)
    coords, mask = unique_coords(q, pc.mask)
    return PointCloud(coords, mask, new_stride)


# ---------------------------------------------------------------------------
# Kernel mapping (paper §4.1.1 + Fig. 9): sort-merge intersection
# ---------------------------------------------------------------------------

def kernel_offsets(kernel_size: int, ndim: int,
                   stride: int) -> np.ndarray:
    """All kernel offsets delta in {-(k//2)..k//2}^D, scaled by the input
    tensor stride.  Static (numpy) — offsets index the weight tensor."""
    half = kernel_size // 2
    rng = np.arange(-half, half + 1) if kernel_size % 2 == 1 else \
        np.arange(0, kernel_size)
    grids = np.meshgrid(*([rng] * ndim), indexing="ij")
    offs = np.stack([g.reshape(-1) for g in grids], axis=1)
    return (offs * stride).astype(np.int32)


def _intersect_one_offset(shifted: jnp.ndarray, in_mask: jnp.ndarray,
                          out_coords: jnp.ndarray, out_mask: jnp.ndarray,
                          cap: int):
    """Find coordinate-equal pairs between one shifted input cloud and the
    output cloud.  Paper Fig. 9: merge-sort both clouds into one array and
    detect adjacent duplicates (DetectIntersection stage).

    Both clouds are coordinate-*sets* (no internal duplicates), so each match
    is 1:1 and adjacency detection is exact.  The tag column (input=0,
    output=1) is the last sort key, guaranteeing the input element of a
    matching pair immediately precedes the output element.
    """
    n, d = shifted.shape
    m = out_coords.shape[0]
    shifted = jnp.where(in_mask[:, None], shifted, SENTINEL)
    out_c = jnp.where(out_mask[:, None], out_coords, SENTINEL)

    merged = jnp.concatenate([shifted, out_c], axis=0)          # (n+m, d)
    tag = jnp.concatenate([jnp.zeros(n, jnp.int32),
                           jnp.ones(m, jnp.int32)])
    payload = jnp.concatenate([jnp.arange(n, dtype=jnp.int32),
                               jnp.arange(m, dtype=jnp.int32)])
    valid = jnp.concatenate([in_mask, out_mask])

    cols = tuple(merged[:, i] for i in range(d)) + (tag, payload, valid)
    sorted_cols = _lex_sort(cols, num_keys=d + 1)
    s_coords = jnp.stack(sorted_cols[:d], axis=1)
    s_tag, s_payload, s_valid = sorted_cols[d], sorted_cols[d + 1], \
        sorted_cols[d + 2]

    nxt_coords = jnp.roll(s_coords, -1, axis=0)
    nxt_tag = jnp.roll(s_tag, -1)
    nxt_payload = jnp.roll(s_payload, -1)
    nxt_valid = jnp.roll(s_valid, -1)

    is_pair = (jnp.all(s_coords == nxt_coords, axis=1)
               & (s_tag == 0) & (nxt_tag == 1)
               & s_valid & nxt_valid)
    is_pair = is_pair.at[-1].set(False)

    in_i = jnp.where(is_pair, s_payload, jnp.int32(-1))
    out_i = jnp.where(is_pair, nxt_payload, jnp.int32(-1))

    # Compact matches to the front (one more ranking pass): sort by
    # (!is_pair) keeps relative (coordinate) order of the matches.
    order_key = (~is_pair).astype(jnp.int32)
    _, in_i, out_i, pair_sorted = _lex_sort(
        (order_key, in_i, out_i, is_pair), num_keys=1)
    return in_i[:cap], out_i[:cap], pair_sorted[:cap]


def kernel_map(in_pc: PointCloud, out_pc: PointCloud, kernel_size: int,
               cap: int | None = None) -> KernelMaps:
    """Build the full kernel maps {(p_i, q_k, w_n)} for a sparse convolution.

    For each weight offset delta, intersects the (-delta)-shifted input cloud
    with the output cloud (paper §4.1.1).  vmapped over offsets — the
    point-level parallelism the paper exploits, with offset-level parallelism
    on top.
    """
    offs = kernel_offsets(kernel_size, in_pc.ndim_spatial, in_pc.stride)
    cap = cap if cap is not None else min(in_pc.capacity, out_pc.capacity)
    # shift only spatial dims; batch column gets zero offset
    offs_full = np.concatenate(
        [np.zeros((offs.shape[0], 1), np.int32), offs], axis=1)

    def one(off):
        shifted = in_pc.coords - off[None, :]   # I' = {p - delta}
        return _intersect_one_offset(shifted, in_pc.mask, out_pc.coords,
                                     out_pc.mask, cap)

    in_idx, out_idx, valid = jax.vmap(one)(jnp.asarray(offs_full))
    return KernelMaps(in_idx, out_idx, valid, offs)


# ---------------------------------------------------------------------------
# v2 packed-key engine: one sort per cloud, binary search per offset
# ---------------------------------------------------------------------------

class SortedCloud(NamedTuple):
    """A point cloud plus its once-computed packed-key ranking structure.

    This is the cache the v2 engine threads through a network: every mapping
    op against the same cloud (27 submanifold offsets, the stride-2 down
    conv, coordinate dedup) reuses the single sort instead of re-ranking.

    sorted_hi/sorted_lo are the packed key words in ascending (logical
    62-bit) key order with sentinels (invalid rows) at the end; perm maps
    sorted position -> original row: sorted = keys[perm].
    """

    pc: PointCloud
    sorted_hi: jnp.ndarray  # (N,) int32
    sorted_lo: jnp.ndarray  # (N,) uint32
    perm: jnp.ndarray       # (N,) int32


def sort_cloud(pc: PointCloud) -> SortedCloud:
    """Rank a cloud once: pack coords to 62-bit keys and sort them.

    The ONLY `lax.sort` the v2 engine runs for a given cloud — every
    kernel-offset lookup afterwards is a binary search.
    """
    if pc.ndim_spatial != 3:
        raise ValueError("packed-key engine requires 3 spatial dims, got "
                         f"{pc.ndim_spatial}; use engine='v1'")
    hi, lo = PK.pack_coords(pc.coords, pc.mask)
    if not isinstance(hi, jax.core.Tracer):
        # Eager call: fail loudly on valid points outside the key budget
        # instead of silently dropping them from every map.  (Under jit the
        # data is unavailable; the saturate-to-sentinel semantics — and the
        # v1 escape hatch — are documented in README.)
        n_bad = int(jnp.sum(PK.is_sentinel_key(hi) & pc.mask))
        if n_bad:
            raise ValueError(
                f"{n_bad} valid point(s) outside the packed-key budget "
                f"(batch 0..{PK.BATCH_MAX}, coords {PK.COORD_MIN}.."
                f"{PK.COORD_MAX}); use engine='v1' for such clouds")
    iota = jnp.arange(pc.capacity, dtype=jnp.int32)
    s_hi, s_lo, perm = lax.sort((hi, lo, iota), dimension=0, num_keys=2,
                                is_stable=True)
    return SortedCloud(pc, s_hi, s_lo, perm)


def downsample_sorted(sc: SortedCloud, factor: int = 2) -> SortedCloud:
    """Output cloud construction reusing the packed keys: quantize in the
    key domain, one single-key sort, adjacent dedup, then compact with a
    cumsum scatter instead of v1's second sorting pass.

    The result is bit-identical to `downsample` (same coords/mask order —
    packed-key order IS lexicographic coordinate order) and arrives already
    sorted, so the next level's SortedCloud costs nothing extra.
    """
    new_stride = sc.pc.stride * factor
    qhi, qlo = PK.quantize_keys(sc.sorted_hi, sc.sorted_lo, new_stride)
    s_hi, s_lo = lax.sort((qhi, qlo), dimension=0, num_keys=2,
                          is_stable=True)
    prev_hi = jnp.roll(s_hi, 1)
    prev_lo = jnp.roll(s_lo, 1)
    is_first = (s_hi != prev_hi) | (s_lo != prev_lo)
    is_first = is_first.at[0].set(True)
    valid = is_first & ~PK.is_sentinel_key(s_hi)

    n = s_hi.shape[0]
    dest = jnp.where(valid, jnp.cumsum(valid.astype(jnp.int32)) - 1, n)
    c_hi = jnp.full(n, PK.KEY_HI_SENTINEL, jnp.int32) \
        .at[dest].set(s_hi, mode="drop")
    c_lo = jnp.full(n, PK.KEY_LO_SENTINEL, jnp.uint32) \
        .at[dest].set(s_lo, mode="drop")
    mask = jnp.zeros(n, bool).at[dest].set(True, mode="drop")
    pc = PointCloud(PK.unpack_keys(c_hi, c_lo), mask, new_stride)
    # compacted keys are already ascending: the sorted view is the identity
    return SortedCloud(pc, c_hi, c_lo, jnp.arange(n, dtype=jnp.int32))


def match_table(sc: SortedCloud, query_pc: PointCloud,
                offsets) -> jnp.ndarray:
    """table[k, j] = row of sc.pc at coords (query_pc.coords[j] + offsets[k]),
    or -1 when that site is absent.

    The primitive behind every v2 inverse table: pack the shifted query
    coords and binary-search them against the cloud's sorted keys.  Pure
    ranking — no scatter, no hash.  `offsets` is (K, D) static (numpy or
    jnp); the batch column is never shifted.
    """
    n = sc.pc.capacity
    q_spatial = query_pc.coords[None, :, 1:] + jnp.asarray(offsets)[:, None, :]
    q_batch = jnp.broadcast_to(query_pc.coords[None, :, :1],
                               (q_spatial.shape[0], query_pc.capacity, 1))
    q_hi, q_lo = PK.pack_coords(jnp.concatenate([q_batch, q_spatial], -1),
                                query_pc.mask[None, :])
    pos = PK.searchsorted_pair(sc.sorted_hi, sc.sorted_lo, q_hi, q_lo)
    posc = jnp.clip(pos, 0, n - 1)
    hit = ((sc.sorted_hi[posc] == q_hi) & (sc.sorted_lo[posc] == q_lo)
           & ~PK.is_sentinel_key(q_hi))
    return jnp.where(hit, sc.perm[posc], jnp.int32(-1))


def kernel_map_v2(in_sc: SortedCloud, out_pc: PointCloud, kernel_size: int,
                  cap: int | None = None) -> KernelMaps:
    """Packed-key kernel mapping: for output q and offset delta, the paired
    input is p = q + delta — found by binary-searching key(q + delta) in the
    input cloud's sorted keys.  One vectorised search per offset replaces
    v1's full merge-sort per offset, and because the search is indexed by
    output row the hit table IS the inverse table the Pallas FoD kernel
    wants (KernelMaps.inv) — no scatter pass.
    """
    offs = kernel_offsets(kernel_size, 3, in_sc.pc.stride)
    m = out_pc.capacity
    n = in_sc.pc.capacity
    cap = cap if cap is not None else min(n, m)

    # queries: (K, m, 4) shifted output coords (batch col untouched)
    q_spatial = out_pc.coords[None, :, 1:] + jnp.asarray(offs)[:, None, :]
    q_batch = jnp.broadcast_to(out_pc.coords[None, :, :1],
                               (offs.shape[0], m, 1))
    q_hi, q_lo = PK.pack_coords(jnp.concatenate([q_batch, q_spatial], -1),
                                out_pc.mask[None, :])

    pos = PK.searchsorted_pair(in_sc.sorted_hi, in_sc.sorted_lo, q_hi, q_lo)
    posc = jnp.clip(pos, 0, n - 1)
    hit = ((in_sc.sorted_hi[posc] == q_hi) & (in_sc.sorted_lo[posc] == q_lo)
           & ~PK.is_sentinel_key(q_hi))

    in_idx = jnp.where(hit, in_sc.perm[posc], jnp.int32(-1))
    out_idx = jnp.where(
        hit, jnp.broadcast_to(jnp.arange(m, dtype=jnp.int32), hit.shape),
        jnp.int32(-1))
    # (K, m): inv[k, j] = i.  Only valid while the maps carry every match —
    # a cap below m may truncate matches, and an inv that still held them
    # would make the pallas flow disagree with gms/fod.
    inv = in_idx if cap >= m else None

    if cap < m:
        # explicit small cap: compact matches to the front (one cheap
        # single-key row sort — only reachable via user-supplied cap)
        order = (~hit).astype(jnp.int32)
        _, in_idx, out_idx, hit = lax.sort((order, in_idx, out_idx, hit),
                                           dimension=1, num_keys=1,
                                           is_stable=True)
    if cap != m:
        in_idx = _fit_cols(in_idx, cap, -1)
        out_idx = _fit_cols(out_idx, cap, -1)
        hit = _fit_cols(hit, cap, False)
    return KernelMaps(in_idx, out_idx, hit, offs, inv=inv)


def _fit_cols(a: jnp.ndarray, cap: int, fill) -> jnp.ndarray:
    if cap <= a.shape[1]:
        return a[:, :cap]
    pad = jnp.full((a.shape[0], cap - a.shape[1]), fill, a.dtype)
    return jnp.concatenate([a, pad], axis=1)


def build_conv_maps_cached(sc: SortedCloud, kernel_size: int, stride: int,
                           cap: int | None = None,
                           out_sc: SortedCloud | None = None):
    """v2 `build_conv_maps` against an existing SortedCloud cache.

    Returns (maps, out_sorted_cloud) so callers building a whole network can
    chain the cache level-to-level (core.tensor.MapContext does).  Pass
    `out_sc` when the downsampled output cloud is already ranked (a context
    cache) to skip recomputing it.

    Strided maps additionally carry the swapped inverse table `inv_t`
    (searching the coarse cloud from the fine coords), so the decoder's
    transposed convs run the scatter-free Pallas path via `maps.swap()`.
    The table is only exact while `cap` drops no matches — the default cap
    covers every match, a user-supplied smaller one may not.
    """
    if out_sc is None:
        out_sc = sc if stride == 1 else downsample_sorted(sc, stride)
    maps = kernel_map_v2(sc, out_sc.pc, kernel_size, cap=cap)
    resolved_cap = cap if cap is not None else min(sc.pc.capacity,
                                                   out_sc.pc.capacity)
    if stride > 1 and resolved_cap >= out_sc.pc.capacity:
        # swapped orientation: fine output i under swapped offset -delta is
        # fed by the coarse row at (fine_coords[i] - delta)
        inv_t = match_table(out_sc, sc.pc, -maps.offsets)
        maps = maps._replace(inv_t=inv_t)
    return maps, out_sc


# ---------------------------------------------------------------------------
# Stride-aware convenience wrappers used by the SparseConv layer
# ---------------------------------------------------------------------------

def build_conv_maps(in_pc: PointCloud, kernel_size: int, stride: int,
                    cap: int | None = None, engine: str | None = None,
                    cache: SortedCloud | None = None):
    """Maps + output cloud for a (possibly strided) sparse convolution.

    stride == 1  -> submanifold conv: output sites == input sites (the
                    paper's no-dilation invariant: nonzeros never dilate).
    stride == 2  -> output cloud from quantization + unique, offsets in units
                    of the *input* stride.

    engine: "v2" (packed keys, default) or "v1" (per-offset lexicographic
    merge-sort; required for ndim_spatial != 3, kept selectable for
    cross-checking).  `cache` short-circuits the v2 sort with an existing
    SortedCloud of `in_pc`.  The default engine falls back to v1 for
    non-3D clouds; an *explicit* engine="v2" raises there instead (a
    silent downgrade would defeat cross-checking).
    """
    requested = engine
    engine = engine or DEFAULT_ENGINE
    if engine == "v2" and in_pc.ndim_spatial != 3 and requested is None:
        engine = "v1"
    if engine == "v2":
        sc = cache if cache is not None else sort_cloud(in_pc)
        maps, out_sc = build_conv_maps_cached(sc, kernel_size, stride,
                                              cap=cap)
        return maps, out_sc.pc
    if engine != "v1":
        raise ValueError(f"unknown mapping engine {engine!r}")
    if stride == 1:
        out_pc = in_pc
    else:
        out_pc = downsample(in_pc, stride)
    maps = kernel_map(in_pc, out_pc, kernel_size, cap=cap)
    return maps, out_pc
