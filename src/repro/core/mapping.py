"""Ranking-based mapping operations (PointAcc Mapping Unit, paper §4.1).

PointAcc's key insight: every mapping operation a point-cloud network needs
(kernel mapping, k-nearest-neighbours, ball query, farthest-point sampling,
coordinate quantization) can be expressed through *ranking* primitives —
MergeSort / TopK / Max over coordinate or distance keys — instead of hash
tables.  Hash tables need random parallel SRAM access (an O(N^2) crossbar in
silicon); sorting networks are log-depth and fully parallel.  The same
trade-off holds on TPU: XLA has no efficient random-access hash path, but its
bitonic `lax.sort` *is* a sorting network.  This module is therefore a direct
software embodiment of the paper's Mapping Unit:

  * kernel mapping  -> sort-merge intersection of the (-delta)-shifted input
                       cloud with the output cloud (paper Fig. 9), realised as
                       one lexicographic `lax.sort` + adjacent-equality
                       detection (paper's DetectIntersection stage).
  * quantization    -> clearing the low log2(ts) bits of the coordinates
                       (paper §2.1.1), i.e. arithmetic shift right then left.
  * unique (output cloud construction) -> sort + adjacent-dedup + re-sort
                       (compaction without dynamic shapes).

All functions are jit-friendly: point clouds are fixed-capacity arrays with
validity masks; invalid slots hold SENTINEL coordinates which sort to the end.

Coordinate convention: `coords` is (N, 1+D) int32 with the batch index in
column 0 and D spatial dims after it.  `stride` (the paper's tensor stride
`ts`) is a static python int and always a power of two.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

# Large-but-safe sentinel: room to add kernel offsets without int32 overflow.
SENTINEL = np.int32(2**30 - 1)


class PointCloud(NamedTuple):
    """A fixed-capacity, masked, sparse voxel point cloud."""

    coords: jnp.ndarray  # (N, 1+D) int32; invalid rows = SENTINEL
    mask: jnp.ndarray    # (N,) bool
    stride: int          # static tensor stride (power of two)

    @property
    def capacity(self) -> int:
        return self.coords.shape[0]

    @property
    def ndim_spatial(self) -> int:
        return self.coords.shape[1] - 1

    def num_valid(self) -> jnp.ndarray:
        return jnp.sum(self.mask.astype(jnp.int32))


class KernelMaps(NamedTuple):
    """Input/output maps for one sparse convolution (paper's map tuples).

    For each kernel offset k (the weight index w_n), row k lists the matched
    (input index, output index) pairs, padded with -1 / valid=False.
    """

    in_idx: jnp.ndarray   # (K, cap) int32, -1 padded
    out_idx: jnp.ndarray  # (K, cap) int32, -1 padded
    valid: jnp.ndarray    # (K, cap) bool
    offsets: np.ndarray   # (K, D) static numpy offsets (units of input stride)

    def swap(self) -> "KernelMaps":
        """Transpose the maps: used for transposed (up-sampling) convolution.

        MinkowskiEngine-style: an upsample conv from coarse->fine reuses the
        maps of the corresponding fine->coarse conv with in/out roles swapped
        (and mirrored weight offsets).
        """
        return KernelMaps(self.out_idx, self.in_idx, self.valid,
                          -self.offsets)


def make_point_cloud(coords: jnp.ndarray, mask: jnp.ndarray,
                     stride: int = 1) -> PointCloud:
    """Normalise a raw (coords, mask) pair: sentinel-fill invalid rows."""
    coords = jnp.where(mask[:, None], coords.astype(jnp.int32), SENTINEL)
    return PointCloud(coords, mask, stride)


# ---------------------------------------------------------------------------
# Coordinate quantization (paper §2.1.1: "clearing the lowest log2(ts) bits")
# ---------------------------------------------------------------------------

def quantize_coords(coords: jnp.ndarray, stride: int) -> jnp.ndarray:
    """q = floor(p / ts) * ts for ts a power of two, batch col untouched.

    Arithmetic shift right then left implements floor-division semantics for
    negative coordinates too (two's complement), exactly the paper's
    "clearing the lowest log2(ts) bits" hardware trick.
    """
    if stride == 1:
        return coords
    k = int(np.log2(stride))
    if 2 ** k != stride:
        raise ValueError(f"stride must be a power of two, got {stride}")
    spatial = (coords[:, 1:] >> k) << k
    return jnp.concatenate([coords[:, :1], spatial], axis=1)


# ---------------------------------------------------------------------------
# Lexicographic sort helpers (the MergeSort stage of the Mapping Unit)
# ---------------------------------------------------------------------------

def _lex_sort(columns: Sequence[jnp.ndarray], num_keys: int):
    """Stable lexicographic sort of parallel 1-D arrays on the first
    `num_keys` columns.  This is the software analogue of the paper's
    merge-sorting network (stage MS)."""
    return lax.sort(tuple(columns), dimension=0, num_keys=num_keys,
                    is_stable=True)


def unique_coords(coords: jnp.ndarray, mask: jnp.ndarray):
    """Deduplicate a masked coordinate set without dynamic shapes.

    Ranking-based: sort lexicographically, mark first occurrences (adjacent
    inequality), overwrite duplicates with SENTINEL, re-sort to compact valid
    entries to the front.  Two passes through the sorting network — the same
    dataflow PointAcc uses for output-cloud construction during
    downsampling.
    """
    n, d = coords.shape
    coords = jnp.where(mask[:, None], coords, SENTINEL)
    cols = tuple(coords[:, i] for i in range(d))
    sorted_cols = _lex_sort(cols, num_keys=d)
    sorted_coords = jnp.stack(sorted_cols, axis=1)
    prev = jnp.roll(sorted_coords, 1, axis=0)
    is_first = jnp.any(sorted_coords != prev, axis=1)
    is_first = is_first.at[0].set(True)
    new_mask = is_first & jnp.all(sorted_coords != SENTINEL, axis=1)
    deduped = jnp.where(new_mask[:, None], sorted_coords, SENTINEL)
    # compaction pass: invalids (SENTINEL) sort to the end
    cols2 = tuple(deduped[:, i] for i in range(d))
    compact_cols = _lex_sort(cols2, num_keys=d)
    compact = jnp.stack(compact_cols, axis=1)
    out_mask = jnp.all(compact != SENTINEL, axis=1)
    return compact, out_mask


def downsample(pc: PointCloud, factor: int = 2) -> PointCloud:
    """Output point cloud construction for a strided sparse conv.

    Quantize to the coarser stride then deduplicate (both ranking-based).
    """
    new_stride = pc.stride * factor
    q = quantize_coords(pc.coords, new_stride)
    q = jnp.where(pc.mask[:, None], q, SENTINEL)
    coords, mask = unique_coords(q, pc.mask)
    return PointCloud(coords, mask, new_stride)


# ---------------------------------------------------------------------------
# Kernel mapping (paper §4.1.1 + Fig. 9): sort-merge intersection
# ---------------------------------------------------------------------------

def kernel_offsets(kernel_size: int, ndim: int,
                   stride: int) -> np.ndarray:
    """All kernel offsets delta in {-(k//2)..k//2}^D, scaled by the input
    tensor stride.  Static (numpy) — offsets index the weight tensor."""
    half = kernel_size // 2
    rng = np.arange(-half, half + 1) if kernel_size % 2 == 1 else \
        np.arange(0, kernel_size)
    grids = np.meshgrid(*([rng] * ndim), indexing="ij")
    offs = np.stack([g.reshape(-1) for g in grids], axis=1)
    return (offs * stride).astype(np.int32)


def _intersect_one_offset(shifted: jnp.ndarray, in_mask: jnp.ndarray,
                          out_coords: jnp.ndarray, out_mask: jnp.ndarray,
                          cap: int):
    """Find coordinate-equal pairs between one shifted input cloud and the
    output cloud.  Paper Fig. 9: merge-sort both clouds into one array and
    detect adjacent duplicates (DetectIntersection stage).

    Both clouds are coordinate-*sets* (no internal duplicates), so each match
    is 1:1 and adjacency detection is exact.  The tag column (input=0,
    output=1) is the last sort key, guaranteeing the input element of a
    matching pair immediately precedes the output element.
    """
    n, d = shifted.shape
    m = out_coords.shape[0]
    shifted = jnp.where(in_mask[:, None], shifted, SENTINEL)
    out_c = jnp.where(out_mask[:, None], out_coords, SENTINEL)

    merged = jnp.concatenate([shifted, out_c], axis=0)          # (n+m, d)
    tag = jnp.concatenate([jnp.zeros(n, jnp.int32),
                           jnp.ones(m, jnp.int32)])
    payload = jnp.concatenate([jnp.arange(n, dtype=jnp.int32),
                               jnp.arange(m, dtype=jnp.int32)])
    valid = jnp.concatenate([in_mask, out_mask])

    cols = tuple(merged[:, i] for i in range(d)) + (tag, payload, valid)
    sorted_cols = _lex_sort(cols, num_keys=d + 1)
    s_coords = jnp.stack(sorted_cols[:d], axis=1)
    s_tag, s_payload, s_valid = sorted_cols[d], sorted_cols[d + 1], \
        sorted_cols[d + 2]

    nxt_coords = jnp.roll(s_coords, -1, axis=0)
    nxt_tag = jnp.roll(s_tag, -1)
    nxt_payload = jnp.roll(s_payload, -1)
    nxt_valid = jnp.roll(s_valid, -1)

    is_pair = (jnp.all(s_coords == nxt_coords, axis=1)
               & (s_tag == 0) & (nxt_tag == 1)
               & s_valid & nxt_valid)
    is_pair = is_pair.at[-1].set(False)

    in_i = jnp.where(is_pair, s_payload, jnp.int32(-1))
    out_i = jnp.where(is_pair, nxt_payload, jnp.int32(-1))

    # Compact matches to the front (one more ranking pass): sort by
    # (!is_pair) keeps relative (coordinate) order of the matches.
    order_key = (~is_pair).astype(jnp.int32)
    _, in_i, out_i, pair_sorted = _lex_sort(
        (order_key, in_i, out_i, is_pair), num_keys=1)
    return in_i[:cap], out_i[:cap], pair_sorted[:cap]


def kernel_map(in_pc: PointCloud, out_pc: PointCloud, kernel_size: int,
               cap: int | None = None) -> KernelMaps:
    """Build the full kernel maps {(p_i, q_k, w_n)} for a sparse convolution.

    For each weight offset delta, intersects the (-delta)-shifted input cloud
    with the output cloud (paper §4.1.1).  vmapped over offsets — the
    point-level parallelism the paper exploits, with offset-level parallelism
    on top.
    """
    offs = kernel_offsets(kernel_size, in_pc.ndim_spatial, in_pc.stride)
    cap = cap if cap is not None else min(in_pc.capacity, out_pc.capacity)
    # shift only spatial dims; batch column gets zero offset
    offs_full = np.concatenate(
        [np.zeros((offs.shape[0], 1), np.int32), offs], axis=1)

    def one(off):
        shifted = in_pc.coords - off[None, :]   # I' = {p - delta}
        return _intersect_one_offset(shifted, in_pc.mask, out_pc.coords,
                                     out_pc.mask, cap)

    in_idx, out_idx, valid = jax.vmap(one)(jnp.asarray(offs_full))
    return KernelMaps(in_idx, out_idx, valid, offs)


# ---------------------------------------------------------------------------
# Stride-aware convenience wrappers used by the SparseConv layer
# ---------------------------------------------------------------------------

def build_conv_maps(in_pc: PointCloud, kernel_size: int, stride: int,
                    cap: int | None = None):
    """Maps + output cloud for a (possibly strided) sparse convolution.

    stride == 1  -> submanifold conv: output sites == input sites (the
                    paper's no-dilation invariant: nonzeros never dilate).
    stride == 2  -> output cloud from quantization + unique, offsets in units
                    of the *input* stride.
    """
    if stride == 1:
        out_pc = in_pc
    else:
        out_pc = downsample(in_pc, stride)
    maps = kernel_map(in_pc, out_pc, kernel_size, cap=cap)
    return maps, out_pc
