"""Ranking-based neighbourhood ops for PointNet++-family networks.

Paper Table 1 / §4.1: farthest point sampling -> Max over distances,
k-nearest-neighbours / ball query -> TopK over distances.  PointAcc runs all
of these on one sorting-network kernel; here `lax.top_k` / `argmax` are the
TPU-native ranking primitives (top_k lowers to a sorting network on TPU).

Convention: dense-batched float clouds `xyz` of shape (B, N, 3) with a
validity mask (B, N) — the standard PointNet++ batching.  Invalid points are
pushed to +inf distance so ranking ignores them.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

_INF = jnp.float32(1e10)


def pairwise_sqdist(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(..., M, 3) x (..., N, 3) -> (..., M, N) squared euclidean distance."""
    a2 = jnp.sum(a * a, axis=-1, keepdims=True)          # (..., M, 1)
    b2 = jnp.sum(b * b, axis=-1)[..., None, :]           # (..., 1, N)
    cross = jnp.einsum("...md,...nd->...mn", a, b)
    return jnp.maximum(a2 + b2 - 2.0 * cross, 0.0)


# ---------------------------------------------------------------------------
# Farthest point sampling: iterative Max ranking (paper Fig. 8b)
# ---------------------------------------------------------------------------

def _fps_single(xyz: jnp.ndarray, mask: jnp.ndarray, n_samples: int):
    """One cloud (N, 3).  Keeps a running min-distance-to-selected array and
    repeatedly takes the argmax — exactly the paper's FPS dataflow (stages
    FS/CD/ST with the blue forwarding loop)."""
    n = xyz.shape[0]
    start = jnp.argmax(mask)  # first valid point
    min_d = jnp.where(mask, _INF, -_INF)

    def body(i, state):
        sel_idx, min_d, last = state
        d = jnp.sum((xyz - xyz[last]) ** 2, axis=-1)
        d = jnp.where(mask, d, -_INF)
        min_d = jnp.minimum(min_d, d)
        nxt = jnp.argmax(min_d)                     # Max ranking op
        sel_idx = sel_idx.at[i].set(nxt)
        return sel_idx, min_d, nxt

    sel = jnp.zeros(n_samples, jnp.int32).at[0].set(start.astype(jnp.int32))
    sel, _, _ = lax.fori_loop(1, n_samples, body,
                              (sel, min_d, start.astype(jnp.int32)))
    return sel


def farthest_point_sampling(xyz: jnp.ndarray, mask: jnp.ndarray,
                            n_samples: int) -> jnp.ndarray:
    """(B, N, 3), (B, N) -> (B, n_samples) int32 indices."""
    return jax.vmap(_fps_single, in_axes=(0, 0, None))(xyz, mask, n_samples)


# ---------------------------------------------------------------------------
# kNN / ball query: TopK ranking (paper Fig. 8c)
# ---------------------------------------------------------------------------

def knn(query: jnp.ndarray, qmask: jnp.ndarray, ref: jnp.ndarray,
        rmask: jnp.ndarray, k: int, chunk: int = 1024):
    """k nearest neighbours.  (B,M,3) queries, (B,N,3) refs ->
    idx (B,M,k) int32, sqdist (B,M,k).

    TopK over negative distances; the M axis is chunked (lax.map) so the
    (M, N) distance tile bounds on-chip memory — the software analogue of the
    paper's arbitrary-length TopK via truncated intermediate subarrays
    (Fig. 10c).
    """
    b, m, _ = query.shape
    n_ref = ref.shape[1]
    k_eff = min(k, n_ref)   # fewer refs than neighbours requested

    def per_batch(args):
        q, qm, r, rm = args

        def per_chunk(qc):
            d = pairwise_sqdist(qc, r)                   # (chunk, N)
            d = jnp.where(rm[None, :], d, _INF)
            neg_d, idx = lax.top_k(-d, k_eff)            # ranking
            if k_eff < k:    # pad with the last neighbour at +inf distance
                idx = jnp.concatenate(
                    [idx] + [idx[:, -1:]] * (k - k_eff), axis=1)
                neg_d = jnp.concatenate(
                    [neg_d, jnp.full((idx.shape[0], k - k_eff), -_INF)],
                    axis=1)
            return idx.astype(jnp.int32), -neg_d

        n_chunks = max(1, (m + chunk - 1) // chunk)
        pad = n_chunks * chunk - m
        qp = jnp.pad(q, ((0, pad), (0, 0)))
        qs = qp.reshape(n_chunks, -1, q.shape[-1])
        idx, dist = lax.map(per_chunk, qs)
        idx = idx.reshape(-1, k)[:m]
        dist = dist.reshape(-1, k)[:m]
        return idx, dist

    return jax.vmap(lambda q, qm, r, rm: per_batch((q, qm, r, rm)))(
        query, qmask, ref, rmask)


def ball_query(query: jnp.ndarray, qmask: jnp.ndarray, ref: jnp.ndarray,
               rmask: jnp.ndarray, radius: float, k: int,
               chunk: int = 1024):
    """Ball query = TopK further constrained to d <= r^2 (paper §2.1.2).

    Out-of-ball slots are replaced by the first in-ball neighbour (standard
    PointNet++ padding so the group tensor stays dense).
    Returns idx (B,M,k) and a validity mask (B,M,k).
    """
    idx, dist = knn(query, qmask, ref, rmask, k, chunk=chunk)
    inside = dist <= radius * radius
    first = idx[..., :1]
    idx = jnp.where(inside, idx, first)
    # a query with zero in-ball neighbours keeps its (invalid) nearest point;
    # mark validity so aggregation can ignore it.
    valid = inside | inside[..., :1]
    return idx, valid


def gather_points(points: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """(B, N, C), (B, ...) -> (B, ..., C) batched gather."""
    return jax.vmap(lambda p, i: p[i])(points, idx)
