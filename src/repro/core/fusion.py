"""Temporal layer fusion planner (paper §4.2.4).

PointAcc fuses consecutive FC layers by configuring the MIR container as a
stack: the point dimension is tiled (no halos — FCs are pointwise), and
intermediates live on-chip.  The number of fused layers and the tiling are
chosen at *compile time*: "for each set of consecutive FCs, try to fuse all
unprocessed FCs.  If the estimated memory of required intermediate data
overflows for all possible tilings, discard the last layer and try to fuse
the remaining ones."

This module reproduces that compilation pass.  The plan drives
`repro.kernels.fused_mlp` (intermediates in VMEM scratch) and the
`benchmarks/bench_fusion.py` DRAM-traffic reproduction of Fig. 20.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

# TPU v5e VMEM is 128 MiB; leave headroom for weights + double buffering.
DEFAULT_ONCHIP_BUDGET_BYTES = 64 * 1024 * 1024
# candidate point-dim tile sizes (multiples of the 8-sublane MXU alignment)
CANDIDATE_TILES = (4096, 2048, 1024, 512, 256, 128)


@dataclass(frozen=True)
class FusionGroup:
    start: int            # first layer index in the chain
    n_layers: int         # how many consecutive FCs are fused
    tile_points: int      # point-dim tile size
    onchip_bytes: int     # estimated on-chip footprint of the group


def _group_bytes(widths: Sequence[int], tile: int, dtype_bytes: int) -> int:
    """On-chip bytes for one tile flowing through the fused chain: every
    inter-layer activation tile is simultaneously live (the MIR stack) plus
    the weights of every fused layer."""
    acts = sum(w * tile for w in widths) * dtype_bytes
    weights = sum(widths[i] * widths[i + 1]
                  for i in range(len(widths) - 1)) * dtype_bytes
    return acts + weights


def plan_fusion(layer_widths: Sequence[int],
                budget_bytes: int = DEFAULT_ONCHIP_BUDGET_BYTES,
                dtype_bytes: int = 4) -> List[FusionGroup]:
    """layer_widths: [in, h1, h2, ..., out] for a chain of len-1 FC layers.

    Greedy longest-prefix fusion under the budget, exactly the paper's
    procedure: try all layers, shrink tiling, then drop the last layer.
    """
    n_fcs = len(layer_widths) - 1
    groups: List[FusionGroup] = []
    start = 0
    while start < n_fcs:
        placed = False
        for n in range(n_fcs - start, 0, -1):
            widths = layer_widths[start:start + n + 1]
            for tile in CANDIDATE_TILES:
                b = _group_bytes(widths, tile, dtype_bytes)
                if b <= budget_bytes:
                    groups.append(FusionGroup(start, n, tile, b))
                    start += n
                    placed = True
                    break
            if placed:
                break
        if not placed:
            # even a single layer at the smallest tile overflows: emit it
            # unfused at the smallest tile (it will stream through HBM).
            widths = layer_widths[start:start + 2]
            groups.append(FusionGroup(
                start, 1, CANDIDATE_TILES[-1],
                _group_bytes(widths, CANDIDATE_TILES[-1], dtype_bytes)))
            start += 1
    return groups


def dram_bytes_unfused(n_points: int, layer_widths: Sequence[int],
                       dtype_bytes: int = 4) -> int:
    """Layer-by-layer execution: every intermediate activation is written to
    and read back from DRAM (paper Fig. 20 baseline)."""
    total = n_points * layer_widths[0] * dtype_bytes       # initial read
    for w in layer_widths[1:-1]:
        total += 2 * n_points * w * dtype_bytes            # write + read
    total += n_points * layer_widths[-1] * dtype_bytes     # final write
    total += sum(layer_widths[i] * layer_widths[i + 1]
                 for i in range(len(layer_widths) - 1)) * dtype_bytes
    return total


def dram_bytes_fused(n_points: int, layer_widths: Sequence[int],
                     groups: Sequence[FusionGroup],
                     dtype_bytes: int = 4) -> int:
    """With temporal fusion only group-boundary activations touch DRAM."""
    total = n_points * layer_widths[0] * dtype_bytes
    for g in groups[:-1]:
        boundary = layer_widths[g.start + g.n_layers]
        total += 2 * n_points * boundary * dtype_bytes
    total += n_points * layer_widths[-1] * dtype_bytes
    # weights are re-read once per point-dim tile sweep of each group
    for g in groups:
        widths = layer_widths[g.start:g.start + g.n_layers + 1]
        w_bytes = sum(widths[i] * widths[i + 1]
                      for i in range(len(widths) - 1)) * dtype_bytes
        total += w_bytes
    return total
