"""Temporal layer fusion planner (paper §4.2.4).

PointAcc fuses consecutive FC layers by configuring the MIR container as a
stack: the point dimension is tiled (no halos — FCs are pointwise), and
intermediates live on-chip.  The number of fused layers and the tiling are
chosen at *compile time*: "for each set of consecutive FCs, try to fuse all
unprocessed FCs.  If the estimated memory of required intermediate data
overflows for all possible tilings, discard the last layer and try to fuse
the remaining ones."

This module reproduces that compilation pass.  The plan drives
`repro.kernels.fused_mlp` (intermediates in VMEM scratch) and the
`benchmarks/bench_fusion.py` DRAM-traffic reproduction of Fig. 20.

`plan_conv_epilogue` extends the same search to sparse convolutions: a conv
plus its epilogue (bias/norm/activation/residual) is a two-stage fusion
group whose on-chip footprint is the resident weights, the output-stationary
accumulator tile, the epilogue operand tiles, and a double-buffered feature
cache block.  The planner picks the largest cache block (the paper's
configurable cache-block size, §4.2.2) that fits the budget — fewest window
sweeps — and declines to fuse only when even the smallest block overflows,
exactly the FC procedure's 'discard the last layer' step.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

# TPU v5e VMEM is 128 MiB; leave headroom for weights + double buffering.
DEFAULT_ONCHIP_BUDGET_BYTES = 64 * 1024 * 1024
# candidate point-dim tile sizes (multiples of the 8-sublane MXU alignment)
CANDIDATE_TILES = (4096, 2048, 1024, 512, 256, 128)


@dataclass(frozen=True)
class FusionGroup:
    start: int            # first layer index in the chain
    n_layers: int         # how many consecutive FCs are fused
    tile_points: int      # point-dim tile size
    onchip_bytes: int     # estimated on-chip footprint of the group


def _group_bytes(widths: Sequence[int], tile: int, dtype_bytes: int) -> int:
    """On-chip bytes for one tile flowing through the fused chain: every
    inter-layer activation tile is simultaneously live (the MIR stack) plus
    the weights of every fused layer."""
    acts = sum(w * tile for w in widths) * dtype_bytes
    weights = sum(widths[i] * widths[i + 1]
                  for i in range(len(widths) - 1)) * dtype_bytes
    return acts + weights


def plan_fusion(layer_widths: Sequence[int],
                budget_bytes: int = DEFAULT_ONCHIP_BUDGET_BYTES,
                dtype_bytes: int = 4) -> List[FusionGroup]:
    """layer_widths: [in, h1, h2, ..., out] for a chain of len-1 FC layers.

    Greedy longest-prefix fusion under the budget, exactly the paper's
    procedure: try all layers, shrink tiling, then drop the last layer.
    """
    n_fcs = len(layer_widths) - 1
    groups: List[FusionGroup] = []
    start = 0
    while start < n_fcs:
        placed = False
        for n in range(n_fcs - start, 0, -1):
            widths = layer_widths[start:start + n + 1]
            for tile in CANDIDATE_TILES:
                b = _group_bytes(widths, tile, dtype_bytes)
                if b <= budget_bytes:
                    groups.append(FusionGroup(start, n, tile, b))
                    start += n
                    placed = True
                    break
            if placed:
                break
        if not placed:
            # even a single layer at the smallest tile overflows: emit it
            # unfused at the smallest tile (it will stream through HBM).
            widths = layer_widths[start:start + 2]
            groups.append(FusionGroup(
                start, 1, CANDIDATE_TILES[-1],
                _group_bytes(widths, CANDIDATE_TILES[-1], dtype_bytes)))
            start += 1
    return groups


# candidate feature cache-block sizes (rows) for the streamed conv kernel;
# multiples of the 8-sublane alignment, largest first (fewest window sweeps)
CONV_FEAT_TILES = (65536, 32768, 16384, 8192, 4096, 2048, 1024, 512, 256,
                   128, 64, 32, 16, 8)


@dataclass(frozen=True)
class ConvFusionPlan:
    """Compile-time decision for one sparse conv + epilogue site."""

    fuse: bool            # fold the epilogue into the kernel flush?
    feat_tile: int        # feature cache-block rows (streaming window)
    out_tile: int         # output-stationary tile rows
    onchip_bytes: int     # estimated VMEM footprint of the fused group


def plan_conv_epilogue(n_in: int, cin: int, cout: int, k: int, *,
                       residual: bool = False, out_tile: int = 128,
                       budget_bytes: int = DEFAULT_ONCHIP_BUDGET_BYTES,
                       dtype_bytes: int = 4) -> ConvFusionPlan:
    """Fusion plan for one sparse conv of K=`k` offsets, (cin -> cout)
    channels over an `n_in`-row input cloud.

    Resident regardless of cache block: all K weight tiles, the f32
    accumulator, the output tile, the inverse-table slice, and (if fused)
    the epilogue operands — a residual skip tile and the per-channel
    norm/bias vectors.  The feature cache block is double-buffered.
    """
    weights = k * cin * cout * dtype_bytes
    acc = out_tile * cout * 4                     # f32 scratch
    out_t = out_tile * cout * dtype_bytes
    inv = k * out_tile * 4
    epi = (out_tile * cout * dtype_bytes if residual else 0) \
        + 3 * cout * dtype_bytes + out_tile * dtype_bytes
    fixed = weights + acc + out_t + inv + epi
    # whole cloud resident first (one window, no sweeps), then shrinking
    # stream blocks — largest fitting block wins
    candidates = [_round_up(n_in, 8)] + [t for t in CONV_FEAT_TILES
                                         if t < n_in]
    for tile in candidates:
        b = fixed + 2 * tile * cin * dtype_bytes  # double-buffered window
        if b <= budget_bytes:
            return ConvFusionPlan(True, tile, out_tile, b)
    # epilogue operands don't fit on-chip next to the conv: stream the conv
    # with the smallest block and run the epilogue layer-by-layer (the
    # paper's 'discard the last layer and fuse the remaining ones').
    tile = candidates[-1]
    b = fixed - epi + 2 * tile * cin * dtype_bytes
    return ConvFusionPlan(False, tile, out_tile, b)


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def dram_bytes_conv_epilogue(n_out: int, cout: int, *, residual: bool =
                             False, fused: bool = True,
                             dtype_bytes: int = 4) -> int:
    """Epilogue-side DRAM traffic of one sparse conv layer (Fig. 20 model
    applied to conv blocks).

    Unfused: the kernel writes the pre-activation accumulator to DRAM, the
    epilogue reads it back and writes the activation (plus a residual read).
    Fused: the epilogue runs at flush — only the final activation is written
    (the residual skip tile is still read once).
    """
    act = n_out * cout * dtype_bytes
    res = act if residual else 0
    if fused:
        return act + res
    return 3 * act + res


def dram_bytes_unfused(n_points: int, layer_widths: Sequence[int],
                       dtype_bytes: int = 4) -> int:
    """Layer-by-layer execution: every intermediate activation is written to
    and read back from DRAM (paper Fig. 20 baseline)."""
    total = n_points * layer_widths[0] * dtype_bytes       # initial read
    for w in layer_widths[1:-1]:
        total += 2 * n_points * w * dtype_bytes            # write + read
    total += n_points * layer_widths[-1] * dtype_bytes     # final write
    total += sum(layer_widths[i] * layer_widths[i + 1]
                 for i in range(len(layer_widths) - 1)) * dtype_bytes
    return total


def dram_bytes_fused(n_points: int, layer_widths: Sequence[int],
                     groups: Sequence[FusionGroup],
                     dtype_bytes: int = 4) -> int:
    """With temporal fusion only group-boundary activations touch DRAM."""
    total = n_points * layer_widths[0] * dtype_bytes
    for g in groups[:-1]:
        boundary = layer_widths[g.start + g.n_layers]
        total += 2 * n_points * boundary * dtype_bytes
    total += n_points * layer_widths[-1] * dtype_bytes
    # weights are re-read once per point-dim tile sweep of each group
    for g in groups:
        widths = layer_widths[g.start:g.start + g.n_layers + 1]
        w_bytes = sum(widths[i] * widths[i + 1]
                      for i in range(len(widths) - 1)) * dtype_bytes
        total += w_bytes
    return total
