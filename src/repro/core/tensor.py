"""SparseTensor + MapContext: the TorchSparse-style frontend state.

TorchSparse (the paper group's inference engine) showed the right shape
for a sparse-conv frontend: a tensor that carries coords+feats+stride and
*owns its kernel-map cache*, so callers stop threading mapping state by
hand.  This module is that shape for the PointAcc reproduction:

  * `SparseTensor` — features + a masked voxel cloud + tensor stride,
    sharing one `MapContext` along a network so geometry work is never
    repeated.
  * `MapContext` — owns everything the Mapping Unit produces for one
    geometry: the `SortedCloud` ranking cache per stride level (v2
    engine), every kernel map keyed by (kernel_size, in_stride,
    out_stride), the temporal-fusion plans per conv site, and the
    stride-pair lookup that hands transposed convs their swapped maps
    without caller bookkeeping.

All mapping state is computed lazily and memoized: the first conv at a
stride level ranks the cloud (one `lax.sort`), every later conv at that
level is binary searches against the cached `SortedCloud` — the paper's
one-sort-per-level invariant, now enforced by the context instead of by
careful call-site plumbing.

`repro.api.PointAccSession` is the verb layer on top of this state.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Union

import jax.numpy as jnp
import numpy as np

from repro.core import fusion as FU
from repro.core import mapping as M

CloudEntry = Union[M.PointCloud, M.SortedCloud]


def geometry_digest(arrays, extra=None) -> bytes:
    """16-byte blake2b identity of a geometry.

    Hashes each array's shape/dtype tag + raw bytes; `extra` (any
    repr-able static metadata — bucket capacity, entry-point tag, stride)
    is folded in so identical coordinates cached under different serving
    shapes never collide.  This is the key the serving caches speak: the
    session's `MappingCache` stores one scene's level pyramid under it,
    and the serve scheduler's `AssemblyCache` keys a whole micro-batch by
    the *ordered tuple* of its scenes' digests (composition key).
    """
    h = hashlib.blake2b(digest_size=16)
    if extra is not None:
        h.update(repr(extra).encode())
    for a in arrays:
        a = np.asarray(a)
        h.update(str((a.shape, a.dtype)).encode())
        h.update(a.tobytes())
    return h.digest()


def infer_kernel_size(k: int, ndim: int) -> int:
    """Weight tensors are (K, Cin, Cout) with K = kernel_size**ndim; the
    frontend recovers kernel_size so callers don't repeat it."""
    ks = round(k ** (1.0 / ndim))
    for cand in (ks - 1, ks, ks + 1):
        if cand >= 1 and cand ** ndim == k:
            return cand
    raise ValueError(
        f"cannot infer kernel_size: {k} weight offsets is not a perfect "
        f"{ndim}-th power; pass kernel_size explicitly")


class MapContext:
    """Mapping-Unit state for one geometry, shared by every SparseTensor
    derived from it.

    clouds  : stride -> SortedCloud (v2) or PointCloud (v1)
    maps    : (kernel_size, in_stride, out_stride) -> KernelMaps
    plans   : conv-site shape -> core.fusion.ConvFusionPlan

    The (kernel_size, in_stride, out_stride) key is also the stride-pair
    table for transposed convs: an up-conv from `out_stride` back to
    `in_stride` finds the forward maps under the same key and swaps them
    (`transposed_maps`), inheriting the scatter-free inverse table when
    the v2 engine built them.
    """

    def __init__(self, engine: str | None = None, cap: int | None = None):
        if engine not in (None, "v1", "v2"):
            raise ValueError(f"unknown mapping engine {engine!r}")
        self.engine = engine
        self.cap = cap
        self.clouds: dict[int, CloudEntry] = {}
        self.maps: dict[tuple[int, int, int], M.KernelMaps] = {}
        self.plans: dict[tuple, FU.ConvFusionPlan] = {}

    # -- clouds -----------------------------------------------------------

    def register_cloud(self, stride: int, cloud: CloudEntry,
                       overwrite: bool = False) -> None:
        """Install a cloud at a stride level (no-op if one is present)."""
        pc = cloud.pc if isinstance(cloud, M.SortedCloud) else cloud
        if self.engine is None:
            self.engine = "v2" if pc.ndim_spatial == 3 else "v1"
        if overwrite or stride not in self.clouds:
            self.clouds[stride] = cloud

    def point_cloud(self, stride: int) -> M.PointCloud:
        entry = self.clouds[stride]
        return entry.pc if isinstance(entry, M.SortedCloud) else entry

    def sorted_cloud(self, stride: int) -> M.SortedCloud:
        """The stride level's ranking cache; sorts once on first demand."""
        entry = self.clouds[stride]
        if not isinstance(entry, M.SortedCloud):
            entry = M.sort_cloud(entry)
            self.clouds[stride] = entry
        return entry

    def down_cloud(self, in_stride: int, factor: int) -> M.PointCloud:
        """Output cloud of a strided conv (memoized per stride level)."""
        target = in_stride * factor
        if target not in self.clouds:
            if self.engine == "v2":
                self.clouds[target] = M.downsample_sorted(
                    self.sorted_cloud(in_stride), factor)
            else:
                self.clouds[target] = M.downsample(
                    self.point_cloud(in_stride), factor)
        return self.point_cloud(target)

    # -- kernel maps ------------------------------------------------------

    def conv_maps(self, kernel_size: int, in_stride: int,
                  factor: int = 1) -> tuple[M.KernelMaps, M.PointCloud]:
        """Maps + output cloud for a (possibly strided) conv, memoized.

        v2: binary searches against the level's SortedCloud; strided maps
        additionally carry the swapped inverse table (`inv_t`) so the
        matching transposed conv stays scatter-free.  v1: per-offset
        lexicographic merge-sort (any spatial dimensionality).
        """
        out_stride = in_stride * factor
        key = (kernel_size, in_stride, out_stride)
        if key in self.maps:
            return self.maps[key], self.point_cloud(out_stride)
        if self.engine == "v2":
            sc = self.sorted_cloud(in_stride)
            if factor == 1:
                out_sc = sc
            else:
                self.down_cloud(in_stride, factor)
                out_sc = self.sorted_cloud(out_stride)
            maps, _ = M.build_conv_maps_cached(sc, kernel_size, factor,
                                               cap=self.cap, out_sc=out_sc)
        else:
            in_pc = self.point_cloud(in_stride)
            out_pc = in_pc if factor == 1 else self.down_cloud(in_stride,
                                                               factor)
            maps = M.kernel_map(in_pc, out_pc, kernel_size, cap=self.cap)
        self.maps[key] = maps
        return maps, self.point_cloud(out_stride)

    def transposed_maps(self, kernel_size: int, coarse_stride: int,
                        factor: int) -> tuple[M.KernelMaps, M.PointCloud]:
        """Swapped maps for an up-conv from `coarse_stride` back to the
        finer level, found by stride-pair lookup of the forward maps.

        MinkowskiEngine semantics: upsampling is the inverse of the
        corresponding downsampling, so the fine output cloud must already
        exist — raise a clear error instead of inventing one.
        """
        if factor < 1 or coarse_stride % factor:
            raise ValueError(
                f"transposed stride {factor} does not divide the input "
                f"stride {coarse_stride}")
        fine_stride = coarse_stride // factor
        key = (kernel_size, fine_stride, coarse_stride)
        if key not in self.maps:
            built = sorted(self.maps) or "none"
            raise ValueError(
                f"no forward maps for stride pair {fine_stride}->"
                f"{coarse_stride} at kernel_size {kernel_size}: a "
                f"transposed conv reuses the encoder's strided maps "
                f"swapped, so the forward conv must run through this "
                f"context first (maps built so far: {built})")
        return self.maps[key].swap(), self.point_cloud(fine_stride)

    # -- fusion plans -----------------------------------------------------

    def plan(self, n_in: int, cin: int, cout: int, k: int, *,
             residual: bool = False,
             budget_bytes: int | None = None) -> FU.ConvFusionPlan:
        """Memoized `core.fusion.plan_conv_epilogue` for one conv site."""
        budget = budget_bytes or FU.DEFAULT_ONCHIP_BUDGET_BYTES
        key = (n_in, cin, cout, k, residual, budget)
        if key not in self.plans:
            self.plans[key] = FU.plan_conv_epilogue(
                n_in, cin, cout, k, residual=residual, budget_bytes=budget)
        return self.plans[key]


@dataclasses.dataclass(frozen=True)
class SparseTensor:
    """Features + masked voxel cloud + tensor stride + shared MapContext.

    `feats` rows align with `coords`/`mask` rows; invalid rows carry the
    coordinate sentinel and zero features.  Derivative tensors produced by
    convs share the same context, so the whole network reuses one
    geometry's mapping work.
    """

    feats: jnp.ndarray          # (N, C)
    coords: jnp.ndarray         # (N, 1+D) int32, sentinel-filled
    mask: jnp.ndarray           # (N,) bool
    stride: int = 1
    context: MapContext = dataclasses.field(default_factory=MapContext,
                                            repr=False, compare=False)

    @property
    def pc(self) -> M.PointCloud:
        return M.PointCloud(self.coords, self.mask, self.stride)

    @property
    def capacity(self) -> int:
        return self.coords.shape[0]

    @property
    def ndim_spatial(self) -> int:
        return self.coords.shape[1] - 1

    @property
    def num_channels(self) -> int:
        return self.feats.shape[-1]

    def num_valid(self) -> jnp.ndarray:
        return jnp.sum(self.mask.astype(jnp.int32))

    def with_feats(self, feats: jnp.ndarray) -> "SparseTensor":
        """Same geometry (and context), new features."""
        return dataclasses.replace(self, feats=feats)

    def padded_to(self, capacity: int) -> "SparseTensor":
        """Row-pad the tensor up to a serving-bucket capacity.

        Padding rows carry SENTINEL coordinates, a False mask and zero
        features, so they sort to the end of the ranking structure and
        never enter a kernel map — valid-row outputs are unchanged.  The
        padded tensor starts a fresh MapContext (same engine/cap policy):
        cached maps are capacity-shaped and cannot be reused.
        """
        if capacity < self.capacity:
            raise ValueError(
                f"cannot pad a capacity-{self.capacity} tensor down to "
                f"{capacity}; buckets only grow")
        if capacity == self.capacity:
            return self
        pad = capacity - self.capacity
        coords = jnp.concatenate(
            [self.coords,
             jnp.full((pad, self.coords.shape[1]), M.SENTINEL, jnp.int32)])
        mask = jnp.concatenate([self.mask, jnp.zeros(pad, bool)])
        feats = jnp.concatenate(
            [self.feats, jnp.zeros((pad,) + self.feats.shape[1:],
                                   self.feats.dtype)])
        ctx = MapContext(engine=self.context.engine, cap=self.context.cap)
        pc = M.PointCloud(coords, mask, self.stride)
        ctx.register_cloud(self.stride, pc)
        return SparseTensor(feats, coords, mask, self.stride, ctx)


def from_point_cloud(pc: M.PointCloud, feats: jnp.ndarray,
                     context: MapContext | None = None) -> SparseTensor:
    """Wrap an existing PointCloud (already sentinel-filled) + features."""
    ctx = context if context is not None else MapContext()
    ctx.register_cloud(pc.stride, pc)
    return SparseTensor(feats, pc.coords, pc.mask, pc.stride, ctx)
