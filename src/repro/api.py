"""PointAccSession: one frontend over mapping, conv flows, fusion planning,
and the cross-request serving cache.

PointAcc's value is the *composition* — ranking-based mapping, streamed
sparse conv, and temporal fusion behind one accelerator interface.  This
module is that interface for the reproduction:

    from repro.api import PointAccSession

    session = PointAccSession(flow="pallas_fused")
    x = session.tensor(coords, mask, feats)          # SparseTensor
    h = session.conv(x, w_subm)                      # submanifold 3^3 conv
    h = session.conv(h, w_down, stride=2)            # strided down conv
    y = session.conv_transposed(h, w_up, stride=2)   # decoder up conv

The session owns the *policy* (mapping engine, computation flow, VMEM
budget for the fusion planner, serving-cache bound); the tensor's
`MapContext` (repro.core.tensor) owns the per-geometry *state* (sorted
clouds, kernel maps, fusion plans).  Transposed convs find their swapped
inverse maps by stride-pair lookup in the context — no caller
bookkeeping — and `MappingCache` reuses whole map pyramids across
requests with identical geometry (digest-keyed, LRU-bounded).

The dense mapping ops the PointNet-family heads need (FPS / kNN / ball
query — all ranking-based, paper Table 1) are exposed on the session too,
so one object fronts every Mapping Unit operation.
"""

from __future__ import annotations

import dataclasses
import warnings
from collections import OrderedDict
from typing import Any, Callable

import jax.numpy as jnp

from repro.core import mapping as M
from repro.core import pointops as P
from repro.core import sparseconv as SC
from repro.core.tensor import (MapContext, SparseTensor, geometry_digest,
                               infer_kernel_size)

FLOWS = ("gms", "fod", "pallas", "pallas_fused")


@dataclasses.dataclass(frozen=True)
class SessionConfig:
    """Session-level policy, threaded to every conv the session runs.

    flow         : computation flow for every conv (see core.sparseconv).
    engine       : mapping engine ("v2" packed keys / "v1" merge-sort /
                   None = v2 for 3-D clouds, v1 otherwise).
    fused_budget : VMEM bytes the temporal-fusion planner may spend per
                   conv site (None = core.fusion default).
    cap          : optional map capacity override (expert knob; the
                   default covers every match).
    cache_entries: LRU bound for the cross-request MappingCache.
    """

    flow: str = "fod"
    engine: str | None = None
    fused_budget: int | None = None
    cap: int | None = None
    cache_entries: int = 32

    def __post_init__(self):
        if self.flow not in FLOWS:
            raise ValueError(f"unknown flow {self.flow!r}; one of {FLOWS}")
        if self.engine not in (None, "v1", "v2"):
            raise ValueError(f"unknown engine {self.engine!r}")


class _LruCache:
    """Shared LRU mechanics (store / touch / evict / counters) behind the
    serving caches — `MappingCache` keys per-scene pyramids, the serve
    scheduler's `AssemblyCache` keys whole stacked micro-batches."""

    def __init__(self, max_entries: int):
        if max_entries < 1:
            raise ValueError(
                f"{type(self).__name__} needs max_entries >= 1")
        self.max_entries = max_entries
        self._store: OrderedDict[Any, Any] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def _lookup(self, key):
        """(value, found) with hit/miss accounting and LRU touch."""
        if key in self._store:
            self.hits += 1
            self._store.move_to_end(key)
            return self._store[key], True
        self.misses += 1
        return None, False

    def _insert(self, key, value) -> None:
        self._store[key] = value
        while len(self._store) > self.max_entries:
            self._store.popitem(last=False)
            self.evictions += 1

    def __len__(self) -> int:
        return len(self._store)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "hit_rate": self.hit_rate, "evictions": self.evictions,
                "entries": len(self._store),
                "max_entries": self.max_entries}


class MappingCache(_LruCache):
    """LRU-bounded, digest-keyed reuse of Mapping-Unit work across requests.

    The Mapping Unit's output depends only on the coordinates, not the
    features, so repeated geometry — a parked scanner, multi-sweep
    aggregation, re-scored frames — is served from cache: one cheap
    blake2b over the coordinate bytes decides whether the ranking sort +
    binary searches run at all (~microseconds vs ~tens of ms).

    Values are whatever the builder returns (typically a jit-built level
    pyramid of concrete arrays).  Hit/miss/eviction counters are exposed
    for serving telemetry; eviction is least-recently-used.
    """

    def __init__(self, max_entries: int = 32):
        super().__init__(max_entries)

    @staticmethod
    def digest(arrays, extra=None) -> bytes:
        """Digest of the geometry bytes (`core.tensor.geometry_digest`);
        `extra` (any repr-able static metadata — bucket capacity,
        entry-point tag, ladder id) is folded into the key so the same
        coordinates padded into different serving buckets, or cached by
        different entry points, never collide."""
        return geometry_digest(arrays, extra)

    def get_by_key(self, key: bytes, build: Callable[[], Any]):
        """(value, hit) for a precomputed digest key; `build()` runs only
        on a miss.  Callers that already hashed the geometry (the serve
        scheduler hashes every admitted scene once for its composition
        keys) use this to avoid digesting the same bytes twice."""
        value, found = self._lookup(key)
        if found:
            return value, True
        value = build()
        self._insert(key, value)
        return value, False

    def get(self, key_arrays, build: Callable[[], Any], extra=None):
        """(value, hit) for the geometry identified by `key_arrays` (+
        optional static `extra` metadata, e.g. the serving bucket);
        `build()` runs only on a miss."""
        return self.get_by_key(self.digest(key_arrays, extra), build)


class AssemblyCache(_LruCache):
    """Composition-keyed reuse of *stacked* micro-batch pyramids.

    The serve scheduler stacks per-scene level pyramids into one
    (max_batch, ...) pytree per micro-batch.  On hot loops the SAME
    ordered composition recurs — a replayed stream, a parked sensor rig,
    re-scored frames — so the stacked result is cached under the ordered
    tuple of per-scene pyramid digests (plus bucket capacity, micro-batch
    width and dummy-tail length).  A hit skips the whole
    `tree_map`/`stack` pass AND the per-scene mapping-cache lookups under
    it: the micro-batch assembly cost drops to one tuple lookup.

    Same LRU discipline as `MappingCache`; the eviction counter lets
    serving telemetry tell cache churn (bound too small for the
    composition working set) from cold misses.
    """

    def __init__(self, max_entries: int = 16):
        super().__init__(max_entries)

    def lookup(self, key):
        """The cached stacked pytree for a composition key, or None (the
        miss is counted; the caller assembles and `put`s)."""
        value, found = self._lookup(key)
        return value if found else None

    def put(self, key, value) -> None:
        self._insert(key, value)


class PointAccSession:
    """The accelerator frontend: conv verbs + mapping ops + serving cache.

    One session serves many geometries; each `tensor(...)` call starts (or
    adopts) a `MapContext` holding that geometry's mapping state.  The
    session holds only policy (`SessionConfig`) and the cross-request
    `MappingCache`, so it is safe to share across requests.
    """

    def __init__(self, flow: str = "fod", engine: str | None = None,
                 fused_budget: int | None = None, cap: int | None = None,
                 cache_entries: int = 32,
                 config: SessionConfig | None = None):
        self.config = config or SessionConfig(
            flow=flow, engine=engine, fused_budget=fused_budget, cap=cap,
            cache_entries=cache_entries)
        self.maps_cache = MappingCache(self.config.cache_entries)

    # -- tensors ----------------------------------------------------------

    def tensor(self, coords: jnp.ndarray, mask: jnp.ndarray,
               feats: jnp.ndarray, stride: int = 1,
               context: MapContext | None = None) -> SparseTensor:
        """Wrap raw (coords, mask, feats) into a SparseTensor.

        Sentinel-fills invalid rows (like `mapping.make_point_cloud`) and
        attaches a fresh MapContext configured from the session — or an
        existing one (e.g. rebuilt from a cached level pyramid)."""
        pc = M.make_point_cloud(coords, mask, stride)
        ctx = context if context is not None else MapContext(
            engine=self.config.engine, cap=self.config.cap)
        ctx.register_cloud(stride, pc)
        return SparseTensor(feats, pc.coords, pc.mask, stride, ctx)

    def out_cloud(self, x: SparseTensor, stride: int = 1) -> M.PointCloud:
        """The output cloud a conv at `stride` writes to (memoized); lets
        callers build epilogues that need the output mask up front."""
        if stride == 1:
            return x.pc
        return x.context.down_cloud(x.stride, stride)

    def canonicalized(self, x: SparseTensor):
        """(x', order): rows permuted into packed-key order, reusing the
        context's ranking sort (no extra `lax.sort`).

        The streamed fused kernel wants key-sorted rows so inverse tables
        are monotone per offset and cache-block windows stay tight; the
        permuted cloud's SortedCloud is seeded for free (identity perm).
        Restore original row order with `zeros.at[order].set(out)`.
        Returns (x, None) when the packed engine doesn't apply (v1 / D!=3).
        """
        if x.context.engine != "v2" or x.ndim_spatial != 3:
            return x, None
        sc = x.context.sorted_cloud(x.stride)
        order = sc.perm
        coords = jnp.take(x.coords, order, axis=0)
        mask = jnp.take(x.mask, order)
        feats = jnp.take(x.feats, order, axis=0)
        pc = M.PointCloud(coords, mask, x.stride)
        ctx = MapContext(engine="v2", cap=x.context.cap)
        ctx.register_cloud(x.stride, M.SortedCloud(
            pc, sc.sorted_hi, sc.sorted_lo,
            jnp.arange(x.capacity, dtype=jnp.int32)))
        return SparseTensor(feats, coords, mask, x.stride, ctx), order

    # -- convolution ------------------------------------------------------

    def conv(self, x: SparseTensor, weights: jnp.ndarray, stride: int = 1,
             *, epilogue: SC.Epilogue | None = None,
             kernel_size: int | None = None) -> SparseTensor:
        """One sparse conv through the session's flow.

        kernel_size is inferred from the weight tensor's offset count when
        not given.  With an epilogue the caller owns masking
        (Epilogue.mask); without one invalid output rows are zeroed."""
        ks = kernel_size if kernel_size is not None else \
            infer_kernel_size(weights.shape[0], x.ndim_spatial)
        maps, out_pc = x.context.conv_maps(ks, x.stride, stride)
        return self._apply_conv(x, maps, out_pc, weights, epilogue,
                                x.stride * stride)

    def conv_transposed(self, x: SparseTensor, weights: jnp.ndarray,
                        stride: int = 2, *,
                        epilogue: SC.Epilogue | None = None,
                        kernel_size: int | None = None) -> SparseTensor:
        """Transposed (up-sampling) conv onto the cached finer cloud.

        The swapped maps come from the context's stride-pair lookup — the
        forward strided conv must have run through this context (a clear
        error explains the fix otherwise).  v2-built maps keep the
        scatter-free Pallas path; v1/capped maps fall back to a
        scatter-built inverse with a warning (see
        `sparseconv.sparse_conv_transposed`)."""
        ks = kernel_size if kernel_size is not None else \
            infer_kernel_size(weights.shape[0], x.ndim_spatial)
        maps, out_pc = x.context.transposed_maps(ks, x.stride, stride)
        if self.config.flow in ("pallas", "pallas_fused") \
                and maps.inv is None:
            warnings.warn(
                "transposed conv on maps without an inverse table (built "
                "with engine='v1' or an explicit cap): the Pallas flow "
                "falls back to a scatter-built inverse — rebuild with "
                "engine='v2' for the scatter-free path", stacklevel=2)
        new_stride = x.stride // stride if stride > 1 else x.stride
        return self._apply_conv(x, maps, out_pc, weights, epilogue,
                                new_stride)

    def _apply_conv(self, x: SparseTensor, maps, out_pc, weights,
                    epilogue: SC.Epilogue | None,
                    new_stride: int) -> SparseTensor:
        """Shared conv body: flow dispatch, fusion plan, masking rule."""
        out = SC.sparse_conv_apply(
            x.feats, maps, weights, out_pc.capacity, self.config.flow,
            epilogue=epilogue,
            plan=self._plan(x.context, x.feats.shape[0], weights, epilogue))
        if epilogue is None:
            out = out * out_pc.mask[:, None]
        return SparseTensor(out, out_pc.coords, out_pc.mask, new_stride,
                            x.context)

    def _plan(self, ctx: MapContext, n_in: int, weights,
              epilogue: SC.Epilogue | None):
        """Fusion-planner hook: only the fused Pallas flow consults it."""
        if self.config.flow != "pallas_fused":
            return None
        residual = epilogue is not None and epilogue.residual is not None
        return ctx.plan(n_in, weights.shape[1], weights.shape[2],
                        weights.shape[0], residual=residual,
                        budget_bytes=self.config.fused_budget)

    # -- dense mapping ops (PointNet-family heads) ------------------------

    @staticmethod
    def fps(xyz, mask, n_samples: int):
        """Farthest-point sampling (Max ranking, paper Table 1)."""
        return P.farthest_point_sampling(xyz, mask, n_samples)

    @staticmethod
    def knn(query_xyz, query_mask, ref_xyz, ref_mask, k: int, **kw):
        """k-nearest-neighbours (TopK ranking)."""
        return P.knn(query_xyz, query_mask, ref_xyz, ref_mask, k, **kw)

    @staticmethod
    def ball_query(query_xyz, query_mask, ref_xyz, ref_mask,
                   radius: float, k: int):
        """Ball query (TopK ranking over clipped distances)."""
        return P.ball_query(query_xyz, query_mask, ref_xyz, ref_mask,
                            radius, k)

    # -- serving ----------------------------------------------------------

    def cache_stats(self) -> dict:
        return self.maps_cache.stats()


# re-exported for frontend completeness: sessions hand these to conv()
Epilogue = SC.Epilogue

__all__ = ["FLOWS", "AssemblyCache", "MappingCache", "PointAccSession",
           "SessionConfig", "SparseTensor", "MapContext", "Epilogue"]
