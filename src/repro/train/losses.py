"""Losses.  `chunked_cross_entropy` never materialises the full (T, V)
logits tensor: the LM head matmul + logsumexp run per sequence chunk inside
a rematerialised scan.  At gemma2 scale (256k vocab) this cuts peak logits
memory by the chunk count (16x default) — a beyond-paper memory
optimization recorded in EXPERIMENTS.md §Perf."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None):
    """logits (B, S, V), labels (B, S) -> (mean_loss, n_tokens)."""
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    mask = mask.astype(jnp.float32)
    n = jnp.maximum(jnp.sum(mask), 1.0)
    return -jnp.sum(ll * mask) / n, n


def chunked_cross_entropy(hidden: jnp.ndarray, head_w: jnp.ndarray,
                          labels: jnp.ndarray,
                          mask: Optional[jnp.ndarray] = None,
                          softcap: Optional[float] = None,
                          n_chunks: int = 16, transpose_head: bool = False):
    """hidden (B, S, D); head_w (D, V) (or (V, D) with transpose_head for
    tied embeddings); labels (B, S).

    The per-chunk body is jax.checkpoint'ed, so backward recomputes each
    chunk's logits instead of keeping them live.
    """
    b, s, d = hidden.shape
    if s % n_chunks != 0:
        n_chunks = 1
    c = s // n_chunks
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    mask = mask.astype(jnp.float32)

    h_c = hidden.reshape(b, n_chunks, c, d).transpose(1, 0, 2, 3)
    l_c = labels.reshape(b, n_chunks, c).transpose(1, 0, 2)
    m_c = mask.reshape(b, n_chunks, c).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_loss(h, lbl, m):
        logits = jnp.einsum("bcd,dv->bcv", h,
                            head_w.T if transpose_head else head_w)
        logits = logits.astype(jnp.float32)
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, lbl[..., None], -1)[..., 0]
        return jnp.sum((lse - picked) * m)

    from repro import costmode
    if costmode.enabled():       # unrolled for exact cost accounting
        total = jnp.zeros((), jnp.float32)
        for i in range(n_chunks):
            total = total + chunk_loss(h_c[i], l_c[i], m_c[i])
    else:
        def step(acc, xs):
            h, lbl, m = xs
            return acc + chunk_loss(h, lbl, m), None
        total, _ = lax.scan(step, jnp.zeros((), jnp.float32),
                            (h_c, l_c, m_c))
    n = jnp.maximum(jnp.sum(mask), 1.0)
    return total / n, n


def zloss(logits: jnp.ndarray, weight: float = 1e-4):
    """Router/logit z-loss regulariser (optional)."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    return weight * jnp.mean(lse ** 2)
