"""The distributed train step: mixed precision, remat, grad accumulation,
chunked CE, sharded via the logical-axis rules.

make_train_step(...) returns a jit-able pure function
    train_step(params, opt_state, batch) -> (params, opt_state, metrics)
whose in/out shardings are produced alongside (for pjit + the dry-run).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro import nn
from repro.distributed import sharding as SH
from repro.models.registry import Model
from repro.train import losses as LO
from repro.train import optim as OPT


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    accum_steps: int = 1
    use_chunked_ce: bool = True
    ce_chunks: int = 16
    aux_weight: float = 0.01       # MoE load-balance loss weight
    # cast gradients before the DP reduction (§Perf H2): halves the
    # all-reduce/reduce-scatter wire bytes; AdamW still accumulates in f32
    grad_reduce_dtype: Any = None


def make_loss_fn(model: Model, tc: TrainConfig, shard=None, mesh=None):
    cfg = model.cfg
    shard = shard or (lambda x, names: x)

    def loss_fn(params, batch):
        cparams = nn.cast_floating(params, tc.compute_dtype)
        labels = batch["labels"]
        mask = batch.get("loss_mask")
        if tc.use_chunked_ce and cfg.vocab_size >= 8192:
            hidden, aux = model.train_hidden(cparams, batch, shard=shard,
                                             mesh=mesh, remat=tc.remat)
            # keep the backbone's backward pass in the compute dtype
            hidden = nn.cotangent_cast(hidden, tc.compute_dtype)
            head_w, transpose, softcap = model.head_info(cparams)
            loss, n = LO.chunked_cross_entropy(
                hidden, head_w, labels, mask=mask, softcap=softcap,
                n_chunks=tc.ce_chunks, transpose_head=transpose)
        else:
            logits, aux = model.train_logits(cparams, batch, shard=shard,
                                             mesh=mesh, remat=tc.remat)
            logits = nn.cotangent_cast(logits, tc.compute_dtype)
            loss, n = LO.cross_entropy(logits, labels, mask=mask)
        total = loss + tc.aux_weight * aux
        return total, {"loss": loss, "aux": aux, "n_tokens": n}

    return loss_fn


def make_train_step(model: Model, tc: TrainConfig,
                    opt_cfg: OPT.AdamWConfig,
                    sc: Optional[SH.ShardingConfig] = None):
    shard = SH.make_shard_fn(sc) if sc is not None else None
    mesh = sc.mesh if sc is not None else None
    loss_fn = make_loss_fn(model, tc, shard=shard, mesh=mesh)

    def train_step(params, opt_state, batch):
        if tc.accum_steps == 1:
            (_, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            if tc.grad_reduce_dtype is not None:
                grads = nn.cast_floating(grads, tc.grad_reduce_dtype)
        else:
            # microbatched gradient accumulation: scan over accum chunks
            def micro(carry, mb):
                g_acc, m_acc = carry
                (_, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
                m_acc = jax.tree_util.tree_map(jnp.add, m_acc, m)
                return (g_acc, m_acc), None

            def split(x):
                a = tc.accum_steps
                return x.reshape((a, x.shape[0] // a) + x.shape[1:])

            mbs = jax.tree_util.tree_map(split, batch)
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            m0 = {"loss": jnp.zeros(()), "aux": jnp.zeros(()),
                  "n_tokens": jnp.zeros(())}
            (grads, metrics), _ = lax.scan(micro, (g0, m0), mbs)
            grads = jax.tree_util.tree_map(
                lambda g: g / tc.accum_steps, grads)
            metrics = {k: v / tc.accum_steps for k, v in metrics.items()}
            metrics["n_tokens"] = metrics["n_tokens"] * tc.accum_steps

        params, opt_state, opt_metrics = OPT.apply_updates(
            params, opt_state, grads, opt_cfg)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step


def train_step_shardings(param_shapes, sc: SH.ShardingConfig):
    """(in_shardings, out_shardings) fragments for jit: params + opt state
    follow the parameter rules; metrics replicated."""
    p_sh = SH.params_shardings(param_shapes, sc)
    opt_sh = OPT.OptState(step=SH.replicated(sc), m=p_sh,
                          v=jax.tree_util.tree_map(lambda s: s, p_sh))
    return p_sh, opt_sh
