"""AdamW + global-norm clipping + schedules, pure JAX pytree functions.

Optimizer state shardings mirror the parameter shardings (m/v inherit the
param's PartitionSpec), so FSDP covers optimizer memory too.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def init(params) -> OptState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros,
                    v=jax.tree_util.tree_map(jnp.copy, zeros))


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * \
        (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def apply_updates(params, opt_state: OptState, grads, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state.m)
    flat_v = treedef.flatten_up_to(opt_state.v)
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(step, new_m, new_v), metrics
