"""Shared LM layers: RoPE/M-RoPE, norms, GQA attention (train/prefill/
decode), gated MLP.  Everything is mode-explicit and cache-functional so the
same code path lowers for train_step, prefill and decode dry-runs.

The `shard` argument threads logical-axis sharding constraints
(distributed/sharding.py) through every layer without coupling model code to
mesh axes; the default is identity (single device).
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro import nn
from repro.configs.base import ArchConfig
from repro.kernels.flash_attention.ref import attention_ref


def _identity_shard(x, names):
    return x


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_init(cfg: ArchConfig, d: int) -> nn.Params:
    return nn.layernorm_init(d) if cfg.norm == "layernorm" \
        else nn.rmsnorm_init(d)


def norm_apply(cfg: ArchConfig, p: nn.Params, x: jnp.ndarray) -> jnp.ndarray:
    return nn.layernorm(p, x) if cfg.norm == "layernorm" else nn.rmsnorm(p, x)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def _rope_angles(positions: jnp.ndarray, head_dim: int, theta: float,
                 mrope_sections=None) -> jnp.ndarray:
    """positions (B, S) or (B, S, 3) -> angles (B, S, head_dim//2).

    M-RoPE (qwen2-vl): the inv-freq spectrum is partitioned into sections,
    each driven by one of the (t, h, w) position ids.
    """
    half = head_dim // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32)
                                / half * 2.0 + 0.0))
    if positions.ndim == 2:
        return positions[..., None].astype(jnp.float32) * inv_freq
    # M-RoPE: (B, S, 3)
    assert mrope_sections is not None and sum(mrope_sections) == half
    parts, start = [], 0
    for axis, sec in enumerate(mrope_sections):
        p = positions[..., axis].astype(jnp.float32)
        parts.append(p[..., None] * inv_freq[start:start + sec])
        start += sec
    return jnp.concatenate(parts, axis=-1)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               mrope_sections=None) -> jnp.ndarray:
    """x (B, S, H, head_dim); split-halves rotation convention."""
    half = x.shape[-1] // 2
    ang = _rope_angles(positions, x.shape[-1], theta, mrope_sections)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jnp.ndarray   # (B, S_max, Hkv, head_dim)
    v: jnp.ndarray


def attention_init(key, cfg: ArchConfig) -> nn.Params:
    d, h, hkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": nn.dense_init(ks[0], d, h * hd, use_bias=cfg.qkv_bias),
        "wk": nn.dense_init(ks[1], d, hkv * hd, use_bias=cfg.qkv_bias),
        "wv": nn.dense_init(ks[2], d, hkv * hd, use_bias=cfg.qkv_bias),
        "wo": nn.dense_init(ks[3], h * hd, d, use_bias=False),
    }


def _decode_attention(q, cache: KVCache, valid, softcap, scale):
    """q (B, 1, H, hd) against a cache with an explicit (B, S) validity
    mask.  Flash-decoding-style: when the cache's S dim is sharded
    (long_500k), XLA-SPMD turns the softmax reductions into cross-shard
    collectives."""
    b, _, h, hd = q.shape
    hkv = cache.k.shape[2]
    g = h // hkv
    qg = q.reshape(b, hkv, g, hd).astype(jnp.float32) * scale
    k = cache.k.astype(jnp.float32)                    # (B, S, Hkv, hd)
    v = cache.v.astype(jnp.float32)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v)
    return out.reshape(b, 1, h * hd).astype(q.dtype)


def attention_apply(p: nn.Params, cfg: ArchConfig, x: jnp.ndarray,
                    positions: jnp.ndarray, *, layer_window: Optional[int],
                    mode: str, cache: Optional[KVCache] = None,
                    cache_pos=None, shard=_identity_shard):
    """x (B, S, D).  mode: train | prefill | decode.

    layer_window resolves the per-layer SWA (gemma2 local/global).
    decode: S == 1, cache_pos (B,) int32 current position.
    Returns (out, new_cache_or_None).
    """
    b, s, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    scale = 1.0 / math.sqrt(hd)

    q = nn.dense(p["wq"], x).reshape(b, s, h, hd)
    k = nn.dense(p["wk"], x).reshape(b, s, hkv, hd)
    v = nn.dense(p["wv"], x).reshape(b, s, hkv, hd)
    mrope = cfg.mrope_sections if cfg.mrope else None
    q = apply_rope(q, positions, cfg.rope_theta, mrope)
    k = apply_rope(k, positions, cfg.rope_theta, mrope)
    q = shard(q, ("batch", "seq", "heads", "head_dim"))
    k = shard(k, ("batch", "seq", "kv_heads", "head_dim"))

    new_cache = None
    if mode == "decode":
        assert s == 1 and cache is not None
        s_cache = cache.k.shape[1]
        ring = layer_window is not None and s_cache <= layer_window
        # SWA layers keep a ring buffer of exactly `window` slots; rope is
        # applied at absolute positions before caching so rotation-order is
        # irrelevant.
        slot = cache_pos % s_cache if ring else cache_pos
        k_full = jax.vmap(lambda c, u, i: lax.dynamic_update_slice(
            c, u, (i, 0, 0)))(cache.k, k, slot)
        v_full = jax.vmap(lambda c, u, i: lax.dynamic_update_slice(
            c, u, (i, 0, 0)))(cache.v, v, slot)
        new_cache = KVCache(k_full, v_full)
        kpos = jnp.arange(s_cache)[None, :]            # (1, S)
        if ring:
            # absolute position held by slot j given current write pos
            abs_pos = cache_pos[:, None] - \
                jnp.mod(cache_pos[:, None] - kpos, s_cache)
            valid = abs_pos >= 0
        else:
            valid = kpos <= cache_pos[:, None]
            if layer_window is not None:
                valid &= kpos > cache_pos[:, None] - layer_window
        out = _decode_attention(q, new_cache, valid, cfg.attn_softcap,
                                scale)
    else:
        if mode == "prefill":
            new_cache = KVCache(k, v)
        out = attention_ref(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=True, window=layer_window,
            softcap=cfg.attn_softcap, scale=scale)
        out = out.transpose(0, 2, 1, 3).reshape(b, s, h * hd)

    out = nn.dense(p["wo"], out)
    return shard(out, ("batch", "seq", "d_model")), new_cache


def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16) -> KVCache:
    hd = cfg.resolved_head_dim
    shape = (batch, max_len, cfg.n_kv_heads, hd)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ArchConfig, d_ff: Optional[int] = None) -> nn.Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"wi": nn.dense_init(ks[0], d, f, use_bias=False),
         "wo": nn.dense_init(ks[1], f, d, use_bias=False)}
    if cfg.gated_mlp:
        p["wg"] = nn.dense_init(ks[2], d, f, use_bias=False)
    return p


def mlp_apply(p: nn.Params, cfg: ArchConfig, x: jnp.ndarray,
              shard=_identity_shard) -> jnp.ndarray:
    act = act_fn(cfg.act)
    h = nn.dense(p["wi"], x)
    if "wg" in p:
        h = act(nn.dense(p["wg"], x)) * h
    else:
        h = act(h)
    h = shard(h, ("batch", "seq", "d_ff"))
    return shard(nn.dense(p["wo"], h), ("batch", "seq", "d_model"))
