"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, chunkwise
recurrent form) and sLSTM (scalar memory, sequential scan).

mLSTM is a decayed linear attention with exponential gating and a max
stabiliser.  Both the stabiliser recurrence  m_t = max(m_{t-1} + f_t, i_t)
(a max-plus scan) and the memory recurrence  C_t = a_t C_{t-1} + b_t
are associative, so training/prefill runs as `lax.scan` over sequence chunks
with `lax.associative_scan` inside — the same pattern as the Mamba block,
keeping the transient (chunk, B, H, dk, dv) bounded.

Decode is the O(1) recurrent step on (C, n, m) / sLSTM (c, n, h, m).
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro import nn
from repro.configs.base import ArchConfig


def _identity_shard(x, names):
    return x


class MLSTMState(NamedTuple):
    c: jnp.ndarray    # (B, H, dk, dv)
    n: jnp.ndarray    # (B, H, dk)
    m: jnp.ndarray    # (B, H)


class SLSTMState(NamedTuple):
    c: jnp.ndarray    # (B, D)
    n: jnp.ndarray
    h: jnp.ndarray
    m: jnp.ndarray


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def _maxplus_combine(x, y):
    (a1, b1), (a2, b2) = x, y
    return a1 + a2, jnp.maximum(b1 + a2, b2)


def _linear_combine(x, y):
    (a1, b1), (a2, b2) = x, y
    return a2 * a1, a2 * b1 + b2


def mlstm_cell(q, k, v, i_pre, f_pre, state: Optional[MLSTMState] = None,
               chunk: int = 16):
    """q/k (B,S,H,dk), v (B,S,H,dv), i/f pre-activations (B,S,H).

    Returns h (B,S,H,dv) and the final MLSTMState."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    scale = 1.0 / math.sqrt(dk)
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    i_pre = i_pre.astype(jnp.float32)
    f_pre = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))   # log f in (-inf,0)

    if state is None:
        state = MLSTMState(
            jnp.zeros((b, h, dk, dv), jnp.float32),
            jnp.zeros((b, h, dk), jnp.float32),
            jnp.full((b, h), -1e30, jnp.float32))

    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk

    def to_chunks(x):  # (B,S,...) -> (nc, chunk, B, ...)
        return x.reshape((b, nc, chunk) + x.shape[2:]) \
            .transpose((1, 2, 0) + tuple(range(3, x.ndim + 1)))

    qc, kc, vc = to_chunks(qf), to_chunks(kf), to_chunks(vf)
    ic, fc = to_chunks(i_pre), to_chunks(f_pre)

    @jax.checkpoint
    def step(carry, xs):
        # checkpointed: the (chunk, B, H, dk, dv) kv outer products are
        # recomputed in backward instead of saved per chunk
        C, n, m = carry
        q_i, k_i, v_i, ii, fi = xs                 # (chunk, B, H, ...)
        # stabiliser: m_t = max(m_{t-1} + f_t, i_t)  (max-plus scan)
        fa, ib = lax.associative_scan(_maxplus_combine, (fi, ii), axis=0)
        m_t = jnp.maximum(m[None] + fa, ib)        # (chunk, B, H)
        m_prev = jnp.concatenate([m[None], m_t[:-1]], axis=0)
        f_eff = jnp.exp(fi + m_prev - m_t)         # (chunk, B, H)
        i_eff = jnp.exp(ii - m_t)
        # memory recurrence (linear scan on matrices)
        kv = k_i[..., :, None] * v_i[..., None, :]           # (c,B,H,dk,dv)
        a4 = f_eff[..., None, None]
        b4 = i_eff[..., None, None] * kv
        acum, bcum = lax.associative_scan(_linear_combine, (a4, b4), axis=0)
        C_t = acum * C[None] + bcum                          # (c,B,H,dk,dv)
        a3 = f_eff[..., None]
        b3 = i_eff[..., None] * k_i
        acum3, bcum3 = lax.associative_scan(_linear_combine, (a3, b3),
                                            axis=0)
        n_t = acum3 * n[None] + bcum3                        # (c,B,H,dk)
        # readout
        num = jnp.einsum("cbhd,cbhdv->cbhv", q_i, C_t)
        den = jnp.abs(jnp.einsum("cbhd,cbhd->cbh", q_i, n_t))
        den = jnp.maximum(den, jnp.exp(-m_t))
        h_i = num / den[..., None]
        return (C_t[-1], n_t[-1], m_t[-1]), h_i

    (C, n, m), hs = lax.scan(step, tuple(state), (qc, kc, vc, ic, fc))
    h_out = hs.reshape(nc * chunk, b, h, dv).transpose(1, 0, 2, 3)
    return h_out.astype(q.dtype), MLSTMState(C, n, m)


def mlstm_cell_decode(q, k, v, i_pre, f_pre, state: MLSTMState):
    """Single-step recurrence.  q/k (B,1,H,dk) etc."""
    b, _, h, dk = q.shape
    scale = 1.0 / math.sqrt(dk)
    qf = q[:, 0].astype(jnp.float32) * scale
    kf = k[:, 0].astype(jnp.float32)
    vf = v[:, 0].astype(jnp.float32)
    ii = i_pre[:, 0].astype(jnp.float32)
    ff = jax.nn.log_sigmoid(f_pre[:, 0].astype(jnp.float32))
    m_t = jnp.maximum(state.m + ff, ii)
    f_eff = jnp.exp(ff + state.m - m_t)[..., None, None]
    i_eff = jnp.exp(ii - m_t)[..., None, None]
    C = f_eff * state.c + i_eff * (kf[..., :, None] * vf[..., None, :])
    n = f_eff[..., 0] * state.n + i_eff[..., 0] * kf
    num = jnp.einsum("bhd,bhdv->bhv", qf, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)),
                      jnp.exp(-m_t))
    h_out = (num / den[..., None])[:, None]
    return h_out.astype(q.dtype), MLSTMState(C, n, m_t)


def mlstm_block_init(key, cfg: ArchConfig) -> nn.Params:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    hh = cfg.n_heads
    dk = di // hh
    ks = jax.random.split(key, 6)
    return {
        "up": nn.dense_init(ks[0], d, 2 * di, use_bias=False),
        "wq": nn.dense_init(ks[1], di, di, use_bias=False),
        "wk": nn.dense_init(ks[2], di, di, use_bias=False),
        "wv": nn.dense_init(ks[3], di, di, use_bias=False),
        "wif": nn.dense_init(ks[4], di, 2 * hh, use_bias=True),
        "norm": nn.rmsnorm_init(di),
        "down": nn.dense_init(ks[5], di, d, use_bias=False),
    }


def mlstm_block_apply(p, cfg: ArchConfig, x, *, mode: str,
                      state: Optional[MLSTMState] = None,
                      shard=_identity_shard):
    b, s, d = x.shape
    di = cfg.ssm_expand * d
    hh = cfg.n_heads
    dk = di // hh
    up = nn.dense(p["up"], x)
    xm, z = up[..., :di], up[..., di:]
    xm = shard(xm, ("batch", "seq", "d_inner"))
    q = nn.dense(p["wq"], xm).reshape(b, s, hh, dk)
    k = nn.dense(p["wk"], xm).reshape(b, s, hh, dk)
    v = nn.dense(p["wv"], xm).reshape(b, s, hh, dk)
    gates = nn.dense(p["wif"], xm).reshape(b, s, hh, 2)
    i_pre, f_pre = gates[..., 0], gates[..., 1]
    if mode == "decode":
        h, new_state = mlstm_cell_decode(q, k, v, i_pre, f_pre, state)
    else:
        h, new_state = mlstm_cell(q, k, v, i_pre, f_pre, state=None)
        if mode != "prefill":
            new_state = None
    h = h.reshape(b, s, di)
    h = nn.rmsnorm(p["norm"], h)
    out = nn.dense(p["down"], h * jax.nn.silu(z))
    return shard(out, ("batch", "seq", "d_model")), new_state


def init_mlstm_state(cfg: ArchConfig, batch: int) -> MLSTMState:
    di = cfg.ssm_expand * cfg.d_model
    hh = cfg.n_heads
    dk = di // hh
    return MLSTMState(
        jnp.zeros((batch, hh, dk, dk), jnp.float32),
        jnp.zeros((batch, hh, dk), jnp.float32),
        jnp.full((batch, hh), -1e30, jnp.float32))


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_block_init(key, cfg: ArchConfig) -> nn.Params:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "wx": nn.dense_init(ks[0], d, 4 * d, use_bias=True),   # z i f o
        "wr": nn.dense_init(ks[1], d, 4 * d, use_bias=False),  # recurrent
        "norm": nn.rmsnorm_init(d),
        "proj": nn.dense_init(ks[2], d, d, use_bias=False),
    }


def _slstm_step(p, cfg, x_t, st: SLSTMState):
    d = cfg.d_model
    pre = nn.dense(p["wx"], x_t) + nn.dense(p["wr"], st.h)
    z = jnp.tanh(pre[..., :d])
    i_pre = pre[..., d:2 * d].astype(jnp.float32)
    f_pre = jax.nn.log_sigmoid(pre[..., 2 * d:3 * d].astype(jnp.float32))
    o = jax.nn.sigmoid(pre[..., 3 * d:])
    m_t = jnp.maximum(f_pre + st.m, i_pre)
    i_eff = jnp.exp(i_pre - m_t)
    f_eff = jnp.exp(f_pre + st.m - m_t)
    c = f_eff * st.c + i_eff * z.astype(jnp.float32)
    n = f_eff * st.n + i_eff
    h = o * (c / jnp.maximum(n, 1e-6)).astype(x_t.dtype)
    return SLSTMState(c, n, h, m_t)


def slstm_block_apply(p, cfg: ArchConfig, x, *, mode: str,
                      state: Optional[SLSTMState] = None,
                      shard=_identity_shard):
    b, s, d = x.shape
    if state is None:
        state = init_slstm_state(cfg, b, x.dtype)

    if mode == "decode":
        new_state = _slstm_step(p, cfg, x[:, 0], state)
        h = new_state.h[:, None]
    else:
        def step(st, x_t):
            st2 = _slstm_step(p, cfg, x_t, st)
            return st2, st2.h
        new_state, hs = lax.scan(step, state, x.transpose(1, 0, 2))
        h = hs.transpose(1, 0, 2)
        if mode != "prefill":
            new_state = None
    out = nn.dense(p["proj"], nn.rmsnorm(p["norm"], h))
    return shard(out, ("batch", "seq", "d_model")), new_state


def init_slstm_state(cfg: ArchConfig, batch: int,
                     dtype=jnp.float32) -> SLSTMState:
    d = cfg.d_model
    return SLSTMState(
        jnp.zeros((batch, d), jnp.float32),
        jnp.zeros((batch, d), jnp.float32),
        jnp.zeros((batch, d), dtype),
        jnp.full((batch, d), -1e30, jnp.float32))
