"""MinkowskiUNet-style sparse conv U-Net (paper's MinkNet(i)/(o) benchmark)
plus the Mini-MinkowskiUNet co-design (paper §5.2.2 / Fig. 16).

Structure: submanifold stem -> N encoder stages (stride-2 down conv +
residual blocks) -> N decoder stages (transposed conv back onto the cached
finer cloud + skip concat + residual blocks) -> linear head.

All kernel maps are computed once per resolution level by the Mapping Unit
and shared across every conv at that level (MinkowskiEngine-style map
caching); transposed convs reuse the downsampling maps swapped — both are
PointAcc dataflows.

Every conv carries its epilogue (layernorm / residual / ReLU / row-mask) as
a `core.sparseconv.Epilogue`, so the executor is flow-uniform: the XLA
flows run epilogues as post-ops, while `flow="pallas_fused"` consults the
temporal-fusion planner (core.fusion.plan_conv_epilogue) per conv site and
folds fusable epilogues into the Pallas kernel flush — the paper's §4.2.4
fusion extended from FC chains to the conv trunk.  The fused flow first
re-ranks the input cloud into packed-key order (one extra sort) so every
level's features are key-sorted, inverse tables are monotone per offset,
and the streamed kernel's cache-block windows stay tight; the head output
is scattered back to the caller's row order.
"""

from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp

from repro import nn
from repro.core import fusion as FU
from repro.core import mapping as M
from repro.core import sparseconv as SC


def conv_w_init(key, k: int, c_in: int, c_out: int, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(k * c_in)
    return jax.random.uniform(key, (k, c_in, c_out), dtype, -scale, scale)


def _block_init(key, c_in: int, c_out: int):
    ks = jax.random.split(key, 4)
    p = {
        "conv1": conv_w_init(ks[0], 27, c_in, c_out),
        "n1": nn.layernorm_init(c_out),
        "conv2": conv_w_init(ks[1], 27, c_out, c_out),
        "n2": nn.layernorm_init(c_out),
    }
    if c_in != c_out:
        p["proj"] = nn.dense_init(ks[2], c_in, c_out, use_bias=False)
    return p


def _norm_epilogue(n_params, mask, residual=None):
    """Epilogue of every trunk conv: layernorm -> (+skip) -> ReLU -> mask."""
    return SC.Epilogue(ln_scale=n_params["scale"], ln_bias=n_params["bias"],
                       relu=True, mask=mask, residual=residual)


def _conv_plan(flow, n_in, w, residual=False, budget=None):
    """Planner hook: pick the cache-block size and the fuse/no-fuse decision
    for one conv site (static shapes -> compile-time, like the paper)."""
    if flow != "pallas_fused":
        return None
    return FU.plan_conv_epilogue(
        n_in, w.shape[1], w.shape[2], w.shape[0], residual=residual,
        budget_bytes=budget or FU.DEFAULT_ONCHIP_BUDGET_BYTES)


def _block_apply(p, feats, maps, out_cap, mask, flow, budget=None):
    e1 = _norm_epilogue(p["n1"], mask)
    h = SC.sparse_conv_apply(feats, maps, p["conv1"], out_cap, flow,
                             epilogue=e1,
                             plan=_conv_plan(flow, feats.shape[0],
                                             p["conv1"], budget=budget))
    skip = nn.dense(p["proj"], feats) if "proj" in p else feats
    e2 = _norm_epilogue(p["n2"], mask, residual=skip)
    return SC.sparse_conv_apply(h, maps, p["conv2"], out_cap, flow,
                                epilogue=e2,
                                plan=_conv_plan(flow, h.shape[0], p["conv2"],
                                                residual=True,
                                                budget=budget))


def minkunet_init(key, c_in: int = 4, n_classes: int = 13,
                  stem: int = 32,
                  enc_planes: Sequence[int] = (32, 64, 128, 256),
                  dec_planes: Sequence[int] = (256, 128, 96, 96),
                  blocks_per_stage: int = 2):
    n_stages = len(enc_planes)
    keys = iter(jax.random.split(key, 4 + 4 * n_stages * (blocks_per_stage
                                                          + 1)))
    params = {"stem": conv_w_init(next(keys), 27, c_in, stem),
              "stem_n": nn.layernorm_init(stem)}
    c = stem
    enc = []
    for i, planes in enumerate(enc_planes):
        stage = {"down": conv_w_init(next(keys), 8, c, planes),
                 "down_n": nn.layernorm_init(planes),
                 "blocks": []}
        c = planes
        for _ in range(blocks_per_stage):
            stage["blocks"].append(_block_init(next(keys), c, planes))
        enc.append(stage)
    params["enc"] = enc
    dec = []
    skip_cs = [stem] + list(enc_planes[:-1])
    for i, planes in enumerate(dec_planes):
        stage = {"up": conv_w_init(next(keys), 8, c, planes),
                 "up_n": nn.layernorm_init(planes),
                 "blocks": []}
        c_cat = planes + skip_cs[-(i + 1)]
        cb = c_cat
        for _ in range(blocks_per_stage):
            stage["blocks"].append(_block_init(next(keys), cb, planes))
            cb = planes
        dec.append(stage)
        c = planes
    params["dec"] = dec
    params["head"] = nn.dense_init(next(keys), c, n_classes)
    return params


def build_unet_maps(pc: M.PointCloud, n_stages: int,
                    engine: str | None = None):
    """Mapping-Unit pass: clouds + kernel maps for every resolution level.

    Returns per-level dicts with the submanifold (k=3) maps, the stride-2
    down maps into the next level, and the level's point cloud.  Decoder
    reuses `down` swapped.

    With the packed-key engine (default) each level's cloud is ranked
    exactly ONCE: the level's SortedCloud serves its 27 submanifold offsets
    AND the 8 down-conv offsets, and `downsample_sorted` hands the next
    level its cloud already sorted — one `lax.sort` per stride level for the
    entire network, every conv afterwards is binary search.
    """
    resolved = engine or M.DEFAULT_ENGINE
    levels = []
    if resolved == "v2" and pc.ndim_spatial == 3:
        sc = M.sort_cloud(pc)
        for i in range(n_stages + 1):
            subm, _ = M.build_conv_maps_cached(sc, kernel_size=3, stride=1)
            level = {"pc": sc.pc, "cloud": sc, "subm": subm}
            if i < n_stages:
                down, nxt = M.build_conv_maps_cached(sc, kernel_size=2,
                                                     stride=2)
                level["down"] = down
                sc = nxt
            levels.append(level)
        return levels
    cur = pc
    for i in range(n_stages + 1):
        subm, _ = M.build_conv_maps(cur, kernel_size=3, stride=1,
                                    engine=engine)
        level = {"pc": cur, "subm": subm}
        if i < n_stages:
            down, nxt = M.build_conv_maps(cur, kernel_size=2, stride=2,
                                          engine=engine)
            level["down"] = down
            cur = nxt
        levels.append(level)
    return levels


def minkunet_apply(params, pc: M.PointCloud, feats: jnp.ndarray,
                   flow: str = "fod", levels=None,
                   fused_budget: int | None = None):
    """Forward pass.  flow="pallas_fused" runs the temporal-fusion fast
    path: features re-ranked once into packed-key order, every conv through
    the streamed fused-epilogue Pallas kernel (cache-block sizes from the
    fusion planner under `fused_budget` bytes of VMEM), decoder up-convs on
    the swapped inverse tables.  Pass precomputed `levels` (with a
    key-sorted cloud for best streaming locality) to skip map building."""
    n_stages = len(params["enc"])
    reorder = flow == "pallas_fused" and levels is None
    if reorder:
        # canonicalise once: the whole network runs in packed-key order so
        # the streamed kernel's windows are tight at every level
        order = M.sort_cloud(pc).perm
        pc = M.PointCloud(jnp.take(pc.coords, order, axis=0),
                          jnp.take(pc.mask, order), pc.stride)
        feats = jnp.take(feats, order, axis=0)
    if levels is None:
        levels = build_unet_maps(pc, n_stages)

    l0 = levels[0]
    h = SC.sparse_conv_apply(
        feats, l0["subm"], params["stem"], l0["pc"].capacity, flow,
        epilogue=_norm_epilogue(params["stem_n"], l0["pc"].mask),
        plan=_conv_plan(flow, feats.shape[0], params["stem"],
                        budget=fused_budget))

    skips = [h]
    for i, stage in enumerate(params["enc"]):
        lvl, nxt = levels[i], levels[i + 1]
        h = SC.sparse_conv_apply(
            h, lvl["down"], stage["down"], nxt["pc"].capacity, flow,
            epilogue=_norm_epilogue(stage["down_n"], nxt["pc"].mask),
            plan=_conv_plan(flow, h.shape[0], stage["down"],
                            budget=fused_budget))
        for b in stage["blocks"]:
            h = _block_apply(b, h, nxt["subm"], nxt["pc"].capacity,
                             nxt["pc"].mask, flow, budget=fused_budget)
        skips.append(h)

    for i, stage in enumerate(params["dec"]):
        lvl = levels[n_stages - 1 - i]          # target (finer) level
        h = SC.sparse_conv_transposed(
            h, lvl["down"], lvl["pc"], stage["up"], flow,
            epilogue=_norm_epilogue(stage["up_n"], lvl["pc"].mask),
            plan=_conv_plan(flow, h.shape[0], stage["up"],
                            budget=fused_budget))
        h = jnp.concatenate([h, skips[n_stages - 1 - i]], axis=-1)
        for b in stage["blocks"]:
            h = _block_apply(b, h, lvl["subm"], lvl["pc"].capacity,
                             lvl["pc"].mask, flow, budget=fused_budget)

    out = nn.dense(params["head"], h) * pc.mask[:, None]
    if reorder:
        out = jnp.zeros_like(out).at[order].set(out)
    return out


def epilogue_dram_bytes(params, levels, fused: bool) -> int:
    """Fig.-20-style DRAM model for the conv epilogues of one forward pass:
    sum `core.fusion.dram_bytes_conv_epilogue` over every conv site.  The
    unfused total counts each conv's pre-activation write + read-back; the
    fused total only the final activation writes (+ residual reads)."""
    n_stages = len(params["enc"])

    def site(n_out, w, residual=False):
        return FU.dram_bytes_conv_epilogue(n_out, w.shape[2],
                                           residual=residual, fused=fused)

    def block(p, cap):
        return site(cap, p["conv1"]) + site(cap, p["conv2"], residual=True)

    total = site(levels[0]["pc"].capacity, params["stem"])
    for i, stage in enumerate(params["enc"]):
        cap = levels[i + 1]["pc"].capacity
        total += site(cap, stage["down"])
        total += sum(block(b, cap) for b in stage["blocks"])
    for i, stage in enumerate(params["dec"]):
        cap = levels[n_stages - 1 - i]["pc"].capacity
        total += site(cap, stage["up"])
        total += sum(block(b, cap) for b in stage["blocks"])
    return total


def mini_minkunet_init(key, c_in: int = 4, n_classes: int = 13):
    """The paper's co-designed shallow/narrow MinkowskiUNet (Fig. 16)."""
    return minkunet_init(key, c_in, n_classes, stem=16,
                         enc_planes=(16, 32), dec_planes=(32, 16),
                         blocks_per_stage=1)
