"""MinkowskiUNet-style sparse conv U-Net (paper's MinkNet(i)/(o) benchmark)
plus the Mini-MinkowskiUNet co-design (paper §5.2.2 / Fig. 16).

Structure: submanifold stem -> N encoder stages (stride-2 down conv +
residual blocks) -> N decoder stages (transposed conv back onto the cached
finer cloud + skip concat + residual blocks) -> linear head.

The network is written against the `PointAccSession` frontend
(`repro.api`): every conv is `session.conv` / `session.conv_transposed`
on a `SparseTensor`, and the tensor's shared `MapContext` owns what used
to be hand-threaded — one `SortedCloud` ranking sort per stride level,
kernel maps shared by every conv at that level, swapped inverse maps for
the decoder found by stride-pair lookup, and per-site temporal-fusion
plans.  Every conv carries its epilogue (layernorm / residual / ReLU /
row-mask) as a `core.sparseconv.Epilogue`, so the executor is
flow-uniform: the XLA flows run epilogues as post-ops while
`flow="pallas_fused"` folds fusable epilogues into the Pallas kernel
flush (paper §4.2.4 fusion extended from FC chains to the conv trunk).
For the fused flow the forward first canonicalises the cloud into
packed-key order — reusing the context's one ranking sort, so the whole
network still costs one `lax.sort` per stride level — and scatters the
head output back to the caller's row order.

`minkunet_apply` / `build_unet_maps` keep their PR-2 signatures as thin
shims over the session API (serving code passes prebuilt level pyramids
through them).
"""

from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp

from repro import nn
from repro.api import PointAccSession
from repro.core import fusion as FU
from repro.core import mapping as M
from repro.core import sparseconv as SC
from repro.core.tensor import MapContext, SparseTensor


def conv_w_init(key, k: int, c_in: int, c_out: int, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(k * c_in)
    return jax.random.uniform(key, (k, c_in, c_out), dtype, -scale, scale)


def _block_init(key, c_in: int, c_out: int):
    ks = jax.random.split(key, 4)
    p = {
        "conv1": conv_w_init(ks[0], 27, c_in, c_out),
        "n1": nn.layernorm_init(c_out),
        "conv2": conv_w_init(ks[1], 27, c_out, c_out),
        "n2": nn.layernorm_init(c_out),
    }
    if c_in != c_out:
        p["proj"] = nn.dense_init(ks[2], c_in, c_out, use_bias=False)
    return p


def _norm_epilogue(n_params, mask, residual=None):
    """Epilogue of every trunk conv: layernorm -> (+skip) -> ReLU -> mask."""
    return SC.Epilogue(ln_scale=n_params["scale"], ln_bias=n_params["bias"],
                       relu=True, mask=mask, residual=residual)


def minkunet_init(key, c_in: int = 4, n_classes: int = 13,
                  stem: int = 32,
                  enc_planes: Sequence[int] = (32, 64, 128, 256),
                  dec_planes: Sequence[int] = (256, 128, 96, 96),
                  blocks_per_stage: int = 2):
    n_stages = len(enc_planes)
    keys = iter(jax.random.split(key, 4 + 4 * n_stages * (blocks_per_stage
                                                          + 1)))
    params = {"stem": conv_w_init(next(keys), 27, c_in, stem),
              "stem_n": nn.layernorm_init(stem)}
    c = stem
    enc = []
    for i, planes in enumerate(enc_planes):
        stage = {"down": conv_w_init(next(keys), 8, c, planes),
                 "down_n": nn.layernorm_init(planes),
                 "blocks": []}
        c = planes
        for _ in range(blocks_per_stage):
            stage["blocks"].append(_block_init(next(keys), c, planes))
        enc.append(stage)
    params["enc"] = enc
    dec = []
    skip_cs = [stem] + list(enc_planes[:-1])
    for i, planes in enumerate(dec_planes):
        stage = {"up": conv_w_init(next(keys), 8, c, planes),
                 "up_n": nn.layernorm_init(planes),
                 "blocks": []}
        c_cat = planes + skip_cs[-(i + 1)]
        cb = c_cat
        for _ in range(blocks_per_stage):
            stage["blocks"].append(_block_init(next(keys), cb, planes))
            cb = planes
        dec.append(stage)
        c = planes
    params["dec"] = dec
    params["head"] = nn.dense_init(next(keys), c, n_classes)
    return params


# ---------------------------------------------------------------------------
# session-native forward
# ---------------------------------------------------------------------------

def _block_forward(session: PointAccSession, p, x: SparseTensor):
    """One residual block: two submanifold convs with fused epilogues."""
    h = session.conv(x, p["conv1"],
                     epilogue=_norm_epilogue(p["n1"], x.mask))
    skip = nn.dense(p["proj"], x.feats) if "proj" in p else x.feats
    return session.conv(h, p["conv2"],
                        epilogue=_norm_epilogue(p["n2"], x.mask,
                                                residual=skip))


def minkunet_forward(session: PointAccSession, params,
                     x: SparseTensor) -> jnp.ndarray:
    """Forward pass through the session frontend.

    The session picks the flow/engine/fusion budget; the tensor's
    MapContext accumulates clouds and maps as the convs demand them (one
    ranking sort per stride level).  For `flow="pallas_fused"` on a fresh
    context the cloud is first canonicalised into packed-key order
    (reusing the context's sort) so the streamed kernel's cache-block
    windows stay tight; the head output is scattered back to the caller's
    row order.  A context that already carries maps (e.g. rebuilt from a
    cached level pyramid) is used as-is.
    """
    n_stages = len(params["enc"])
    order = None
    if session.config.flow == "pallas_fused" and not x.context.maps:
        x, order = session.canonicalized(x)

    h = session.conv(x, params["stem"],
                     epilogue=_norm_epilogue(params["stem_n"], x.mask))

    skips = [h]
    for stage in params["enc"]:
        out_mask = session.out_cloud(h, 2).mask
        h = session.conv(h, stage["down"], stride=2,
                         epilogue=_norm_epilogue(stage["down_n"], out_mask))
        for b in stage["blocks"]:
            h = _block_forward(session, b, h)
        skips.append(h)

    for i, stage in enumerate(params["dec"]):
        skip = skips[n_stages - 1 - i]          # target (finer) level
        h = session.conv_transposed(
            h, stage["up"], stride=2,
            epilogue=_norm_epilogue(stage["up_n"], skip.mask))
        h = h.with_feats(jnp.concatenate([h.feats, skip.feats], axis=-1))
        for b in stage["blocks"]:
            h = _block_forward(session, b, h)

    out = nn.dense(params["head"], h.feats) * h.mask[:, None]
    if order is not None:
        out = jnp.zeros_like(out).at[order].set(out)
    return out


# ---------------------------------------------------------------------------
# level-pyramid shims (serving caches pass prebuilt pyramids around)
# ---------------------------------------------------------------------------

def build_unet_maps(pc: M.PointCloud, n_stages: int,
                    engine: str | None = None):
    """Mapping-Unit pass: clouds + kernel maps for every resolution level.

    Returns per-level dicts with the submanifold (k=3) maps, the stride-2
    down maps into the next level, and the level's point cloud — the
    serialisable form of a `MapContext` (see `_context_from_levels` for
    the way back).  Decoder reuses `down` swapped.

    With the packed-key engine (default) each level's cloud is ranked
    exactly ONCE: the level's SortedCloud serves its 27 submanifold
    offsets AND the 8 down-conv offsets, and the downsample hands the next
    level its cloud already sorted — one `lax.sort` per stride level for
    the entire network, every conv afterwards is binary search.
    """
    ctx = MapContext(engine=engine)
    ctx.register_cloud(pc.stride, pc)
    levels = []
    stride = pc.stride
    for i in range(n_stages + 1):
        subm, _ = ctx.conv_maps(3, stride, 1)
        level = {"pc": ctx.point_cloud(stride), "subm": subm}
        if ctx.engine == "v2":
            level["cloud"] = ctx.sorted_cloud(stride)
        if i < n_stages:
            level["down"], _ = ctx.conv_maps(2, stride, 2)
            stride *= 2
        levels.append(level)
    return levels


def _context_from_levels(levels, base_stride: int = 1) -> MapContext:
    """Rebuild a MapContext from a `build_unet_maps` level pyramid.

    Level pyramids that crossed a jit boundary carry array-ified stride
    leaves, so strides are reassigned statically (level i sits at
    base_stride * 2^i — the UNet convention the pyramid was built with).
    """
    engine = "v2" if any("cloud" in lv for lv in levels) else "v1"
    ctx = MapContext(engine=engine)
    stride = base_stride
    for level in levels:
        ctx.clouds[stride] = level.get("cloud", level["pc"])
        ctx.maps[(3, stride, stride)] = level["subm"]
        if "down" in level:
            ctx.maps[(2, stride, 2 * stride)] = level["down"]
        stride *= 2
    return ctx


def minkunet_apply(params, pc: M.PointCloud, feats: jnp.ndarray,
                   flow: str = "fod", levels=None,
                   fused_budget: int | None = None):
    """Deprecated shim over the session API (kept for PR-2 call sites).

    Equivalent to building a `PointAccSession` with (flow, fused_budget)
    and running `minkunet_forward`; pass precomputed `levels` (a
    `build_unet_maps` pyramid, e.g. from a serving cache) to skip map
    building.  New code should hold a session and call
    `minkunet_forward(session, params, session.tensor(...))` directly.
    """
    session = PointAccSession(flow=flow, fused_budget=fused_budget)
    context = _context_from_levels(levels, pc.stride) \
        if levels is not None else None
    x = session.tensor(pc.coords, pc.mask, feats, stride=pc.stride,
                       context=context)
    return minkunet_forward(session, params, x)


def epilogue_dram_bytes(params, levels, fused: bool) -> int:
    """Fig.-20-style DRAM model for the conv epilogues of one forward pass:
    sum `core.fusion.dram_bytes_conv_epilogue` over every conv site.  The
    unfused total counts each conv's pre-activation write + read-back; the
    fused total only the final activation writes (+ residual reads)."""
    n_stages = len(params["enc"])

    def site(n_out, w, residual=False):
        return FU.dram_bytes_conv_epilogue(n_out, w.shape[2],
                                           residual=residual, fused=fused)

    def block(p, cap):
        return site(cap, p["conv1"]) + site(cap, p["conv2"], residual=True)

    total = site(levels[0]["pc"].capacity, params["stem"])
    for i, stage in enumerate(params["enc"]):
        cap = levels[i + 1]["pc"].capacity
        total += site(cap, stage["down"])
        total += sum(block(b, cap) for b in stage["blocks"])
    for i, stage in enumerate(params["dec"]):
        cap = levels[n_stages - 1 - i]["pc"].capacity
        total += site(cap, stage["up"])
        total += sum(block(b, cap) for b in stage["blocks"])
    return total


def halo_spec(params):
    """Receptive-field spec of this UNet for the partition planner.

    `repro.partition.halo` mirrors the network's conv sites backward to
    compute exact per-chunk halos; this names what it must mirror: one
    stem dilation at level 0, two submanifold dilations per residual
    block at every level each stage touches (encoder and decoder), with
    the stride-2 down / transposed convs as the level transitions.
    """
    from repro.partition.halo import HaloSpec
    n_stages = len(params["enc"])
    blocks = len(params["enc"][0]["blocks"]) if n_stages else 0
    return HaloSpec.uniform(n_stages, blocks)


def mini_minkunet_init(key, c_in: int = 4, n_classes: int = 13):
    """The paper's co-designed shallow/narrow MinkowskiUNet (Fig. 16)."""
    return minkunet_init(key, c_in, n_classes, stem=16,
                         enc_planes=(16, 32), dec_planes=(32, 16),
                         blocks_per_stage=1)
