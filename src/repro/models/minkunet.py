"""MinkowskiUNet-style sparse conv U-Net (paper's MinkNet(i)/(o) benchmark)
plus the Mini-MinkowskiUNet co-design (paper §5.2.2 / Fig. 16).

Structure: submanifold stem -> N encoder stages (stride-2 down conv +
residual blocks) -> N decoder stages (transposed conv back onto the cached
finer cloud + skip concat + residual blocks) -> linear head.

All kernel maps are computed once per resolution level by the Mapping Unit
and shared across every conv at that level (MinkowskiEngine-style map
caching); transposed convs reuse the downsampling maps swapped — both are
PointAcc dataflows.
"""

from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp

from repro import nn
from repro.core import mapping as M
from repro.core import sparseconv as SC


def conv_w_init(key, k: int, c_in: int, c_out: int, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(k * c_in)
    return jax.random.uniform(key, (k, c_in, c_out), dtype, -scale, scale)


def _block_init(key, c_in: int, c_out: int):
    ks = jax.random.split(key, 4)
    p = {
        "conv1": conv_w_init(ks[0], 27, c_in, c_out),
        "n1": nn.layernorm_init(c_out),
        "conv2": conv_w_init(ks[1], 27, c_out, c_out),
        "n2": nn.layernorm_init(c_out),
    }
    if c_in != c_out:
        p["proj"] = nn.dense_init(ks[2], c_in, c_out, use_bias=False)
    return p


def _block_apply(p, feats, maps, out_cap, mask, flow):
    h = SC.sparse_conv_apply(feats, maps, p["conv1"], out_cap, flow)
    h = jax.nn.relu(nn.layernorm(p["n1"], h))
    h = SC.sparse_conv_apply(h, maps, p["conv2"], out_cap, flow)
    h = nn.layernorm(p["n2"], h)
    skip = nn.dense(p["proj"], feats) if "proj" in p else feats
    return jax.nn.relu(h + skip) * mask[:, None]


def minkunet_init(key, c_in: int = 4, n_classes: int = 13,
                  stem: int = 32,
                  enc_planes: Sequence[int] = (32, 64, 128, 256),
                  dec_planes: Sequence[int] = (256, 128, 96, 96),
                  blocks_per_stage: int = 2):
    n_stages = len(enc_planes)
    keys = iter(jax.random.split(key, 4 + 4 * n_stages * (blocks_per_stage
                                                          + 1)))
    params = {"stem": conv_w_init(next(keys), 27, c_in, stem),
              "stem_n": nn.layernorm_init(stem)}
    c = stem
    enc = []
    for i, planes in enumerate(enc_planes):
        stage = {"down": conv_w_init(next(keys), 8, c, planes),
                 "down_n": nn.layernorm_init(planes),
                 "blocks": []}
        c = planes
        for _ in range(blocks_per_stage):
            stage["blocks"].append(_block_init(next(keys), c, planes))
        enc.append(stage)
    params["enc"] = enc
    dec = []
    skip_cs = [stem] + list(enc_planes[:-1])
    for i, planes in enumerate(dec_planes):
        stage = {"up": conv_w_init(next(keys), 8, c, planes),
                 "up_n": nn.layernorm_init(planes),
                 "blocks": []}
        c_cat = planes + skip_cs[-(i + 1)]
        cb = c_cat
        for _ in range(blocks_per_stage):
            stage["blocks"].append(_block_init(next(keys), cb, planes))
            cb = planes
        dec.append(stage)
        c = planes
    params["dec"] = dec
    params["head"] = nn.dense_init(next(keys), c, n_classes)
    return params


def build_unet_maps(pc: M.PointCloud, n_stages: int,
                    engine: str | None = None):
    """Mapping-Unit pass: clouds + kernel maps for every resolution level.

    Returns per-level dicts with the submanifold (k=3) maps, the stride-2
    down maps into the next level, and the level's point cloud.  Decoder
    reuses `down` swapped.

    With the packed-key engine (default) each level's cloud is ranked
    exactly ONCE: the level's SortedCloud serves its 27 submanifold offsets
    AND the 8 down-conv offsets, and `downsample_sorted` hands the next
    level its cloud already sorted — one `lax.sort` per stride level for the
    entire network, every conv afterwards is binary search.
    """
    resolved = engine or M.DEFAULT_ENGINE
    levels = []
    if resolved == "v2" and pc.ndim_spatial == 3:
        sc = M.sort_cloud(pc)
        for i in range(n_stages + 1):
            subm, _ = M.build_conv_maps_cached(sc, kernel_size=3, stride=1)
            level = {"pc": sc.pc, "cloud": sc, "subm": subm}
            if i < n_stages:
                down, nxt = M.build_conv_maps_cached(sc, kernel_size=2,
                                                     stride=2)
                level["down"] = down
                sc = nxt
            levels.append(level)
        return levels
    cur = pc
    for i in range(n_stages + 1):
        subm, _ = M.build_conv_maps(cur, kernel_size=3, stride=1,
                                    engine=engine)
        level = {"pc": cur, "subm": subm}
        if i < n_stages:
            down, nxt = M.build_conv_maps(cur, kernel_size=2, stride=2,
                                          engine=engine)
            level["down"] = down
            cur = nxt
        levels.append(level)
    return levels


def minkunet_apply(params, pc: M.PointCloud, feats: jnp.ndarray,
                   flow: str = "fod", levels=None):
    n_stages = len(params["enc"])
    if levels is None:
        levels = build_unet_maps(pc, n_stages)

    l0 = levels[0]
    h = SC.sparse_conv_apply(feats, l0["subm"], params["stem"],
                             l0["pc"].capacity, flow)
    h = jax.nn.relu(nn.layernorm(params["stem_n"], h)) * l0["pc"].mask[:, None]

    skips = [h]
    for i, stage in enumerate(params["enc"]):
        lvl, nxt = levels[i], levels[i + 1]
        h = SC.sparse_conv_apply(h, lvl["down"], stage["down"],
                                 nxt["pc"].capacity, flow)
        h = jax.nn.relu(nn.layernorm(stage["down_n"], h)) \
            * nxt["pc"].mask[:, None]
        for b in stage["blocks"]:
            h = _block_apply(b, h, nxt["subm"], nxt["pc"].capacity,
                             nxt["pc"].mask, flow)
        skips.append(h)

    for i, stage in enumerate(params["dec"]):
        lvl = levels[n_stages - 1 - i]          # target (finer) level
        h = SC.sparse_conv_transposed(h, lvl["down"], lvl["pc"],
                                      stage["up"], flow)
        h = jax.nn.relu(nn.layernorm(stage["up_n"], h)) \
            * lvl["pc"].mask[:, None]
        h = jnp.concatenate([h, skips[n_stages - 1 - i]], axis=-1)
        for b in stage["blocks"]:
            h = _block_apply(b, h, lvl["subm"], lvl["pc"].capacity,
                             lvl["pc"].mask, flow)

    return nn.dense(params["head"], h) * pc.mask[:, None]


def mini_minkunet_init(key, c_in: int = 4, n_classes: int = 13):
    """The paper's co-designed shallow/narrow MinkowskiUNet (Fig. 16)."""
    return minkunet_init(key, c_in, n_classes, stem=16,
                         enc_planes=(16, 32), dec_planes=(32, 16),
                         blocks_per_stage=1)
