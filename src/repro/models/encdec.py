"""Encoder-decoder backbone for seamless-m4t-medium.

Per the assignment, only the transformer backbone is modelled: the speech
frontend is a stub — `input_specs()` supplies precomputed frame embeddings
(B, S_enc, d_model) directly to the encoder.  The decoder is a causal stack
with cross-attention onto the encoder output; decode caches the self-attn KV
per layer and the cross-attn K/V once (computed at prefill).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro import nn
from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.kernels.flash_attention.ref import attention_ref


def _identity_shard(x, names):
    return x


class CrossCache(NamedTuple):
    k: jnp.ndarray   # (B, S_enc, H, hd) — static after prefill
    v: jnp.ndarray


class DecLayerState(NamedTuple):
    self_kv: L.KVCache
    cross: CrossCache


# ---------------------------------------------------------------------------
# cross attention
# ---------------------------------------------------------------------------

def cross_attention_init(key, cfg: ArchConfig) -> nn.Params:
    d, h = cfg.d_model, cfg.n_heads
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": nn.dense_init(ks[0], d, h * hd, use_bias=cfg.qkv_bias),
        "wk": nn.dense_init(ks[1], d, h * hd, use_bias=cfg.qkv_bias),
        "wv": nn.dense_init(ks[2], d, h * hd, use_bias=cfg.qkv_bias),
        "wo": nn.dense_init(ks[3], h * hd, d, use_bias=False),
    }


def cross_kv(p, cfg: ArchConfig, enc_out) -> CrossCache:
    b, se, _ = enc_out.shape
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    k = nn.dense(p["wk"], enc_out).reshape(b, se, h, hd)
    v = nn.dense(p["wv"], enc_out).reshape(b, se, h, hd)
    return CrossCache(k, v)


def cross_attention_apply(p, cfg: ArchConfig, x, cache: CrossCache,
                          shard=_identity_shard):
    b, s, _ = x.shape
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    q = nn.dense(p["wq"], x).reshape(b, s, h, hd)
    out = attention_ref(q.transpose(0, 2, 1, 3),
                        cache.k.transpose(0, 2, 1, 3),
                        cache.v.transpose(0, 2, 1, 3), causal=False)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    return shard(nn.dense(p["wo"], out), ("batch", "seq", "d_model"))


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------

def enc_layer_init(key, cfg: ArchConfig) -> nn.Params:
    k1, k2 = jax.random.split(key)
    return {
        "norm_attn": L.norm_init(cfg, cfg.d_model),
        "attn": L.attention_init(k1, cfg),
        "norm_ffn": L.norm_init(cfg, cfg.d_model),
        "ffn": L.mlp_init(k2, cfg),
    }


def enc_layer_apply(p, cfg, x, positions, shard=_identity_shard):
    h = L.norm_apply(cfg, p["norm_attn"], x)
    b, s, d = h.shape
    hh, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = nn.dense(p["attn"]["wq"], h).reshape(b, s, hh, hd)
    k = nn.dense(p["attn"]["wk"], h).reshape(b, s, hkv, hd)
    v = nn.dense(p["attn"]["wv"], h).reshape(b, s, hkv, hd)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    o = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                      v.transpose(0, 2, 1, 3), causal=False)   # bidirectional
    o = o.transpose(0, 2, 1, 3).reshape(b, s, hh * hd)
    x = x + nn.dense(p["attn"]["wo"], o)
    x = x + L.mlp_apply(p["ffn"], cfg, L.norm_apply(cfg, p["norm_ffn"], x),
                        shard=shard)
    return shard(x, ("batch", "seq", "d_model"))


# ---------------------------------------------------------------------------
# decoder layer
# ---------------------------------------------------------------------------

def dec_layer_init(key, cfg: ArchConfig) -> nn.Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm_self": L.norm_init(cfg, cfg.d_model),
        "self": L.attention_init(k1, cfg),
        "norm_cross": L.norm_init(cfg, cfg.d_model),
        "cross": cross_attention_init(k2, cfg),
        "norm_ffn": L.norm_init(cfg, cfg.d_model),
        "ffn": L.mlp_init(k3, cfg),
    }


def dec_layer_apply(p, cfg, x, positions, *, mode: str, enc_out=None,
                    state: Optional[DecLayerState] = None, cache_pos=None,
                    shard=_identity_shard):
    h = L.norm_apply(cfg, p["norm_self"], x)
    h, self_kv = L.attention_apply(
        p["self"], cfg, h, positions, layer_window=None, mode=mode,
        cache=state.self_kv if state is not None else None,
        cache_pos=cache_pos, shard=shard)
    x = x + h

    h = L.norm_apply(cfg, p["norm_cross"], x)
    if mode == "decode":
        cc = state.cross
    else:
        cc = cross_kv(p["cross"], cfg, enc_out)
    x = x + cross_attention_apply(p["cross"], cfg, h, cc, shard=shard)

    h = L.norm_apply(cfg, p["norm_ffn"], x)
    x = x + L.mlp_apply(p["ffn"], cfg, h, shard=shard)
    new_state = DecLayerState(self_kv, cc) if mode != "train" else None
    return shard(x, ("batch", "seq", "d_model")), new_state


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def encdec_init(key, cfg: ArchConfig, dtype=jnp.float32) -> nn.Params:
    ke, kd, kt, kh = jax.random.split(key, 4)
    enc_keys = jax.random.split(ke, cfg.encoder_layers)
    dec_keys = jax.random.split(kd, cfg.n_layers)
    params = {
        "embed": nn.embedding_init(kt, cfg.vocab_size, cfg.d_model),
        "enc_layers": jax.vmap(lambda k: enc_layer_init(k, cfg))(enc_keys),
        "enc_norm": L.norm_init(cfg, cfg.d_model),
        "dec_layers": jax.vmap(lambda k: dec_layer_init(k, cfg))(dec_keys),
        "final_norm": L.norm_init(cfg, cfg.d_model),
        "lm_head": nn.dense_init(kh, cfg.d_model, cfg.vocab_size,
                                 use_bias=False),
    }
    return nn.cast_floating(params, dtype)


def _depth_scan(scan_fn, carry, xs):
    """lax.scan over layers, unrolled under cost mode (repro.costmode)."""
    from repro import costmode
    if not costmode.enabled():
        return lax.scan(scan_fn, carry, xs)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        xi = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = scan_fn(carry, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def encode(params, cfg: ArchConfig, frame_embeds, enc_positions,
           shard=_identity_shard):
    """frame_embeds (B, S_enc, D): the stubbed audio frontend output."""
    x = shard(frame_embeds, ("batch", "seq", "d_model"))

    def scan_fn(x, p_layer):
        return enc_layer_apply(p_layer, cfg, x, enc_positions, shard), None

    x, _ = _depth_scan(scan_fn, x, params["enc_layers"])
    return L.norm_apply(cfg, params["enc_norm"], x)


def encdec_apply(params, cfg: ArchConfig, frame_embeds, enc_positions,
                 tokens, dec_positions, *, mode: str = "train",
                 states=None, cache_pos=None, shard=_identity_shard,
                 remat: bool = False, return_hidden: bool = False):
    """Returns (logits, new_states, aux=0)."""
    aux = jnp.zeros((), jnp.float32)
    x = nn.embed(params["embed"], tokens)
    x = shard(x, ("batch", "seq", "d_model"))

    if mode == "decode":
        def scan_fn(x, xs):
            p_layer, st = xs
            x, nst = dec_layer_apply(p_layer, cfg, x, dec_positions,
                                     mode="decode", state=st,
                                     cache_pos=cache_pos, shard=shard)
            return x, nst
        x, new_states = _depth_scan(scan_fn, x,
                                    (params["dec_layers"], states))
    else:
        enc_out = encode(params, cfg, frame_embeds, enc_positions, shard)

        def body(x, p_layer):
            return dec_layer_apply(p_layer, cfg, x, dec_positions,
                                   mode=mode, enc_out=enc_out, shard=shard)
        if remat and mode == "train":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)

        def scan_fn(x, p_layer):
            return body(x, p_layer)
        x, new_states = _depth_scan(scan_fn, x, params["dec_layers"])
        if mode == "train":
            new_states = None

    x = L.norm_apply(cfg, params["final_norm"], x)
    if return_hidden:
        return x, new_states, aux
    logits = nn.dense(params["lm_head"], x)
    return shard(logits, ("batch", "seq", "vocab")), new_states, aux
