"""Uniform model API over every assigned architecture family.

build(cfg) -> Model with:
  init(key, dtype)                          -> params
  train_logits(params, batch, ...)          -> (logits, aux)
  prefill(params, batch, ...)               -> (logits, states, aux)
  decode(params, batch, states, ...)        -> (logits, states, aux)
  init_state(batch_size, max_len, ...)      -> decode-state pytree

batch dict keys by family:
  lm:    tokens (B,S) positions (B,S) [labels]
  vlm:   + patch_embeds (B,S_img,D); positions (B,S_tot,3)
  audio: frame_embeds (B,S_enc,D) enc_positions tokens (B,S_dec) positions
decode: tokens (B,1), positions (B,1[,3]), cache_pos (B,)
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec as ED
from repro.models import lm as LM


def _identity_shard(x, names):
    return x


class Model(NamedTuple):
    cfg: ArchConfig
    init: Callable
    train_logits: Callable
    prefill: Callable
    decode: Callable
    init_state: Callable
    train_hidden: Callable     # final-normed hidden states (for chunked CE)
    head_info: Callable        # params -> (head_w, transpose, softcap)


def default_moe_impl(cfg: ArchConfig, mode: str, mesh=None) -> str:
    if not cfg.n_experts:
        return "dense"
    if mesh is not None and mode in ("train", "prefill"):
        return "ep"        # sharded sorted dispatch
    if mode == "decode":
        return "dense"     # a handful of tokens: G-M-S is optimal here
    return "sorted"


def build(cfg: ArchConfig) -> Model:
    if cfg.family == "audio":
        return _build_encdec(cfg)
    return _build_lm(cfg)


def _build_lm(cfg: ArchConfig) -> Model:
    def init(key, dtype=jnp.float32):
        return LM.lm_init(key, cfg, dtype)

    def train_logits(params, batch, shard=_identity_shard, mesh=None,
                     moe_impl: Optional[str] = None, remat: bool = False):
        impl = moe_impl or default_moe_impl(cfg, "train", mesh)
        logits, _, aux = LM.lm_apply(
            params, cfg, batch["tokens"], batch["positions"], mode="train",
            shard=shard, moe_impl=impl, mesh=mesh, remat=remat,
            embeds=batch.get("patch_embeds"))
        return logits, aux

    def train_hidden(params, batch, shard=_identity_shard, mesh=None,
                     moe_impl: Optional[str] = None, remat: bool = False):
        impl = moe_impl or default_moe_impl(cfg, "train", mesh)
        x, _, aux = LM.lm_apply(
            params, cfg, batch["tokens"], batch["positions"], mode="train",
            shard=shard, moe_impl=impl, mesh=mesh, remat=remat,
            embeds=batch.get("patch_embeds"), return_hidden=True)
        from repro.models.layers import norm_apply
        return norm_apply(cfg, params["final_norm"], x), aux

    def head_info(params):
        if cfg.tie_embeddings:
            return params["embed"]["emb"], True, cfg.final_softcap
        return params["lm_head"]["w"], False, cfg.final_softcap

    def prefill(params, batch, shard=_identity_shard, mesh=None,
                moe_impl: Optional[str] = None):
        impl = moe_impl or default_moe_impl(cfg, "prefill", mesh)
        return LM.lm_apply(
            params, cfg, batch["tokens"], batch["positions"],
            mode="prefill", shard=shard, moe_impl=impl, mesh=mesh,
            embeds=batch.get("patch_embeds"))

    def decode(params, batch, states, shard=_identity_shard, mesh=None,
               moe_impl: Optional[str] = None):
        impl = moe_impl or default_moe_impl(cfg, "decode", mesh)
        return LM.lm_apply(
            params, cfg, batch["tokens"], batch["positions"], mode="decode",
            states=states, cache_pos=batch["cache_pos"], shard=shard,
            moe_impl=impl, mesh=mesh)

    def init_state(batch_size, max_len, dtype=jnp.bfloat16):
        return LM.init_lm_state(cfg, batch_size, max_len, dtype)

    return Model(cfg, init, train_logits, prefill, decode, init_state,
                 train_hidden, head_info)


def _build_encdec(cfg: ArchConfig) -> Model:
    def init(key, dtype=jnp.float32):
        return ED.encdec_init(key, cfg, dtype)

    def train_logits(params, batch, shard=_identity_shard, mesh=None,
                     moe_impl=None, remat: bool = False):
        logits, _, aux = ED.encdec_apply(
            params, cfg, batch["frame_embeds"], batch["enc_positions"],
            batch["tokens"], batch["positions"], mode="train", shard=shard,
            remat=remat)
        return logits, aux

    def train_hidden(params, batch, shard=_identity_shard, mesh=None,
                     moe_impl=None, remat: bool = False):
        x, _, aux = ED.encdec_apply(
            params, cfg, batch["frame_embeds"], batch["enc_positions"],
            batch["tokens"], batch["positions"], mode="train", shard=shard,
            remat=remat, return_hidden=True)
        return x, aux

    def head_info(params):
        return params["lm_head"]["w"], False, None

    def prefill(params, batch, shard=_identity_shard, mesh=None,
                moe_impl=None):
        return ED.encdec_apply(
            params, cfg, batch["frame_embeds"], batch["enc_positions"],
            batch["tokens"], batch["positions"], mode="prefill", shard=shard)

    def decode(params, batch, states, shard=_identity_shard, mesh=None,
               moe_impl=None):
        return ED.encdec_apply(
            params, cfg, None, None, batch["tokens"], batch["positions"],
            mode="decode", states=states, cache_pos=batch["cache_pos"],
            shard=shard)

    def init_state(batch_size, max_len, dtype=jnp.bfloat16,
                   enc_len: Optional[int] = None):
        enc_len = enc_len or max_len
        hd = cfg.resolved_head_dim
        from repro.models.layers import init_kv_cache
        one = ED.DecLayerState(
            self_kv=init_kv_cache(cfg, batch_size, max_len, dtype),
            cross=ED.CrossCache(
                jnp.zeros((batch_size, enc_len, cfg.n_heads, hd), dtype),
                jnp.zeros((batch_size, enc_len, cfg.n_heads, hd), dtype)))
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), one)

    return Model(cfg, init, train_logits, prefill, decode, init_state,
                 train_hidden, head_info)
