"""Mamba (S6) selective-scan block for the jamba hybrid architecture.

Train/prefill: chunked parallel scan — `lax.scan` over sequence chunks with
a `lax.associative_scan` inside each chunk, carrying the (B, d_inner,
d_state) SSM state across chunks.  This bounds the materialised state tensor
to (chunk, B, d_inner, d_state) (the Mamba-2/SSD trick, adapted), which is
what makes the 52B jamba fit at seq 4k.

Decode: O(1) recurrent step carrying (conv_state, ssm_state).

TP sharding: d_inner is the sharded axis (conv is depthwise -> no
cross-channel comm; x_proj/dt_proj contract over it with a psum inserted by
SPMD), threaded via the `shard` callback.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro import nn
from repro.configs.base import ArchConfig


class MambaState(NamedTuple):
    conv: jnp.ndarray    # (B, d_conv - 1, d_inner)
    ssm: jnp.ndarray     # (B, d_inner, d_state)


def _identity_shard(x, names):
    return x


def mamba_init(key, cfg: ArchConfig) -> nn.Params:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.d_state
    dt_rank = max(1, math.ceil(d / 16))
    ks = jax.random.split(key, 7)
    p = {
        "in_proj": nn.dense_init(ks[0], d, 2 * di, use_bias=False),
        "conv_w": jax.random.normal(ks[1], (cfg.d_conv, di)) * 0.1,
        "conv_b": jnp.zeros((di,)),
        "x_proj": nn.dense_init(ks[2], di, dt_rank + 2 * n, use_bias=False),
        "dt_proj": nn.dense_init(ks[3], dt_rank, di, use_bias=True),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))),
        "D": jnp.ones((di,)),
        "out_proj": nn.dense_init(ks[4], di, d, use_bias=False),
    }
    return p


def _split_xproj(cfg: ArchConfig, dbc: jnp.ndarray):
    d = cfg.d_model
    n = cfg.d_state
    dt_rank = max(1, math.ceil(d / 16))
    return (dbc[..., :dt_rank], dbc[..., dt_rank:dt_rank + n],
            dbc[..., dt_rank + n:])


def _ssm_inputs(p, cfg, x):
    """x (B, S, di) post-conv -> (da, u, C) scan inputs.

    da (B,S,di,N) decay, u (B,S,di,N) injection, C (B,S,N) readout."""
    dt_r, B, C = _split_xproj(cfg, nn.dense(p["x_proj"], x))
    dt = jax.nn.softplus(
        nn.dense(p["dt_proj"], dt_r)).astype(jnp.float32)    # (B,S,di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))             # (di, N)
    da = jnp.exp(dt[..., None] * A[None, None])              # (B,S,di,N)
    # scan runs in f32: mixing dtypes breaks associative_scan's concat
    u = (dt * x.astype(jnp.float32))[..., None] \
        * B.astype(jnp.float32)[:, :, None, :]               # (B,S,di,N)
    return da, u, C


def _scan_combine(a, b):
    (a1, u1), (a2, u2) = a, b
    return a2 * a1, a2 * u1 + u2


def selective_scan(p, cfg, x, h0: Optional[jnp.ndarray] = None,
                   chunk: int = 128):
    """x (B, S, di) -> (y (B, S, di), h_final (B, di, N))."""
    b, s, di = x.shape
    n = cfg.d_state
    da, u, c = _ssm_inputs(p, cfg, x)
    h0 = h0 if h0 is not None else jnp.zeros((b, di, n), jnp.float32)

    chunk = min(chunk, s)
    assert s % chunk == 0
    n_chunks = s // chunk
    # (n_chunks, chunk, B, di, N): the (chunk, B, di, N) state tensor is the
    # only transient — never materialise (B, S, di, N).
    da_c = da.reshape(b, n_chunks, chunk, di, n).transpose(1, 2, 0, 3, 4)
    u_c = u.reshape(b, n_chunks, chunk, di, n).transpose(1, 2, 0, 3, 4)
    c_c = c.astype(jnp.float32) \
        .reshape(b, n_chunks, chunk, n).transpose(1, 2, 0, 3)

    @jax.checkpoint
    def step(h, xs):
        # checkpointed: backward recomputes the chunk internals instead of
        # saving (chunk, B, di, N) tensors for every chunk
        da_i, u_i, c_i = xs
        acum, ucum = lax.associative_scan(_scan_combine, (da_i, u_i), axis=0)
        h_t = acum * h[None] + ucum                          # (chunk,B,di,N)
        y_i = jnp.einsum("cbdn,cbn->cbd", h_t, c_i)
        return h_t[-1], y_i

    h_final, y = lax.scan(step, h0, (da_c, u_c, c_c))
    y = y.reshape(n_chunks * chunk, b, di).transpose(1, 0, 2)  # (B,S,di)
    return y.astype(x.dtype), h_final


def _causal_conv(p, cfg, x, conv_state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv, k = d_conv.  x (B, S, di)."""
    k = cfg.d_conv
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                   # (B, S+k-1, di)
    out = sum(xp[:, i:i + x.shape[1]] * p["conv_w"][i]
              for i in range(k))
    new_state = xp[:, -(k - 1):]
    return out + p["conv_b"], new_state


def mamba_apply(p: nn.Params, cfg: ArchConfig, x: jnp.ndarray, *,
                mode: str, state: Optional[MambaState] = None,
                shard=_identity_shard):
    """x (B, S, D).  Returns (out, new_state_or_None)."""
    b, s, d = x.shape
    di = cfg.ssm_expand * d

    xz = nn.dense(p["in_proj"], x)
    xin, z = xz[..., :di], xz[..., di:]
    xin = shard(xin, ("batch", "seq", "d_inner"))

    if mode == "decode":
        assert state is not None and s == 1
        xc, conv_state = _causal_conv(p, cfg, xin, state.conv)
        xc = jax.nn.silu(xc)
        da, u, c = _ssm_inputs(p, cfg, xc)
        h = da[:, 0] * state.ssm + u[:, 0]                   # (B, di, N)
        y = jnp.einsum("bdn,bn->bd", h, c[:, 0].astype(jnp.float32))[:, None]
        new_state = MambaState(conv_state, h)
    else:
        xc, conv_state = _causal_conv(p, cfg, xin)
        xc = jax.nn.silu(xc)
        y, h_final = selective_scan(p, cfg, xc)
        new_state = MambaState(conv_state, h_final) if mode == "prefill" \
            else None

    y = y.astype(x.dtype) + p["D"] * xc
    out = nn.dense(p["out_proj"], y * jax.nn.silu(z))
    return shard(out, ("batch", "seq", "d_model")), new_state


def init_mamba_state(cfg: ArchConfig, batch: int,
                     dtype=jnp.float32) -> MambaState:
    di = cfg.ssm_expand * cfg.d_model
    return MambaState(
        conv=jnp.zeros((batch, cfg.d_conv - 1, di), dtype),
        ssm=jnp.zeros((batch, di, cfg.d_state), jnp.float32))
