"""PointNet / PointNet++ / DGCNN family (paper Table 1, PointNet++-based).

Dense-batched representation: xyz (B, N, 3) float32, mask (B, N) bool.
Mapping ops (FPS / ball query / kNN) come from repro.core.pointops — the
ranking-based Mapping Unit.  Aggregation is masked max-pooling (paper
Table 1: MaxPool).  T-Nets are omitted (they do not change the system-level
compute structure); noted in DESIGN.md.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro import nn
from repro.core import pointops as P

_NEG = jnp.float32(-1e9)


# ---------------------------------------------------------------------------
# shared building blocks
# ---------------------------------------------------------------------------

def masked_max(x: jnp.ndarray, mask: jnp.ndarray, axis: int) -> jnp.ndarray:
    """Max-pool ignoring invalid slots; all-invalid groups produce 0."""
    big = jnp.where(mask, 0.0, _NEG)
    y = jnp.max(x + jnp.expand_dims(big, -1), axis=axis)
    any_valid = jnp.any(mask, axis=axis)
    return jnp.where(any_valid[..., None], y, 0.0)


def set_abstraction_init(key, c_in: int, mlp: Sequence[int]) -> nn.Params:
    return {"mlp": nn.mlp_chain_init(key, [c_in + 3] + list(mlp))}


def set_abstraction(p: nn.Params, xyz, feats, mask, n_out: int,
                    radius: float, k: int):
    """FPS (Max ranking) -> ball query (TopK ranking) -> shared MLP -> max."""
    centers = P.farthest_point_sampling(xyz, mask, n_out)     # (B, M)
    new_xyz = P.gather_points(xyz, centers)
    new_mask = P.gather_points(mask[..., None], centers)[..., 0]
    idx, valid = P.ball_query(new_xyz, new_mask, xyz, mask, radius, k)
    grouped_xyz = P.gather_points(xyz, idx) - new_xyz[:, :, None, :]
    if feats is not None:
        grouped = jnp.concatenate(
            [grouped_xyz, P.gather_points(feats, idx)], axis=-1)
    else:
        grouped = grouped_xyz
    g = nn.mlp_chain(p["mlp"], grouped)                       # (B,M,k,C)
    valid = valid & new_mask[:, :, None]
    new_f = masked_max(g, valid, axis=2)
    return new_xyz, new_f * new_mask[..., None], new_mask


def global_abstraction_init(key, c_in: int, mlp: Sequence[int]) -> nn.Params:
    return {"mlp": nn.mlp_chain_init(key, [c_in + 3] + list(mlp))}


def global_abstraction(p, xyz, feats, mask):
    g = jnp.concatenate([xyz, feats], axis=-1)
    g = nn.mlp_chain(p["mlp"], g)
    return masked_max(g, mask, axis=1)                        # (B, C)


def feature_propagation_init(key, c_in: int, mlp: Sequence[int]) -> nn.Params:
    return {"mlp": nn.mlp_chain_init(key, [c_in] + list(mlp))}


def feature_propagation(p, xyz_fine, mask_fine, xyz_coarse, mask_coarse,
                        f_coarse, f_skip):
    """3-NN inverse-distance interpolation (kNN = TopK ranking) + MLP."""
    idx, dist = P.knn(xyz_fine, mask_fine, xyz_coarse, mask_coarse, 3)
    w = 1.0 / (dist + 1e-8)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    interp = jnp.einsum("bmk,bmkc->bmc", w, P.gather_points(f_coarse, idx))
    f = jnp.concatenate([interp, f_skip], axis=-1) if f_skip is not None \
        else interp
    return nn.mlp_chain(p["mlp"], f) * mask_fine[..., None]


# ---------------------------------------------------------------------------
# PointNet (classification)
# ---------------------------------------------------------------------------

def pointnet_init(key, n_classes: int = 40, width: int = 1) -> nn.Params:
    k1, k2 = jax.random.split(key)
    w = width
    return {
        "feat": nn.mlp_chain_init(k1, [3, 64 * w, 64 * w, 64 * w,
                                       128 * w, 1024 * w]),
        "head": nn.mlp_chain_init(k2, [1024 * w, 512 * w, 256 * w,
                                       n_classes]),
    }


def pointnet_apply(params, xyz, mask):
    f = nn.mlp_chain(params["feat"], xyz)
    g = masked_max(f, mask, axis=1)
    return nn.mlp_chain(params["head"], g, final_act=False)


# ---------------------------------------------------------------------------
# PointNet++ SSG (classification) — paper's PointNet++(c)
# ---------------------------------------------------------------------------

def pointnetpp_cls_init(key, n_classes: int = 40, width: int = 1):
    ks = jax.random.split(key, 4)
    w = width
    return {
        "sa1": set_abstraction_init(ks[0], 0, [64 * w, 64 * w, 128 * w]),
        "sa2": set_abstraction_init(ks[1], 128 * w,
                                    [128 * w, 128 * w, 256 * w]),
        "sa3": global_abstraction_init(ks[2], 256 * w,
                                       [256 * w, 512 * w, 1024 * w]),
        "head": nn.mlp_chain_init(ks[3], [1024 * w, 512 * w, 256 * w,
                                          n_classes]),
    }


def pointnetpp_cls_apply(params, xyz, mask, n1=512, n2=128):
    x1, f1, m1 = set_abstraction(params["sa1"], xyz, None, mask, n1, 0.2, 32)
    x2, f2, m2 = set_abstraction(params["sa2"], x1, f1, m1, n2, 0.4, 64)
    g = global_abstraction(params["sa3"], x2, f2, m2)
    return nn.mlp_chain(params["head"], g, final_act=False)


# ---------------------------------------------------------------------------
# PointNet++ segmentation (SSG) — paper's PointNet++(s) / (ps) backbone
# ---------------------------------------------------------------------------

def pointnetpp_seg_init(key, n_classes: int = 13, c_in: int = 0,
                        width: int = 1):
    ks = jax.random.split(key, 6)
    w = width
    return {
        "sa1": set_abstraction_init(ks[0], c_in, [32 * w, 32 * w, 64 * w]),
        "sa2": set_abstraction_init(ks[1], 64 * w, [64 * w, 64 * w, 128 * w]),
        "fp2": feature_propagation_init(ks[2], 128 * w + 64 * w,
                                        [128 * w, 64 * w]),
        "fp1": feature_propagation_init(ks[3], 64 * w + c_in,
                                        [64 * w, 64 * w]),
        "head": nn.mlp_chain_init(ks[4], [64 * w, 64 * w, n_classes]),
    }


def pointnetpp_seg_apply(params, xyz, mask, feats=None, n1=256, n2=64,
                         return_features: bool = False):
    x1, f1, m1 = set_abstraction(params["sa1"], xyz, feats, mask,
                                 n1, 0.1, 32)
    x2, f2, m2 = set_abstraction(params["sa2"], x1, f1, m1, n2, 0.2, 32)
    u1 = feature_propagation(params["fp2"], x1, m1, x2, m2, f2, f1)
    u0 = feature_propagation(params["fp1"], xyz, mask, x1, m1, u1, feats)
    logits = nn.mlp_chain(params["head"], u0, final_act=False)
    if return_features:
        return logits, u0
    return logits


# ---------------------------------------------------------------------------
# DGCNN — graph-based: kNN on *features* (paper §2: mapping on features)
# ---------------------------------------------------------------------------

def edgeconv_init(key, c_in: int, c_out: int):
    return {"mlp": nn.mlp_chain_init(key, [2 * c_in, c_out])}


def edgeconv(p, feats, mask, k: int):
    idx, _ = P.knn(feats, mask, feats, mask, k)
    nbrs = P.gather_points(feats, idx)                        # (B,N,k,C)
    center = feats[:, :, None, :]
    edge = jnp.concatenate([center * jnp.ones_like(nbrs), nbrs - center],
                           axis=-1)
    e = nn.mlp_chain(p["mlp"], edge)
    valid = mask[:, :, None] & P.gather_points(mask[..., None], idx)[..., 0]
    return masked_max(e, valid, axis=2) * mask[..., None]


def dgcnn_init(key, n_classes: int = 16, width: int = 1):
    ks = jax.random.split(key, 5)
    w = width
    return {
        "ec1": edgeconv_init(ks[0], 3, 64 * w),
        "ec2": edgeconv_init(ks[1], 64 * w, 64 * w),
        "ec3": edgeconv_init(ks[2], 64 * w, 128 * w),
        "agg": nn.mlp_chain_init(ks[3], [(64 + 64 + 128) * w, 1024 * w]),
        "head": nn.mlp_chain_init(ks[4], [1024 * w, 256 * w, n_classes]),
    }


def dgcnn_apply(params, xyz, mask, k: int = 20):
    f1 = edgeconv(params["ec1"], xyz, mask, k)
    f2 = edgeconv(params["ec2"], f1, mask, k)
    f3 = edgeconv(params["ec3"], f2, mask, k)
    f = jnp.concatenate([f1, f2, f3], axis=-1)
    f = nn.mlp_chain(params["agg"], f)
    g = masked_max(f, mask, axis=1)
    return nn.mlp_chain(params["head"], g, final_act=False)


# ---------------------------------------------------------------------------
# F-PointNet++ (detection): instance seg + centre/box regression heads
# ---------------------------------------------------------------------------

def fpointnetpp_init(key, n_box_params: int = 7, width: int = 1):
    ks = jax.random.split(key, 3)
    w = width
    return {
        "seg": pointnetpp_seg_init(ks[0], n_classes=2, width=w),
        "center": nn.mlp_chain_init(ks[1], [64 * w + 3, 128 * w, 3]),
        "box": nn.mlp_chain_init(ks[2], [64 * w + 3, 256 * w,
                                         n_box_params]),
    }


def fpointnetpp_apply(params, xyz, mask):
    """Frustum pipeline: instance seg -> foreground-weighted pooling ->
    centre + box regression (the paper's detection benchmark structure)."""
    seg_logits, feats = pointnetpp_seg_apply(params["seg"], xyz, mask,
                                             return_features=True)
    fg = jax.nn.softmax(seg_logits, -1)[..., 1:2] * mask[..., None]
    denom = jnp.sum(fg, axis=1) + 1e-6
    pooled_f = jnp.sum(fg * feats, axis=1) / denom            # (B, 64w)
    centroid = jnp.sum(fg * xyz, axis=1) / denom              # (B, 3)
    h = jnp.concatenate([pooled_f, centroid], axis=-1)
    center = centroid + nn.mlp_chain(params["center"], h, final_act=False)
    box = nn.mlp_chain(params["box"], h, final_act=False)
    return {"seg": seg_logits, "center": center, "box": box}
