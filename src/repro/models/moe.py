"""Mixture-of-Experts with PointAcc-style ranking-based dispatch.

Three selectable implementations (mirroring the paper's flow ablation):

  * `dense`  — Gather-MatMul-Scatter baseline: every token through every
    expert, one-hot combine.  Maximum regularity, topk/E-fold wasted FLOPs.
  * `sorted` — single-shard Fetch-on-Demand: assignments sorted by expert
    (Mapping Unit), grouped matmul over contiguous segments
    (kernels/grouped_matmul).
  * `ep`     — production sharded version: shard_map over the `model` mesh
    axis.  Tokens are ranked into per-destination-shard segments, exchanged
    with a single all_to_all, processed by the local expert(s) as plain
    dense GEMMs (the sort bought back full MXU utilisation), and returned by
    the inverse all_to_all.  Supports E % ep == 0 (multiple experts/shard)
    and ep % E == 0 (experts replicated r times, assignments load-balanced
    across replicas by position parity — another ranking byproduct).

The aux load-balance loss (Switch-style) is returned alongside.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro import nn
from repro.configs.base import ArchConfig
from repro.kernels.grouped_matmul import ops as gmm
from repro.models.layers import act_fn


def moe_init(key, cfg: ArchConfig, d_ff: Optional[int] = None) -> nn.Params:
    d, f, e = cfg.d_model, d_ff or cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    scale_in = 1.0 / jnp.sqrt(d)
    scale_out = 1.0 / jnp.sqrt(f)
    p = {
        "router": nn.dense_init(ks[0], d, e, use_bias=False),
        "w_in": jax.random.uniform(ks[1], (e, d, f), jnp.float32,
                                   -scale_in, scale_in),
        "w_out": jax.random.uniform(ks[2], (e, f, d), jnp.float32,
                                    -scale_out, scale_out),
    }
    if cfg.gated_mlp:
        p["w_gate"] = jax.random.uniform(ks[3], (e, d, f), jnp.float32,
                                         -scale_in, scale_in)
    return p


def route(p: nn.Params, cfg: ArchConfig, x2d: jnp.ndarray):
    """x2d (T, D) -> (gates (T, topk), expert_idx (T, topk), aux_loss)."""
    logits = nn.dense(p["router"], x2d).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = lax.top_k(probs, cfg.topk)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    # Switch-style aux loss: E * sum_e f_e * P_e
    e = cfg.n_experts
    hard = jnp.sum(jax.nn.one_hot(idx, e), axis=1)            # (T, E)
    f_e = jnp.mean(hard, axis=0)
    p_e = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f_e * p_e)
    return gates.astype(x2d.dtype), idx, aux


# ---------------------------------------------------------------------------
# dense baseline (Gather-MatMul-Scatter analogue)
# ---------------------------------------------------------------------------

def moe_apply_dense(p: nn.Params, cfg: ArchConfig, x: jnp.ndarray):
    b, s, d = x.shape
    x2 = x.reshape(-1, d)
    gates, idx, aux = route(p, cfg, x2)
    act = act_fn(cfg.act)
    h = jnp.einsum("td,edf->tef", x2, p["w_in"])
    if "w_gate" in p:
        h = act(jnp.einsum("td,edf->tef", x2, p["w_gate"])) * h
    else:
        h = act(h)
    y = jnp.einsum("tef,efd->ted", h, p["w_out"])
    onehot = jax.nn.one_hot(idx, cfg.n_experts,
                            dtype=gates.dtype) * gates[..., None]
    out = jnp.einsum("tke,ted->td", onehot, y)
    return out.reshape(b, s, d).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# single-shard sorted dispatch (Fetch-on-Demand)
# ---------------------------------------------------------------------------

def moe_apply_sorted(p: nn.Params, cfg: ArchConfig, x: jnp.ndarray,
                     capacity_factor: float = 1.5, row_tile: int = 128,
                     use_kernel: bool = False, interpret: bool = True):
    b, s, d = x.shape
    x2 = x.reshape(-1, d)
    gates, idx, aux = route(p, cfg, x2)
    out = gmm.sorted_moe_ffn(
        x2, idx, gates, p["w_in"], p["w_out"],
        w_gate=p.get("w_gate"), capacity_factor=capacity_factor,
        row_tile=row_tile, act=act_fn(cfg.act), use_kernel=use_kernel,
        interpret=interpret)
    return out.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# sharded expert parallelism (shard_map over the `model` axis)
# ---------------------------------------------------------------------------

def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def make_ep_dispatch(expert_idx: jnp.ndarray, n_experts: int, ep: int,
                     cap_per_slot: int):
    """Rank assignments into (shard, local-slot, position) coordinates.

    n_slots = max(E, ep).  E >= ep: slot == expert (epl = E/ep slots per
    shard).  E < ep: each expert owns r = ep/E consecutive slots and its
    assignments round-robin across them (balanced by position parity).
    Returns (dest_row, src_token):
      dest_row (T, topk): row in the flattened (n_slots * C) send buffer,
        -1 for capacity-dropped assignments;
      src_token (n_slots * C,): source token per buffer row (-1 = padding)
        — lets the send buffer be built by GATHER instead of materialising
        a (T * topk, D) repeat + scatter (§Perf H3).
    """
    t, topk = expert_idx.shape
    a = t * topk
    r = max(1, ep // n_experts)
    n_rows = max(n_experts, ep) * cap_per_slot
    flat_e = expert_idx.reshape(-1).astype(jnp.int32)

    s_e, s_a = lax.sort((flat_e, jnp.arange(a, dtype=jnp.int32)),
                        dimension=0, num_keys=1, is_stable=True)
    seg_start = jnp.searchsorted(s_e, jnp.arange(n_experts), side="left")
    pos = jnp.arange(a, dtype=jnp.int32) - seg_start[s_e]
    slot = s_e * r + pos % r
    pos_slot = pos // r
    keep = pos_slot < cap_per_slot
    dest = jnp.where(keep, slot * cap_per_slot + pos_slot, -1)
    dest_row = jnp.full((a,), -1, jnp.int32).at[s_a].set(dest)
    src_token = jnp.full((n_rows,), -1, jnp.int32).at[
        jnp.where(keep, dest, n_rows)].set(s_a // topk, mode="drop")
    return dest_row.reshape(t, topk), src_token


# §Perf H3 toggle: token-sharded EP dispatch (the optimized layout).
# Flipped off by `dryrun --baseline` for the paper-faithful baseline table.
TOKEN_SHARDED_DEFAULT = True


def moe_apply_ep(p: nn.Params, cfg: ArchConfig, x: jnp.ndarray, *,
                 mesh, model_axis: str = "model",
                 data_spec=None, capacity_factor: float = 1.5,
                 token_sharded: bool = None):
    """x (B, S, D) with batch sharded over the data axes.

    Runs under shard_map: everything inside is per-device; the only
    communication is one all_to_all out and one back (plus psum for aux).

    token_sharded (§Perf H3): the seq dim additionally shards over the
    model axis, so each device routes/dispatches only its own tokens —
    dispatch buffers shrink by the model-axis size AND the layer consumes
    the Megatron-SP boundary layout directly (no entry all-gather).
    """
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map

    ep = mesh.shape[model_axis]
    e = cfg.n_experts
    assert e % ep == 0 or ep % e == 0, (e, ep)
    epl = max(1, e // ep)           # local experts per shard
    b, s, d = x.shape
    if data_spec is None:
        # all data-parallel axes present in the production mesh
        data_spec = tuple(a for a in ("pod", "data") if a in mesh.shape)

    # per-device token count (static): batch is sharded over data axes only
    n_data = 1
    for ax in (data_spec if isinstance(data_spec, tuple) else (data_spec,)):
        if ax is None:
            continue
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            n_data *= mesh.shape[a]
    if b % n_data != 0:
        # batch not shardable over data (e.g. long-context decode):
        # keep tokens replicated over data axes
        data_spec = None
        n_data = 1
    if token_sharded is None:
        token_sharded = TOKEN_SHARDED_DEFAULT
    token_sharded = token_sharded and s % ep == 0
    seq_spec = model_axis if token_sharded else None
    n_seq = ep if token_sharded else 1
    t_loc = (b // n_data) * (s // n_seq)
    n_slots = max(e, ep)
    cap = _round_up(int(t_loc * cfg.topk * capacity_factor / n_slots) + 1, 8)

    gated = "w_gate" in p
    act = act_fn(cfg.act)

    def local_fn(xl, router_w, w_in, w_gate, w_out):
        # xl (b_loc, s_loc, d); weights already shard-local: (epl, D, F)
        bl, sl = xl.shape[0], xl.shape[1]
        x2 = xl.reshape(-1, d)
        gates, idx, aux = route({"router": {"w": router_w}}, cfg, x2)
        # aux differs per data shard (different tokens) but is replicated
        # across the model axis; return it per-shard and mean outside.
        aux = lax.pmean(aux, model_axis).reshape(1)
        dest, src_token = make_ep_dispatch(idx, e, ep, cap)   # (T, topk)

        # gather-based send building: no (T*topk, D) repeat materialised
        send = jnp.where(src_token[:, None] >= 0,
                         x2[jnp.maximum(src_token, 0)], 0)

        # (ep, epl*cap, D) -> exchange -> (ep_src, epl*cap, D)
        send = send.reshape(ep, epl * cap, d)
        recv = lax.all_to_all(send, model_axis, split_axis=0,
                              concat_axis=0, tiled=False)
        recv = recv.reshape(ep, epl, cap, d)

        outs = []
        for le in range(epl):
            rows = recv[:, le].reshape(ep * cap, d)           # one expert
            h = rows @ w_in[le]
            if gated:
                h = act(rows @ w_gate[le]) * h
            else:
                h = act(h)
            outs.append((h @ w_out[le]).reshape(ep, cap, d))
        back = jnp.stack(outs, axis=1)                        # (ep,epl,cap,D)
        back = back.reshape(ep, epl * cap, d)
        ret = lax.all_to_all(back, model_axis, split_axis=0,
                             concat_axis=0, tiled=False)
        ret = ret.reshape(n_slots * cap, d)

        picked = jnp.where(dest[..., None] >= 0,
                           ret[jnp.maximum(dest, 0)], 0.0)    # (T, topk, D)
        out = jnp.sum(picked * gates[..., None], axis=1)
        return out.reshape(bl, sl, d).astype(xl.dtype), aux

    # place weights: E >= ep -> shard expert dim; E < ep -> replicate r times
    w_in, w_out = p["w_in"], p["w_out"]
    w_gate = p.get("w_gate", jnp.zeros((e, d, 1), w_in.dtype))
    if ep > e:
        r = ep // e
        w_in = jnp.repeat(w_in, r, axis=0)
        w_out = jnp.repeat(w_out, r, axis=0)
        w_gate = jnp.repeat(w_gate, r, axis=0)

    out, aux = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(data_spec, seq_spec, None), P(None, None),
                  P(model_axis, None, None), P(model_axis, None, None),
                  P(model_axis, None, None)),
        out_specs=(P(data_spec, seq_spec, None), P(data_spec)),
        check_vma=False,
    )(x, p["router"]["w"], w_in, w_gate, w_out)
    return out, jnp.mean(aux)


def moe_apply(p, cfg, x, impl: str = "sorted", **kw):
    if impl == "dense":
        return moe_apply_dense(p, cfg, x)
    if impl == "sorted":
        return moe_apply_sorted(p, cfg, x, **kw)
    if impl == "ep":
        return moe_apply_ep(p, cfg, x, **kw)
    raise ValueError(impl)
