"""Causal LM assembly: heterogeneous sub-layer bodies scanned over depth.

The depth dimension is a `lax.scan` over "bodies" of `cfg.block_pattern`
sub-layers (1 for homogeneous stacks, 2 for gemma2 local/global, 8 for the
jamba 7:1 mamba:attn interleave).  Scanning keeps the HLO O(1) in depth —
essential for the 512-device dry-run compiles — and the per-body functions
are exported for the roofline accounting (body cost x n_bodies).

Modes: "train" (no state), "prefill" (produce per-body states), "decode"
(consume + produce).  States are pytrees stacked along the scan axis.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro import nn
from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import mamba as MB
from repro.models import moe as MOE
from repro.models import xlstm as XL


def _identity_shard(x, names):
    return x


class SubLayerSpec(NamedTuple):
    kind: str               # attn | mamba | mlstm | slstm
    ffn: Optional[str]      # dense | moe | None
    window: Optional[int]   # per-layer attention window


def body_layout(cfg: ArchConfig):
    """Static description of one scan body (cfg.block_pattern sub-layers)."""
    subs = []
    for i in range(cfg.block_pattern):
        if cfg.ssm_type == "xlstm":
            kind = "slstm" if (cfg.slstm_every and
                               i % cfg.slstm_every == cfg.slstm_every - 1) \
                else "mlstm"
            subs.append(SubLayerSpec(kind, None, None))
            continue
        if cfg.ssm_type == "mamba":
            # jamba: one attention layer per attn_every, middle of the block
            kind = "attn" if i == cfg.attn_every // 2 else "mamba"
        else:
            kind = "attn"
        if cfg.n_experts:
            ffn = "moe" if i % cfg.moe_every == cfg.moe_every - 1 else \
                "dense"
        else:
            ffn = "dense" if cfg.d_ff else None
        window = None
        if kind == "attn" and cfg.sliding_window is not None:
            if cfg.local_global:
                window = cfg.sliding_window if i % 2 == 0 else None
            else:
                window = cfg.sliding_window
        subs.append(SubLayerSpec(kind, ffn, window))
    return subs


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _sublayer_init(key, cfg: ArchConfig, spec: SubLayerSpec) -> nn.Params:
    ks = jax.random.split(key, 6)
    p: dict = {"norm_mix": L.norm_init(cfg, cfg.d_model)}
    if spec.kind == "attn":
        p["mix"] = L.attention_init(ks[0], cfg)
    elif spec.kind == "mamba":
        p["mix"] = MB.mamba_init(ks[0], cfg)
    elif spec.kind == "mlstm":
        p["mix"] = XL.mlstm_block_init(ks[0], cfg)
    elif spec.kind == "slstm":
        p["mix"] = XL.slstm_block_init(ks[0], cfg)
    if cfg.sandwich_norm:
        p["norm_mix_post"] = L.norm_init(cfg, cfg.d_model)
    if spec.ffn is not None:
        p["norm_ffn"] = L.norm_init(cfg, cfg.d_model)
        if spec.ffn == "moe":
            p["ffn"] = MOE.moe_init(ks[1], cfg)
        else:
            p["ffn"] = L.mlp_init(ks[1], cfg)
        if cfg.sandwich_norm:
            p["norm_ffn_post"] = L.norm_init(cfg, cfg.d_model)
    return p


def body_init(key, cfg: ArchConfig) -> nn.Params:
    specs = body_layout(cfg)
    ks = jax.random.split(key, len(specs))
    return {f"sub{i}": _sublayer_init(ks[i], cfg, s)
            for i, s in enumerate(specs)}


def lm_init(key, cfg: ArchConfig, dtype=jnp.float32) -> nn.Params:
    n_bodies = cfg.n_layers // cfg.block_pattern
    k_emb, k_body, k_head = jax.random.split(key, 3)
    body_keys = jax.random.split(k_body, n_bodies)
    layers = jax.vmap(lambda k: body_init(k, cfg))(body_keys)
    params = {
        "embed": nn.embedding_init(k_emb, cfg.vocab_size, cfg.d_model),
        "layers": layers,
        "final_norm": L.norm_init(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = nn.dense_init(k_head, cfg.d_model,
                                          cfg.vocab_size, use_bias=False)
    return nn.cast_floating(params, dtype)


# ---------------------------------------------------------------------------
# state init (prefill/decode caches)
# ---------------------------------------------------------------------------

def _sublayer_state(cfg: ArchConfig, spec: SubLayerSpec, batch: int,
                    max_len: int, dtype=jnp.bfloat16):
    if spec.kind == "attn":
        # SWA layers only ever hold a window of KV
        eff = min(max_len, spec.window) if spec.window else max_len
        return L.init_kv_cache(cfg, batch, eff, dtype)
    if spec.kind == "mamba":
        return MB.init_mamba_state(cfg, batch)
    if spec.kind == "mlstm":
        return XL.init_mlstm_state(cfg, batch)
    if spec.kind == "slstm":
        return XL.init_slstm_state(cfg, batch, dtype)
    raise ValueError(spec.kind)


def init_lm_state(cfg: ArchConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16):
    """Stacked per-body decode state (the serving 'KV cache' pytree)."""
    specs = body_layout(cfg)
    n_bodies = cfg.n_layers // cfg.block_pattern
    one = {f"sub{i}": _sublayer_state(cfg, s, batch, max_len, dtype)
           for i, s in enumerate(specs)}
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (n_bodies,) + x.shape), one)


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------

def sublayer_apply(p, cfg: ArchConfig, spec: SubLayerSpec, x, positions, *,
                   mode: str, state, cache_pos, shard, moe_impl, mesh):
    aux = jnp.zeros((), jnp.float32)
    h = L.norm_apply(cfg, p["norm_mix"], x)
    if spec.kind == "attn":
        h, new_state = L.attention_apply(
            p["mix"], cfg, h, positions, layer_window=spec.window,
            mode=mode, cache=state, cache_pos=cache_pos, shard=shard)
    elif spec.kind == "mamba":
        h, new_state = MB.mamba_apply(p["mix"], cfg, h, mode=mode,
                                      state=state, shard=shard)
    elif spec.kind == "mlstm":
        h, new_state = XL.mlstm_block_apply(p["mix"], cfg, h, mode=mode,
                                            state=state, shard=shard)
    elif spec.kind == "slstm":
        h, new_state = XL.slstm_block_apply(p["mix"], cfg, h, mode=mode,
                                            state=state, shard=shard)
    if cfg.sandwich_norm:
        h = L.norm_apply(cfg, p["norm_mix_post"], h)
    x = x + h

    if spec.ffn is not None:
        h = L.norm_apply(cfg, p["norm_ffn"], x)
        if spec.ffn == "moe":
            if moe_impl == "ep":
                h, aux = MOE.moe_apply_ep(p["ffn"], cfg, h, mesh=mesh)
            else:
                h, aux = MOE.moe_apply(p["ffn"], cfg, h, impl=moe_impl)
        else:
            h = L.mlp_apply(p["ffn"], cfg, h, shard=shard)
        if cfg.sandwich_norm:
            h = L.norm_apply(cfg, p["norm_ffn_post"], h)
        x = x + h
    return x, new_state, aux


def body_apply(p, cfg: ArchConfig, x, positions, *, mode: str,
               states=None, cache_pos=None, shard=_identity_shard,
               moe_impl: str = "sorted", mesh=None):
    specs = body_layout(cfg)
    new_states = {}
    aux = jnp.zeros((), jnp.float32)
    for i, spec in enumerate(specs):
        st = states[f"sub{i}"] if states is not None else None
        x, nst, a = sublayer_apply(
            p[f"sub{i}"], cfg, spec, x, positions, mode=mode, state=st,
            cache_pos=cache_pos, shard=shard, moe_impl=moe_impl, mesh=mesh)
        new_states[f"sub{i}"] = nst
        aux = aux + a
        x = shard(x, ("batch", "seq", "d_model"))
    return x, new_states, aux


def _pinned_embed_lookup(table, ids, mesh):
    """Vocab-sharded embedding lookup with a masked-local-take formulation.

    Written so SPMD keeps the (bf16) table sharded and combines per-shard
    partial rows with ONE (B,S,D)-sized reduction instead of all-gathering
    the (V,D) table in f32 (which is what the naive `take` compiled to —
    2.36 GB vs 0.3 GB for gemma2; §Perf H2 iter 5).  Pure pjit: the table
    is viewed as (n_shards, V/n, D) sharded on dim 0, every shard's local
    take is masked, and the sum over the shard dim becomes a psum.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P
    n = mesh.shape["model"]
    v, d = table.shape
    t3 = lax.with_sharding_constraint(
        table.reshape(n, v // n, d),
        NamedSharding(mesh, P("model", None, None)))
    loc = ids % (v // n)                       # (B, S)
    owner = ids // (v // n)                    # which shard holds the row
    rows = jnp.take(t3, loc, axis=1)           # (n, B, S, D)
    # force the take shard-local (otherwise SPMD all-gathers the f32 parent
    # of the table before converting — 8x the wire bytes)
    data = tuple(a for a in ("pod", "data") if a in mesh.shape)
    rows = lax.with_sharding_constraint(
        rows, NamedSharding(mesh, P("model", data, None, None)))
    mask = jax.nn.one_hot(owner, n, dtype=table.dtype)   # (B, S, n)
    out = jnp.einsum("nbsd,bsn->bsd", rows, mask)        # psum over n
    return out


# §Perf H2 toggle: masked-local-lookup embedding (the optimized path).
# Flipped off by `dryrun --baseline` for the paper-faithful baseline table.
PINNED_EMBED_DEFAULT = False


def embed_tokens(params, cfg: ArchConfig, tokens, embeds=None, mesh=None):
    """Token embedding (+ optional modality-frontend embeddings prepended —
    the audio/vlm stubs per the assignment)."""
    table = params["embed"]["emb"]
    if PINNED_EMBED_DEFAULT and mesh is not None and \
            "model" in mesh.shape and \
            cfg.vocab_size % mesh.shape["model"] == 0 and \
            cfg.vocab_size >= 8192:
        x = _pinned_embed_lookup(table, tokens, mesh)
    else:
        x = nn.embed(params["embed"], tokens)
    if cfg.embed_scale:
        x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    return x


def lm_head(params, cfg: ArchConfig, x, shard=_identity_shard):
    x = L.norm_apply(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["emb"].T
    else:
        logits = nn.dense(params["lm_head"], x)
    if cfg.final_softcap is not None:
        logits = cfg.final_softcap * jnp.tanh(
            logits.astype(jnp.float32) / cfg.final_softcap)
    return shard(logits, ("batch", "seq", "vocab"))


def lm_apply(params, cfg: ArchConfig, tokens, positions, *,
             mode: str = "train", states=None, cache_pos=None,
             shard=_identity_shard, moe_impl: str = "sorted", mesh=None,
             embeds=None, return_hidden: bool = False, remat: bool = False):
    """tokens (B, S); positions (B, S[, 3]).  Returns
    (logits_or_hidden, new_states, aux)."""
    x = embed_tokens(params, cfg, tokens, embeds, mesh=mesh)
    x = shard(x, ("batch", "seq", "d_model"))

    from repro import costmode
    unroll = costmode.enabled()

    def _depth_scan(scan_fn, carry, xs):
        """lax.scan over bodies, or an unrolled python loop under cost
        mode (see repro.costmode)."""
        if not unroll:
            return lax.scan(scan_fn, carry, xs)
        n = jax.tree_util.tree_leaves(xs)[0].shape[0]
        ys = []
        for i in range(n):
            xi = jax.tree_util.tree_map(lambda a: a[i], xs)
            carry, y = scan_fn(carry, xi)
            ys.append(y)
        if ys and ys[0] is not None:
            ys = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
        else:
            ys = None
        return carry, ys

    if mode == "train":
        def body_fn(x, p_body):
            y, _, a = body_apply(p_body, cfg, x, positions, mode="train",
                                 shard=shard, moe_impl=moe_impl, mesh=mesh)
            return y, a
        if remat:
            # full remat per body: only the (SP-sharded) boundary
            # activations survive the forward pass
            body_fn = jax.checkpoint(
                body_fn, policy=jax.checkpoint_policies.nothing_saveable)

        def scan_fn(carry, p_body):
            x, aux = carry
            x, a = body_fn(x, p_body)
            return (x, aux + a), None
        (x, aux), _ = _depth_scan(scan_fn, (x, jnp.zeros((), jnp.float32)),
                                  params["layers"])
        new_states = None
    elif mode == "prefill":
        def scan_fn(carry, p_body):
            x, aux = carry
            x, nst, a = body_apply(p_body, cfg, x, positions,
                                   mode="prefill", shard=shard,
                                   moe_impl=moe_impl, mesh=mesh)
            return (x, aux + a), nst
        (x, aux), new_states = _depth_scan(
            scan_fn, (x, jnp.zeros((), jnp.float32)), params["layers"])
    elif mode == "decode":
        def scan_fn(carry, xs):
            x, aux = carry
            p_body, st = xs
            x, nst, a = body_apply(p_body, cfg, x, positions, mode="decode",
                                   states=st, cache_pos=cache_pos,
                                   shard=shard, moe_impl=moe_impl, mesh=mesh)
            return (x, aux + a), nst
        (x, aux), new_states = _depth_scan(
            scan_fn, (x, jnp.zeros((), jnp.float32)),
            (params["layers"], states))
    else:
        raise ValueError(mode)

    if return_hidden:
        return x, new_states, aux
    return lm_head(params, cfg, x, shard), new_states, aux
