"""SLO-aware overload control for the point-cloud serving runtime.

PR 6 gave the scheduler *mechanisms* against overload (a static
`max_backlog` shed bound, per-request deadlines, watchdog flushes) and
PR 9 the *telemetry* a controller needs (per-bucket scene counters,
queue-wait and latency histograms in one `MetricsRegistry`).  This
module closes the loop: an `OverloadController` reads the live
telemetry back into admission and dispatch, so the stack holds its
latency SLO when offered load exceeds capacity instead of queueing
until every completion is late.  Four cooperating pieces:

  * **Adaptive shedding** — the controller estimates each bucket's
    service rate online (EWMA over per-tick deltas of the
    `serve_scenes_total{instance,bucket}` counter — the per-bucket
    series; the instance-level `serve_request_latency_seconds` count
    cross-checks the aggregate) and derives the *effective* backlog
    bound from Little's law: a queue longer than
    `ceil(service_rate x slo.deadline_headroom_s)` cannot drain within
    the SLO, so admitting into it only manufactures late results.  The
    bound is clamped by the static `max_backlog` (never looser) and
    floored at `min_backlog`; with no rate estimate yet (cold start)
    only the static bound applies — the controller never sheds on a
    guess.  Shed and timeout `ServeError`s carry a computed
    `retry_after_s` hint (how long until the bucket drains below the
    bound at the observed rate).

  * **Priority lanes** — `submit(..., priority=)` orders a bucket's
    queue at flush time: higher priority first, earliest deadline first
    within a priority (EDF), FIFO within ties.  Only the *queue order*
    changes — micro-batch shapes and per-scene predictions stay
    bit-identical.

  * **Circuit breakers** — a `CircuitBreaker` per bucket (scheduler)
    and per worker (router) trips OPEN after `k_failures` failures
    inside `window_s` (failed dispatches / `exec_failed`, and
    watchdog-fired deadline flushes — both are "this target is not
    keeping up"); OPEN sheds admissions (scheduler) or routes around
    via the rendezvous ranking (router) for `cooldown_s`, then
    HALF_OPEN admits a single probe: success restores CLOSED, failure
    re-opens.  A probe that never resolves is taken over after another
    `cooldown_s` so a lost probe cannot wedge the breaker.

  * **Brownout ladder** — under *sustained* pressure (some bucket
    pinned at its effective bound for `escalate_after_s`) the
    controller degrades stepwise and recovers in reverse order once
    calm for `recover_after_s`:

        level 1: shrink `max_wait_s` by `wait_shrink` (cut batching
                 latency — partial batches flush sooner);
        level 2: cap `pipeline_depth` at `depth_cap` (bound in-flight
                 memory + queue-time amplification);
        level 3: shed every admission with
                 `priority < shed_below_priority` (lowest lane first —
                 the interactive lanes keep their SLO).

    Every transition is recorded as a `FlightRecorder` incident and a
    span event on the controller's own trace, so a brownout episode is
    reconstructible after the fact.

Wiring: `ServeScheduler(overload=OverloadPolicy(...))` builds and binds
one controller per scheduler; `ServeRouter(overload=...)` forwards the
policy to every worker's scheduler and keeps its own per-worker
breakers.  Every controller hook is gated on `is None` checks in the
scheduler/router hot paths — with no controller the serving paths are
bit-identical to the uncontrolled stack (asserted by tests and the
`serve/overload_goodput` bench parity check).

Thread-safety: the controller is owned by exactly one scheduler and
every method is called under that scheduler's lock (same discipline as
the metrics children) — no internal locking.
"""

from __future__ import annotations

import dataclasses
import math
import time
from collections import deque

from repro.serve import faults as FLT
from repro.serve.faults import ServeError

# breaker states (gauge encodes them 0/1/2 so dashboards can alert on
# "any breaker > 0")
CLOSED = "closed"
HALF_OPEN = "half_open"
OPEN = "open"
STATE_CODE = {CLOSED: 0, HALF_OPEN: 1, OPEN: 2}

MAX_BROWNOUT_LEVEL = 3


@dataclasses.dataclass(frozen=True)
class BreakerPolicy:
    """Circuit breaker tuning: trip after `k_failures` failures inside
    `window_s`; stay OPEN for `cooldown_s` before the HALF_OPEN probe
    (and take over a probe that has not resolved after another
    `cooldown_s`)."""

    k_failures: int = 5
    window_s: float = 2.0
    cooldown_s: float = 0.5

    def __post_init__(self):
        if self.k_failures < 1:
            raise ValueError("k_failures must be >= 1")
        if self.window_s <= 0 or self.cooldown_s <= 0:
            raise ValueError("window_s and cooldown_s must be > 0")


@dataclasses.dataclass(frozen=True)
class ServeSLO:
    """The latency objective the controller defends:
    `deadline_headroom_s` is the queueing budget — the longest a queue
    may take to drain (at the observed service rate) before admitting
    into it would blow the SLO."""

    deadline_headroom_s: float = 0.25

    def __post_init__(self):
        if self.deadline_headroom_s <= 0:
            raise ValueError("deadline_headroom_s must be > 0")


@dataclasses.dataclass(frozen=True)
class BrownoutPolicy:
    """Brownout ladder tuning (see the module docstring for the level
    semantics).  Escalation requires pressure *sustained* for
    `escalate_after_s`; recovery requires calm for `recover_after_s`
    (longer, so the ladder does not flap)."""

    escalate_after_s: float = 0.5
    recover_after_s: float = 1.0
    wait_shrink: float = 0.5
    depth_cap: int = 1
    shed_below_priority: int = 0

    def __post_init__(self):
        if self.escalate_after_s <= 0 or self.recover_after_s <= 0:
            raise ValueError("escalate/recover intervals must be > 0")
        if not 0.0 < self.wait_shrink <= 1.0:
            raise ValueError("wait_shrink must be in (0, 1]")
        if self.depth_cap < 0:
            raise ValueError("depth_cap must be >= 0")


@dataclasses.dataclass(frozen=True)
class OverloadPolicy:
    """Everything the controller needs: the SLO, the estimator cadence
    (`tick_s` between rate re-estimates, `ewma_alpha` smoothing), the
    adaptive bound floor (`min_backlog` — the bound never starves a
    bucket below this many outstanding scenes), and the breaker +
    brownout sub-policies."""

    slo: ServeSLO = ServeSLO()
    tick_s: float = 0.05
    ewma_alpha: float = 0.4
    min_backlog: int = 1
    breaker: BreakerPolicy = BreakerPolicy()
    brownout: BrownoutPolicy = BrownoutPolicy()

    def __post_init__(self):
        if self.tick_s <= 0:
            raise ValueError("tick_s must be > 0")
        if not 0.0 < self.ewma_alpha <= 1.0:
            raise ValueError("ewma_alpha must be in (0, 1]")
        if self.min_backlog < 1:
            raise ValueError("min_backlog must be >= 1")


class CircuitBreaker:
    """CLOSED -> OPEN -> HALF_OPEN -> CLOSED failure breaker.

    Not internally locked: every call happens under the owning
    component's lock.  `now` is injectable everywhere so the state
    machine is unit-testable without sleeping.  `gauge` (optional) is a
    metrics Gauge child kept at the STATE_CODE of the current state.
    """

    def __init__(self, policy: BreakerPolicy, name: str = "",
                 gauge=None):
        self.policy = policy
        self.name = name
        self.gauge = gauge
        self.state = CLOSED
        self._failures: deque[float] = deque()
        self._opened_at: float | None = None
        self._probe_at: float | None = None
        self.n_trips = 0
        if gauge is not None:
            gauge.set(STATE_CODE[CLOSED])

    def _set(self, state: str) -> None:
        self.state = state
        if self.gauge is not None:
            self.gauge.set(STATE_CODE[state])

    def _prune(self, now: float) -> None:
        horizon = now - self.policy.window_s
        while self._failures and self._failures[0] < horizon:
            self._failures.popleft()

    def allow(self, now: float | None = None) -> bool:
        """May a request be admitted/routed to this target right now?
        The first allow after the cooldown IS the half-open probe —
        callers must report its outcome via record_success/failure."""
        now = time.monotonic() if now is None else now
        if self.state == CLOSED:
            return True
        if self.state == OPEN:
            if now - self._opened_at >= self.policy.cooldown_s:
                self._set(HALF_OPEN)
                self._probe_at = now
                return True
            return False
        # HALF_OPEN: one probe at a time, but a probe that never
        # resolved (lost request) is taken over after a cooldown
        if self._probe_at is None or \
                now - self._probe_at >= self.policy.cooldown_s:
            self._probe_at = now
            return True
        return False

    def record_failure(self, now: float | None = None) -> bool:
        """Count one failure; returns True when this call TRIPPED the
        breaker (CLOSED->OPEN or a failed HALF_OPEN probe)."""
        now = time.monotonic() if now is None else now
        self._failures.append(now)
        self._prune(now)
        if self.state == HALF_OPEN:
            self._opened_at = now
            self._probe_at = None
            self.n_trips += 1
            self._set(OPEN)
            return True
        if self.state == CLOSED and \
                len(self._failures) >= self.policy.k_failures:
            self._opened_at = now
            self.n_trips += 1
            self._set(OPEN)
            return True
        return False

    def record_success(self, now: float | None = None) -> None:
        """A request against this target completed fine; a HALF_OPEN
        probe success restores CLOSED and clears the failure window."""
        if self.state == HALF_OPEN:
            self._failures.clear()
            self._opened_at = None
            self._probe_at = None
            self._set(CLOSED)

    def retry_after(self, now: float | None = None) -> float:
        """Seconds until the next probe slot (the retry hint a shed
        caused by this breaker should carry)."""
        now = time.monotonic() if now is None else now
        anchor = self._opened_at if self.state == OPEN else self._probe_at
        if anchor is None:
            return 0.0
        return max(0.0, anchor + self.policy.cooldown_s - now)


class OverloadController:
    """The control loop: telemetry -> admission/dispatch policy.

    Owned by exactly one `ServeScheduler` (`bind()` wires the gauges and
    records the knobs the brownout ladder mutates); every method is
    called under that scheduler's lock.  `clock` is injectable for
    deterministic tests.
    """

    def __init__(self, policy: OverloadPolicy | None = None,
                 clock=time.monotonic):
        self.policy = policy if policy is not None else OverloadPolicy()
        self._clock = clock
        self._sched = None
        self._rates: dict[int, float] = {}       # cap -> EWMA scenes/s
        # completions per bucket, fed by record_dispatch_success at
        # retire time: the estimator MUST measure service (completion)
        # throughput — the dispatch-time scene counters track admission
        # under deferred dispatch, and an estimator reading those
        # converges on the offered rate instead of capacity
        self._completed: dict[int, int] = {}
        self._prev_scenes: dict[int, int] = {}   # cap -> last fold value
        self._last_fold: dict[int, float] = {}   # cap -> last delta>0 time
        self._est_start: float | None = None     # first-snapshot time
        self._prev_lat_count = 0
        self._total_fold: float | None = None    # last aggregate fold
        self._total_rate = 0.0                   # EWMA completions/s
        self._last_tick: float | None = None
        self.level = 0
        self.n_transitions = 0
        self._pressure_since: float | None = None
        self._calm_since: float | None = None
        self._bucket_breakers: dict[int, CircuitBreaker] = {}
        self._orig_max_wait_s = None
        self._orig_pipeline_depth = None
        self._trace_id = None
        # gauges bound at bind()
        self._g_state = None
        self._fam_eff = None
        self._fam_breaker = None

    # -- wiring ------------------------------------------------------------

    def bind(self, sched) -> None:
        """Attach to the owning scheduler: register the controller
        gauges under its instance label and record the original values
        of the knobs the brownout ladder mutates."""
        self._sched = sched
        self._orig_max_wait_s = sched.max_wait_s
        self._orig_pipeline_depth = sched.pipeline_depth
        reg, inst = sched.obs.registry, sched.instance
        self._g_state = reg.gauge(
            "serve_overload_state",
            "brownout ladder level (0 = nominal)",
            ("instance",)).labels(inst)
        self._g_state.set(0)
        self._fam_eff = reg.gauge(
            "serve_effective_backlog",
            "adaptive per-bucket admission bound (Little's law)",
            ("instance", "bucket"))
        self._fam_breaker = reg.gauge(
            "serve_breaker_state",
            "circuit breaker state (0 closed / 1 half-open / 2 open)",
            ("instance", "target"))

    def close(self) -> None:
        """Restore the knobs the ladder mutated and close the
        controller's trace (if transitions opened one)."""
        if self._sched is not None and self.level > 0:
            self._sched.max_wait_s = self._orig_max_wait_s
            self._sched.pipeline_depth = self._orig_pipeline_depth
        tr = self._tracer()
        if tr is not None and self._trace_id is not None:
            tr.end(self._trace_id, outcome="ok")
            self._trace_id = None

    def _tracer(self):
        return self._sched.obs.tracer if self._sched is not None else None

    def bucket_breaker(self, cap: int) -> CircuitBreaker:
        br = self._bucket_breakers.get(cap)
        if br is None:
            gauge = None
            if self._fam_breaker is not None:
                gauge = self._fam_breaker.labels(
                    self._sched.instance, f"bucket:{cap}")
            br = CircuitBreaker(self.policy.breaker,
                                name=f"bucket:{cap}", gauge=gauge)
            self._bucket_breakers[cap] = br
        return br

    # -- rate estimation ---------------------------------------------------

    def maybe_tick(self, now: float | None = None) -> None:
        """Rate-limited tick: cheap no-op until `tick_s` has elapsed
        since the last estimate (called opportunistically from the
        scheduler's deadline sweep, i.e. from submit()/poll() and the
        watchdog)."""
        now = self._clock() if now is None else now
        if self._last_tick is not None and \
                now - self._last_tick < self.policy.tick_s:
            return
        self.tick(now)

    def tick(self, now: float | None = None) -> None:
        """One estimator step: fold the per-bucket completion-counter
        deltas into the EWMA service rates, refresh the effective-
        backlog gauges, and advance the brownout ladder.

        A rate sample is taken only on ticks where scenes COMPLETED,
        over the elapsed time since the bucket's previous completion-
        bearing tick.  Retirement lands in whole micro-batches, so the
        zero-delta ticks between completions carry no rate information
        — folding them in would whipsaw the EWMA toward zero exactly
        when the admission bound matters most.  Idle buckets likewise
        keep their last estimate."""
        now = self._clock() if now is None else now
        sched = self._sched
        if self._last_tick is None:
            # first tick only snapshots the counters — a rate needs two
            # observations
            self._last_tick = now
            self._est_start = now
            for cap, done in self._completed.items():
                self._prev_scenes[cap] = done
                self._last_fold[cap] = now
            self._prev_lat_count = sched._h_latency.count
            return
        if now - self._last_tick <= 0:
            return
        self._last_tick = now
        a = self.policy.ewma_alpha
        for cap, cur in self._completed.items():
            delta = cur - self._prev_scenes.get(cap, 0)
            if delta <= 0:
                continue
            self._prev_scenes[cap] = cur
            since = now - self._last_fold.get(cap, self._est_start)
            self._last_fold[cap] = now
            if since <= 0:
                continue
            inst = delta / since
            old = self._rates.get(cap)
            self._rates[cap] = inst if old is None else \
                (1.0 - a) * old + a * inst
        # aggregate completion rate (latency-histogram count deltas) —
        # the cross-check series the retry hints fall back to
        lat_count = sched._h_latency.count
        lat_delta = lat_count - self._prev_lat_count
        if lat_delta > 0:
            self._prev_lat_count = lat_count
            since = now - (self._total_fold if self._total_fold
                           is not None else self._est_start)
            self._total_fold = now
            if since > 0:
                inst = lat_delta / since
                self._total_rate = inst if self._total_rate <= 0 else \
                    (1.0 - a) * self._total_rate + a * inst
        self._update_brownout(now)

    def service_rate(self, cap: int) -> float | None:
        """EWMA scenes/s for one bucket; None before the estimator has
        seen the bucket complete work."""
        return self._rates.get(cap)

    def effective_backlog(self, cap: int) -> int | None:
        """Little's-law admission bound for one bucket:
        ceil(service_rate x deadline_headroom_s), floored at
        `min_backlog` AND at two full micro-batches (one executing, one
        assembling — bounding below that cannot sustain continuous
        batching, and would starve the very throughput the bound is
        estimated from), clamped by the static `max_backlog`.  None
        means unbounded (no rate estimate AND no static bound)."""
        static = self._sched.max_backlog
        rate = self._rates.get(cap)
        if rate is None or rate <= 0:
            return static
        bound = max(self.policy.min_backlog,
                    2 * self._sched.max_batch_for(cap),
                    math.ceil(rate * self.policy.slo.deadline_headroom_s))
        if static is not None:
            bound = min(bound, static)
        if self._fam_eff is not None:
            self._fam_eff.labels(self._sched.instance,
                                 str(cap)).set(bound)
        return bound

    def retry_after(self, cap: int, outstanding: int) -> float:
        """Backpressure hint: estimated seconds until this bucket has
        drained below its effective bound at the observed service rate
        (the `retry_after_s` a shed/timeout ServeError carries)."""
        rate = self._rates.get(cap)
        if rate is not None and rate > 0:
            bound = self.effective_backlog(cap)
            excess = outstanding - (bound if bound is not None
                                    else outstanding) + 1
            return max(0.0, excess / rate)
        return self.policy.slo.deadline_headroom_s

    def retry_after_hint(self) -> float:
        """Instance-aggregate hint (routers aggregate these across
        workers): total outstanding work over the total observed
        completion rate, falling back to the SLO headroom."""
        sched = self._sched
        total_out = sum(sched._outstanding.values())
        if self._total_rate > 0:
            return max(0.0, total_out / self._total_rate)
        return self.policy.slo.deadline_headroom_s

    # -- admission ---------------------------------------------------------

    def check_admission_locked(self, cap: int, outstanding: int,
                               priority: int) -> ServeError | None:
        """The controller's admission gate, called from submit() under
        the scheduler lock AFTER the static max_backlog check (the
        static path's behaviour and message stay exactly PR-6).  Returns
        the shed error, or None to admit."""
        now = self._clock()
        self.maybe_tick(now)
        bp = self.policy.brownout
        if self.level >= 3 and priority < bp.shed_below_priority:
            return ServeError(
                FLT.SHED,
                f"brownout level {self.level}: priority {priority} lane "
                f"shed (lanes below {bp.shed_below_priority} are browned "
                f"out)", retry_after_s=self.retry_after(cap, outstanding))
        br = self._bucket_breakers.get(cap)
        if br is not None and br.state != CLOSED and not br.allow(now):
            return ServeError(
                FLT.SHED,
                f"bucket {cap} circuit breaker {br.state} after repeated "
                f"dispatch failures ({br.policy.k_failures} in "
                f"{br.policy.window_s}s window)",
                retry_after_s=br.retry_after(now))
        bound = self.effective_backlog(cap)
        static = self._sched.max_backlog
        if bound is not None and outstanding >= bound and \
                (static is None or bound < static):
            # tighter than the static bound -> the adaptive shed; at the
            # static bound the scheduler's own check fires (message
            # compatibility) with the retry hint attached
            rate = self._rates.get(cap)
            return ServeError(
                FLT.SHED,
                f"bucket {cap} backlog at the adaptive bound ({outstanding}"
                f" outstanding >= {bound}; service rate "
                f"{rate:.1f} scenes/s x {self.policy.slo.deadline_headroom_s}"
                f"s headroom; static max_backlog "
                f"{static if static is not None else 'unbounded'})",
                retry_after_s=self.retry_after(cap, outstanding))
        return None

    # -- breaker hooks -----------------------------------------------------

    def record_dispatch_success(self, cap: int, n_scenes: int = 0) -> None:
        """A micro-batch retired cleanly: feed the breaker and count its
        `n_scenes` real scenes toward the bucket's service-rate
        estimate (the estimator's ONLY input — see tick())."""
        if n_scenes > 0:
            self._completed[cap] = self._completed.get(cap, 0) + n_scenes
        br = self._bucket_breakers.get(cap)
        if br is not None:
            br.record_success(self._clock())

    def record_dispatch_failure(self, cap: int) -> None:
        br = self.bucket_breaker(cap)
        if br.record_failure(self._clock()):
            self._incident("breaker_trip", target=f"bucket:{cap}",
                           state=br.state, trips=br.n_trips)

    # -- brownout ladder ---------------------------------------------------

    def _update_brownout(self, now: float) -> None:
        bp = self.policy.brownout
        sched = self._sched
        pressured = False
        for cap, out in sched._outstanding.items():
            if out <= 0:
                continue
            bound = self.effective_backlog(cap)
            if bound is not None and out >= bound:
                pressured = True
                break
        if pressured:
            self._calm_since = None
            if self._pressure_since is None:
                self._pressure_since = now
            elif now - self._pressure_since >= bp.escalate_after_s \
                    and self.level < MAX_BROWNOUT_LEVEL:
                self._transition(self.level + 1, now)
                self._pressure_since = now      # re-arm for the next step
        else:
            self._pressure_since = None
            if self.level == 0:
                self._calm_since = None
            elif self._calm_since is None:
                self._calm_since = now
            elif now - self._calm_since >= bp.recover_after_s:
                self._transition(self.level - 1, now)
                self._calm_since = now          # re-arm for the next step

    def _transition(self, level: int, now: float) -> None:
        """Move the ladder one step and apply the level's knob values
        (originals restored on the way back down)."""
        prev, self.level = self.level, level
        self.n_transitions += 1
        bp = self.policy.brownout
        sched = self._sched
        if self._orig_max_wait_s is not None:
            sched.max_wait_s = self._orig_max_wait_s \
                if level < 1 else self._orig_max_wait_s * bp.wait_shrink
        sched.pipeline_depth = self._orig_pipeline_depth \
            if level < 2 else min(self._orig_pipeline_depth, bp.depth_cap)
        if self._g_state is not None:
            self._g_state.set(level)
        self._incident("brownout", prev_level=prev, level=level,
                       direction="escalate" if level > prev else "recover",
                       max_wait_s=sched.max_wait_s,
                       pipeline_depth=sched.pipeline_depth)

    def _incident(self, kind: str, **attrs) -> None:
        """One controller incident: a FlightRecorder dump + a span event
        on the controller's own trace (opened lazily, closed by
        close())."""
        sched = self._sched
        rec = sched.obs.recorder
        if rec is not None:
            rec.record(kind, instance=sched.instance, **attrs)
            rec.dump(kind, key=(kind, sched.instance,
                                self.n_transitions,
                                sum(b.n_trips
                                    for b in self._bucket_breakers.values())))
        tr = self._tracer()
        if tr is not None:
            if self._trace_id is None:
                self._trace_id = f"{sched.instance}:overload"
                tr.begin(self._trace_id, instance=sched.instance,
                         controller=True)
            tr.event(self._trace_id, kind, **attrs)

    # -- telemetry ---------------------------------------------------------

    def stats(self) -> dict:
        """Controller state snapshot (NOT part of the frozen scheduler
        stats() schema — callers reach it via `sched.overload`)."""
        return {
            "level": self.level,
            "transitions": self.n_transitions,
            "service_rate": {int(c): r for c, r in self._rates.items()},
            "total_rate": self._total_rate,
            "effective_backlog": {
                int(c): self.effective_backlog(c) for c in self._rates},
            "breakers": {b.name: {"state": b.state, "trips": b.n_trips}
                         for b in self._bucket_breakers.values()},
        }


def resolve_controller(overload) -> OverloadController | None:
    """Normalize the `overload=` constructor argument: None stays off,
    True means default policy, a policy builds a controller, a
    controller is used as-is."""
    if overload is None or overload is False:
        return None
    if overload is True:
        return OverloadController(OverloadPolicy())
    if isinstance(overload, OverloadPolicy):
        return OverloadController(overload)
    if isinstance(overload, OverloadController):
        return overload
    raise TypeError(
        f"overload= takes None/True/OverloadPolicy/OverloadController, "
        f"got {type(overload).__name__}")
