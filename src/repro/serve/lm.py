"""Token-LM serving engine (the non-point-cloud half of `repro.serve`).

`prefill_step` / `decode_step` are the jit-able pure functions the dry-run
lowers for the decode_* / long_* shapes.  `ServeEngine` drives them for the
runnable examples: static-batch greedy generation with slot bookkeeping
(a continuous-batching slot refill hook is provided but refills re-run
prefill on the whole slot batch — documented trade-off for simplicity).

This lives apart from `serve.engine` (the PointAcc point-cloud serving
stack) on purpose: the two share nothing but the word "serve".
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.distributed import sharding as SH
from repro.models.registry import Model


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 1024
    cache_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    greedy: bool = True
    temperature: float = 1.0


def make_prefill_step(model: Model, svc: ServeConfig,
                      sc: Optional[SH.ShardingConfig] = None):
    shard = SH.make_shard_fn(sc) if sc is not None else \
        (lambda x, names: x)
    mesh = sc.mesh if sc is not None else None

    def prefill_step(params, batch):
        cparams = nn.cast_floating(params, svc.compute_dtype)
        logits, states, _ = model.prefill(cparams, batch, shard=shard,
                                          mesh=mesh)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, states

    return prefill_step


def make_decode_step(model: Model, svc: ServeConfig,
                     sc: Optional[SH.ShardingConfig] = None):
    shard = SH.make_shard_fn(sc) if sc is not None else \
        (lambda x, names: x)
    mesh = sc.mesh if sc is not None else None

    def decode_step(params, states, batch):
        cparams = nn.cast_floating(params, svc.compute_dtype)
        logits, states, _ = model.decode(cparams, batch, states,
                                         shard=shard, mesh=mesh)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, states

    return decode_step


class ServeEngine:
    """Greedy batched generation over fixed slots."""

    def __init__(self, model: Model, params, svc: ServeConfig,
                 sc: Optional[SH.ShardingConfig] = None):
        self.model = model
        self.params = params
        self.svc = svc
        self.prefill_step = jax.jit(make_prefill_step(model, svc, sc))
        self.decode_step = jax.jit(make_decode_step(model, svc, sc),
                                   donate_argnums=(1,))

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 eos_id: int = -1) -> np.ndarray:
        """prompts (B, S) int32 -> generated ids (B, max_new_tokens)."""
        b, s = prompts.shape
        cfg = self.model.cfg
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        batch = {"tokens": jnp.asarray(prompts), "positions": positions}
        tok, pre_states = self.prefill_step(self.params, batch)

        # place prefill states into max_len decode buffers
        init = self.model.init_state(b, self.svc.max_len,
                                     self.svc.cache_dtype)

        def place(dst, src):
            src = src.astype(dst.dtype)
            if src.shape == dst.shape:
                return src
            pad = [(0, d - s_) for d, s_ in zip(dst.shape, src.shape)]
            return jnp.pad(src, pad)

        states = jax.tree_util.tree_map(place, init, pre_states)

        out = np.zeros((b, max_new_tokens), np.int32)
        done = np.zeros(b, bool)
        pos = s
        for t in range(max_new_tokens):
            out[:, t] = np.asarray(tok)
            done |= np.asarray(tok) == eos_id
            if done.all():
                break
            dec_batch = {
                "tokens": tok[:, None],
                "positions": jnp.full((b, 1), pos, jnp.int32),
                "cache_pos": jnp.full((b,), pos, jnp.int32),
            }
            tok, states = self.decode_step(self.params, states, dec_batch)
            pos += 1
        return out
