"""Capacity-bucket policy for continuous batching of point-cloud scenes.

Real LiDAR streams have heterogeneous point counts; a jit'd serving path
recompiles for every distinct capacity it sees.  The classic fix (the
TorchSparse "adaptive grouping" observation, applied to shapes instead of
workloads) is a *bucket ladder*: a small geometric set of capacities every
scene is padded up to, so the number of compiled programs is bounded by
the number of buckets — not by the number of distinct scene sizes — while
the padding overhead per scene is bounded by the ladder's growth factor.

`BucketLadder` is pure policy (no jax); `pad_scene` is the mechanism: pad
rows up to the bucket capacity with SENTINEL coordinates and a False
mask, which the mapping engine already treats as "not a point" (sentinel
keys sort to the end and never match), so a padded scene produces
bit-compatible mapping work and numerically identical valid-row outputs.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import mapping as M


@dataclasses.dataclass(frozen=True)
class BucketLadder:
    """An ascending tuple of scene capacities (the compile-shape budget)."""

    capacities: tuple[int, ...]

    def __post_init__(self):
        caps = tuple(int(c) for c in self.capacities)
        if not caps or any(c <= 0 for c in caps):
            raise ValueError("BucketLadder needs positive capacities, got "
                             f"{self.capacities}")
        if list(caps) != sorted(set(caps)):
            raise ValueError("BucketLadder capacities must be strictly "
                             f"ascending, got {self.capacities}")
        object.__setattr__(self, "capacities", caps)

    @property
    def n_buckets(self) -> int:
        return len(self.capacities)

    def index_for(self, n_points: int) -> int:
        """Index of the smallest bucket holding an n_points-row scene."""
        for i, cap in enumerate(self.capacities):
            if n_points <= cap:
                return i
        raise ValueError(
            f"scene with {n_points} points exceeds the bucket ladder "
            f"(max capacity {self.capacities[-1]}); extend the ladder")

    def bucket_for(self, n_points: int) -> int:
        """Capacity of the smallest bucket holding the scene."""
        return self.capacities[self.index_for(n_points)]

    def padding_fraction(self, n_points: int) -> float:
        """Wasted fraction of the bucket a scene of n_points rows pays."""
        cap = self.bucket_for(n_points)
        return 1.0 - n_points / cap


def geometric_ladder(min_capacity: int = 128, max_capacity: int = 65536,
                     growth: float = 2.0) -> BucketLadder:
    """Geometric capacity ladder: worst-case padding = 1 - 1/growth.

    Capacities are rounded up to multiples of 8 so downstream tiled
    kernels never see ragged row counts.
    """
    if growth <= 1.0:
        raise ValueError(f"ladder growth must be > 1, got {growth}")
    caps, c = [], float(min_capacity)
    while True:
        cap = int(8 * math.ceil(c / 8))
        if not caps or cap > caps[-1]:
            caps.append(cap)
        if cap >= max_capacity:
            break
        c *= growth
    return BucketLadder(tuple(caps))


DEFAULT_LADDER = geometric_ladder()


def pad_scene(coords, mask, feats, capacity: int):
    """Pad one scene's (coords, mask, feats) rows up to `capacity`.

    Invalid rows (padding AND pre-existing masked rows) get SENTINEL
    coordinates and zero features, matching `mapping.make_point_cloud`
    normalisation, so the padded scene maps and convolves identically to
    the original on its valid rows.  Host-side numpy: padding happens at
    admission time, before arrays are stacked and shipped to the device.
    """
    coords = np.asarray(coords)
    mask = np.asarray(mask, bool)
    n = coords.shape[0]
    if capacity < n:
        raise ValueError(f"cannot pad a {n}-row scene down to {capacity}")
    out_c = np.full((capacity, coords.shape[1]), M.SENTINEL, np.int32)
    out_c[:n] = np.where(mask[:, None], coords.astype(np.int32), M.SENTINEL)
    out_m = np.zeros(capacity, bool)
    out_m[:n] = mask
    if feats is None:
        return out_c, out_m, None
    feats = np.asarray(feats)
    out_f = np.zeros((capacity,) + feats.shape[1:], feats.dtype)
    out_f[:n] = np.where(mask.reshape((n,) + (1,) * (feats.ndim - 1)),
                         feats, 0)
    return out_c, out_m, out_f
