"""Capacity-bucket policy for continuous batching of point-cloud scenes.

Real LiDAR streams have heterogeneous point counts; a jit'd serving path
recompiles for every distinct capacity it sees.  The classic fix (the
TorchSparse "adaptive grouping" observation, applied to shapes instead of
workloads) is a *bucket ladder*: a small geometric set of capacities every
scene is padded up to, so the number of compiled programs is bounded by
the number of buckets — not by the number of distinct scene sizes — while
the padding overhead per scene is bounded by the ladder's growth factor.

`BucketLadder` is pure policy (no jax); `pad_scene` is the mechanism: pad
rows up to the bucket capacity with SENTINEL coordinates and a False
mask, which the mapping engine already treats as "not a point" (sentinel
keys sort to the end and never match), so a padded scene produces
bit-compatible mapping work and numerically identical valid-row outputs.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import mapping as M


DEFAULT_MAX_BATCH = 4


@dataclasses.dataclass(frozen=True)
class BucketLadder:
    """An ascending tuple of scene capacities (the compile-shape budget).

    `max_batch` optionally carries a per-capacity micro-batch width (same
    length as `capacities`) — ladder-level serving config, typically
    seeded from occupancy telemetry via `max_batch_from_occupancy` so
    rarely-full buckets stop waiting for (and dummy-filling) wide
    batches.  The scheduler still rounds every width up to a device
    multiple.
    """

    capacities: tuple[int, ...]
    max_batch: tuple[int, ...] | None = None

    def __post_init__(self):
        caps = tuple(int(c) for c in self.capacities)
        if not caps or any(c <= 0 for c in caps):
            raise ValueError("BucketLadder needs positive capacities, got "
                             f"{self.capacities}")
        if list(caps) != sorted(set(caps)):
            raise ValueError("BucketLadder capacities must be strictly "
                             f"ascending, got {self.capacities}")
        object.__setattr__(self, "capacities", caps)
        if self.max_batch is not None:
            mb = tuple(int(b) for b in self.max_batch)
            if len(mb) != len(caps) or any(b < 1 for b in mb):
                raise ValueError(
                    "BucketLadder max_batch needs one positive width per "
                    f"capacity, got {self.max_batch} for {caps}")
            object.__setattr__(self, "max_batch", mb)

    @property
    def n_buckets(self) -> int:
        return len(self.capacities)

    def index_for(self, n_points: int) -> int:
        """Index of the smallest bucket holding an n_points-row scene."""
        for i, cap in enumerate(self.capacities):
            if n_points <= cap:
                return i
        raise ValueError(
            f"scene with {n_points} points exceeds the bucket ladder "
            f"(max capacity {self.capacities[-1]}); extend the ladder")

    def bucket_for(self, n_points: int) -> int:
        """Capacity of the smallest bucket holding the scene."""
        return self.capacities[self.index_for(n_points)]

    def fits(self, n_points: int) -> bool:
        """Non-raising probe: does an n_points-row scene fit the ladder?
        (Admission control asks before `bucket_for` commits — an
        oversized scene becomes a `rejected` serve result, not a
        ValueError out of submit.)"""
        return 0 <= n_points <= self.capacities[-1]

    def padding_fraction(self, n_points: int) -> float:
        """Wasted fraction of the bucket a scene of n_points rows pays."""
        cap = self.bucket_for(n_points)
        return 1.0 - n_points / cap


def geometric_ladder(min_capacity: int = 128, max_capacity: int = 65536,
                     growth: float = 2.0) -> BucketLadder:
    """Geometric capacity ladder: worst-case padding = 1 - 1/growth.

    Capacities are rounded up to multiples of 8 so downstream tiled
    kernels never see ragged row counts.
    """
    if growth <= 1.0:
        raise ValueError(f"ladder growth must be > 1, got {growth}")
    caps, c = [], float(min_capacity)
    while True:
        cap = int(8 * math.ceil(c / 8))
        if not caps or cap > caps[-1]:
            caps.append(cap)
        if cap >= max_capacity:
            break
        c *= growth
    return BucketLadder(tuple(caps))


DEFAULT_LADDER = geometric_ladder()


def resolve_max_batch(spec, ladder: BucketLadder) -> tuple[int, dict]:
    """(default_width, {capacity: width}) from a max_batch spec.

    Accepts an int (uniform width), a {capacity: width} dict (optional
    "default" key for unlisted buckets), or None — which falls back to
    the ladder's own `max_batch` config when present, else
    `DEFAULT_MAX_BATCH`.  Override capacities must be on the ladder (a
    typo'd capacity would silently never match a bucket otherwise).
    """
    if spec is None:
        if ladder.max_batch is not None:
            return (DEFAULT_MAX_BATCH,
                    dict(zip(ladder.capacities, ladder.max_batch)))
        return DEFAULT_MAX_BATCH, {}
    if isinstance(spec, dict):
        overrides = dict(spec)
        default = int(overrides.pop("default", DEFAULT_MAX_BATCH))
        unknown = [c for c in overrides if int(c) not in ladder.capacities]
        if unknown:
            raise ValueError(
                f"max_batch overrides for capacities {unknown} not on the "
                f"ladder {ladder.capacities}")
        overrides = {int(c): int(b) for c, b in overrides.items()}
        widths = [default, *overrides.values()]
    else:
        default, overrides, widths = int(spec), {}, [int(spec)]
    if any(b < 1 for b in widths):
        raise ValueError(f"max_batch must be >= 1, got {spec}")
    return default, overrides


def max_batch_from_occupancy(bucket_stats: dict, default: int =
                             DEFAULT_MAX_BATCH, floor: int = 1) -> dict:
    """Seed per-bucket max_batch overrides from serving telemetry.

    `bucket_stats` is `ServeScheduler.stats()["buckets"]`; each bucket's
    suggested width is its observed mean real scenes per micro-batch
    (rounded up), clamped to [floor, default] — a bucket that mostly
    executed dummy-filled stops waiting for a full wide batch, a busy
    bucket keeps the full width.  Feed the result back as
    `ServeScheduler(max_batch={**overrides, "default": default})` or
    `BucketLadder(caps, max_batch=...)`.
    """
    out = {}
    for cap, b in bucket_stats.items():
        seen = math.ceil(b["scenes"] / b["batches"]) if b["batches"] else \
            default
        out[int(cap)] = max(floor, min(default, seen))
    return out


def pad_scene(coords, mask, feats, capacity: int):
    """Pad one scene's (coords, mask, feats) rows up to `capacity`.

    Invalid rows (padding AND pre-existing masked rows) get SENTINEL
    coordinates and zero features, matching `mapping.make_point_cloud`
    normalisation, so the padded scene maps and convolves identically to
    the original on its valid rows.  Host-side numpy: padding happens at
    admission time, before arrays are stacked and shipped to the device.
    """
    coords = np.asarray(coords)
    mask = np.asarray(mask, bool)
    n = coords.shape[0]
    if capacity < n:
        raise ValueError(f"cannot pad a {n}-row scene down to {capacity}")
    out_c = np.full((capacity, coords.shape[1]), M.SENTINEL, np.int32)
    out_c[:n] = np.where(mask[:, None], coords.astype(np.int32), M.SENTINEL)
    out_m = np.zeros(capacity, bool)
    out_m[:n] = mask
    if feats is None:
        return out_c, out_m, None
    feats = np.asarray(feats)
    out_f = np.zeros((capacity,) + feats.shape[1:], feats.dtype)
    out_f[:n] = np.where(mask.reshape((n,) + (1,) * (feats.ndim - 1)),
                         feats, 0)
    return out_c, out_m, out_f
