"""Multi-worker serving fabric: a digest-affinity router over a pool of
`ServeScheduler` workers.

One pipelined `ServeScheduler` maxes out a single engine; the "millions
of users" jump is a front-end `ServeRouter` that fans a request stream
out over N workers — each owning its OWN `PointCloudEngine` (private jit
entry points, `MappingCache`, `AssemblyCache`) and its own scheduler —
while keeping the cached-geometry hot path hot:

  * **digest affinity** — every admitted scene is hashed once
    (`PointCloudEngine.scene_key` over the bucket-padded geometry — the
    same digest the worker's scheduler uses for its mapping/assembly
    cache keys) and routed by *rendezvous hashing* (highest-random-
    weight) over the live workers.  Identical geometry therefore keeps
    landing on the worker that already holds its `MappingCache` /
    `AssemblyCache` entries, and when the pool changes only the keys
    that hashed to the departed/joined worker move — every other
    geometry keeps its warm worker;
  * **health-checked failover** — each worker thread beats a
    `launch.fault_tolerance.Pulse` every loop iteration; a background
    `Ticker` (and every blocking router call) runs the health check: a
    worker whose thread died is failed over immediately, and a worker
    whose pulse has gone stale past the `LivenessPolicy` (missed beats —
    a hung dispatch, a wedged device) is declared dead without waiting
    for it;
  * **in-flight replay** — failing a worker over first *salvages* any
    results already completed inside its scheduler (non-blocking poll),
    then REPLAYS everything still queued or in flight on it onto the
    surviving workers, re-routed by the same rendezvous ranking minus
    the dead worker.  Per-request replay attempts are bounded
    (`max_replays`, the router-level analogue of the scheduler's
    `max_retries`); exhaustion completes the request with the same typed
    `exec_failed` taxonomy as PR 6.  Replayed scenes re-run the same
    deterministic model, so survivors stay bit-identical to a no-fault
    run.  A late result from a worker that woke up after being declared
    dead is discarded by an ownership check — a request completes
    exactly once;
  * **elastic pool** — `add_worker()` joins a fresh worker (immediately
    rendezvous-eligible: only the keys that rank it first move);
    `remove_worker()` drains-then-leaves: the worker stops receiving new
    routes, finishes its outstanding work, then its scheduler closes and
    the thread joins;
  * **graceful degradation** — a submit with zero live workers, or with
    every live worker at its `max_backlog` outstanding bound, completes
    with a typed `shed` result instead of raising or queueing unbounded;
    replay with no survivors sheds the same way.  The stream keeps
    flowing at whatever capacity remains;
  * **aggregate telemetry** — `stats()` rolls the pool up (per-worker
    state / occupancy / cache rates + pooled totals, failovers, replayed
    requests, failure→recovered time) and nests each worker's full
    scheduler stats.

Worker chaos (`serve.faults.FaultPlan.kill_workers` / `hang_workers`)
threads through the worker-loop seam, so the failover and replay paths
are deterministic to test — and with one worker and no faults the router
is bit-identical to its bare scheduler (asserted, with overhead bounded,
by `benchmarks/bench_serve.py serve/router_overhead`).
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from collections import OrderedDict, deque

import numpy as np

from repro.api import MappingCache
from repro.launch import fault_tolerance as FT
from repro.obs import Observability
from repro.serve import buckets as BK
from repro.serve import faults as FLT
from repro.serve import overload as OV
from repro.serve.faults import ServeError
from repro.serve.scheduler import ServeResult, ServeScheduler

DEFAULT_MAX_REPLAYS = 2
# settle loops wake on every completion (condition notify); the timeout
# is only the fallback cadence for health checks / flush nudges while
# nothing completes, so it can be coarse without adding latency
_SETTLE_WAIT_S = 0.05

LIVE = "live"
DRAINING = "draining"
DEAD = "dead"
LEFT = "left"


@dataclasses.dataclass(frozen=True)
class LivenessPolicy:
    """When is a worker dead?

    beat_s     : target heartbeat cadence — the worker loop beats at
                 least this often while healthy (its idle wait is
                 beat_s / 2).
    miss_beats : a worker whose pulse is older than beat_s * miss_beats
                 is declared hung and failed over.  The default budget
                 (30s) is deliberately generous: a worker blocks its
                 loop for a full device wait — including a cold jit
                 compile, easily 10s+ for a full model — and a false
                 hang verdict costs a full replay.  `router.liveness`
                 is read live, so chaos tests (and latency-sensitive
                 deployments) warm the pool under the default policy,
                 then assign a tight one.
    health_s   : background health-check interval (None = beat_s).  The
                 check also runs inline in every blocking router call,
                 so failover latency is bounded by min(health_s,
                 caller's wait) even without the ticker.
    """

    beat_s: float = 0.25
    miss_beats: int = 120
    health_s: float | None = None

    def __post_init__(self):
        if self.beat_s <= 0 or self.miss_beats < 1:
            raise ValueError(
                f"LivenessPolicy needs beat_s > 0 and miss_beats >= 1, "
                f"got beat_s={self.beat_s}, miss_beats={self.miss_beats}")

    @property
    def stall_s(self) -> float:
        return self.beat_s * self.miss_beats


@dataclasses.dataclass
class _Routed:
    """Router-side record of one admitted request: everything needed to
    replay it on another worker if its current owner dies."""

    rrid: int
    key: bytes                  # rendezvous salt (geometry digest)
    coords: object
    feats: object
    mask: object
    n_points: int
    deadline: float | None      # absolute monotonic deadline (router)
    t_submit: float
    worker: "_Worker"
    attempts: int = 0           # completed-worker losses survived
    priority: int = 0           # lane (forwarded to the worker scheduler)


class _Worker:
    """One serving worker: a thread owning a private engine + scheduler.

    The router enqueues `(rrid, scene)` items into the worker's inbox;
    the loop admits them into the scheduler, publishes completed results
    back to the router (translating scheduler-local rids to router
    rids), and beats its `Pulse` every iteration so the router's
    liveness policy can tell a busy worker from a dead one.  All
    *blocking* work (scheduler flush — device waits included) happens on
    this thread, never on a router caller's, which is what makes a hung
    dispatch detectable and survivable.
    """

    def __init__(self, router: "ServeRouter", name: str, ordinal: int,
                 engine, sched_kwargs: dict):
        self.router = router
        self.name = name
        self.ordinal = ordinal
        self.engine = engine
        self.sched = ServeScheduler(engine, **sched_kwargs)
        self.pulse = FT.Pulse()
        self.state = LIVE
        self.cv = threading.Condition()
        self.inbox: deque = deque()
        self.local_rrid: dict[int, int] = {}   # scheduler rid -> router rid
        self.crash: BaseException | None = None
        self.reason: str | None = None
        self.n_processed = 0    # items admitted into the scheduler
        self.n_routed = 0       # items ever routed here (telemetry)
        self.assigned = 0       # incomplete router requests owned here
        self._flush_req = False
        self._stop = False
        self.thread = threading.Thread(target=self._run, daemon=True,
                                       name=f"serve-worker-{name}")
        self.thread.start()

    # -- router-side controls (called under the router lock) ---------------

    def enqueue(self, item) -> None:
        with self.cv:
            self.inbox.append(item)
            self.n_routed += 1
            self.cv.notify()

    def request_flush(self) -> None:
        with self.cv:
            self._flush_req = True
            self.cv.notify()

    def request_stop(self) -> None:
        with self.cv:
            self._stop = True
            self.cv.notify()

    def abandon(self) -> list:
        """Fail-over teardown: stop the thread (it may be hung — not
        joined here), clear the inbox, and hand the un-admitted items
        back for replay."""
        with self.cv:
            self._stop = True
            orphans = list(self.inbox)
            self.inbox.clear()
            self.cv.notify()
        return orphans

    def idle(self) -> bool:
        with self.cv:
            return not self.inbox and not self._flush_req

    def harvest(self) -> list:
        """Non-blocking: pop results already completed inside the
        scheduler, translated to (router_rid, ServeResult) pairs.  Used
        by the worker loop to publish, and by the router to salvage a
        dead worker's finished work before replaying the rest."""
        results = self.sched.poll()
        if not results:
            return []
        with self.cv:
            pairs = [(self.local_rrid.pop(r.rid, None), r)
                     for r in results]
        return [(rrid, r) for rrid, r in pairs if rrid is not None]

    # -- the worker loop ---------------------------------------------------

    def _publish(self) -> None:
        pairs = self.harvest()
        if pairs:
            self.router._absorb(self, pairs)

    def _run(self) -> None:
        try:
            while True:
                beat_s = self.router.liveness.beat_s   # read live
                with self.cv:
                    if self._stop and not self.inbox \
                            and not self._flush_req:
                        break
                    has_work = bool(self.inbox) or self._flush_req
                    if not has_work:
                        self.cv.wait(beat_s / 2)
                        has_work = bool(self.inbox) or self._flush_req
                self.pulse.beat()
                if has_work:
                    plan = self.router.fault_plan
                    if plan is not None:
                        # chaos seam: a planned hang stops the beat (the
                        # liveness policy must catch it); a planned kill
                        # raises and crashes this thread with the popped
                        # item still safely in the inbox
                        plan.on_worker_step(self.ordinal,
                                            self.n_processed)
                    with self.cv:
                        item = self.inbox.popleft() if self.inbox \
                            else None
                        flush = self._flush_req if item is None else False
                    if item is not None:
                        (rrid, coords, feats, mask, deadline, priority,
                         tid) = item
                        remaining = None if deadline is None else \
                            max(0.0, deadline - time.monotonic())
                        local = self.sched.submit(coords, feats, mask,
                                                  deadline_s=remaining,
                                                  priority=priority,
                                                  trace_id=tid)
                        with self.cv:
                            self.local_rrid[local] = rrid
                        self.n_processed += 1
                    elif flush:
                        # blocking device waits live HERE, on the worker
                        # thread — a wedged wait stalls the pulse, not
                        # the router
                        self.sched.flush()
                        self._publish()
                        with self.cv:
                            self._flush_req = False
                        self.router._notify_done()
                self._publish()
        except BaseException as e:   # noqa: BLE001 — injected kills too
            self.crash = e
            try:
                self._publish()
            except Exception:
                pass


def _rendezvous_score(key: bytes, name: str) -> int:
    """Highest-random-weight score of (geometry key, worker name): each
    key ranks every worker deterministically, and removing a worker
    reassigns ONLY the keys that ranked it first."""
    h = hashlib.blake2b(key, digest_size=8, person=b"serve-rdzv",
                        salt=hashlib.blake2b(
                            name.encode(), digest_size=16).digest())
    return int.from_bytes(h.digest(), "big")


class ServeRouter:
    """Digest-affinity front end over a pool of `ServeScheduler` workers.

    engine_factory   : zero-arg callable building one `PointCloudEngine`
                       per worker (same params/config — predictions must
                       be worker-independent; see
                       `PointCloudEngine.factory`).
    n_workers        : initial pool size (>= 1; the pool can shrink to
                       zero later — submits then shed).
    liveness         : `LivenessPolicy` (heartbeat cadence, missed-beat
                       budget, health-check interval).
    max_replays      : worker losses one request survives before it
                       completes `exec_failed` (the router-level
                       analogue of the scheduler's `max_retries`).
    max_backlog      : PER-WORKER bound on outstanding (routed,
                       incomplete) requests — scenes assigned to one
                       worker across all of its buckets; a submit
                       finding every live worker at the bound completes
                       with a `shed` result.  None = unbounded.  (The
                       scheduler's same-named knob is PER-BUCKET;
                       `stats()` surfaces this one as
                       `router_max_backlog`.)
    overload         : `overload.OverloadPolicy` (or True for the
                       defaults) — every worker's scheduler builds its
                       own `OverloadController` from it (adaptive
                       shedding, priority lanes, bucket breakers,
                       brownout), and the router adds PER-WORKER
                       circuit breakers: a worker producing
                       `exec_failed` results trips its breaker and the
                       rendezvous ranking routes around it until a
                       half-open probe succeeds.  Shed results carry an
                       aggregated `retry_after_s` hint (the minimum
                       over the live workers' drain estimates).  None
                       (default) keeps routing bit-identical to the
                       uncontrolled router.
    fault_plan       : `serve.faults.FaultPlan` chaos seam — worker
                       kills/hangs fire in the worker loops; the
                       scheduler-level seams (dispatch failures, bucket
                       delays, poisons) are threaded into every worker's
                       scheduler (note: per-scheduler dispatch ordinals,
                       so `fail_dispatches={0}` fails dispatch 0 of
                       EVERY worker).
    scheduler_kwargs : forwarded to each worker's `ServeScheduler`
                       (max_batch, pipeline_depth, max_wait_s, ...).

    `submit`/`poll`/`flush`/`drain`/`take`/`serve` mirror the scheduler's
    surface and contract: thread-safe, and no per-request problem ever
    raises — every request completes with predictions or a typed
    `ServeResult.error`.  Request ids are router-level (worker-local rids
    never escape).
    """

    def __init__(self, engine_factory, n_workers: int = 2, *,
                 liveness: LivenessPolicy | None = None,
                 max_replays: int = DEFAULT_MAX_REPLAYS,
                 max_backlog: int | None = None,
                 overload=None,
                 fault_plan: FLT.FaultPlan | None = None,
                 obs: Observability | None = None,
                 **scheduler_kwargs):
        if n_workers < 1:
            raise ValueError("ServeRouter needs n_workers >= 1 to start "
                             "(the pool may shrink to zero later)")
        if max_replays < 0:
            raise ValueError("max_replays must be >= 0")
        if max_backlog is not None and max_backlog < 1:
            raise ValueError("max_backlog must be >= 1 (or None)")
        if overload is True:
            overload = OV.OverloadPolicy()
        if overload is not None and \
                not isinstance(overload, OV.OverloadPolicy):
            raise TypeError(
                "ServeRouter overload= takes None/True/OverloadPolicy "
                "(each worker scheduler builds its own controller)")
        self.engine_factory = engine_factory
        self.liveness = liveness if liveness is not None \
            else LivenessPolicy()
        self.max_replays = int(max_replays)
        self.max_backlog = max_backlog
        self.overload = overload
        self.fault_plan = fault_plan
        self._sched_kwargs = dict(scheduler_kwargs)
        self._sched_kwargs.setdefault("fault_plan", fault_plan)
        if overload is not None:
            self._sched_kwargs.setdefault("overload", overload)

        self._lock = threading.RLock()
        self._done = threading.Condition(self._lock)
        self._workers: OrderedDict[str, _Worker] = OrderedDict()
        self._next_ordinal = 0
        self._next_rrid = 0
        self._routed: dict[int, _Routed] = {}
        self._completed: OrderedDict[int, ServeResult] = OrderedDict()
        self._closed = False
        # telemetry: registry children shared with every worker's
        # scheduler (the workers bind their own `instance` labels);
        # tracer/recorder are optional — the same bundle reaches the
        # workers, so one trace tree spans route -> worker -> failover
        # replay on a survivor
        self.obs = obs if obs is not None else Observability()
        self._tracer = self.obs.tracer
        self._recorder = self.obs.recorder
        reg = self.obs.registry
        inst = "router"
        self._c_submitted = reg.counter(
            "serve_requests_submitted_total",
            "scenes admitted via submit()", ("instance",)).labels(inst)
        self._c_completed = reg.counter(
            "serve_requests_completed_total",
            "requests completed (ok or typed error)",
            ("instance",)).labels(inst)
        self._c_ok = reg.counter(
            "serve_requests_ok_total",
            "requests completed with predictions", ("instance",)).labels(inst)
        fam_faults = reg.counter(
            "serve_faults_total", "typed error results by code",
            ("instance", "code"))
        self._c_faults = {c: fam_faults.labels(inst, c)
                          for c in FLT.ERROR_CODES}
        self._c_failovers = reg.counter(
            "serve_failovers_total", "workers declared dead",
            ("instance",)).labels(inst)
        self._c_replays = reg.counter(
            "serve_replays_total",
            "requests replayed onto surviving workers",
            ("instance",)).labels(inst)
        self._h_latency = reg.histogram(
            "serve_request_latency_seconds",
            "submit -> predictions (OK results only)",
            ("instance",)).labels(inst)
        fam_errlat = reg.histogram(
            "serve_error_latency_seconds",
            "submit -> typed error result, by code", ("instance", "code"))
        self._h_errlat = {c: fam_errlat.labels(inst, c)
                          for c in FLT.ERROR_CODES}
        self._g_recovery = reg.gauge(
            "serve_recovery_seconds",
            "failover -> last victim resolved", ("instance",)).labels(inst)
        self._recovering: set[int] = set()
        self._t_failover: float | None = None
        # per-worker circuit breakers (overload control only — the
        # disabled path registers nothing and routes identically)
        self._breakers: dict[str, OV.CircuitBreaker] = {}
        self._fam_breaker = reg.gauge(
            "serve_breaker_state",
            "circuit breaker state (0 closed / 1 half-open / 2 open)",
            ("instance", "target")) if self.overload is not None else None

        for _ in range(n_workers):
            self._add_worker_locked()
        self.ladder = next(iter(self._workers.values())).engine.ladder
        health_s = self.liveness.health_s \
            if self.liveness.health_s is not None else self.liveness.beat_s
        self._health = FT.Ticker(health_s, self._health_tick,
                                 name="serve-router-health")

    # -- pool management ---------------------------------------------------

    def _add_worker_locked(self, name: str | None = None) -> "_Worker":
        ordinal = self._next_ordinal
        self._next_ordinal += 1
        name = name if name is not None else f"w{ordinal}"
        if name in self._workers:
            raise ValueError(f"worker {name!r} already exists")
        w = _Worker(self, name, ordinal, self.engine_factory(),
                    dict(self._sched_kwargs, obs=self.obs, instance=name))
        self._workers[name] = w
        if self.overload is not None:
            self._breakers[name] = OV.CircuitBreaker(
                self.overload.breaker, name=f"worker:{name}",
                gauge=self._fam_breaker.labels("router", f"worker:{name}"))
        return w

    def add_worker(self, name: str | None = None) -> str:
        """Join a fresh worker (own engine + scheduler + thread) to the
        pool; it is rendezvous-eligible immediately, so exactly the keys
        that rank it first start landing on it.  Returns the worker
        name."""
        with self._lock:
            if self._closed:
                raise RuntimeError("router is closed")
            return self._add_worker_locked(name).name

    def remove_worker(self, name: str, timeout_s: float = 60.0) -> None:
        """Drain-then-leave: the worker stops receiving new routes, its
        outstanding requests complete (or fail over if it dies while
        draining), then its scheduler closes and the thread joins.
        Digest re-affinity is automatic — only the keys that ranked the
        departed worker first move, each to its next-ranked survivor."""
        with self._lock:
            w = self._workers.get(name)
            if w is None:
                raise KeyError(f"no worker named {name!r}")
            if w.state != LIVE:
                raise ValueError(f"worker {name!r} is {w.state}, "
                                 f"not live")
            w.state = DRAINING
        self._settle(lambda: w.assigned == 0 or w.state != DRAINING,
                     timeout_s)
        with self._lock:
            if w.state != DRAINING:     # died mid-drain: already handled
                return
            w.request_stop()
        w.thread.join(timeout_s)
        try:
            w.sched.close()
        except Exception:
            pass
        with self._lock:
            if w.state == DRAINING:
                w.state = LEFT

    def workers(self) -> dict[str, str]:
        """{name: state} snapshot of the pool."""
        with self._lock:
            return {name: w.state for name, w in self._workers.items()}

    # -- routing -----------------------------------------------------------

    def _affinity_key(self, coords, mask):
        """The geometry digest identical geometry always maps to: the
        scene padded to its ladder bucket, hashed exactly like the
        worker scheduler's mapping-cache key — so affinity routing and
        worker-local caching agree byte for byte.  Falls back to None
        (rrid-salted routing) for scenes admission will reject anyway."""
        try:
            coords = np.asarray(coords)
            n = coords.shape[0]
            mask = np.ones(n, bool) if mask is None \
                else np.asarray(mask, bool)
            cap = self.ladder.bucket_for(n)
            c, m, _ = BK.pad_scene(coords, mask, None, cap)
            return MappingCache.digest((c, m), extra=("levels", cap))
        except Exception:
            return None

    def _route_locked(self, key: bytes) -> "_Worker | None":
        """Rendezvous-ranked live worker with backlog headroom and a
        non-open circuit breaker, else None (no live workers, every one
        saturated, or every one circuit-broken).  The backlog check runs
        BEFORE the breaker check so a saturated worker never consumes a
        half-open probe slot it cannot serve."""
        live = [w for w in self._workers.values() if w.state == LIVE]
        if not live:
            return None
        ranked = sorted(live,
                        key=lambda w: _rendezvous_score(key, w.name),
                        reverse=True)
        for w in ranked:
            if self.max_backlog is not None and \
                    w.assigned >= self.max_backlog:
                continue
            br = self._breakers.get(w.name)
            if br is not None and br.state != OV.CLOSED \
                    and not br.allow():
                continue
            return w
        return None

    def preview(self, coords, mask=None) -> str | None:
        """The live worker this geometry would route to right now (None
        for a scene admission would reject, or an empty/saturated pool)
        — affinity introspection for tests, chaos targeting, and
        capacity planning.  Pure: nothing is enqueued."""
        key = self._affinity_key(coords, mask)
        if key is None:
            return None
        with self._lock:
            w = self._route_locked(key)
            return w.name if w is not None else None

    def _retry_hint_locked(self) -> float | None:
        """Aggregated backpressure hint for a pool-level shed: the
        minimum over the live workers' drain estimates (the first
        worker to free up is when a resubmit can land) and any tripped
        breaker's next probe slot.  None without overload control."""
        if self.overload is None:
            return None
        hints = []
        for w in self._workers.values():
            if w.state != LIVE:
                continue
            h = w.sched.retry_after_hint()
            if h is not None:
                hints.append(h)
            br = self._breakers.get(w.name)
            if br is not None and br.state != OV.CLOSED:
                hints.append(br.retry_after())
        return min(hints) if hints else \
            self.overload.slo.deadline_headroom_s

    def submit(self, coords, feats, mask=None,
               deadline_s: float | None = None,
               priority: int = 0) -> int:
        """Admit one scene; returns its router request id — ALWAYS.

        The scene is digested and rendezvous-routed to its affinity
        worker (falling past saturated or circuit-broken workers to the
        next-ranked one); a pool with zero live workers, or every
        worker at `max_backlog` / circuit-broken, completes the request
        with a `shed` result (carrying an aggregated `retry_after_s`
        hint under overload control).  Validation itself happens in the
        worker's scheduler — malformed scenes come back as `rejected`
        results exactly as on the bare scheduler.  `priority` rides
        along to the worker scheduler's lane ordering."""
        t_submit = time.monotonic()
        key = self._affinity_key(coords, mask)
        try:
            n_points = int(np.asarray(coords).shape[0])
        except Exception:
            n_points = 0
        with self._lock:
            rrid = self._next_rrid
            self._next_rrid += 1
            self._c_submitted.inc()
            tid = None
            if self._tracer is not None:
                tid = f"router:rrid:{rrid}"
                self._tracer.begin(tid, t=t_submit, rrid=rrid,
                                   instance="router")
            if self._closed:
                self._complete_error_locked(
                    rrid, n_points, t_submit,
                    ServeError(FLT.REJECTED, "router is closed"))
                return rrid
            salt = key if key is not None else f"rrid:{rrid}".encode()
            w = self._route_locked(salt)
            if w is None:
                live = [x for x in self._workers.values()
                        if x.state == LIVE]
                broken = sum(1 for x in live
                             if self._breakers.get(x.name) is not None
                             and self._breakers[x.name].state != OV.CLOSED)
                if not live:
                    msg = "no live workers in the pool"
                elif broken and self.overload is not None:
                    backlogs = [x.assigned for x in live]
                    msg = (f"all {len(live)} live workers unavailable: "
                           f"{broken} circuit-broken, backlogs "
                           f"{backlogs} vs the max_backlog bound "
                           f"({self.max_backlog} outstanding per worker)")
                else:
                    msg = (f"all {len(live)} live workers at the "
                           f"max_backlog bound ({self.max_backlog} "
                           f"outstanding)")
                self._complete_error_locked(
                    rrid, n_points, t_submit,
                    ServeError(FLT.SHED, msg,
                               retry_after_s=self._retry_hint_locked()))
                return rrid
            deadline = t_submit + deadline_s \
                if deadline_s is not None else None
            routed = _Routed(rrid, salt, coords, feats, mask, n_points,
                             deadline, t_submit, w, priority=int(priority))
            self._routed[rrid] = routed
            if self._tracer is not None:
                self._tracer.span(tid, "route", t_start=t_submit,
                                  t_end=time.monotonic(), worker=w.name)
            w.assigned += 1
            w.enqueue((rrid, coords, feats, mask, deadline,
                       int(priority), tid))
            return rrid

    # -- completion --------------------------------------------------------

    def _complete_locked(self, routed: _Routed,
                         result: ServeResult) -> None:
        routed.worker.assigned -= 1
        del self._routed[routed.rrid]
        self._completed[routed.rrid] = result
        self._c_completed.inc()
        if result.error is None:
            self._c_ok.inc()
            self._h_latency.observe(result.latency_s)
        else:
            self._c_faults[result.error.code].inc()
            self._h_errlat[result.error.code].observe(result.latency_s)
        if self._tracer is not None:
            self._tracer.end(
                f"router:rrid:{routed.rrid}",
                outcome="ok" if result.error is None
                else result.error.code)
        if self._recovering:
            self._recovering.discard(routed.rrid)
            if not self._recovering and self._t_failover is not None:
                self._g_recovery.set(time.monotonic() - self._t_failover)
                self._t_failover = None
        self._done.notify_all()

    def _complete_error_locked(self, rrid: int, n_points: int,
                               t_submit: float, err: ServeError) -> None:
        """Terminate a request the router itself refuses (shed / closed
        / replay exhaustion) — same result shape as the scheduler's.
        The wait lands in the per-code error histogram (error-path
        latency used to vanish from the ok-only average)."""
        lat = time.monotonic() - t_submit
        self._completed[rrid] = ServeResult(
            rrid, None, int(n_points), -1, 0.0, False, lat, err)
        self._c_completed.inc()
        self._c_faults[err.code].inc()
        self._h_errlat[err.code].observe(lat)
        if self._tracer is not None:
            tid = f"router:rrid:{rrid}"
            self._tracer.event(tid, "error", code=err.code,
                               message=err.message)
            self._tracer.end(tid, outcome=err.code)
        if self._recovering:
            self._recovering.discard(rrid)
            if not self._recovering and self._t_failover is not None:
                self._g_recovery.set(time.monotonic() - self._t_failover)
                self._t_failover = None
        self._done.notify_all()

    def _absorb(self, w: "_Worker", pairs) -> None:
        """Accept (router_rid, worker ServeResult) pairs from a worker.
        Ownership-checked: a result for a request that already completed
        or was replayed onto another worker is discarded — each request
        completes exactly once, from its current owner."""
        with self._lock:
            now = time.monotonic()
            br = self._breakers.get(w.name)
            for rrid, res in pairs:
                routed = self._routed.get(rrid)
                if routed is None or routed.worker is not w:
                    continue            # stale: replayed or completed
                if br is not None:
                    # exec_failed results count toward the worker's
                    # breaker window; ok results close a half-open
                    # probe (shed/timeout are load signals, not worker
                    # failures — they count toward neither)
                    if res.error is not None and \
                            res.error.code == FLT.EXEC_FAILED:
                        if br.record_failure(now) and \
                                self._recorder is not None:
                            self._recorder.record(
                                "breaker_trip", target=f"worker:{w.name}",
                                state=br.state, trips=br.n_trips,
                                instance="router")
                            self._recorder.dump(
                                "breaker_trip",
                                key=("breaker", w.name, br.n_trips))
                    elif res.error is None:
                        br.record_success(now)
                self._complete_locked(routed, dataclasses.replace(
                    res, rid=rrid, latency_s=now - routed.t_submit))

    # -- health + failover -------------------------------------------------

    def _health_tick(self) -> None:
        with self._lock:
            self._health_tick_locked()

    def _health_tick_locked(self) -> None:
        stall = self.liveness.stall_s
        for w in list(self._workers.values()):
            if w.state not in (LIVE, DRAINING):
                continue
            if not w.thread.is_alive():
                self._fail_worker_locked(
                    w, f"worker thread crashed: {w.crash!r}")
            elif w.pulse.stalled(stall):
                self._fail_worker_locked(
                    w, f"hung: no heartbeat for {w.pulse.age():.2f}s "
                       f"(stall budget {stall:.2f}s)")

    def _fail_worker_locked(self, w: "_Worker", reason: str) -> None:
        """Declare one worker dead and make its work whole: salvage
        results its scheduler already finished, then replay everything
        still queued or in flight onto the surviving workers (bounded by
        `max_replays` per request; exhaustion and empty pools complete
        the request with typed errors).  The dead worker's thread is
        told to stop but never joined here — it may be hung; a late
        result it publishes after waking is discarded by `_absorb`'s
        ownership check."""
        if w.state not in (LIVE, DRAINING):
            return
        w.state = DEAD
        w.reason = reason
        self._c_failovers.inc()
        t_death = time.monotonic()
        w.abandon()
        try:                            # non-blocking salvage
            self._absorb(w, w.harvest())
        except Exception:
            pass
        victims = [r for r in self._routed.values() if r.worker is w]
        if self._recorder is not None:
            self._recorder.record(
                "failover", worker=w.name, reason=reason,
                victims=[r.rrid for r in victims], instance="router")
            # one post-mortem snapshot per dead worker — ten stranded
            # requests still produce ONE dump
            self._recorder.dump("failover", key=("failover", w.name))
        if victims:
            self._recovering.update(r.rrid for r in victims)
            if self._t_failover is None:
                self._t_failover = t_death
        for r in victims:
            r.attempts += 1
            if self._tracer is not None:
                self._tracer.event(f"router:rrid:{r.rrid}", "failover",
                                   t=t_death, worker=w.name,
                                   reason=reason, attempts=r.attempts)
            if r.attempts > self.max_replays:
                self._complete_locked(r, ServeResult(
                    r.rrid, None, r.n_points, -1, 0.0, False,
                    time.monotonic() - r.t_submit,
                    ServeError(FLT.EXEC_FAILED,
                               f"lost {r.attempts}x to failed workers "
                               f"(last: {w.name} {reason}); replay "
                               f"budget exhausted")))
                continue
            nw = self._route_locked(r.key)
            if nw is None:
                self._complete_locked(r, ServeResult(
                    r.rrid, None, r.n_points, -1, 0.0, False,
                    time.monotonic() - r.t_submit,
                    ServeError(FLT.SHED,
                               f"no live workers to replay onto after "
                               f"{w.name} was lost ({reason})")))
                continue
            w.assigned -= 1
            nw.assigned += 1
            r.worker = nw
            self._c_replays.inc()
            tid = None
            if self._tracer is not None:
                tid = f"router:rrid:{r.rrid}"
                self._tracer.event(tid, "replay", worker=nw.name,
                                   attempts=r.attempts)
            if self._recorder is not None:
                self._recorder.record("replay", rrid=r.rrid,
                                      worker=nw.name, instance="router")
            nw.enqueue((r.rrid, r.coords, r.feats, r.mask, r.deadline,
                        r.priority, tid))

    # -- waiting helpers ---------------------------------------------------

    def _notify_done(self) -> None:
        """Wake settled waiters (called by workers on completions and
        finished flushes)."""
        with self._lock:
            self._done.notify_all()

    def _settle(self, done, timeout_s: float | None = None) -> None:
        """Run health checks + flush nudges until `done()` (checked
        under the lock) holds.  Blocking router calls funnel through
        here, so a worker dying mid-wait is failed over and replayed
        WHILE the caller waits — the wait converges instead of hanging
        on a dead worker.  Waits are completion-notified (zero added
        latency on the hot path); `_SETTLE_WAIT_S` only paces the
        health checks while nothing completes."""
        deadline = time.monotonic() + timeout_s \
            if timeout_s is not None else None
        while True:
            with self._lock:
                self._health_tick_locked()
                if done():
                    return
                for w in self._workers.values():
                    if w.state in (LIVE, DRAINING) and w.assigned > 0:
                        w.request_flush()
                self._done.wait(_SETTLE_WAIT_S)
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError(
                    "router wait did not settle within "
                    f"{timeout_s}s")

    # -- serving surface (mirrors ServeScheduler) --------------------------

    def poll(self) -> list[ServeResult]:
        """Non-blocking tick: run the health check (failing over dead
        workers) and hand back everything completed so far."""
        with self._lock:
            self._health_tick_locked()
            out = list(self._completed.values())
            self._completed.clear()
            return out

    def flush(self) -> None:
        """Ask every live worker to execute its queued scenes (partial
        micro-batches dummy-fill) and wait for those flushes; a worker
        dying mid-flush is failed over and its work replayed."""
        with self._lock:
            targets = [w for w in self._workers.values()
                       if w.state in (LIVE, DRAINING)]
            for w in targets:
                w.request_flush()
        self._settle(lambda: all(
            w.state not in (LIVE, DRAINING) or w.idle()
            for w in targets))

    def drain(self) -> list[ServeResult]:
        """Complete every outstanding request (flushing and failing over
        as needed) and hand back all results, in completion order."""
        self._settle(lambda: not self._routed)
        with self._lock:
            out = list(self._completed.values())
            self._completed.clear()
            return out

    def take(self, rids) -> dict[int, ServeResult]:
        """Complete and pop results for `rids` only; other callers'
        results stay drainable."""
        want = [int(r) for r in rids]
        want_set = set(want)
        self._settle(lambda: not want_set.intersection(self._routed))
        with self._lock:
            return {r: self._completed.pop(r) for r in want
                    if r in self._completed}

    def serve(self, scenes) -> dict[int, ServeResult]:
        """Submit an iterable of (coords, feats[, mask]) scenes and
        return {rrid: result} for THIS call's requests only."""
        rids = [self.submit(*scene) for scene in scenes]
        return self.take(rids)

    # -- lifecycle ---------------------------------------------------------

    def close(self) -> None:
        """Finish outstanding work, then stop the pool: every worker's
        scheduler closes and its thread joins; the health ticker joins;
        a submit after close completes with a `rejected` result.
        Idempotent."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        if self.fault_plan is not None:
            self.fault_plan.close()     # wake injected waits
        try:
            self._settle(lambda: not self._routed, timeout_s=120.0)
        except TimeoutError:
            pass                        # counted work stays addressable
        self._health.close()
        with self._lock:
            workers = list(self._workers.values())
        for w in workers:
            w.request_stop()
        for w in workers:
            w.thread.join(5.0)
            try:
                w.sched.close()
            except Exception:
                pass
            if w.state in (LIVE, DRAINING):
                w.state = LEFT

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- telemetry ---------------------------------------------------------

    def stats(self) -> dict:
        """Pool-wide serving picture: per-worker state / throughput /
        nested scheduler stats, pooled cache totals, and the failover
        counters (workers lost, requests replayed, failure->recovered
        time)."""
        with self._lock:
            workers = {}
            map_hits = map_misses = asm_hits = asm_misses = 0
            for name, w in self._workers.items():
                st = w.sched.stats()
                mc = st["mapping_cache"]
                map_hits += mc["hits"]
                map_misses += mc["misses"]
                ac = st["assembly_cache"]
                if ac is not None:
                    asm_hits += ac["hits"]
                    asm_misses += ac["misses"]
                workers[name] = {
                    "ordinal": w.ordinal,
                    "state": w.state,
                    "routed": w.n_routed,
                    "processed": w.n_processed,
                    "assigned": w.assigned,
                    "inbox": len(w.inbox),
                    "reason": w.reason,
                    "scheduler": st,
                }
            lookups = map_hits + map_misses + asm_hits + asm_misses
            h_lat = self._h_latency
            return {
                "n_workers": len(self._workers),
                "n_live": sum(1 for w in self._workers.values()
                              if w.state == LIVE),
                "workers": workers,
                "n_submitted": self._c_submitted.value,
                "n_completed": self._c_completed.value,
                "n_ok": self._c_ok.value,
                "routed_incomplete": len(self._routed),
                "latency_avg_s": (h_lat.sum / h_lat.count
                                  if h_lat.count else 0.0),
                "latency_quantiles_s": h_lat.quantiles(),
                "pool_cache": {
                    "mapping_hits": map_hits,
                    "mapping_misses": map_misses,
                    "assembly_hits": asm_hits,
                    "assembly_misses": asm_misses,
                    "combined_hit_rate": ((map_hits + asm_hits) / lookups
                                          if lookups else 0.0),
                },
                "faults": {
                    **{c: m.value for c, m in self._c_faults.items()},
                    "failovers": self._c_failovers.value,
                    "replayed": self._c_replays.value,
                    "recovery_s": self._g_recovery.value,
                },
                "liveness": {
                    "beat_s": self.liveness.beat_s,
                    "miss_beats": self.liveness.miss_beats,
                    "stall_s": self.liveness.stall_s,
                },
                "max_replays": self.max_replays,
                "max_backlog": self.max_backlog,
                # disambiguated alias: the router's bound is PER-WORKER
                # outstanding scenes (vs the scheduler's per-bucket
                # scheduler_max_backlog)
                "router_max_backlog": self.max_backlog,
                "closed": self._closed,
            }
