"""Fault taxonomy, admission validation, and the fault-injection harness
for the point-cloud serving runtime.

PointAcc's target workloads are real-time streams (AR/VR, autonomous
driving): a serving stack for them must degrade gracefully — one
malformed scene or one failed dispatch must cost exactly that request,
never the stream.  This module holds the three pieces the scheduler
builds its fault-tolerance on:

  * **`ServeError`** — the typed error a request completes with instead
    of an exception escaping `submit()`/`drain()`.  Four codes:

      `rejected`     admission control refused the scene (bad shape /
                     dtype, NaN features, packed-key budget overflow,
                     oversized vs the top ladder bucket, closed
                     scheduler);
      `shed`         load shedding — the bucket's backlog bound was
                     exceeded, newest request rejected;
      `timeout`      the request's `deadline_s` elapsed while it was
                     still queued;
      `exec_failed`  its micro-batch dispatch raised, and the retry /
                     bisect policy could not complete it.

  * **`validate_scene`** — the up-front admission check `submit()` runs
    before a scene touches the pipeline: shapes, dtypes, finite
    features, the packed-key coordinate budget, and the ladder fit.  It
    raises `AdmissionError` (a `ValueError` carrying the error code) so
    the scheduler can route the failure into a `rejected` result.

  * **`FaultPlan`** — the injectable chaos seam threaded through
    `ServeScheduler`/`PointCloudEngine`/`ServeRouter`: fail dispatch *i*
    (one-shot — the retry gets a fresh dispatch id and succeeds), poison
    request *j* (every dispatch containing it fails, exercising the
    bisect isolation path), corrupt submitted scene *k* (NaN features,
    caught by admission control), delay bucket *c* (slow-device
    simulation for deadline / shed / watchdog tests), kill worker *w* at
    its *n*-th served request (the worker thread dies — the router must
    fail it over and replay its queued + in-flight work), hang worker
    *w* (the worker loop stops beating — the router's liveness policy
    must declare it dead by missed heartbeats).  The no-plan path costs
    one `is None` check per seam — the happy path stays bit-identical.
    Every timed wait goes through one wake event, so `close()` (called
    by `ServeScheduler.close()` / `ServeRouter.close()`) wakes pending
    injected delays early and shutdown under chaos is prompt.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Mapping

import numpy as np

from repro.core import mapping as M
from repro.core import packed as PK
from repro.obs import metrics as MX

# -- error taxonomy ---------------------------------------------------------

REJECTED = "rejected"
TIMEOUT = "timeout"
SHED = "shed"
EXEC_FAILED = "exec_failed"
ERROR_CODES = (REJECTED, TIMEOUT, SHED, EXEC_FAILED)

# `rejected` detail codes: a MALFORMED scene can never be served (bad
# shapes/dtypes/values), an OVERSIZED one is well-formed but exceeds the
# ladder — resubmittable through the partition path.  Triage dispatches
# on the detail, not on message text.
OVERSIZED = "oversized"
MALFORMED = "malformed"


@dataclasses.dataclass(frozen=True)
class ServeError:
    """Typed failure a `ServeResult` carries instead of predictions.
    `detail` refines `rejected` results (`oversized` vs `malformed`);
    None elsewhere.  `retry_after_s` is the backpressure hint on `shed`
    and `timeout` results: the estimated seconds until this bucket has
    drained enough that a resubmit would be admitted (computed from the
    observed service rate when an `OverloadController` is attached,
    None when no estimate exists)."""

    code: str                   # one of ERROR_CODES
    message: str
    detail: str | None = None
    retry_after_s: float | None = None

    def __post_init__(self):
        if self.code not in ERROR_CODES:
            raise ValueError(f"unknown serve error code {self.code!r}; "
                             f"expected one of {ERROR_CODES}")

    def __str__(self):
        return f"[{self.code}] {self.message}"


class AdmissionError(ValueError):
    """A scene failed admission validation; `code` is the ServeError
    code the scheduler should complete the request with, `detail` the
    rejection class (`oversized` scenes can be replayed through the
    partition path, `malformed` ones cannot)."""

    def __init__(self, message: str, code: str = REJECTED,
                 detail: str = MALFORMED):
        super().__init__(message)
        self.code = code
        self.detail = detail

    def as_error(self) -> ServeError:
        return ServeError(self.code, str(self), self.detail)


class InjectedFault(RuntimeError):
    """Raised by a `FaultPlan` seam — distinguishable from organic
    failures in logs, handled identically by the retry machinery."""


# -- admission validation ---------------------------------------------------

def validate_scene(coords, feats, mask, ladder, *,
                   check_key_budget: bool = True,
                   coord_dim: int | None = None,
                   feat_shape: tuple | None = None):
    """Validate one raw scene before it enters the serving pipeline.

    Returns `(coords, mask, feats, n, cap)` as host numpy arrays with
    the bucket capacity resolved, or raises `AdmissionError` ("rejected")
    describing exactly what is wrong:

      * coords must be a (N, 1+D) integer-compatible array with every
        valid row finite;
      * mask (when given) must be a (N,) boolean-compatible vector;
      * feats must be (N, C...) with finite values on valid rows — a NaN
        feature would propagate through the whole micro-batch's conv
        trunk, so it is refused up front;
      * with `check_key_budget` (the packed-key v2 engine), valid
        coordinates must fit the 62-bit key budget (batch 0..BATCH_MAX,
        spatial COORD_MIN..COORD_MAX) — out-of-budget points would
        otherwise raise out of the jit build mid-pipeline;
      * `coord_dim` / `feat_shape` (first-seen values, supplied by the
        scheduler) must match — mixed widths cannot share a micro-batch;
      * N must fit the ladder's top bucket.
    """
    try:
        coords = np.asarray(coords)
    except Exception as e:              # ragged / non-numeric input
        raise AdmissionError(f"coords not array-convertible: {e}")
    if coords.ndim != 2 or coords.shape[1] < 2:
        raise AdmissionError(
            f"coords must be (N, 1+D) with D >= 1, got shape "
            f"{coords.shape}")
    if coord_dim is not None and coords.shape[1] != coord_dim:
        raise AdmissionError(
            f"coords width {coords.shape[1]} does not match this "
            f"scheduler's stream ({coord_dim} columns)")
    n = coords.shape[0]
    if np.issubdtype(coords.dtype, np.floating):
        if not np.isfinite(coords).all():
            raise AdmissionError("coords contain NaN/Inf values")
    elif not np.issubdtype(coords.dtype, np.integer):
        raise AdmissionError(
            f"coords dtype {coords.dtype} is not integer-compatible")

    if mask is None:
        mask = np.ones(n, bool)
    else:
        try:
            mask = np.asarray(mask, bool)
        except Exception as e:
            raise AdmissionError(f"mask not bool-convertible: {e}")
        if mask.shape != (n,):
            raise AdmissionError(
                f"mask shape {mask.shape} does not match {n} coord rows")

    try:
        feats = np.asarray(feats)
    except Exception as e:
        raise AdmissionError(f"feats not array-convertible: {e}")
    if feats.ndim < 1 or feats.shape[0] != n:
        raise AdmissionError(
            f"feats shape {feats.shape} does not match {n} coord rows")
    if feat_shape is not None and feats.shape[1:] != tuple(feat_shape):
        raise AdmissionError(
            f"feats trailing shape {feats.shape[1:]} does not match this "
            f"scheduler's stream ({tuple(feat_shape)})")
    if np.issubdtype(feats.dtype, np.floating) and n:
        valid_feats = feats[mask]
        if valid_feats.size and not np.isfinite(valid_feats).all():
            raise AdmissionError(
                "feats contain NaN/Inf values on valid rows")

    if check_key_budget and coords.shape[1] == 4 and mask.any():
        vc = coords[mask].astype(np.int64)
        # all-sentinel spatial rows are "not a point" to the mapping
        # engine (they sort to the end and never match) — exempt from
        # the budget like the padding they usually are
        vc = vc[(vc[:, 1:] != M.SENTINEL).any(axis=1)]
        if vc.size and ((vc[:, 0] < 0).any()
                        or (vc[:, 0] > PK.BATCH_MAX).any()):
            raise AdmissionError(
                f"batch index outside the packed-key budget "
                f"(0..{PK.BATCH_MAX}); use engine='v1' for such clouds")
        sp = vc[:, 1:]
        if sp.size and ((sp < PK.COORD_MIN).any()
                        or (sp > PK.COORD_MAX).any()):
            raise AdmissionError(
                f"coordinates outside the packed-key budget "
                f"({PK.COORD_MIN}..{PK.COORD_MAX}); use engine='v1' for "
                f"such clouds")

    try:
        cap = ladder.bucket_for(n)
    except ValueError:                  # oversized vs the top bucket
        raise AdmissionError(
            f"scene has {n} rows and exceeds the bucket ladder, which "
            f"tops out at {ladder.capacities[-1]} (buckets "
            f"{ladder.capacities}; the packed-key budget itself allows "
            f"batches 0..{PK.BATCH_MAX} x coords "
            f"{PK.COORD_MIN}..{PK.COORD_MAX}); extend the ladder, or "
            f"serve it chunked via "
            f"PointCloudEngine.segment(partition='auto')",
            detail=OVERSIZED)
    return coords, mask, feats, n, cap


# -- fault injection --------------------------------------------------------

@dataclasses.dataclass
class FaultPlan:
    """Deterministic chaos plan threaded through the serving runtime.

    All seams are thread-safe (producers submit concurrently) and cheap
    enough to leave compiled artifacts untouched: a plan never changes
    shapes or compiled programs, only *when* a wait raises or a scene
    arrives corrupted.

    fail_dispatches : dispatch ordinals (0-based, global across buckets
                      and retries) whose device wait raises
                      `InjectedFault` — retries get fresh ordinals, so a
                      single entry models a transient fault.
    poison_rids     : request ids whose *every* containing dispatch
                      fails — models a scene that crashes the kernel,
                      exercising bisect isolation + `exec_failed`.
    corrupt_scenes  : submit ordinals (0-based, per plan) whose feats
                      are NaN-corrupted before validation — models a
                      garbage sensor frame, caught by admission control.
    delay_buckets   : {bucket_capacity: seconds} waited in the device
                      wait — models a slow device for deadline / shed /
                      watchdog tests.  Interruptible: `close()` wakes
                      pending delays so shutdown under chaos is prompt.
    kill_workers    : {worker_ordinal: step} — the worker's serving loop
                      raises `InjectedFault` when it is about to process
                      its `step`-th request (0-based, counted per
                      worker), crashing the worker thread mid-stream.
                      The request itself and everything queued or in
                      flight on that worker stays incomplete — the
                      router must fail the worker over and replay them.
    hang_workers    : {worker_ordinal: seconds} — the worker's serving
                      loop stops dead for that long on its first request
                      after having served at least one (so the hang hits
                      a *warm* worker mid-stream).  No exception is
                      raised: the worker just stops beating, which is
                      exactly what a wedged device wait looks like — the
                      router's liveness policy must catch it by missed
                      heartbeats.  Woken early by `close()`.
    slow_device     : extra seconds added to *every* dispatch's device
                      wait — a uniformly degraded device, for overload /
                      brownout tests where `delay_buckets` (per-bucket)
                      is too targeted.  Interruptible like the rest.
    storm_buckets   : {bucket_capacity: dispatches_per_second} — caps
                      the bucket's dispatch RATE with token-bucket
                      pacing (each dispatch waits until its slot),
                      giving the bucket a *deterministic service rate*
                      so overload tests can offer a known multiple of
                      capacity.  Distinct from `delay_buckets`, which
                      adds a fixed delay regardless of arrival rate.
    """

    fail_dispatches: frozenset = frozenset()
    poison_rids: frozenset = frozenset()
    corrupt_scenes: frozenset = frozenset()
    delay_buckets: Mapping[int, float] = dataclasses.field(
        default_factory=dict)
    kill_workers: Mapping[int, int] = dataclasses.field(
        default_factory=dict)
    hang_workers: Mapping[int, float] = dataclasses.field(
        default_factory=dict)
    slow_device: float = 0.0
    storm_buckets: Mapping[int, float] = dataclasses.field(
        default_factory=dict)

    def __post_init__(self):
        self.fail_dispatches = frozenset(int(i) for i in self.fail_dispatches)
        self.poison_rids = frozenset(int(i) for i in self.poison_rids)
        self.corrupt_scenes = frozenset(int(i) for i in self.corrupt_scenes)
        self.delay_buckets = {int(c): float(s)
                              for c, s in dict(self.delay_buckets).items()}
        self.kill_workers = {int(w): int(s)
                             for w, s in dict(self.kill_workers).items()}
        self.hang_workers = {int(w): float(s)
                             for w, s in dict(self.hang_workers).items()}
        self.slow_device = float(self.slow_device)
        self.storm_buckets = {int(c): float(r)
                              for c, r in dict(self.storm_buckets).items()}
        if any(r <= 0 for r in self.storm_buckets.values()):
            raise ValueError("storm_buckets rates must be > 0 "
                             "dispatches/second")
        self._lock = threading.Lock()
        self._wake = threading.Event()
        self._hung: set = set()
        self._storm_next: dict[int, float] = {}     # cap -> next slot time
        # seam-firing counters live in a private registry (one family,
        # labeled per seam) — stats() below is the legacy view over it
        self._mx = MX.MetricsRegistry()
        fam = self._mx.counter("fault_plan_seam_firings_total",
                               "chaos seam firings by kind", ("seam",))
        self._c_submits = fam.labels("submit")
        self._c_corrupted = fam.labels("corrupt")
        self._c_injected = fam.labels("fail")
        self._c_delays = fam.labels("delay")
        self._c_kills = fam.labels("kill")
        self._c_hangs = fam.labels("hang")
        self._c_slows = fam.labels("slow")
        self._c_storms = fam.labels("storm")

    # -- lifecycle ---------------------------------------------------------

    def close(self) -> None:
        """Wake every pending injected wait (bucket delays, worker
        hangs) and skip future ones — called by the scheduler's/router's
        close() so shutdown under chaos never sits out a planned sleep.
        Instant seams (kills, dispatch failures, corruptions) keep
        firing; only the *waits* are cancelled."""
        self._wake.set()

    @property
    def closed(self) -> bool:
        return self._wake.is_set()

    # -- seams (called by the scheduler) ----------------------------------

    def on_submit(self, coords, feats, mask):
        """Admission seam: corrupt the feats of a planned submit ordinal
        (NaN payload — admission control must catch it)."""
        with self._lock:
            i = self._c_submits.value
            self._c_submits.inc()
            corrupt = i in self.corrupt_scenes
            if corrupt:
                self._c_corrupted.inc()
        if corrupt:
            # the whole payload goes NaN (a garbage sensor frame): some
            # row is valid whatever the mask, so admission always trips
            feats = np.full_like(np.asarray(feats, np.float32), np.nan)
        return coords, feats, mask

    def check_wait(self, dispatch_id: int, cap: int, rids) -> None:
        """Wait seam (runs OUTSIDE the scheduler lock): wait out the
        bucket's planned delay (interruptible — `close()` wakes it
        early), then raise `InjectedFault` if this dispatch — or any
        poisoned request on it — is planned to fail."""
        delay = self.delay_buckets.get(int(cap), 0.0)
        if delay > 0:
            with self._lock:
                self._c_delays.inc()
            self._wake.wait(delay)
        if self.slow_device > 0:
            with self._lock:
                self._c_slows.inc()
            self._wake.wait(self.slow_device)
        rate = self.storm_buckets.get(int(cap))
        if rate is not None:
            # token-bucket pacing: each dispatch claims the next slot on
            # a 1/rate grid, so the bucket's service rate is exactly
            # `rate` under saturation regardless of arrival pattern
            now = time.monotonic()
            with self._lock:
                slot = max(self._storm_next.get(int(cap), now), now)
                self._storm_next[int(cap)] = slot + 1.0 / rate
                self._c_storms.inc()
            if slot > now:
                self._wake.wait(slot - now)
        poisoned = self.poison_rids.intersection(int(r) for r in rids)
        if int(dispatch_id) in self.fail_dispatches or poisoned:
            with self._lock:
                self._c_injected.inc()
            raise InjectedFault(
                f"injected dispatch failure (dispatch {dispatch_id}, "
                f"bucket {cap}, rids {sorted(int(r) for r in rids)}"
                + (f", poisoned {sorted(poisoned)}" if poisoned else "")
                + ")")

    def on_worker_step(self, worker: int, step: int) -> None:
        """Worker-loop seam (called by a `ServeRouter` worker thread just
        before it processes its `step`-th request, 0-based per worker):

          * a planned HANG stops the loop cold for the planned duration
            (once, on the first request after the worker has served at
            least one — i.e. on a warm worker) without raising: the
            worker simply stops beating, and the router's liveness
            policy must notice;
          * a planned KILL raises `InjectedFault` at exactly the planned
            step, crashing the worker thread with its queued and
            in-flight work unfinished.
        """
        worker, step = int(worker), int(step)
        hang = self.hang_workers.get(worker)
        if hang is not None:
            with self._lock:
                fire = step >= 1 and worker not in self._hung
                if fire:
                    self._hung.add(worker)
                    self._c_hangs.inc()
            if fire:
                self._wake.wait(hang)
        if self.kill_workers.get(worker) == step:
            with self._lock:
                self._c_kills.inc()
            raise InjectedFault(
                f"injected worker kill (worker {worker}, step {step})")

    # -- telemetry --------------------------------------------------------

    def stats(self) -> dict:
        with self._lock:
            return {"submits_seen": self._c_submits.value,
                    "scenes_corrupted": self._c_corrupted.value,
                    "failures_injected": self._c_injected.value,
                    "delays_injected": self._c_delays.value,
                    "workers_killed": self._c_kills.value,
                    "workers_hung": self._c_hangs.value,
                    "slowdowns_injected": self._c_slows.value,
                    "storm_paced": self._c_storms.value}
