"""Continuous-batching serve scheduler for point-cloud segmentation.

The missing piece between the jit'd vmapped serving path (PR 3) and real
traffic: scenes arrive one at a time with heterogeneous point counts, but
a compiled program wants fixed shapes and the accelerator wants full
batches.  `ServeScheduler` closes the gap:

  * **admission** — `submit()` pads each scene up to its capacity bucket
    (`serve.buckets.BucketLadder`) and queues it with its bucket peers;
  * **grouping** — a bucket queue that reaches `max_batch` scenes is
    executed immediately as one micro-batch (continuous batching); a
    final `flush()` runs stragglers with fully-masked dummy scenes
    filling the fixed scene axis, so every execution has the SAME
    (max_batch, bucket_capacity) shape — compilations are bounded by the
    number of buckets, not by the traffic mix;
  * **mapping reuse** — each scene's level pyramid is built by the
    engine's single-scene jit and cached per-scene in the session's
    digest-keyed `MappingCache` (bucket-aware keys), then stacked into
    the micro-batch: repeated geometry skips the ranking sort + binary
    searches even when the batch composition around it changes;
  * **execution** — through the engine's `jax.vmap`-over-scenes path,
    optionally wrapped in `shard_map` over a scene-axis device mesh
    (`distributed.sharding.make_scene_mesh` / `shard_over_scenes`); a
    single-device host degrades to the plain vmapped path with no code
    changes;
  * **drain** — results complete out of submission order (whichever
    bucket fills first executes first); `drain()` hands them back with
    per-request latency, padding and cache telemetry, and `stats()`
    aggregates the serving picture (padding overhead %, mapping-cache
    hit rate, per-bucket occupancy, compile counts).
"""

from __future__ import annotations

import dataclasses
import math
import time
from collections import OrderedDict, deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mapping as M
from repro.distributed import sharding as SH
from repro.serve import buckets as BK


@dataclasses.dataclass(frozen=True)
class ServeRequest:
    """One admitted scene, already padded to its bucket capacity."""

    rid: int
    coords: np.ndarray          # (bucket, 1+D) int32, sentinel-padded
    mask: np.ndarray            # (bucket,) bool
    feats: np.ndarray           # (bucket, C)
    n_points: int               # caller's row count (pre-padding)
    n_valid: int                # unmasked rows (what the bucket serves)
    bucket: int                 # capacity bucket the scene landed in
    t_submit: float


@dataclasses.dataclass(frozen=True)
class ServeResult:
    """One served scene, un-padded back to the caller's row count."""

    rid: int
    preds: np.ndarray           # (n_points,) int32 class ids
    n_points: int
    bucket: int
    padding_frac: float         # dead fraction of the bucket's rows
                                # (padding + pre-masked rows)
    mapping_hit: bool           # scene's level pyramid came from cache
    latency_s: float            # submit -> result (queue wait included)


def _jit_cache_size(fn) -> int:
    try:
        return int(fn._cache_size())
    except Exception:
        return -1


class ServeScheduler:
    """Bucketed continuous batching in front of a `PointCloudEngine`.

    The engine owns the model + session (flow/engine policy, MappingCache)
    and the jit'd per-scene and vmapped entry points; the scheduler owns
    the traffic: queues per capacity bucket, fixed-shape micro-batches,
    the sharded executor, and serving telemetry.

    mesh="auto" picks a scene-axis mesh over the host's devices
    (`sharding.make_scene_mesh`) and runs micro-batches through
    `shard_map`; on a single-device host it resolves to None and the
    plain vmapped path runs — same code, no changes.  `max_batch` is
    rounded up to a multiple of the device count so the scene axis always
    divides the mesh.
    """

    def __init__(self, engine, max_batch: int = 4, mesh="auto",
                 axis: str = "scene"):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.engine = engine
        self.ladder: BK.BucketLadder = engine.ladder
        if mesh == "auto":
            mesh = SH.make_scene_mesh(axis)
        self.mesh = mesh
        if mesh is not None:
            n_dev = int(np.prod(list(mesh.shape.values())))
            max_batch = n_dev * max(1, math.ceil(max_batch / n_dev))
            self._apply = jax.jit(
                SH.shard_over_scenes(engine._apply_batch_fn, mesh, axis))
        else:
            self._apply = engine._apply_batch
        self.max_batch = int(max_batch)

        self._queues: OrderedDict[int, deque] = OrderedDict()
        self._completed: deque[ServeResult] = deque()
        self._dummy_levels: dict[int, object] = {}
        self._next_rid = 0
        # telemetry accumulators
        self._n_submitted = 0
        self._n_completed = 0
        self._real_points = 0           # valid (unmasked) caller rows
        self._issued_rows = 0           # bucket rows issued to the device
        self._scenes = {}               # bucket -> real scenes executed
        self._batches = {}              # bucket -> micro-batches executed
        self._dummies = {}              # bucket -> dummy fill scenes
        self._latency_sum = 0.0

    # -- admission --------------------------------------------------------

    def submit(self, coords, feats, mask=None) -> int:
        """Admit one scene; returns its request id.

        `coords` (N, 1+D) int32, `feats` (N, C); `mask` defaults to all
        rows valid.  The scene is padded to the smallest ladder bucket
        holding N rows and queued with its bucket peers; a bucket that
        reaches `max_batch` queued scenes executes immediately.
        """
        coords = np.asarray(coords)
        n = coords.shape[0]
        if mask is None:
            mask = np.ones(n, bool)
        cap = self.ladder.bucket_for(n)
        c, m, f = BK.pad_scene(coords, mask, feats, cap)
        req = ServeRequest(self._next_rid, c, m, f, n,
                           int(np.asarray(mask, bool).sum()), cap,
                           time.monotonic())
        self._next_rid += 1
        self._n_submitted += 1
        self._queues.setdefault(cap, deque()).append(req)
        if len(self._queues[cap]) >= self.max_batch:
            self._run_bucket(cap)
        return req.rid

    def flush(self) -> int:
        """Execute every queued scene (partial micro-batches are filled
        with masked dummy scenes); returns how many scenes ran."""
        ran = 0
        for cap in list(self._queues):
            while self._queues[cap]:
                ran += self._run_bucket(cap)
        return ran

    def drain(self) -> list[ServeResult]:
        """Hand back every completed result, in completion order (NOT
        submission order — whichever bucket filled first ran first)."""
        out = list(self._completed)
        self._completed.clear()
        return out

    def take(self, rids) -> dict[int, ServeResult]:
        """Pop completed results for `rids` only; anything else stays
        drainable (lets one caller collect its requests from a shared
        scheduler without discarding another caller's results)."""
        want = set(rids)
        out, keep = {}, deque()
        for r in self._completed:
            if r.rid in want:
                out[r.rid] = r
            else:
                keep.append(r)
        self._completed = keep
        return out

    def serve(self, scenes) -> dict[int, ServeResult]:
        """Convenience: submit an iterable of (coords, feats[, mask])
        scenes, flush, and return {rid: result}."""
        for scene in scenes:
            self.submit(*scene)
        self.flush()
        return {r.rid: r for r in self.drain()}

    # -- execution --------------------------------------------------------

    def _dummy_request(self, like: ServeRequest) -> ServeRequest:
        """A fully-masked scene filling the fixed scene axis: sentinel
        coords sort to the end and match nothing, so it costs one cached
        (all-sentinel) pyramid per bucket and zero result rows."""
        cap = like.bucket
        coords = np.full_like(like.coords, M.SENTINEL)
        mask = np.zeros(cap, bool)
        feats = np.zeros_like(like.feats)
        return ServeRequest(-1, coords, mask, feats, 0, 0, cap,
                            time.monotonic())

    def _run_bucket(self, cap: int) -> int:
        q = self._queues[cap]
        reqs = [q.popleft() for _ in range(min(self.max_batch, len(q)))]
        n_real = len(reqs)

        levels, hits = [], []
        for r in reqs:
            lv, hit = self.engine._levels_padded(r.coords, r.mask, cap)
            levels.append(lv)
            hits.append(hit)
        while len(reqs) < self.max_batch:
            # dummy fill: cached scheduler-side so the MappingCache
            # telemetry only counts real scenes
            d = self._dummy_request(reqs[0])
            if cap not in self._dummy_levels:
                self._dummy_levels[cap] = jax.block_until_ready(
                    self.engine._build(jnp.asarray(d.coords),
                                       jnp.asarray(d.mask)))
            reqs.append(d)
            levels.append(self._dummy_levels[cap])
        levels_b = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                          *levels)
        coords_b = jnp.asarray(np.stack([r.coords for r in reqs]))
        mask_b = jnp.asarray(np.stack([r.mask for r in reqs]))
        feats_b = jnp.asarray(np.stack([r.feats for r in reqs]))
        preds = np.asarray(
            jax.block_until_ready(
                self._apply(levels_b, coords_b, mask_b, feats_b)))

        t_done = time.monotonic()
        for i, r in enumerate(reqs[:n_real]):
            lat = t_done - r.t_submit
            self._completed.append(ServeResult(
                r.rid, preds[i, :r.n_points].astype(np.int32), r.n_points,
                cap, 1.0 - r.n_valid / cap, bool(hits[i]), lat))
            self._latency_sum += lat
        self._n_completed += n_real
        self._real_points += sum(r.n_valid for r in reqs[:n_real])
        self._issued_rows += self.max_batch * cap
        self._scenes[cap] = self._scenes.get(cap, 0) + n_real
        self._batches[cap] = self._batches.get(cap, 0) + 1
        self._dummies[cap] = self._dummies.get(cap, 0) \
            + (self.max_batch - n_real)
        return n_real

    # -- telemetry --------------------------------------------------------

    def stats(self) -> dict:
        """Serving telemetry: padding overhead, mapping-cache hit rate,
        per-bucket occupancy, compile counts, latency."""
        buckets = {}
        for cap in self._batches:
            issued = self._batches[cap] * self.max_batch
            buckets[int(cap)] = {
                "scenes": self._scenes[cap],
                "batches": self._batches[cap],
                "dummy_scenes": self._dummies[cap],
                "occupancy": self._scenes[cap] / issued if issued else 0.0,
            }
        overhead = (self._issued_rows / self._real_points - 1.0) \
            if self._real_points else 0.0
        return {
            "n_submitted": self._n_submitted,
            "n_completed": self._n_completed,
            "queue_depth": sum(len(q) for q in self._queues.values()),
            "padding_overhead": overhead,
            "mapping_cache": self.engine.cache_stats(),
            "buckets": buckets,
            "max_batch": self.max_batch,
            "n_devices": (int(np.prod(list(self.mesh.shape.values())))
                          if self.mesh is not None else 1),
            "compiles": {
                "build": _jit_cache_size(self.engine._build),
                "apply_batch": _jit_cache_size(self._apply),
            },
            "latency_avg_s": (self._latency_sum / self._n_completed
                              if self._n_completed else 0.0),
        }
