"""Continuous-batching serve scheduler for point-cloud segmentation.

The missing piece between the jit'd vmapped serving path (PR 3) and real
traffic: scenes arrive one at a time with heterogeneous point counts, but
a compiled program wants fixed shapes and the accelerator wants full
batches.  `ServeScheduler` closes the gap — and since the hot-loop PR it
is a small *pipelined runtime*, not a synchronous loop:

  * **admission** — `submit()` validates each scene up front
    (`serve.faults.validate_scene`: shapes, dtypes, finite features, the
    packed-key coordinate budget, the ladder fit) and refuses bad input
    with a typed `rejected` result instead of crashing mid-pipeline;
    accepted scenes are padded to their capacity bucket
    (`serve.buckets.BucketLadder`), digested once, and queued with their
    bucket peers.  Bounded backlog (`max_backlog`) sheds the newest
    request with a `shed` result when a bucket backs up; a per-request
    `deadline_s` converts overdue queued requests into `timeout`
    results.  `submit` is thread-safe, so producers can admit scenes
    WHILE a micro-batch executes;
  * **grouping** — a bucket queue that reaches its `max_batch` width
    (per-bucket overrides supported) executes immediately as one
    micro-batch; `flush()` runs stragglers with fully-masked dummy
    scenes; `max_wait_s` adds a deadline — a partial micro-batch executes
    once its oldest queued request has waited that long (checked in
    `submit()`/`poll()` and by the background watchdog).  Every
    execution of a bucket has the SAME (max_batch, bucket_capacity)
    shape, so compilations stay bounded by the number of buckets;
  * **assembly** — per-scene level pyramids come from the session's
    digest-keyed `MappingCache`, and the *stacked* micro-batch pytree is
    cached one level up in a composition-keyed `AssemblyCache`
    (`repro.api`): a hot loop replaying the same ordered batch
    composition skips the whole `tree_map`/`stack` pass, and dummy-fill
    tails are pre-stacked once per (bucket, n_dummies).  Host staging
    goes through preallocated per-(bucket, max_batch) arenas filled in
    place — no per-batch `np.stack`;
  * **execution** — through the engine's `jax.vmap`-over-scenes path
    (feats operand donated), optionally wrapped in `shard_map` over a
    scene-axis device mesh; dispatch is ASYNC: `_run_bucket` parks an
    in-flight slot (double-buffered, `pipeline_depth` per bucket) instead
    of blocking, so assembling micro-batch i+1 overlaps executing
    micro-batch i.  `pipeline_depth=0` restores the synchronous path
    (with `assembly_cache_entries=0` it is bit-for-bit the PR-4
    scheduler — the baseline `benchmarks/bench_serve.py` measures
    against);
  * **failure isolation** — a dispatch whose device wait raises does NOT
    poison the FIFO: the slot is dropped, its requests are retried as
    fresh dispatches (bisected into halves when the batch held several
    scenes, isolating a single poison scene in O(log max_batch)
    rounds), and a request that exhausts its `max_retries` re-dispatch
    budget completes with a typed `exec_failed` result while the
    scheduler keeps serving.  `serve.faults.FaultPlan` is the injectable
    chaos seam the policy is tested with;
  * **completion** — in-flight slots retire in `drain()` / `poll()` /
    `flush()` / `take()`; `poll()` retires only slots whose results are
    already on host (non-blocking pipeline tick), `drain()`/`take()`
    block for everything in flight, and the background watchdog
    (`watchdog_s`, a `launch.fault_tolerance.Ticker`) retires ready
    slots and fires `max_wait_s` deadline flushes on an *idle*
    scheduler, so `poll()` is truly constant-time.  Results complete out
    of submission order with per-request latency, padding and cache
    telemetry; errors arrive as `ServeResult.error` (typed taxonomy:
    rejected / shed / timeout / exec_failed) — no exception escapes
    `submit`/`poll`/`drain`/`take`/`serve`.  `stats()` aggregates the
    serving picture (padding overhead %, cache hit rates, per-bucket
    occupancy, deadline flushes, compile counts, fault counters).
    `close()` (or the context manager) drains in-flight work and joins
    the watchdog thread.
"""

from __future__ import annotations

import dataclasses
import math
import random
import threading
import time
from collections import OrderedDict, deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import AssemblyCache
from repro.core import mapping as M
from repro.distributed import sharding as SH
from repro.launch import fault_tolerance as FT
from repro.obs import Observability
from repro.serve import buckets as BK
from repro.serve import faults as FLT
from repro.serve import overload as OV
from repro.serve.faults import ServeError

DEFAULT_PIPELINE_DEPTH = 2
DEFAULT_ASSEMBLY_ENTRIES = 16
DEFAULT_MAX_RETRIES = 2
_MIN_WATCHDOG_S = 0.005


@dataclasses.dataclass(frozen=True)
class ServeRequest:
    """One admitted scene, already padded to its bucket capacity."""

    rid: int
    coords: np.ndarray          # (bucket, 1+D) int32, sentinel-padded
    mask: np.ndarray            # (bucket,) bool
    feats: np.ndarray           # (bucket, C)
    n_points: int               # caller's row count (pre-padding)
    n_valid: int                # unmasked rows (what the bucket serves)
    bucket: int                 # capacity bucket the scene landed in
    t_submit: float
    key: bytes = None           # pyramid digest (None on the legacy path)
    deadline: float | None = None   # absolute monotonic queue deadline
    priority: int = 0           # lane: higher dispatches first at flush


@dataclasses.dataclass(frozen=True)
class ServeResult:
    """One served scene, un-padded back to the caller's row count.

    Exactly one of `preds` / `error` is set: a request either completes
    with predictions or with a typed `ServeError` (rejected / shed /
    timeout / exec_failed) — the stream survives either way.
    """

    rid: int
    preds: np.ndarray | None    # (n_points,) int32 class ids; None on error
    n_points: int
    bucket: int                 # -1 when the scene never reached a bucket
    padding_frac: float         # dead fraction of the bucket's rows
                                # (padding + pre-masked rows)
    mapping_hit: bool           # scene's level pyramid came from cache
                                # (per-scene hit, or via a whole-batch
                                # assembly-cache hit)
    latency_s: float            # submit -> result (queue wait included)
    error: ServeError | None = None

    @property
    def ok(self) -> bool:
        return self.error is None


@dataclasses.dataclass
class _InFlight:
    """One dispatched, not-yet-retired micro-batch."""

    cap: int
    reqs: list                  # real requests only (dummies carry none)
    hits: list                  # per-request mapping/assembly hit flags
    preds: object               # (max_batch, cap) device array, un-waited
    dispatch_id: int = 0        # global dispatch ordinal (fault seam key)
    retries: int = 0            # redispatch generation (0 = fresh)


class _HostArena:
    """Preallocated host staging buffers for one (bucket, max_batch).

    Micro-batches are filled in place (no per-batch `np.stack`
    allocation), rotating over `depth` slots so assembling batch i+1
    never touches the slot batch i was shipped from — the host half of
    the double buffer.  feats is allocated lazily on first fill (channel
    count and dtype come from traffic, not config).
    """

    def __init__(self, depth: int, max_batch: int, cap: int,
                 coord_dim: int):
        self.depth = max(1, depth)
        self.coords = np.full((self.depth, max_batch, cap, coord_dim),
                              M.SENTINEL, np.int32)
        self.mask = np.zeros((self.depth, max_batch, cap), bool)
        self.feats = None
        self._slot = -1

    def next_slot(self, feats_like: np.ndarray) -> int:
        # reallocate on a channel-count/dtype change so a mixed stream is
        # staged at the caller's dtype (no silent in-place downcast) —
        # exactly like the per-batch np.stack path would behave
        shape = self.mask.shape + feats_like.shape[1:]
        if self.feats is None or self.feats.shape != shape \
                or self.feats.dtype != feats_like.dtype:
            self.feats = np.zeros(shape, feats_like.dtype)
        self._slot = (self._slot + 1) % self.depth
        return self._slot


def _jit_cache_size(fn) -> int:
    try:
        return int(fn._cache_size())
    except Exception:
        return -1


def _is_ready(x) -> bool:
    try:
        return all(leaf.is_ready()
                   for leaf in jax.tree_util.tree_leaves(x))
    except Exception:           # non-jax leaves / older runtimes
        return True


class ServeScheduler:
    """Bucketed continuous batching in front of a `PointCloudEngine`.

    The engine owns the model + session (flow/engine policy, MappingCache)
    and the jit'd per-scene and vmapped entry points; the scheduler owns
    the traffic: queues per capacity bucket, fixed-shape micro-batches,
    the composition-keyed assembly cache, the in-flight pipeline, the
    sharded executor, the failure-isolation policy, and serving
    telemetry.

    mesh="auto" picks a scene-axis mesh over the host's devices
    (`sharding.make_scene_mesh`) and runs micro-batches through
    `shard_map`; on a single-device host it resolves to None and the
    plain vmapped path runs — same code, no changes.  Every micro-batch
    width is rounded up to a multiple of the device count so the scene
    axis always divides the mesh.

    max_batch              : int, {capacity: width, "default": w} dict,
                             or None (ladder-level `BucketLadder.max_batch`
                             config, else `buckets.DEFAULT_MAX_BATCH`).
    pipeline_depth         : in-flight micro-batches per bucket before
                             dispatch blocks on the oldest; 0 = fully
                             synchronous execution.
    assembly_cache_entries : LRU bound of the composition-keyed stacked-
                             pyramid cache; 0 disables the cache AND the
                             host arenas (per-batch stack — the PR-4
                             assembly path, kept as the benchmark
                             baseline).
    max_wait_s             : deadline before a partial micro-batch
                             executes anyway (None = only on flush).
    validate               : admission validation (`faults.validate_scene`)
                             on submit; malformed / oversized scenes
                             complete with a `rejected` result instead of
                             raising.  False restores the raise-on-bad-
                             input PR-5 behaviour (the bench baseline).
    max_backlog            : PER-BUCKET bound on outstanding (queued +
                             in-flight) scenes; a submit beyond it is
                             shed with a `shed` result.  None = unbounded.
                             A natural setting is
                             (pipeline_depth + 1) * max_batch.  (The
                             router's same-named knob is PER-WORKER —
                             scenes assigned to one worker across all
                             buckets; `stats()` surfaces this one as
                             `scheduler_max_backlog`.)  With an
                             `overload` controller the EFFECTIVE bound
                             tightens adaptively to
                             ceil(service_rate x deadline_headroom)
                             (never looser than this static bound).
    max_retries            : re-dispatch budget per request after a
                             failed execution (2 isolates one poison
                             scene in a micro-batch of up to 4 via
                             bisect); a request that exhausts it
                             completes with `exec_failed`.
    retry_bisect           : split a failed multi-scene batch into halves
                             on retry (poison isolation) instead of
                             retrying it whole.
    retry_backoff_s        : base of the jittered exponential backoff
                             slept before each retry dispatch —
                             generation g waits retry_backoff_s * 2^g *
                             uniform(0.5, 1.5), so a transiently sick
                             device is not hammered with immediate
                             redispatches and concurrent retriers
                             decorrelate.  The default 0 preserves the
                             immediate-retry timing (and the bench
                             baseline).  The wait releases the scheduler
                             lock, so producers keep admitting scenes
                             while a retry backs off.
    retry_backoff_seed     : seed for the backoff jitter RNG — two
                             schedulers built with the same seed produce
                             identical backoff schedules (deterministic
                             chaos tests).  None (default) keeps the
                             module-level `random` source.
    overload               : `overload.OverloadPolicy` (or True for the
                             defaults, or a pre-built
                             `OverloadController`) — attaches the
                             SLO-aware overload controller: adaptive
                             shedding from the observed service rate,
                             priority/EDF queue ordering, per-bucket
                             circuit breakers, and the brownout ladder
                             (see `serve/overload.py`).  With a
                             controller, pipeline depth is enforced by
                             DEFERRING dispatch (full batches queue
                             until a slot retires — submit never blocks
                             on a device wait) instead of by the
                             blocking depth-overflow loop; the queues
                             that build are what the priority lanes
                             order and the adaptive bound sheds.  None
                             (default) keeps every serving path
                             bit-identical to the uncontrolled
                             scheduler.
    watchdog_s             : background ticker interval — fires
                             `max_wait_s` deadline flushes, expires
                             per-request deadlines and retires ready
                             slots on an idle scheduler.  None = auto
                             (max_wait_s / 4 when max_wait_s is set,
                             else off); 0 disables.  `close()` joins it.
    fault_plan             : `faults.FaultPlan` chaos seam (tests/CI);
                             None (the default) leaves the hot path
                             bit-identical.

    `submit`/`poll`/`drain`/`take`/`flush`/`stats` are thread-safe (one
    reentrant lock around queues, caches and telemetry), so producers can
    admit scenes while earlier micro-batches execute — including while
    another thread sits in `drain()`/`flush()`: the lock is released for
    the duration of every device wait (see `_retire_oldest_locked`).
    None of them raise for per-request problems — a request always
    completes, with predictions or with a typed `ServeResult.error`.
    """

    def __init__(self, engine, max_batch=None, mesh="auto",
                 axis: str = "scene",
                 pipeline_depth: int = DEFAULT_PIPELINE_DEPTH,
                 assembly_cache_entries: int = DEFAULT_ASSEMBLY_ENTRIES,
                 max_wait_s: float | None = None,
                 validate: bool = True,
                 max_backlog: int | None = None,
                 max_retries: int = DEFAULT_MAX_RETRIES,
                 retry_bisect: bool = True,
                 retry_backoff_s: float = 0.0,
                 retry_backoff_seed: int | None = None,
                 overload=None,
                 watchdog_s: float | None = None,
                 fault_plan: FLT.FaultPlan | None = None,
                 obs: Observability | None = None,
                 instance: str = "scheduler"):
        if pipeline_depth < 0:
            raise ValueError("pipeline_depth must be >= 0")
        if max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if retry_backoff_s < 0:
            raise ValueError("retry_backoff_s must be >= 0")
        if max_backlog is not None and max_backlog < 1:
            raise ValueError("max_backlog must be >= 1 (or None)")
        self.engine = engine
        self.ladder: BK.BucketLadder = engine.ladder
        if mesh == "auto":
            mesh = SH.make_scene_mesh(axis)
        self.mesh = mesh
        n_dev = int(np.prod(list(mesh.shape.values()))) \
            if mesh is not None else 1
        if mesh is not None:
            self._apply = jax.jit(
                SH.shard_over_scenes(engine._apply_batch_fn, mesh, axis),
                donate_argnums=(3,))
        else:
            self._apply = engine._apply_batch
        default, overrides = BK.resolve_max_batch(max_batch, self.ladder)

        def round_up(b):
            return n_dev * max(1, math.ceil(b / n_dev))

        self.max_batch = round_up(default)
        self.max_batch_overrides = {c: round_up(b)
                                    for c, b in overrides.items()}
        self.pipeline_depth = int(pipeline_depth)
        self.max_wait_s = max_wait_s
        self.validate = bool(validate)
        self.max_backlog = max_backlog
        self.max_retries = int(max_retries)
        self.retry_bisect = bool(retry_bisect)
        self.retry_backoff_s = float(retry_backoff_s)
        self._rng = random.Random(retry_backoff_seed) \
            if retry_backoff_seed is not None else random
        self.overload = OV.resolve_controller(overload)
        self.fault_plan = fault_plan if fault_plan is not None else \
            getattr(engine, "fault_plan", None)
        # the packed-key budget is only a constraint for the v2 engine
        self._check_key_budget = \
            getattr(engine.session.config, "engine", None) != "v1"
        self._legacy_assembly = assembly_cache_entries == 0
        self.assembly_cache = None if self._legacy_assembly else \
            AssemblyCache(assembly_cache_entries)

        self._lock = threading.RLock()
        # serializes retirement of the in-flight FIFO head: the waiting
        # thread drops the lock during block_until_ready (so submit()
        # stays responsive) and this condition keeps a second retirer
        # from racing past it
        self._retire_cv = threading.Condition(self._lock)
        self._retiring = False
        self._closed = False
        self._queues: OrderedDict[int, deque] = OrderedDict()
        self._completed: deque[ServeResult] = deque()
        self._inflight: deque[_InFlight] = deque()   # global dispatch FIFO
        self._arenas: dict[tuple, _HostArena] = {}
        self._dummy_levels: dict[int, object] = {}
        self._dummy_tails: dict[tuple, object] = {}
        self._next_rid = 0
        self._next_dispatch = 0
        self._attempts: dict[int, int] = {}     # rid -> failed dispatches
        self._outstanding: dict[int, int] = {}  # bucket -> admitted, live
        self._coord_dim = None                  # first-seen stream widths
        self._feat_shape = None
        self._has_deadlines = False
        self._has_priorities = False
        # telemetry: every accumulator is a child of the shared metrics
        # registry (repro.obs), bound once here so the hot path pays one
        # attribute lookup + inc — stats() below is a bit-compatible
        # view over these children.  Tracer/recorder stay None unless
        # the caller opted in (Observability.enabled()).
        self.obs = obs if obs is not None else Observability()
        self.instance = str(instance)
        self._tracer = self.obs.tracer
        self._recorder = self.obs.recorder
        reg, inst = self.obs.registry, self.instance
        self._c_submitted = reg.counter(
            "serve_requests_submitted_total",
            "scenes admitted via submit()", ("instance",)).labels(inst)
        self._c_completed = reg.counter(
            "serve_requests_completed_total",
            "requests completed (ok or typed error)",
            ("instance",)).labels(inst)
        self._c_ok = reg.counter(
            "serve_requests_ok_total",
            "requests completed with predictions", ("instance",)).labels(inst)
        fam_faults = reg.counter(
            "serve_faults_total", "typed error results by code",
            ("instance", "code"))
        self._c_faults = {c: fam_faults.labels(inst, c)
                          for c in FLT.ERROR_CODES}
        self._fam_scenes = reg.counter(
            "serve_scenes_total", "real scenes executed",
            ("instance", "bucket"))
        self._fam_batches = reg.counter(
            "serve_batches_total", "micro-batches executed",
            ("instance", "bucket"))
        self._fam_dummies = reg.counter(
            "serve_dummy_scenes_total", "dummy fill scenes executed",
            ("instance", "bucket"))
        self._m_buckets = {}            # cap -> (scenes, batches, dummies)
        self._c_points_real = reg.counter(
            "serve_points_real_total", "valid (unmasked) caller rows",
            ("instance",)).labels(inst)
        self._c_rows_issued = reg.counter(
            "serve_rows_issued_total", "bucket rows issued to the device",
            ("instance",)).labels(inst)
        self._c_deadline_flushes = reg.counter(
            "serve_deadline_flushes_total",
            "partial batches flushed by max_wait_s", ("instance",)).labels(inst)
        self._c_failed_dispatches = reg.counter(
            "serve_failed_dispatches_total",
            "micro-batch executions that raised", ("instance",)).labels(inst)
        self._c_retries = reg.counter(
            "serve_retries_total", "retry dispatches issued",
            ("instance",)).labels(inst)
        self._c_backoff = reg.counter(
            "serve_retry_backoff_seconds_total",
            "total time spent backing off before retries",
            ("instance",)).labels(inst)
        self._g_recovery = reg.gauge(
            "serve_recovery_seconds",
            "last failure -> next good retire", ("instance",)).labels(inst)
        self._h_latency = reg.histogram(
            "serve_request_latency_seconds",
            "submit -> predictions (OK results only)",
            ("instance",)).labels(inst)
        fam_errlat = reg.histogram(
            "serve_error_latency_seconds",
            "submit -> typed error result, by code", ("instance", "code"))
        self._h_errlat = {c: fam_errlat.labels(inst, c)
                          for c in FLT.ERROR_CODES}
        self._h_assembly = reg.histogram(
            "serve_assembly_seconds", "host assembly time per micro-batch",
            ("instance",)).labels(inst)
        self._h_queue_wait = reg.histogram(
            "serve_queue_wait_seconds", "admission -> dispatch",
            ("instance",)).labels(inst)
        reg.gauge("serve_queue_depth", "queued scenes (all buckets)",
                  ("instance",)).labels(inst).set_function(
            lambda: sum(len(q) for q in self._queues.values()))
        reg.gauge("serve_inflight_batches", "dispatched, un-retired slots",
                  ("instance",)).labels(inst).set_function(
            lambda: len(self._inflight))
        self._last_failure_t = None
        # trace bookkeeping (only touched when a tracer is wired in)
        self._rid_trace: dict[int, tuple[str, bool]] = {}  # rid->(tid,owned)
        self._qspans: dict[int, int] = {}    # rid -> open queue_wait span
        self._wspans: dict[int, int] = {}    # rid -> open device_wait span

        if self.overload is not None:
            self.overload.bind(self)

        if watchdog_s is None:
            if max_wait_s is not None:
                watchdog_s = max_wait_s / 4
            elif self.overload is not None:
                # the controller needs periodic ticks even when nobody
                # is polling — the estimator and the brownout ladder
                # both advance on the deadline sweep
                watchdog_s = self.overload.policy.tick_s
            else:
                watchdog_s = 0.0
        self._watchdog = FT.Ticker(
            max(_MIN_WATCHDOG_S, float(watchdog_s)), self._watchdog_tick,
            name="serve-watchdog") if watchdog_s > 0 else None

    def max_batch_for(self, cap: int) -> int:
        """Micro-batch width of one capacity bucket."""
        return self.max_batch_overrides.get(cap, self.max_batch)

    def _bucket_counters(self, cap: int):
        """(scenes, batches, dummy_scenes) counter children for one
        capacity bucket, bound on first dispatch into it."""
        m = self._m_buckets.get(cap)
        if m is None:
            b = str(cap)
            m = self._m_buckets[cap] = (
                self._fam_scenes.labels(self.instance, b),
                self._fam_batches.labels(self.instance, b),
                self._fam_dummies.labels(self.instance, b))
        return m

    # -- lifecycle --------------------------------------------------------

    def close(self) -> None:
        """Drain in-flight work and stop the watchdog.

        Queued scenes are executed (dummy-filled partial batches) and
        every in-flight micro-batch retires, so completed results stay
        drainable after close; the watchdog ticker thread is JOINED (no
        leaked daemon threads).  A chaos `FaultPlan` is closed first, so
        pending injected delays wake early and shutdown under chaos is
        prompt.  Idempotent; a submit after close completes with a
        `rejected` result instead of raising.
        """
        if self.fault_plan is not None:
            self.fault_plan.close()     # wake injected waits first
        wd, self._watchdog = self._watchdog, None
        if wd is not None:
            wd.close()                  # join OUTSIDE the lock
        with self._lock:
            if self._closed:
                return
            self._closed = True
            self._expire_overdue_locked()
            for cap in list(self._queues):
                while self._queues[cap]:
                    self._run_bucket(cap)
            while self._retire_oldest_locked():
                pass
            if self.overload is not None:
                self.overload.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- admission --------------------------------------------------------

    def submit(self, coords, feats, mask=None,
               deadline_s: float | None = None,
               priority: int = 0,
               trace_id: str | None = None) -> int:
        """Admit one scene; returns its request id — ALWAYS.

        `coords` (N, 1+D) int32, `feats` (N, C); `mask` defaults to all
        rows valid.  The scene is validated up front (shapes, dtypes,
        finite features, packed-key budget, ladder fit — see
        `faults.validate_scene`); a scene that fails admission completes
        immediately with a `rejected` result under the returned rid
        instead of raising.  Accepted scenes are padded to the smallest
        ladder bucket holding N rows and queued with their bucket peers;
        a bucket that reaches its `max_batch` width dispatches
        immediately (async — the call returns while the micro-batch
        executes).  `deadline_s` bounds the QUEUE wait: a request still
        queued that long later completes with a `timeout` result (a
        request already dispatched runs to completion).  With
        `max_backlog`, a submit into a backed-up bucket completes with a
        `shed` result.  Thread-safe: padding and digesting happen
        outside the lock, so concurrent producers overlap their
        admission work.

        `priority` (default 0, higher = more urgent) picks the lane:
        when any nonzero priority has been seen — or an overload
        controller is attached and deadlines are in play — each
        micro-batch takes the highest-priority queued scenes first,
        earliest deadline first within a priority (EDF), FIFO within
        ties.  Only the queue ORDER changes; per-scene predictions are
        bit-identical.  Under brownout level 3 the lanes below the
        policy's `shed_below_priority` are shed at admission.

        `trace_id` attaches this request's spans to an EXISTING trace
        (a router began it before enqueueing); the scheduler then never
        ends that trace's root — the component that began it does.
        With no tracer wired in (the default) the argument is ignored.
        """
        t_submit = time.monotonic()
        if self.fault_plan is not None:
            coords, feats, mask = self.fault_plan.on_submit(
                coords, feats, mask)
        err = None
        n, cap = 0, -1
        if self.validate:
            try:
                coords, mask, feats, n, cap = FLT.validate_scene(
                    coords, feats, mask, self.ladder,
                    check_key_budget=self._check_key_budget,
                    coord_dim=self._coord_dim,
                    feat_shape=self._feat_shape)
            except FLT.AdmissionError as e:
                err = e.as_error()
        else:
            # PR-5 behaviour (bench baseline): no validation, a ladder
            # overflow raises out of submit()
            coords = np.asarray(coords)
            n = coords.shape[0]
            if mask is None:
                mask = np.ones(n, bool)
            cap = self.ladder.bucket_for(n)
        if err is None:
            c, m, f = BK.pad_scene(coords, mask, feats, cap)
            key = None if self._legacy_assembly else \
                self.engine.scene_key(c, m, cap)
            n_valid = int(np.asarray(mask, bool).sum())
            deadline = t_submit + deadline_s \
                if deadline_s is not None else None
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
            self._c_submitted.inc()
            if err is None and self._closed:
                err = ServeError(FLT.REJECTED, "scheduler is closed")
            if err is None and self.max_backlog is not None and \
                    self._outstanding.get(cap, 0) >= self.max_backlog:
                ov = self.overload
                rate = ov.service_rate(cap) if ov is not None else None
                err = ServeError(
                    FLT.SHED,
                    f"bucket {cap} backlog at the max_backlog bound "
                    f"({self.max_backlog} outstanding scenes"
                    + (f"; observed service rate {rate:.1f} scenes/s"
                       if rate is not None else "") + ")",
                    retry_after_s=ov.retry_after(
                        cap, self._outstanding.get(cap, 0))
                    if ov is not None else None)
            if err is None and self.overload is not None:
                err = self.overload.check_admission_locked(
                    cap, self._outstanding.get(cap, 0), priority)
            tr = self._tracer
            if tr is not None:
                tid = trace_id if trace_id is not None else \
                    f"{self.instance}:rid:{rid}"
                tr.begin(tid, t=t_submit, rid=rid, instance=self.instance)
                self._rid_trace[rid] = (tid, trace_id is None)
                t_adm = time.monotonic()
                tr.span(tid, "admission", t_start=t_submit, t_end=t_adm,
                        bucket=cap, n_points=int(n))
            if self._recorder is not None:
                self._recorder.record("submit", rid=rid, bucket=int(cap),
                                      instance=self.instance,
                                      rejected=err is not None)
            if err is not None:
                self._complete_error_locked(rid, n, cap, t_submit, err)
                return rid
            if tr is not None:
                sid = tr.span(tid, "queue_wait", t_start=t_adm,
                              bucket=cap)
                if sid is not None:
                    self._qspans[rid] = sid
            if self._coord_dim is None:
                self._coord_dim = int(coords.shape[1])
                self._feat_shape = tuple(np.asarray(feats).shape[1:])
            req = ServeRequest(rid, c, m, f, n, n_valid, cap,
                               t_submit, key, deadline, int(priority))
            if deadline is not None:
                self._has_deadlines = True
            if priority:
                self._has_priorities = True
            self._outstanding[cap] = self._outstanding.get(cap, 0) + 1
            self._queues.setdefault(cap, deque()).append(req)
            if len(self._queues[cap]) >= self.max_batch_for(cap):
                if self.overload is None or self.pipeline_depth == 0 \
                        or not self._bucket_at_depth_locked(cap):
                    self._run_bucket(cap)
                # else: DEFERRED dispatch (controller mode) — the bucket
                # is at its pipeline depth, so the batch stays queued
                # until a slot retires (_pump_locked).  This is what
                # gives the priority/EDF lanes something to order and
                # the adaptive bound a real backlog to measure; the
                # uncontrolled scheduler keeps the PR-6 behaviour of
                # dispatching immediately and blocking in the depth
                # overflow loop instead.
            self._check_deadlines_locked()
            return rid

    def poll(self) -> list[ServeResult]:
        """Non-blocking pipeline tick: deadline-flush overdue partial
        buckets, expire overdue requests, retire in-flight micro-batches
        whose results are already on host, and hand back everything
        completed so far."""
        with self._lock:
            self._check_deadlines_locked()
            while self._retire_oldest_locked(only_ready=True):
                pass
            if self._pump_locked():
                while self._retire_oldest_locked(only_ready=True):
                    pass
            out = list(self._completed)
            self._completed.clear()
            return out

    def flush(self) -> int:
        """Execute every queued scene (partial micro-batches are filled
        with masked dummy scenes), wait for everything in flight, and
        return how many scenes ran."""
        with self._lock:
            self._expire_overdue_locked()
            ran = 0
            for cap in list(self._queues):
                while self._queues[cap]:
                    ran += self._run_bucket(cap)
            while self._retire_oldest_locked():
                pass
            return ran

    def drain(self) -> list[ServeResult]:
        """Hand back every completed result, in completion order (NOT
        submission order — whichever bucket filled first ran first);
        waits for in-flight micro-batches."""
        with self._lock:
            while True:
                while self._retire_oldest_locked():
                    pass
                if not self._pump_locked():
                    break
            out = list(self._completed)
            self._completed.clear()
            return out

    def take(self, rids) -> dict[int, ServeResult]:
        """Pop completed results for `rids` only; anything else stays
        drainable (lets one caller collect its requests from a shared
        scheduler without discarding another caller's results).  Waits
        for in-flight micro-batches (the rids may be on one)."""
        with self._lock:
            while True:
                while self._retire_oldest_locked():
                    pass
                if not self._pump_locked():
                    break
            want = set(rids)
            out, keep = {}, deque()
            for r in self._completed:
                if r.rid in want:
                    out[r.rid] = r
                else:
                    keep.append(r)
            self._completed = keep
            return out

    def serve(self, scenes) -> dict[int, ServeResult]:
        """Convenience: submit an iterable of (coords, feats[, mask])
        scenes, flush, and return {rid: result} for THIS call's requests
        only — on a shared scheduler, other callers' results stay
        drainable/takeable."""
        rids = [self.submit(*scene) for scene in scenes]
        self.flush()
        return self.take(rids)

    # -- execution --------------------------------------------------------

    def _dummy_request(self, like: ServeRequest) -> ServeRequest:
        """A fully-masked scene filling the fixed scene axis: sentinel
        coords sort to the end and match nothing, so it costs one cached
        (all-sentinel) pyramid per bucket and zero result rows."""
        cap = like.bucket
        coords = np.full_like(like.coords, M.SENTINEL)
        mask = np.zeros(cap, bool)
        feats = np.zeros_like(like.feats)
        return ServeRequest(-1, coords, mask, feats, 0, 0, cap,
                            time.monotonic())

    def _dummy_pyramid(self, like: ServeRequest):
        """The bucket's all-sentinel level pyramid, built once — cached
        scheduler-side so MappingCache telemetry only counts real
        scenes."""
        cap = like.bucket
        if cap not in self._dummy_levels:
            self._dummy_levels[cap] = jax.block_until_ready(
                self.engine._build(
                    jnp.asarray(np.full_like(like.coords, M.SENTINEL)),
                    jnp.asarray(np.zeros(cap, bool))))
        return self._dummy_levels[cap]

    def _dummy_tail(self, like: ServeRequest, n_dummy: int):
        """The pre-stacked (n_dummy, ...) dummy pyramid tail for partial
        micro-batches, built once per (bucket, n_dummies)."""
        key = (like.bucket, n_dummy)
        if key not in self._dummy_tails:
            base = self._dummy_pyramid(like)
            self._dummy_tails[key] = jax.tree_util.tree_map(
                lambda x: jnp.stack([x] * n_dummy), base)
        return self._dummy_tails[key]

    def _assemble(self, reqs, cap: int, mb: int, marks: dict = None):
        """Arena + composition-cache assembly: (hits, apply operands).

        coords/mask/feats are staged in the bucket's preallocated host
        arena (rotating slot, filled in place); the stacked level-pyramid
        pytree — and the stacked coords/mask device arrays, which the
        composition key fully determines — are served from the
        AssemblyCache when the ordered composition repeats, else stacked
        once (real scenes + the pre-stacked dummy tail) and cached.  Only
        feats is re-staged on a hit: it is the one operand the key does
        not cover (same geometry, fresh sensor payload).

        `marks` (tracing only) receives monotonic timestamps for the
        arena-staging and cache-lookup phases plus the hit flag.
        """
        n_real, n_dummy = len(reqs), mb - len(reqs)
        if marks is not None:
            marks["arena_t0"] = time.monotonic()
        arena = self._arenas.get((cap, mb))
        if arena is None:
            arena = self._arenas[(cap, mb)] = _HostArena(
                max(1, self.pipeline_depth), mb, cap,
                reqs[0].coords.shape[1])
        s = arena.next_slot(reqs[0].feats)
        for i, r in enumerate(reqs):
            arena.feats[s, i] = r.feats
        if n_dummy:                     # clear stale rows from fuller runs
            arena.feats[s, n_real:] = 0
        feats_b = jnp.asarray(arena.feats[s])

        comp_key = (cap, mb, n_dummy, tuple(r.key for r in reqs))
        if marks is not None:
            marks["lookup_t0"] = time.monotonic()
        cached = self.assembly_cache.lookup(comp_key)
        if cached is not None:
            # the whole stacked batch is reused: every scene's mapping
            # work was skipped wholesale, so each request reports a hit
            # (the per-scene MappingCache is bypassed, not consulted)
            levels_b, coords_b, mask_b = cached
            hits = [True] * n_real
        else:
            for i, r in enumerate(reqs):
                arena.coords[s, i] = r.coords
                arena.mask[s, i] = r.mask
            if n_dummy:
                arena.coords[s, n_real:] = M.SENTINEL
                arena.mask[s, n_real:] = False
            coords_b = jnp.asarray(arena.coords[s])
            mask_b = jnp.asarray(arena.mask[s])
            per = [self.engine._levels_padded(r.coords, r.mask, cap,
                                              key=r.key) for r in reqs]
            hits = [h for _, h in per]
            levels_b = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *[lv for lv, _ in per])
            if n_dummy:
                levels_b = jax.tree_util.tree_map(
                    lambda a, b: jnp.concatenate([a, b]),
                    levels_b, self._dummy_tail(reqs[0], n_dummy))
            self.assembly_cache.put(comp_key,
                                    (levels_b, coords_b, mask_b))
        if marks is not None:
            marks["lookup_t1"] = time.monotonic()
            marks["cache_hit"] = cached is not None
        return hits, (levels_b, coords_b, mask_b, feats_b)

    def _assemble_legacy(self, reqs, cap: int, mb: int):
        """PR-4 assembly (per-batch np.stack + tree_map over per-scene
        cached pyramids) — the `assembly_cache_entries=0` baseline path
        that `bench_serve` measures the pipelined path against."""
        reqs = list(reqs)
        levels, hits = [], []
        for r in reqs:
            lv, hit = self.engine._levels_padded(r.coords, r.mask, cap)
            levels.append(lv)
            hits.append(hit)
        while len(reqs) < mb:
            d = self._dummy_request(reqs[0])
            reqs.append(d)
            levels.append(self._dummy_pyramid(d))
        levels_b = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                          *levels)
        coords_b = jnp.asarray(np.stack([r.coords for r in reqs]))
        mask_b = jnp.asarray(np.stack([r.mask for r in reqs]))
        feats_b = jnp.asarray(np.stack([r.feats for r in reqs]))
        return hits, (levels_b, coords_b, mask_b, feats_b)

    def _bucket_at_depth_locked(self, cap: int) -> bool:
        """Is this bucket's in-flight slot count at its pipeline depth?
        (The same bound the uncontrolled depth-overflow loop enforces by
        blocking — controller mode enforces it by deferring dispatch.)"""
        return sum(1 for slot in self._inflight if slot.cap == cap) \
            > self.pipeline_depth

    def _pump_locked(self) -> int:
        """Dispatch deferred full batches that now fit their bucket's
        pipeline depth (controller mode only — without a controller
        submit never defers).  Returns how many scenes were dispatched;
        callers that just retired slots loop until this returns 0."""
        if self.overload is None or self.pipeline_depth == 0:
            return 0
        ran = 0
        for cap in list(self._queues):
            q = self._queues[cap]
            while len(q) >= self.max_batch_for(cap) and \
                    not self._bucket_at_depth_locked(cap):
                ran += self._run_bucket(cap)
        return ran

    def _lane_order_enabled(self) -> bool:
        """Priority/EDF queue ordering is live once any nonzero
        priority has been submitted, or an overload controller is
        attached and deadlines are in play.  Plain FIFO streams (the
        PR-9 behaviour) never enter the reorder path — bit-identical
        dispatch composition."""
        return self._has_priorities or \
            (self.overload is not None and self._has_deadlines)

    def _run_bucket(self, cap: int) -> int:
        """Pop up to max_batch queued scenes and dispatch them (caller
        holds the lock).  With priority lanes active the pop takes the
        highest-priority scenes first, earliest deadline first within a
        priority (EDF), FIFO within ties — the micro-batch SHAPE and
        each scene's predictions are unchanged, only which queued
        scenes go first."""
        q = self._queues[cap]
        mb = self.max_batch_for(cap)
        take = min(mb, len(q))
        if take > 1 and len(q) > take and self._lane_order_enabled():
            items = list(q)
            chosen = sorted(
                range(len(items)),
                key=lambda i: (-items[i].priority,
                               items[i].deadline
                               if items[i].deadline is not None
                               else math.inf, i))[:take]
            picked = set(chosen)
            reqs = [items[i] for i in sorted(picked)]
            q.clear()
            q.extend(items[i] for i in range(len(items))
                     if i not in picked)
        else:
            reqs = [q.popleft() for _ in range(take)]
        if not reqs:
            return 0
        return self._dispatch(reqs, cap, retries=0)

    def _dispatch(self, reqs, cap: int, retries: int) -> int:
        """Assemble + dispatch one micro-batch (caller holds the lock).

        Dispatch is asynchronous: the jit call returns a future-like
        device array that is parked on the in-flight FIFO; completion
        happens in drain()/poll()/flush()/take() (or the watchdog).
        Once a bucket exceeds `pipeline_depth` in-flight slots the
        oldest slots retire first (double buffering) — with depth 0 the
        batch retires immediately (synchronous PR-4 behaviour).  Retry
        dispatches (`retries > 0`) run partial batches at the SAME
        (max_batch, capacity) shape with dummy fill, so failure recovery
        never compiles a new program.  A dispatch that raises on the
        spot (assembly or launch) goes straight to the failure-isolation
        path instead of propagating.
        """
        mb = self.max_batch_for(cap)
        n_real = len(reqs)
        did = self._next_dispatch
        self._next_dispatch += 1
        if retries:
            self._c_retries.inc()
        tr = self._tracer
        t_disp = time.monotonic()
        marks = {} if tr is not None and not self._legacy_assembly else None
        try:
            t0 = time.perf_counter()
            if self._legacy_assembly:
                hits, operands = self._assemble_legacy(reqs, cap, mb)
            else:
                hits, operands = self._assemble(reqs, cap, mb, marks)
            t1 = time.perf_counter()
            self._h_assembly.observe(t1 - t0)
            preds = self._apply(*operands)
        except Exception as e:
            self._on_slot_failed(
                _InFlight(cap, list(reqs), [False] * n_real, None,
                          did, retries), e)
            return n_real
        if tr is not None:
            self._trace_dispatch(reqs, did, cap, retries, t_disp, marks)
        if self._recorder is not None:
            self._recorder.record(
                "dispatch", dispatch_id=did, bucket=int(cap),
                n_real=n_real, retries=retries,
                rids=[r.rid for r in reqs], instance=self.instance)
        self._inflight.append(_InFlight(cap, list(reqs), hits, preds,
                                        did, retries))

        m_scenes, m_batches, m_dummies = self._bucket_counters(cap)
        self._c_points_real.inc(sum(r.n_valid for r in reqs))
        self._c_rows_issued.inc(mb * cap)
        m_scenes.inc(n_real)
        m_batches.inc()
        m_dummies.inc(mb - n_real)
        for r in reqs:
            self._h_queue_wait.observe(t_disp - r.t_submit)

        if self.pipeline_depth == 0:
            while self._retire_oldest_locked():
                pass
        elif self.overload is None:
            # double buffering: once this bucket exceeds its depth, pay
            # for the FIFO head (possibly an older bucket's slot — see
            # _retire_oldest_locked) until the bucket is back in budget
            while sum(1 for slot in self._inflight if slot.cap == cap) \
                    > self.pipeline_depth:
                self._retire_oldest_locked()
        # else: controller mode bounds depth at ADMISSION (deferred
        # dispatch in submit) instead of blocking here — retirement
        # belongs to poll()/flush()/take()/the watchdog, so submit never
        # sits in a device wait and the deferral decision is
        # deterministic (only a deadline flush can transiently exceed
        # the depth)
        return n_real

    def _trace_dispatch(self, reqs, did: int, cap: int, retries: int,
                        t_disp: float, marks: dict | None) -> None:
        """Per-request dispatch spans (caller holds the lock, tracer is
        wired in): close the queue_wait span, record the dispatch span
        with its assembly children, open the device_wait span."""
        tr = self._tracer
        t_launch = time.monotonic()
        for r in reqs:
            tid_owned = self._rid_trace.get(r.rid)
            if tid_owned is None:
                continue
            tid = tid_owned[0]
            tr.end_span(tid, self._qspans.pop(r.rid, None), t_end=t_disp)
            dspan = tr.span(tid, "dispatch", t_start=t_disp,
                            t_end=t_launch, dispatch_id=did,
                            bucket=cap, retries=retries)
            if marks:
                aspan = tr.span(tid, "assembly", parent=dspan,
                                t_start=marks["arena_t0"],
                                t_end=marks["lookup_t1"],
                                cache_hit=marks["cache_hit"])
                tr.span(tid, "arena_staging", parent=aspan,
                        t_start=marks["arena_t0"],
                        t_end=marks["lookup_t0"])
                tr.span(tid, "assembly_lookup", parent=aspan,
                        t_start=marks["lookup_t0"],
                        t_end=marks["lookup_t1"])
            sid = tr.span(tid, "device_wait", t_start=t_launch,
                          dispatch_id=did)
            if sid is not None:
                self._wspans[r.rid] = sid

    def _wait_slot(self, slot: _InFlight):
        """Block for one slot's device results (runs WITHOUT the lock).
        The fault plan's wait seam lives here: an injected delay or
        failure behaves exactly like a slow or crashing device."""
        if self.fault_plan is not None:
            self.fault_plan.check_wait(slot.dispatch_id, slot.cap,
                                       [r.rid for r in slot.reqs])
        return jax.block_until_ready(slot.preds)

    def _on_slot_failed(self, slot: _InFlight, exc: BaseException) -> None:
        """Failure isolation (caller holds the lock): a failed
        micro-batch never re-enters the FIFO to poison later retires.

        Every real request on the slot gets another chance as a fresh
        dispatch — bisected into halves when the batch held several
        scenes (`retry_bisect`), so a single poison scene is isolated in
        O(log max_batch) rounds while its neighbours complete normally —
        and a request that has exhausted its `max_retries` re-dispatch
        budget completes with a typed `exec_failed` result.  The
        scheduler keeps serving either way.
        """
        self._c_failed_dispatches.inc()
        self._last_failure_t = time.monotonic()
        if self.overload is not None:
            self.overload.record_dispatch_failure(slot.cap)
        if self._tracer is not None:
            for r in slot.reqs:
                tid_owned = self._rid_trace.get(r.rid)
                if tid_owned is not None:
                    tid = tid_owned[0]
                    self._tracer.end_span(
                        tid, self._wspans.pop(r.rid, None),
                        t_end=self._last_failure_t, failed=True)
                    self._tracer.event(
                        tid, "dispatch_failed", t=self._last_failure_t,
                        dispatch_id=slot.dispatch_id, error=repr(exc))
        if self._recorder is not None:
            self._recorder.record(
                "dispatch_failed", dispatch_id=slot.dispatch_id,
                bucket=int(slot.cap), rids=[r.rid for r in slot.reqs],
                retries=slot.retries, error=repr(exc),
                instance=self.instance)
        retryable, dead = [], []
        for r in slot.reqs:
            a = self._attempts.get(r.rid, 0) + 1
            self._attempts[r.rid] = a
            (retryable if a <= self.max_retries else dead).append(r)
        for r in dead:
            self._attempts.pop(r.rid, None)
            self._outstanding[slot.cap] = \
                self._outstanding.get(slot.cap, 1) - 1
            self._complete_error_locked(
                r.rid, r.n_points, slot.cap, r.t_submit,
                ServeError(FLT.EXEC_FAILED,
                           f"micro-batch execution failed "
                           f"{self.max_retries + 1}x; last error: {exc}"))
        if not retryable:
            return
        self._backoff_locked(slot.retries)
        if len(retryable) > 1 and self.retry_bisect:
            mid = (len(retryable) + 1) // 2
            groups = (retryable[:mid], retryable[mid:])
        else:
            groups = (retryable,)
        for group in groups:
            self._dispatch(group, slot.cap, slot.retries + 1)

    def _backoff_locked(self, generation: int) -> None:
        """Jittered exponential backoff before a retry dispatch (the
        `retry_backoff_s` knob; 0 — the default — keeps retries
        immediate).  The retried requests live only on this call's
        stack, so the lock is safe to release for the wait: producers
        keep admitting scenes, and nothing can re-dispatch the failed
        slot's requests concurrently."""
        if self.retry_backoff_s <= 0 or self._closed:
            return
        delay = self.retry_backoff_s * (2 ** generation) \
            * (0.5 + self._rng.random())
        self._c_backoff.inc(delay)
        self._lock.release()
        try:
            time.sleep(delay)
        finally:
            self._lock.acquire()

    def _retire_oldest_locked(self, only_ready: bool = False) -> bool:
        """Retire the OLDEST in-flight micro-batch; returns False when
        there is nothing (eligible) to retire.

        FIFO retirement keeps completion order = dispatch order, like
        the synchronous scheduler — even when one bucket's depth
        overflow pays for older buckets' slots first (they were
        dispatched earlier, so waiting on them in order is the bound on
        total in-flight memory, not an accident).  The lock is RELEASED
        during the device wait so producer threads can keep admitting
        scenes; `_retiring` serializes retirers on the FIFO head.  With
        `only_ready` the call never blocks: it retires only a head whose
        result is already on host (poll()'s non-blocking tick).

        A wait that raises resolves the slot through the
        failure-isolation path (`_on_slot_failed`: retry / bisect /
        `exec_failed` results) — the slot is NOT re-queued, so one
        failed execution can never poison every later retire.  Only
        BaseExceptions that aren't Exceptions (KeyboardInterrupt,
        SystemExit) re-queue the slot and propagate.

        Caller must hold the lock exactly once (every public entry point
        acquires it with one `with self._lock:` and internal helpers
        never re-enter), so the release/re-acquire below fully drops it.
        """
        if only_ready and self._retiring:
            return False                # a blocking retirer owns the head
        while self._retiring:
            self._retire_cv.wait()
        if not self._inflight:
            return False
        if only_ready and not _is_ready(self._inflight[0].preds):
            return False
        slot = self._inflight.popleft()
        self._retiring = True
        self._lock.release()
        failure = None
        try:
            preds = np.asarray(self._wait_slot(slot))
        except Exception as e:
            failure = e
        except BaseException:
            self._lock.acquire()
            self._retiring = False
            # interpreter-level interrupt: put the slot back at the head
            # so its requests stay addressable, and propagate
            self._inflight.appendleft(slot)
            self._retire_cv.notify_all()
            raise
        self._lock.acquire()
        self._retiring = False
        self._retire_cv.notify_all()
        if failure is not None:
            self._on_slot_failed(slot, failure)
            return True                 # the slot WAS resolved
        t_done = time.monotonic()
        if self._last_failure_t is not None:
            self._g_recovery.set(t_done - self._last_failure_t)
            self._last_failure_t = None
        if self.overload is not None:
            self.overload.record_dispatch_success(slot.cap,
                                                  len(slot.reqs))
        tr = self._tracer
        for i, r in enumerate(slot.reqs):
            lat = t_done - r.t_submit
            self._attempts.pop(r.rid, None)
            self._outstanding[slot.cap] = \
                self._outstanding.get(slot.cap, 1) - 1
            self._completed.append(ServeResult(
                r.rid, preds[i, :r.n_points].astype(np.int32), r.n_points,
                slot.cap, 1.0 - r.n_valid / slot.cap, bool(slot.hits[i]),
                lat))
            self._h_latency.observe(lat)
            if tr is not None:
                tid_owned = self._rid_trace.pop(r.rid, None)
                if tid_owned is not None:
                    tid, owned = tid_owned
                    tr.end_span(tid, self._wspans.pop(r.rid, None),
                                t_end=t_done)
                    tr.event(tid, "retire", t=t_done,
                             dispatch_id=slot.dispatch_id)
                    if owned:
                        tr.end(tid, t=t_done, outcome="ok")
        if self._recorder is not None:
            self._recorder.record(
                "retire", dispatch_id=slot.dispatch_id,
                bucket=int(slot.cap), rids=[r.rid for r in slot.reqs],
                instance=self.instance)
        self._c_completed.inc(len(slot.reqs))
        self._c_ok.inc(len(slot.reqs))
        return True

    # -- failure completion / deadlines -----------------------------------

    def _complete_error_locked(self, rid: int, n_points: int, bucket: int,
                               t_submit: float, err: ServeError) -> None:
        """Terminate one request with a typed error result.

        The latency lands in the per-code error histogram — the average
        only ever covered OK results, so shed/timeout/exec_failed wait
        times used to vanish from telemetry entirely."""
        now = time.monotonic()
        lat = now - t_submit
        self._completed.append(ServeResult(
            rid, None, int(n_points), int(bucket), 0.0, False, lat, err))
        self._c_completed.inc()
        self._c_faults[err.code].inc()
        self._h_errlat[err.code].observe(lat)
        if self._tracer is not None:
            tid_owned = self._rid_trace.pop(rid, None)
            if tid_owned is not None:
                tid, owned = tid_owned
                self._tracer.end_span(tid, self._qspans.pop(rid, None),
                                      t_end=now)
                self._wspans.pop(rid, None)
                self._tracer.event(tid, "error", t=now, code=err.code,
                                   message=err.message)
                if owned:
                    self._tracer.end(tid, t=now, outcome=err.code)
        if self._recorder is not None:
            self._recorder.record("error", rid=rid, code=err.code,
                                  bucket=int(bucket),
                                  instance=self.instance)
            if err.code == FLT.EXEC_FAILED:
                self._recorder.dump("exec_failed",
                                    key=("exec_failed", self.instance, rid))

    def _expire_overdue_locked(self) -> None:
        """Convert queued requests whose `deadline_s` elapsed into
        `timeout` results (a dispatched request runs to completion —
        device work cannot be cancelled)."""
        if not self._has_deadlines:
            return
        now = time.monotonic()
        live = 0
        for cap in list(self._queues):
            q = self._queues[cap]
            if any(r.deadline is not None for r in q):
                keep = deque()
                for r in q:
                    if r.deadline is not None and now >= r.deadline:
                        self._attempts.pop(r.rid, None)
                        self._outstanding[cap] = \
                            self._outstanding.get(cap, 1) - 1
                        self._complete_error_locked(
                            r.rid, r.n_points, cap, r.t_submit,
                            ServeError(
                                FLT.TIMEOUT,
                                f"deadline_s exceeded after "
                                f"{now - r.t_submit:.3f}s in queue",
                                retry_after_s=self.overload.retry_after(
                                    cap, self._outstanding.get(cap, 0))
                                if self.overload is not None else None))
                    else:
                        keep.append(r)
                self._queues[cap] = keep
            live += sum(1 for r in self._queues[cap]
                        if r.deadline is not None)
        self._has_deadlines = live > 0

    def _check_deadlines_locked(self, from_watchdog: bool = False) -> None:
        """Deadline policies: expire overdue requests (`deadline_s` ->
        `timeout` results), then the max_wait_s flush — a partial
        micro-batch executes once its oldest queued request exceeds the
        batching deadline.  A WATCHDOG-fired flush also snapshots the
        flight recorder: nobody was polling, so the ring around the
        stall is the evidence worth keeping.  The overload controller
        ticks here too (rate re-estimation + brownout ladder) — this
        sweep runs from submit()/poll() and the watchdog, so the
        control loop advances with traffic and on idle schedulers
        alike."""
        if self.overload is not None:
            self.overload.maybe_tick()
        self._expire_overdue_locked()
        if self.max_wait_s is None:
            return
        now = time.monotonic()
        for cap in list(self._queues):
            q = self._queues[cap]
            if q and now - q[0].t_submit >= self.max_wait_s:
                self._c_deadline_flushes.inc()
                if self._recorder is not None:
                    self._recorder.record(
                        "deadline_flush", bucket=int(cap),
                        queued=len(q), from_watchdog=from_watchdog,
                        instance=self.instance)
                    if from_watchdog:
                        self._recorder.dump(
                            "watchdog_deadline_flush",
                            key=("wd_flush", self.instance,
                                 int(self._c_deadline_flushes.value)))
                self._run_bucket(cap)

    def _watchdog_tick(self) -> None:
        """Background completion (the `watchdog_s` Ticker): fire
        `max_wait_s` deadline flushes, expire per-request deadlines, and
        retire already-ready slots on an idle scheduler — so results
        complete without anyone calling poll(), and poll() itself stays
        constant-time."""
        with self._lock:
            if self._closed:
                return
            self._check_deadlines_locked(from_watchdog=True)
            while self._retire_oldest_locked(only_ready=True):
                pass
            if self._pump_locked():
                while self._retire_oldest_locked(only_ready=True):
                    pass

    # -- telemetry --------------------------------------------------------

    def service_rate(self, cap: int) -> float | None:
        """Observed EWMA service rate (scenes/s) for one bucket — None
        without an overload controller or before it has an estimate."""
        with self._lock:
            return self.overload.service_rate(cap) \
                if self.overload is not None else None

    def retry_after_hint(self) -> float | None:
        """Aggregate backpressure hint: estimated seconds until this
        scheduler's outstanding work drains at the observed completion
        rate (what a router aggregates across workers for a pool-level
        shed).  None without an overload controller."""
        with self._lock:
            return self.overload.retry_after_hint() \
                if self.overload is not None else None

    def stats(self) -> dict:
        """Serving telemetry: padding overhead, mapping + assembly cache
        hit rates, assembly time, per-bucket occupancy, deadline flushes,
        pipeline state, compile counts, latency, and the fault counters
        (rejected / shed / timeout / exec_failed, failed dispatches,
        retries, last failure->recovery time).  `scheduler_max_backlog`
        is the PER-BUCKET admission bound (the router's per-worker bound
        surfaces as `router_max_backlog` in ITS stats())."""
        with self._lock:
            buckets = {}
            for cap, (m_scenes, m_batches, m_dummies) in \
                    self._m_buckets.items():
                issued = m_scenes.value + m_dummies.value
                buckets[int(cap)] = {
                    "scenes": m_scenes.value,
                    "batches": m_batches.value,
                    "dummy_scenes": m_dummies.value,
                    "occupancy": (m_scenes.value / issued
                                  if issued else 0.0),
                    "max_batch": self.max_batch_for(cap),
                }
            real_points = self._c_points_real.value
            overhead = (self._c_rows_issued.value / real_points - 1.0) \
                if real_points else 0.0
            n_batches = self._h_assembly.count
            assembly_s = self._h_assembly.sum
            h_lat = self._h_latency
            return {
                "n_submitted": self._c_submitted.value,
                "n_completed": self._c_completed.value,
                "n_ok": self._c_ok.value,
                "queue_depth": sum(len(q) for q in self._queues.values()),
                "in_flight": len(self._inflight),
                "padding_overhead": overhead,
                "mapping_cache": self.engine.cache_stats(),
                "assembly_cache": (self.assembly_cache.stats()
                                   if self.assembly_cache else None),
                "assembly_time_s": assembly_s,
                "assembly_time_per_batch_s": (assembly_s / n_batches
                                              if n_batches else 0.0),
                "deadline_flushes": self._c_deadline_flushes.value,
                "buckets": buckets,
                "max_batch": self.max_batch,
                "max_batch_overrides": dict(self.max_batch_overrides),
                "scheduler_max_backlog": self.max_backlog,
                "pipeline_depth": self.pipeline_depth,
                "n_devices": (int(np.prod(list(self.mesh.shape.values())))
                              if self.mesh is not None else 1),
                "compiles": {
                    "build": _jit_cache_size(self.engine._build),
                    "apply_batch": _jit_cache_size(self._apply),
                },
                "latency_avg_s": (h_lat.sum / h_lat.count
                                  if h_lat.count else 0.0),
                "latency_quantiles_s": h_lat.quantiles(),
                "faults": {
                    **{c: m.value for c, m in self._c_faults.items()},
                    "failed_dispatches": self._c_failed_dispatches.value,
                    "retries": self._c_retries.value,
                    "retry_backoff_s": float(self._c_backoff.value),
                    "recovery_s": self._g_recovery.value,
                },
                "watchdog": self._watchdog is not None,
                "closed": self._closed,
            }
