"""Batched serving engines.

`prefill_step` / `decode_step` are the jit-able pure functions the dry-run
lowers for the decode_* / long_* shapes.  `ServeEngine` drives them for the
runnable examples: static-batch greedy generation with slot bookkeeping
(a continuous-batching slot refill hook is provided but refills re-run
prefill on the whole slot batch — documented trade-off for simplicity).

`PointCloudEngine` is the sparse point-cloud counterpart: it fronts a
`PointAccSession` (flow/engine policy + the LRU digest-keyed MappingCache)
with jit'd single-scene and `jax.vmap`-over-scenes entry points for
MinkUNet-style segmentation serving.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.api import PointAccSession
from repro.core import mapping as M
from repro.distributed import sharding as SH
from repro.models import minkunet as MU
from repro.models.registry import Model


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 1024
    cache_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    greedy: bool = True
    temperature: float = 1.0


def make_prefill_step(model: Model, svc: ServeConfig,
                      sc: Optional[SH.ShardingConfig] = None):
    shard = SH.make_shard_fn(sc) if sc is not None else \
        (lambda x, names: x)
    mesh = sc.mesh if sc is not None else None

    def prefill_step(params, batch):
        cparams = nn.cast_floating(params, svc.compute_dtype)
        logits, states, _ = model.prefill(cparams, batch, shard=shard,
                                          mesh=mesh)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, states

    return prefill_step


def make_decode_step(model: Model, svc: ServeConfig,
                     sc: Optional[SH.ShardingConfig] = None):
    shard = SH.make_shard_fn(sc) if sc is not None else \
        (lambda x, names: x)
    mesh = sc.mesh if sc is not None else None

    def decode_step(params, states, batch):
        cparams = nn.cast_floating(params, svc.compute_dtype)
        logits, states, _ = model.decode(cparams, batch, states,
                                         shard=shard, mesh=mesh)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, states

    return decode_step


class ServeEngine:
    """Greedy batched generation over fixed slots."""

    def __init__(self, model: Model, params, svc: ServeConfig,
                 sc: Optional[SH.ShardingConfig] = None):
        self.model = model
        self.params = params
        self.svc = svc
        self.prefill_step = jax.jit(make_prefill_step(model, svc, sc))
        self.decode_step = jax.jit(make_decode_step(model, svc, sc),
                                   donate_argnums=(1,))

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 eos_id: int = -1) -> np.ndarray:
        """prompts (B, S) int32 -> generated ids (B, max_new_tokens)."""
        b, s = prompts.shape
        cfg = self.model.cfg
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        batch = {"tokens": jnp.asarray(prompts), "positions": positions}
        tok, pre_states = self.prefill_step(self.params, batch)

        # place prefill states into max_len decode buffers
        init = self.model.init_state(b, self.svc.max_len,
                                     self.svc.cache_dtype)

        def place(dst, src):
            src = src.astype(dst.dtype)
            if src.shape == dst.shape:
                return src
            pad = [(0, d - s_) for d, s_ in zip(dst.shape, src.shape)]
            return jnp.pad(src, pad)

        states = jax.tree_util.tree_map(place, init, pre_states)

        out = np.zeros((b, max_new_tokens), np.int32)
        done = np.zeros(b, bool)
        pos = s
        for t in range(max_new_tokens):
            out[:, t] = np.asarray(tok)
            done |= np.asarray(tok) == eos_id
            if done.all():
                break
            dec_batch = {
                "tokens": tok[:, None],
                "positions": jnp.full((b, 1), pos, jnp.int32),
                "cache_pos": jnp.full((b,), pos, jnp.int32),
            }
            tok, states = self.decode_step(self.params, states, dec_batch)
            pos += 1
        return out


# ---------------------------------------------------------------------------
# sparse point-cloud serving (PointAcc)
# ---------------------------------------------------------------------------

class PointCloudEngine:
    """Serving frontend for MinkUNet-style sparse segmentation models.

    Owns a `PointAccSession` — the flow/engine policy plus the LRU-bounded
    digest-keyed `MappingCache` — and two jit'd entry points:

      * `segment(coords, mask, feats)` — one flattened cloud per request
        (scenes distinguished by the batch column, the PR-2 serving shape);
      * `segment_batch(coords, mask, feats)` — (B, N, ...) per-scene
        arrays, `jax.vmap` over scenes: one compiled program serves the
        whole batch, per-scene map pyramids are built by a vmapped Mapping
        Unit pass and cached across requests by the geometry digest.

    The Mapping Unit output depends only on coordinates, so repeated
    geometry (parked scanner, multi-sweep aggregation, re-scored frames)
    skips the ranking sort + binary searches entirely on a cache hit.
    """

    def __init__(self, params, n_stages: int, flow: str = "fod",
                 engine: Optional[str] = None, cache_entries: int = 32):
        self.session = PointAccSession(flow=flow, engine=engine,
                                       cache_entries=cache_entries)
        self.params = params
        self.n_stages = n_stages

        def build_one(coords, mask):
            return MU.build_unet_maps(M.PointCloud(coords, mask, 1),
                                      n_stages, engine=engine)

        def apply_one(levels, coords, mask, feats):
            pc = M.PointCloud(coords, mask, 1)
            logits = MU.minkunet_apply(params, pc, feats, flow=flow,
                                       levels=levels)
            return jnp.argmax(logits, -1)

        self._build = jax.jit(build_one)
        self._build_batch = jax.jit(jax.vmap(build_one))
        self._apply = jax.jit(apply_one)
        self._apply_batch = jax.jit(jax.vmap(apply_one))

    def levels_for(self, coords, mask, batched: bool = False):
        """(level pyramid, cache_hit) for a geometry; builds on miss."""
        build = self._build_batch if batched else self._build
        return self.session.maps_cache.get(
            (coords, mask),
            lambda: jax.block_until_ready(
                build(jnp.asarray(coords), jnp.asarray(mask))))

    def segment(self, coords, mask, feats, levels=None):
        """One flattened cloud -> (per-point class ids, mapping_cache_hit).

        Pass `levels` (from `levels_for`) to skip the cache lookup; the
        returned hit flag is then None."""
        hit = None
        if levels is None:
            levels, hit = self.levels_for(coords, mask)
        preds = self._apply(levels, jnp.asarray(coords), jnp.asarray(mask),
                            jnp.asarray(feats))
        return preds, hit

    def segment_batch(self, coords, mask, feats, levels=None):
        """(B, N, 1+D) scenes -> ((B, N) class ids, mapping_cache_hit)."""
        hit = None
        if levels is None:
            levels, hit = self.levels_for(coords, mask, batched=True)
        preds = self._apply_batch(levels, jnp.asarray(coords),
                                  jnp.asarray(mask), jnp.asarray(feats))
        return preds, hit

    def cache_stats(self) -> dict:
        return self.session.cache_stats()
