"""Point-cloud serving engine: the model-side half of the serving stack.

`PointCloudEngine` fronts a `PointAccSession` (flow/engine policy + the
LRU digest-keyed `MappingCache`) with jit'd entry points for
MinkUNet-style segmentation, and — since the continuous-batching PR —
routes EVERY entry point through a capacity `BucketLadder`
(`serve.buckets`): scenes are padded up to a small geometric set of
capacities, so the jit cache holds at most one program per bucket per
entry point instead of one per distinct point count.

  * `segment(coords, mask, feats)` — one scene; padded to its bucket,
    level pyramid served from the per-scene mapping cache, predictions
    un-padded back to the caller's row count.
  * `segment_batch(coords, mask, feats)` — (B, N, ...) per-scene arrays,
    served through an internal `serve.scheduler.ServeScheduler`: the
    scenes are admitted, grouped into fixed-shape micro-batches,
    executed on the vmapped (and, multi-device, shard_map-sharded) path,
    and reassembled in submission order.
  * `levels_for(coords, mask)` — the cached Mapping-Unit pass alone; the
    batched form stacks per-scene cached pyramids, so a batch whose
    composition changes still hits the cache scene by scene.

The Mapping Unit output depends only on coordinates, so repeated geometry
(parked scanner, multi-sweep aggregation, re-scored frames) skips the
ranking sort + binary searches entirely on a cache hit.

The token-LM serving engine (`ServeEngine` and friends) lives in
`repro.serve.lm`.
"""

from __future__ import annotations

import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import MappingCache, PointAccSession
from repro.core import mapping as M
from repro.models import minkunet as MU
from repro.serve import buckets as BK

def _silence_cpu_donation_warning():
    """The apply entry points donate their feats operand (fresh
    host->device copy every call, so XLA may reuse the buffer for
    same-shaped temps).  CPU has no buffer donation and warns on every
    donated call — expected and not actionable, so it is silenced THERE
    ONLY; on GPU/TPU the warning stays live (an unusable donated buffer
    is a real perf signal).  Called from engine construction, not at
    import: `jax.default_backend()` initializes the backend, which must
    not happen as an import side effect (it would break
    `jax.distributed.initialize()` / platform config done after
    import)."""
    if jax.default_backend() == "cpu":
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")


class PointCloudEngine:
    """Serving frontend for MinkUNet-style sparse segmentation models.

    Owns the `PointAccSession` (policy + MappingCache), the bucket
    ladder, and the jit'd single-scene / vmapped-batch entry points the
    `ServeScheduler` executes through.  `max_batch` / `mesh` configure
    the internal scheduler behind `segment_batch` (mesh="auto" shards
    over the host's devices when there are several; single-device hosts
    run the plain vmapped path).
    """

    def __init__(self, params, n_stages: int, flow: str = "fod",
                 engine: Optional[str] = None, cache_entries: int = 32,
                 ladder: Optional[BK.BucketLadder] = None,
                 max_batch=None, mesh="auto", fault_plan=None,
                 obs=None):
        _silence_cpu_donation_warning()
        self.session = PointAccSession(flow=flow, engine=engine,
                                       cache_entries=cache_entries)
        self.params = params
        self.n_stages = n_stages
        self.ladder = ladder if ladder is not None else BK.DEFAULT_LADDER
        self._max_batch = max_batch
        self._mesh = mesh
        # chaos seam: a serve.faults.FaultPlan picked up by every
        # scheduler built over this engine (None = nothing injected)
        self.fault_plan = fault_plan
        # observability bundle (repro.obs.Observability) picked up by
        # the lazy default scheduler and the partition path; None keeps
        # both on their private metrics-only default
        self.obs = obs
        self._n_partitions = 0
        self._scheduler = None
        # stats() of the most recent segment(partition=) chunk plan
        self.last_partition_stats = None

        def build_one(coords, mask):
            return MU.build_unet_maps(M.PointCloud(coords, mask, 1),
                                      n_stages, engine=engine)

        def apply_one(levels, coords, mask, feats):
            pc = M.PointCloud(coords, mask, 1)
            logits = MU.minkunet_apply(params, pc, feats, flow=flow,
                                       levels=levels)
            return jnp.argmax(logits, -1)

        # feats (argument 3) is donated: every call ships a fresh copy of
        # the padded features, so its device buffer is free for reuse the
        # moment the conv trunk consumes it.  levels (argument 0) is NOT
        # donated — the scheduler's AssemblyCache keeps stacked pyramids
        # alive across micro-batches, and donating them would invalidate
        # cached entries on backends with real buffer donation.
        self._build = jax.jit(build_one)
        self._apply = jax.jit(apply_one, donate_argnums=(3,))
        self._apply_batch_fn = jax.vmap(apply_one)
        self._apply_batch = jax.jit(self._apply_batch_fn,
                                    donate_argnums=(3,))

    @classmethod
    def factory(cls, params, n_stages: int, **kwargs):
        """Zero-arg engine builder for pool owners (`serve.router.
        ServeRouter` gives each worker its own engine: private jit entry
        points + caches, identical params/config — so predictions are
        worker-independent while cache locality stays worker-local,
        which is what digest-affinity routing monetizes)."""

        def build() -> "PointCloudEngine":
            return cls(params, n_stages, **kwargs)

        return build

    # -- scheduler hookup -------------------------------------------------

    def scheduler(self):
        """The engine's lazily-built default `ServeScheduler` (the one
        `segment_batch` serves through); build your own for a different
        max_batch / mesh / pipeline depth / assembly-cache bound /
        deadline policy."""
        if self._scheduler is None:
            from repro.serve.scheduler import ServeScheduler
            kwargs = {} if self.obs is None else {"obs": self.obs}
            self._scheduler = ServeScheduler(self, max_batch=self._max_batch,
                                             mesh=self._mesh, **kwargs)
        return self._scheduler

    # -- mapping ----------------------------------------------------------

    def scene_key(self, coords, mask, bucket: int) -> bytes:
        """Digest identifying one already-padded scene's level pyramid in
        the mapping cache.  The serve scheduler hashes every admitted
        scene once and reuses the key both for the per-scene pyramid
        lookup and as its element of the micro-batch composition key
        (AssemblyCache)."""
        return MappingCache.digest((np.asarray(coords), np.asarray(mask)),
                                   extra=("levels", int(bucket)))

    def _levels_padded(self, coords, mask, bucket: int, key: bytes = None):
        """(levels, hit) for ONE already-padded scene; cached per scene
        with a bucket-aware key (precomputed `key` skips re-hashing)."""
        coords = np.asarray(coords)
        mask = np.asarray(mask)
        if key is None:
            key = self.scene_key(coords, mask, bucket)
        return self.session.maps_cache.get_by_key(
            key,
            lambda: jax.block_until_ready(
                self._build(jnp.asarray(coords), jnp.asarray(mask))))

    def _scene_levels(self, coords, mask):
        """(levels, hit, bucket) for one raw scene: pad to its bucket,
        then the cached build."""
        cap = self.ladder.bucket_for(np.asarray(coords).shape[0])
        c, m, _ = BK.pad_scene(coords, mask, None, cap)
        levels, hit = self._levels_padded(c, m, cap)
        return levels, hit, cap

    def levels_for(self, coords, mask, batched: bool = False):
        """(level pyramid, cache_hit) for a geometry; builds on miss.

        Every pyramid is built at the scene's BUCKET capacity (pass the
        same arrays to `segment`, which pads identically).  The batched
        form builds/caches per scene and stacks, so the hit flag is True
        only when every scene hit; changing the batch composition around
        a repeated scene still reuses that scene's pyramid.
        """
        if not batched:
            levels, hit, _ = self._scene_levels(coords, mask)
            return levels, hit
        coords = np.asarray(coords)
        mask = np.asarray(mask)
        per_scene = [self._scene_levels(coords[b], mask[b])
                     for b in range(coords.shape[0])]
        levels = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *[lv for lv, _, _ in per_scene])
        return levels, all(hit for _, hit, _ in per_scene)

    # -- serving entry points ---------------------------------------------

    def segment(self, coords, mask, feats, levels=None, partition=None):
        """One scene -> (per-point class ids, mapping_cache_hit).

        The scene is padded to its ladder bucket before the jit'd apply
        (bounding retraces to one per bucket) and predictions are sliced
        back to the caller's row count.  Pass `levels` (from
        `levels_for`, built at the same bucket) to skip the cache lookup;
        the returned hit flag is then None.

        `partition` opens the city-scale path: `True`/"auto" (default
        policy) or a `repro.partition.PartitionPolicy`.  A scene too big
        for the ladder — which the seed path rejects — is then octree-
        chunked over its packed keys with exact receptive-field halos
        (`repro.partition`), each chunk served through the engine's
        scheduler as an ordinary scene, and the predictions stitched back
        into the caller's row order (halo rows dropped; rows outside
        every chunk, i.e. masked-invalid rows, come back as -1).  Chunked
        output equals the monolithic output on every valid row; a policy
        with `force=True` partitions even scenes that fit the ladder
        (parity tests and benchmarks rely on it).  The hit flag is True
        only when every chunk's pyramid came from the mapping cache.
        """
        n = np.asarray(coords).shape[0]
        if partition is not None:
            from repro.partition import PartitionPolicy
            policy = PartitionPolicy() if partition in (True, "auto") \
                else partition
            if policy.force or not self.ladder.fits(n):
                return self._segment_partitioned(coords, mask, feats,
                                                 policy)
        cap = self.ladder.bucket_for(n)
        c, m, f = BK.pad_scene(coords, mask, feats, cap)
        hit = None
        if levels is None:
            levels, hit = self._levels_padded(c, m, cap)
        preds = self._apply(levels, jnp.asarray(c), jnp.asarray(m),
                            jnp.asarray(f))
        return preds[:n], hit

    def _segment_partitioned(self, coords, mask, feats, policy):
        """Chunk-stream one oversized scene through the scheduler and
        stitch (see `segment(partition=)`).  Chunk plan telemetry lands
        in `self.last_partition_stats`."""
        from repro.partition import plan_partition
        spec = MU.halo_spec(self.params)
        plan = plan_partition(coords, mask, feats, spec=spec,
                              ladder=self.ladder, policy=policy)
        tracer = self.obs.tracer if self.obs is not None else None
        tid = None
        if tracer is not None:
            self._n_partitions += 1
            tid = f"partition:{self._n_partitions}"
            tracer.begin(tid, name="partition",
                         n_chunks=plan.n_chunks,
                         n_rows=int(plan.n_rows))
        preds, hit, errors = plan.run(self.scheduler(), tracer, tid)
        if tracer is not None:
            tracer.end(tid, outcome="ok" if not errors else "chunk_errors",
                       n_errors=len(errors))
        self.last_partition_stats = plan.stats()
        self.last_partition_stats["chunk_errors"] = len(errors)
        if errors:
            detail = "; ".join(f"chunk {i}: {err}"
                               for i, err in sorted(errors.items()))
            raise RuntimeError(
                f"segment(partition=): {len(errors)}/{plan.n_chunks} "
                f"chunks failed — {detail}")
        return jnp.asarray(preds), hit

    def segment_batch(self, coords, mask, feats, on_error: str = "raise",
                      priority: int = 0):
        """(B, N, 1+D) scenes -> ((B, N) class ids, mapping_cache_hit).

        Served through the internal `ServeScheduler`: each scene is
        admitted, micro-batched with its bucket peers, executed on the
        vmapped (multi-device: shard_map-sharded) path, and results are
        reassembled in submission order.  The hit flag is True only when
        every scene's pyramid came from the mapping cache.

        Per-scene failures (the scheduler's typed `ServeResult.error`
        taxonomy — rejected / shed / timeout / exec_failed) surface by
        `on_error`:

          * "raise" (default) — raise `RuntimeError` naming every failed
            scene index and its error;
          * "partial" — return `(preds, hit, errors)` where `errors` is
            {scene_index: ServeError} and failed scenes' prediction rows
            are filled with -1 (never a valid class id).

        The scheduler is shared (`self.scheduler()`): scenes another
        caller queued are flushed along with this batch, but their
        results stay drainable — only this call's requests are taken.

        `priority` is forwarded to every scene's `submit`: higher values
        win the scheduler's priority lanes under overload, and lanes
        below the brownout shed threshold are rejected at admission
        (surfacing here through the normal error taxonomy).
        """
        if on_error not in ("raise", "partial"):
            raise ValueError(f"on_error must be 'raise' or 'partial', "
                             f"got {on_error!r}")
        coords = np.asarray(coords)
        mask = np.asarray(mask)
        feats = np.asarray(feats)
        # stacked scenes share N: one ladder check up front, so an
        # overflow raises before any scene is admitted
        self.ladder.bucket_for(coords.shape[1])
        sched = self.scheduler()
        rids = [sched.submit(coords[b], feats[b], mask[b],
                             priority=priority)
                for b in range(coords.shape[0])]
        sched.flush()
        by_rid = sched.take(rids)
        errors = {b: by_rid[rid].error for b, rid in enumerate(rids)
                  if by_rid[rid].error is not None}
        if errors and on_error == "raise":
            detail = "; ".join(f"scene {b}: {err}"
                               for b, err in sorted(errors.items()))
            raise RuntimeError(
                f"segment_batch: {len(errors)}/{len(rids)} scenes "
                f"failed — {detail}")
        n = coords.shape[1]
        preds = np.stack([
            np.asarray(by_rid[rid].preds) if b not in errors
            else np.full(n, -1, np.int32)
            for b, rid in enumerate(rids)])
        hit = all(by_rid[rid].mapping_hit for b, rid in enumerate(rids)
                  if b not in errors)
        if on_error == "partial":
            return jnp.asarray(preds), hit, errors
        return jnp.asarray(preds), hit

    # -- telemetry --------------------------------------------------------

    def cache_stats(self) -> dict:
        return self.session.cache_stats()

    def compile_stats(self) -> dict:
        """jit-cache sizes of the engine's entry points — bounded by the
        number of ladder buckets actually seen (asserted in tier-1)."""
        from repro.serve.scheduler import _jit_cache_size
        return {"build": _jit_cache_size(self._build),
                "apply": _jit_cache_size(self._apply),
                "apply_batch": _jit_cache_size(self._apply_batch)}
