"""Cost-accounting mode for the dry-run's depth-extrapolation compiles.

XLA's `cost_analysis()` visits a `while` body once, independent of trip
count.  The dry-run's depth-1/2 extrapolation therefore cancels any cost
that lives INSIDE the layer scan (both compiles contain one identical
body): per-layer flops/bytes/collectives would be undercounted by
n_bodies.  Under cost mode the layer scan and the chunked-CE scan unroll
into straight-line code, so the depth-2 minus depth-1 delta is exactly one
body's true cost.  The full-depth compile (memory/compile proof) stays
scanned.

Inner SSM chunk scans (mamba/mLSTM) remain scanned even in cost mode (their
trip counts are seq/chunk ~ 32-256; unrolling would explode the HLO); their
FLOPs are covered by the analytic model and their bytes/collective
contributions are documented as lower-bounded.
"""

import contextlib
import contextvars

_COST_MODE = contextvars.ContextVar("repro_cost_mode", default=False)


def enabled() -> bool:
    return _COST_MODE.get()


@contextlib.contextmanager
def enable():
    tok = _COST_MODE.set(True)
    try:
        yield
    finally:
        _COST_MODE.reset(tok)
