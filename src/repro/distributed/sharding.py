"""Logical-axis sharding rules: DP / TP / SP / EP / FSDP on the production
mesh.

Mesh axes: single-pod ("data", "model") = 16x16; multi-pod
("pod", "data", "model") = 2x16x16.

  * DP       batch over ("pod","data")
  * TP       heads / d_ff / vocab over "model" (Megatron)
  * SP       block-boundary activations: seq over "model" (Megatron-SP) —
             what makes 34B/72B activations fit at seq 4k under remat
  * EP       MoE expert dim over "model" (shard_map all_to_all in moe.py)
  * FSDP     parameter + optimizer fan-in dim over the data axes (ZeRO-3)

Every rule degrades gracefully: an axis is only applied when the dim is
divisible by the mesh axis size, so reduced/smoke configs and odd head
counts (e.g. qwen1.5 kv=40, xlstm H=4) fall back to replication on that dim.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingConfig:
    mesh: Mesh
    fsdp: bool = False            # shard params over the data axes (ZeRO-3)
    seq_parallel: bool = True     # Megatron-SP at block boundaries
    shard_seq_over_data: bool = False  # long-context decode (batch < data)
    # decode KV caches whose head dim can't shard over 'model' (MHA/MQA odd
    # head counts) shard their SEQ dim over 'model' instead and let SPMD
    # generate the flash-decoding partial-softmax combine (§Perf H1)
    kv_seq_over_model: bool = True

    @property
    def data_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in ("pod", "data") if a in self.mesh.shape)

    @property
    def n_data(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.data_axes]))

    @property
    def n_model(self) -> int:
        return int(self.mesh.shape["model"])


def _div(n: Optional[int], m: int) -> bool:
    return n is not None and n % m == 0 and n >= m


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    axes = axes if isinstance(axes, tuple) else (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _maybe(mesh: Mesh, dim: int, axes):
    """Apply `axes` to a dim only if divisible; else replicate."""
    return axes if _div(dim, _axis_size(mesh, axes)) else None


# ---------------------------------------------------------------------------
# activation rules (the `shard` callback threaded through model code)
# ---------------------------------------------------------------------------

def make_shard_fn(sc: ShardingConfig):
    mesh = sc.mesh
    data = sc.data_axes if len(sc.data_axes) > 1 else \
        (sc.data_axes[0] if sc.data_axes else None)

    def shard(x, names):
        dims = dict(zip(names, x.shape))
        batch = dims.get("batch")
        spec = [None] * len(names)
        for i, nm in enumerate(names):
            d = x.shape[i]
            if nm == "batch":
                spec[i] = _maybe(mesh, d, data)
            elif nm == "seq_full":
                pass   # explicit SP gather point (placed on bf16 tensors)
            elif nm == "seq":
                if names[-1] == "d_model" and sc.seq_parallel:
                    spec[i] = _maybe(mesh, d, "model")
                elif sc.shard_seq_over_data and not _div(batch, sc.n_data):
                    spec[i] = _maybe(mesh, d, data)
            elif nm in ("heads", "kv_heads", "d_ff", "d_inner", "vocab"):
                if not (names[-1] == "d_model" and sc.seq_parallel
                        and nm != "vocab"):
                    spec[i] = _maybe(mesh, d, "model")
            # d_model / head_dim stay replicated
        # never shard the same mesh axis twice
        used = set()
        for i, s in enumerate(spec):
            axes = s if isinstance(s, tuple) else (s,) if s else ()
            if any(a in used for a in axes):
                spec[i] = None
            used.update(axes)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*spec)))

    return shard


# ---------------------------------------------------------------------------
# parameter rules (path-name dispatch)
# ---------------------------------------------------------------------------

_COL_PARALLEL = {"wq", "wk", "wv", "wi", "wg", "up", "wx", "wif",
                 "in_proj", "dt_proj", "lm_head", "head"}
_ROW_PARALLEL = {"wo", "down", "out_proj", "proj", "x_proj"}
_NORM_LEAVES = {"scale"}


def _path_names(path) -> list:
    names = []
    for k in path:
        if hasattr(k, "key"):            # DictKey
            names.append(str(k.key))
        elif hasattr(k, "name"):         # GetAttrKey (NamedTuple fields)
            names.append(str(k.name))
        elif hasattr(k, "idx"):          # SequenceKey
            names.append(str(k.idx))
    return names


def param_spec(path, shape, sc: ShardingConfig,
               stacked: bool = False) -> P:
    """Sharding spec for one parameter leaf, identified by its tree path."""
    mesh = sc.mesh
    names = _path_names(path)
    leaf = names[-1] if names else ""
    parent = names[-2] if len(names) > 1 else ""
    fsdp = (sc.data_axes if len(sc.data_axes) > 1 else sc.data_axes[0]) \
        if sc.fsdp and sc.data_axes else None

    core = list(shape[1:]) if stacked else list(shape)
    spec: list = [None] * len(core)

    def col2d():    # (fan_in, fan_out) -> (fsdp, model)
        spec[0] = _maybe(mesh, core[0], fsdp)
        spec[1] = _maybe(mesh, core[1], "model")

    def row2d():    # (fan_in, fan_out) -> (model, fsdp)
        spec[0] = _maybe(mesh, core[0], "model")
        spec[1] = _maybe(mesh, core[1], fsdp)

    if leaf == "emb":                       # (V, D): vocab over model
        spec[0] = _maybe(mesh, core[0], "model")
        spec[1] = _maybe(mesh, core[1], fsdp)
    elif leaf in _NORM_LEAVES or parent.startswith("norm") or \
            parent in ("n1", "n2", "final_norm", "enc_norm"):
        pass                                # replicated
    elif leaf in ("w_in", "w_gate"):        # (E, D, F)
        spec[0] = _maybe(mesh, core[0], "model")
        if spec[0] is None:
            spec[1] = _maybe(mesh, core[1], fsdp)
            spec[2] = _maybe(mesh, core[2], "model")
        else:
            spec[1] = _maybe(mesh, core[1], fsdp)
    elif leaf == "w_out":                   # (E, F, D)
        spec[0] = _maybe(mesh, core[0], "model")
        if spec[0] is None:
            spec[1] = _maybe(mesh, core[1], "model")
            spec[2] = _maybe(mesh, core[2], fsdp)
        else:
            spec[2] = _maybe(mesh, core[2], fsdp)
    elif parent == "router":
        spec[0] = _maybe(mesh, core[0], fsdp)
    elif leaf == "w" and len(core) == 2:
        if parent in _ROW_PARALLEL:
            row2d()
        else:                               # col-parallel default
            col2d()
    elif leaf == "b" and len(core) == 1:
        if parent in _COL_PARALLEL or parent not in _ROW_PARALLEL:
            spec[0] = _maybe(mesh, core[0], "model")
    elif leaf == "conv_w":                  # (k, d_inner)
        spec[1] = _maybe(mesh, core[1], "model")
    elif leaf in ("conv_b", "D"):           # (d_inner,)
        spec[0] = _maybe(mesh, core[0], "model")
    elif leaf == "A_log":                   # (d_inner, N)
        spec[0] = _maybe(mesh, core[0], "model")
    elif len(core) == 3 and leaf == "w":    # stacked conv-ish (K, Cin, Cout)
        spec[2] = _maybe(mesh, core[2], "model")

    if stacked:
        spec = [None] + spec
    return P(*spec)


def params_shardings(param_shapes, sc: ShardingConfig):
    """ShapeDtypeStruct tree -> NamedSharding tree.  Anything under a
    'layers' / 'enc_layers' / 'dec_layers' subtree is scan-stacked (leading
    body dim)."""
    def one(path, leaf):
        names = _path_names(path)
        stacked = any(n.endswith("layers") for n in names)
        return NamedSharding(sc.mesh,
                             param_spec(path, leaf.shape, sc, stacked))
    return jax.tree_util.tree_map_with_path(one, param_shapes)


# ---------------------------------------------------------------------------
# batch / decode-state rules
# ---------------------------------------------------------------------------

def batch_specs(batch_shapes, sc: ShardingConfig):
    mesh = sc.mesh
    data = sc.data_axes if len(sc.data_axes) > 1 else \
        (sc.data_axes[0] if sc.data_axes else None)

    def one(path, leaf):
        shape = leaf.shape
        spec = [None] * len(shape)
        if len(shape) >= 1 and _div(shape[0], sc.n_data):
            spec[0] = data
        elif len(shape) >= 2 and sc.shard_seq_over_data:
            # long-context: batch too small, shard the seq dim instead
            if _div(shape[1], sc.n_data):
                spec[1] = data
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, batch_shapes)


def state_specs(state_shapes, sc: ShardingConfig):
    """Decode-state tree: KV caches (nb, B, S, H, hd), SSM states, etc."""
    mesh = sc.mesh
    data = sc.data_axes if len(sc.data_axes) > 1 else \
        (sc.data_axes[0] if sc.data_axes else None)

    def one(path, leaf):
        names = _path_names(path)
        shape = leaf.shape
        spec: list = [None] * len(shape)
        batch_ok = len(shape) > 1 and _div(shape[1], sc.n_data)
        if "self_kv" in names or "cross" in names or \
                (len(shape) == 5 and names[-1] in ("k", "v")):
            # (nb, B, S, H, hd)
            if batch_ok:
                spec[1] = data
            elif _div(shape[2], sc.n_data):
                spec[2] = data            # flash-decoding: shard seq
            spec[3] = _maybe(mesh, shape[3], "model")
            if spec[3] is None and spec[2] is None and \
                    sc.kv_seq_over_model and _div(shape[2], sc.n_model):
                # H1: heads unshardable -> flash-decode over 'model'
                spec[2] = "model"
        elif names[-1] == "ssm":          # (nb, B, di, N)
            if batch_ok:
                spec[1] = data
            spec[2] = _maybe(mesh, shape[2], "model")
        elif names[-1] == "conv":         # (nb, B, k-1, di)
            if batch_ok:
                spec[1] = data
            spec[3] = _maybe(mesh, shape[3], "model")
        else:                             # mlstm / slstm scalar states
            if batch_ok:
                spec[1] = data
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, state_shapes)


def replicated(sc: ShardingConfig):
    return NamedSharding(sc.mesh, P())


# ---------------------------------------------------------------------------
# scene-axis serving rules (continuous-batching point-cloud scheduler)
# ---------------------------------------------------------------------------

def make_scene_mesh(axis: str = "scene", devices=None) -> Optional[Mesh]:
    """1-D mesh over the host's devices for scene-parallel serving.

    Returns None on a single-device host — the serve scheduler treats
    that as "run the vmapped path directly" (no shard_map), so the same
    code degrades to single-device CPU without changes.
    """
    devs = list(devices) if devices is not None else jax.devices()
    if len(devs) < 2:
        return None
    return Mesh(np.asarray(devs), (axis,))


def shard_over_scenes(fn, mesh: Mesh, axis: str = "scene"):
    """shard_map a vmapped batch function over its leading scene axis.

    `fn(*args) -> out` must take arrays / pytrees whose every leaf is
    batched along axis 0 (the scene axis) and return leaves batched the
    same way; each device runs `fn` on its local B/n_devices scenes.
    The scene axis of every argument must be divisible by the mesh size —
    the scheduler guarantees this by padding micro-batches to a fixed
    scene count that is a multiple of the device count.

    The wrapper is transparent to positional `donate_argnums`: argument i
    of the returned function is argument i of `fn`, so
    `jax.jit(shard_over_scenes(fn, ...), donate_argnums=...)` donates the
    same operands the unsharded `jax.jit(fn, donate_argnums=...)` would
    (the serve scheduler donates the feats operand this way).  The
    shard_map body is built once per arity, not per call — the pipelined
    scheduler dispatches from the submit hot path.
    """
    from repro import compat

    spec = P(axis)
    bodies: dict[int, object] = {}

    def sharded(*args):
        body = bodies.get(len(args))
        if body is None:
            body = bodies[len(args)] = compat.shard_map(
                fn, mesh=mesh, in_specs=tuple(spec for _ in args),
                out_specs=spec, axis_names={axis})
        return body(*args)

    return sharded
