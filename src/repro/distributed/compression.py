"""Cross-pod gradient compression with error feedback.

The multi-pod mesh's leading "pod" axis rides DCN-class links that are an
order of magnitude slower than in-pod ICI, and in plain DP they carry a full
gradient all-reduce every step.  This module replaces that exchange with:

    v   = g_pod_local + error            (error feedback, Seide et al.)
    q   = int8 per-block quantise(v)
    sum = all_gather(q) over 'pod' -> local dequant-sum
    error' = v - dequant(q)

Wire bytes per step drop 8x vs f32 all-reduce (int8 payload + f32
per-block scales at 1/256 granularity; all_gather over pod=2 moves the same
payload an all-reduce would).  Error feedback makes the scheme contractive:
quantisation noise is re-injected next step instead of lost, preserving
convergence (verified in tests/test_distributed.py on the debug mesh).

Integration: `hierarchical_grads` wraps a per-pod loss gradient in a
partial-manual shard_map (only the 'pod' axis is manual; 'data'/'model'
stay under GSPMD), so in-pod reduction is still XLA's fused reduce-scatter
and ONLY the cross-pod hop is compressed.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax

from repro import compat
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

BLOCK = 256


def _quantize_int8(v: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-block symmetric int8: returns (q int8, scales f32)."""
    flat = v.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray,
                shape, dtype) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def compressed_psum(x: jnp.ndarray, axis_name: str,
                    error: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Error-feedback int8 mean over `axis_name` (call inside shard_map).

    Returns (mean, new_error)."""
    n = compat.axis_size(axis_name)
    v = x.astype(jnp.float32) + error
    q, scale = _quantize_int8(v)
    new_error = v - _dequantize(q, scale, x.shape, jnp.float32)
    # wire: int8 payload + f32 scales (1/256 overhead)
    q_all = lax.all_gather(q, axis_name)            # (n, blocks, BLOCK) int8
    s_all = lax.all_gather(scale, axis_name)
    total = jnp.sum(q_all.astype(jnp.float32) * s_all, axis=0)
    flat = (total / n).reshape(-1)
    k = 1
    for d in x.shape:
        k *= d
    mean = flat[:k].reshape(x.shape).astype(x.dtype)
    return mean, new_error.astype(jnp.float32)


def init_error_buffers(grad_shapes, n_pods: int = 2) -> Any:
    """Per-pod error-feedback buffers, pod-stacked on the leading dim."""
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros((n_pods,) + tuple(g.shape), jnp.float32),
        grad_shapes)


def hierarchical_grads(grad_fn, mesh, params, batch, errors):
    """Per-pod gradients + compressed cross-pod exchange.

    grad_fn(params, batch) -> (grads, metrics) computed over the pod-LOCAL
    half of the batch (in-pod DP/TP handled by GSPMD as usual).
    Returns (mean grads, new error buffers, metrics).
    """
    if "pod" not in mesh.shape:
        grads, metrics = grad_fn(params, batch)
        return grads, errors, metrics

    n_pods = mesh.shape["pod"]

    def local(params, batch, errors):
        # shard_map keeps split dims as size 1: squeeze pod-local leading.
        # Params MUST arrive pod-varying (stacked + P('pod')): if they were
        # replicated, jax.grad's vma transpose would insert an implicit
        # full-precision psum over 'pod' — silently bypassing compression.
        params = jax.tree_util.tree_map(lambda x: x[0], params)
        batch = jax.tree_util.tree_map(lambda x: x[0], batch)
        errors = jax.tree_util.tree_map(lambda x: x[0], errors)
        grads, metrics = grad_fn(params, batch)
        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_e = treedef.flatten_up_to(errors)
        out_g, out_e = [], []
        for g, e in zip(flat_g, flat_e):
            m, e2 = compressed_psum(g, "pod", e)
            out_g.append(m[None])     # vma: pod-varying -> stacked out
            out_e.append(e2[None])
        metrics = jax.tree_util.tree_map(
            lambda x: lax.pmean(x, "pod")[None], metrics)
        return (jax.tree_util.tree_unflatten(treedef, out_g),
                jax.tree_util.tree_unflatten(treedef, out_e), metrics)

    # only 'pod' is manual; 'data'/'model' sharding stays with GSPMD.
    # grads come back pod-stacked (identical rows, int8-exchanged) -> [0].
    pod = jax.tree_util.tree_map(lambda _: P("pod"), params)
    batch_spec = jax.tree_util.tree_map(lambda _: P("pod"), batch)
    err_spec = jax.tree_util.tree_map(lambda _: P("pod"), errors)
    batch_stacked = jax.tree_util.tree_map(
        lambda x: x.reshape((n_pods, x.shape[0] // n_pods) + x.shape[1:]),
        batch)
    params_stacked = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n_pods,) + x.shape), params)
    grads, new_err, metrics = compat.shard_map(
        local, mesh=mesh, axis_names={"pod"},
        in_specs=(pod, batch_spec, err_spec),
        out_specs=(pod, err_spec, P("pod")),
        check_vma=True,
    )(params_stacked, batch_stacked, errors)
    grads = jax.tree_util.tree_map(lambda g: g[0], grads)
    metrics = jax.tree_util.tree_map(lambda m: m[0], metrics)
    return grads, new_err, metrics
