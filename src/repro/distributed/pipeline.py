"""GPipe-style pipeline parallelism over the `pod` mesh axis.

Rationale: the cross-pod links are the slowest in the system.  Plain DP
sends a full gradient all-reduce across them every step; PP sends only the
(microbatch, seq, d_model) boundary activations — orders of magnitude less
for the large dense archs.  The multi-pod mesh therefore supports both
layouts: DP-over-pod (default, optional compressed grads) and PP-over-pod
(this module, --pipeline in the launcher).

Implementation: partial-manual shard_map over 'pod' ('data'/'model' stay
under GSPMD, so TP/SP/FSDP inside each stage are unchanged).  The layer
scan's stacked params are split into S stage chunks; each tick runs one
microbatch through the local stage and passes the boundary activation to
the next stage with `collective_permute` (bidirectional ring not needed —
a straight line).  GPipe schedule: n_micro + n_stages - 1 ticks; bubble
fraction = (S-1)/(n_micro + S - 1).  The whole schedule is a `lax.scan`,
so it differentiates: backward runs the reverse pipeline automatically.
"""

from __future__ import annotations

from typing import Callable

import jax

from repro import compat
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P


def pipeline_apply(body_fn: Callable, stage_params, x, n_micro: int,
                   axis_name: str = "pod"):
    """Run a stack of scanned bodies as a pipeline over `axis_name`.

    body_fn(params_one_body, x) -> x      (one scan body, pure)
    stage_params: stacked body params with leading dim = bodies_per_stage
                  (already shard_map-local, i.e. this stage's slice).
    x: (n_micro, micro_batch, seq, d) microbatched input (stage-0 holds the
       real input; other stages receive via permute).
    Returns (n_micro, micro_batch, seq, d) output from the LAST stage
    (other stages return zeros — caller selects).
    """
    n_stages = compat.axis_size(axis_name)
    stage = lax.axis_index(axis_name)
    n_ticks = n_micro + n_stages - 1
    mb_shape = x.shape[1:]

    def stage_fwd(xmb):
        def scan_body(h, p_body):
            return body_fn(p_body, h), None
        out, _ = lax.scan(scan_body, xmb, stage_params)
        return out

    def tick(carry, t):
        inbuf, outputs = carry
        mb_idx = t - stage                    # microbatch this stage runs
        active = (mb_idx >= 0) & (mb_idx < n_micro)
        # stage 0 reads from x, others from the permuted input buffer
        src = jnp.where(stage == 0,
                        x[jnp.clip(mb_idx, 0, n_micro - 1)], inbuf)
        out = jnp.where(active, stage_fwd(src), jnp.zeros(mb_shape,
                                                          x.dtype))
        # last stage records its finished microbatch
        is_last = stage == n_stages - 1
        outputs = jnp.where(
            active & is_last,
            outputs.at[jnp.clip(mb_idx, 0, n_micro - 1)].set(out),
            outputs)
        # hand the activation to the next stage
        nxt = lax.ppermute(out, axis_name,
                           [(i, i + 1) for i in range(n_stages - 1)])
        return (nxt, outputs), None

    # carries vary across pipeline stages: mark them pod-varying for the
    # vma (varying-manual-axes) type system
    inbuf0 = compat.pcast_varying(jnp.zeros(mb_shape, x.dtype),
                                  (axis_name,))
    outputs0 = compat.pcast_varying(jnp.zeros_like(x), (axis_name,))
    (_, outputs), _ = lax.scan(tick, (inbuf0, outputs0),
                               jnp.arange(n_ticks))
    # broadcast the last stage's outputs to every stage (masked psum: only
    # the last stage contributes)
    outputs = lax.psum(
        jnp.where(stage == n_stages - 1, outputs, 0.0), axis_name)
    return outputs


def split_stages(stacked_params, n_stages: int):
    """Split scan-stacked body params into per-stage chunks along dim 0.
    Returns params with a new leading stage dim, ready for shard_map over
    'pod'."""
    def split(x):
        nb = x.shape[0]
        assert nb % n_stages == 0, (nb, n_stages)
        return x.reshape((n_stages, nb // n_stages) + x.shape[1:])
    return jax.tree_util.tree_map(split, stacked_params)


def pipelined_forward(body_fn, params_layers, x, mesh, n_micro: int = 4):
    """Convenience wrapper: shard_map over 'pod' with auto data/model.

    x: (B, S, D) — microbatched internally along batch.
    """
    n_stages = mesh.shape["pod"]
    staged = split_stages(params_layers, n_stages)

    def local(staged_local, xb):
        # shard_map keeps the split dim as size 1: squeeze to this stage's
        # (bodies_per_stage, ...) params
        staged_local = jax.tree_util.tree_map(lambda a: a[0], staged_local)
        b = xb.shape[0]
        mb = b // n_micro
        xm = xb.reshape((n_micro, mb) + xb.shape[1:])
        out = pipeline_apply(body_fn, staged_local, xm,
                             n_micro=n_micro, axis_name="pod")
        return out.reshape(xb.shape)

    stage_spec = jax.tree_util.tree_map(lambda _: P("pod"), staged)
    return compat.shard_map(
        local, mesh=mesh, axis_names={"pod"},
        in_specs=(stage_spec, P()),
        out_specs=P(),
        check_vma=True,
    )(staged, x)
