"""Pure-jnp oracle for the Fetch-on-Demand sparse conv kernel."""

import jax.numpy as jnp


def spconv_fod_ref(features: jnp.ndarray, inv_idx: jnp.ndarray,
                   weights: jnp.ndarray) -> jnp.ndarray:
    """out[j] = sum_k valid[k,j] * features[inv_idx[k,j]] @ W[k]."""
    valid = inv_idx >= 0                                     # (K, M)
    rows = features[jnp.maximum(inv_idx, 0)]                 # (K, M, Cin)
    rows = rows * valid[..., None]
    out = jnp.einsum("kmc,kcd->md", rows, weights,
                     preferred_element_type=jnp.float32)
    return out.astype(features.dtype)
