"""Pure-jnp oracles for the Fetch-on-Demand sparse conv kernels."""

import jax.numpy as jnp

from repro.core.sparseconv import Epilogue, apply_epilogue


def spconv_fod_ref(features: jnp.ndarray, inv_idx: jnp.ndarray,
                   weights: jnp.ndarray) -> jnp.ndarray:
    """out[j] = sum_k valid[k,j] * features[inv_idx[k,j]] @ W[k]."""
    valid = inv_idx >= 0                                     # (K, M)
    rows = features[jnp.maximum(inv_idx, 0)]                 # (K, M, Cin)
    rows = rows * valid[..., None]
    out = jnp.einsum("kmc,kcd->md", rows, weights,
                     preferred_element_type=jnp.float32)
    return out.astype(features.dtype)


def spconv_fod_fused_ref(features: jnp.ndarray, inv_idx: jnp.ndarray,
                         weights: jnp.ndarray,
                         epilogue: Epilogue | None = None) -> jnp.ndarray:
    """Conv oracle + the shared XLA epilogue — what the fused kernel's
    in-flush epilogue must reproduce."""
    return apply_epilogue(spconv_fod_ref(features, inv_idx, weights),
                          epilogue)
