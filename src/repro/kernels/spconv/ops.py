"""jit'd public wrapper: KernelMaps -> inverted index table -> Pallas call."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.mapping import KernelMaps
from repro.kernels.spconv.spconv import spconv_fod_pallas
from repro.kernels.spconv.ref import spconv_fod_ref


def invert_maps(maps: KernelMaps, out_cap: int) -> jnp.ndarray:
    """(K, cap) map lists -> (K, out_cap) inverse table inv[k, j] = i.

    The v2 packed-key engine emits the inverse table directly from its
    binary-search hit positions (KernelMaps.inv) — that path is a no-op
    here.  v1 maps (and swapped maps, whose inv is dropped) fall back to
    the scatter: kernel mapping is 1:1 per offset (both clouds are
    coordinate sets), so the scatter is collision-free.
    """
    if maps.inv is not None and maps.inv.shape[1] == out_cap:
        return maps.inv
    k, cap = maps.in_idx.shape
    inv = jnp.full((k, out_cap), -1, jnp.int32)
    oidx = jnp.where(maps.valid, maps.out_idx, out_cap)      # OOB -> dropped
    rows = jnp.broadcast_to(jnp.arange(k, dtype=jnp.int32)[:, None],
                            (k, cap))
    return inv.at[rows.reshape(-1), oidx.reshape(-1)].set(
        maps.in_idx.reshape(-1), mode="drop")


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit,
                   static_argnames=("out_cap", "out_tile", "interpret"))
def _sparse_conv_fod(features: jnp.ndarray, maps: KernelMaps,
                     weights: jnp.ndarray, out_cap: int,
                     out_tile: int, interpret: bool) -> jnp.ndarray:
    inv = invert_maps(maps, out_cap)
    m_pad = _round_up(out_cap, out_tile)
    inv = jnp.pad(inv, ((0, 0), (0, m_pad - out_cap)), constant_values=-1)
    out = spconv_fod_pallas(features, inv, weights, out_tile=out_tile,
                            interpret=interpret)
    return out[:out_cap]


def sparse_conv_fod(features: jnp.ndarray, maps: KernelMaps,
                    weights: jnp.ndarray, out_cap: int,
                    out_tile: int = 128,
                    interpret: bool | None = None) -> jnp.ndarray:
    """Drop-in replacement for core.sparseconv flows (flow='pallas').

    interpret=None auto-selects from the active backend: compiled on TPU,
    interpreter everywhere else (CPU validation).  Pass a bool to override.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _sparse_conv_fod(features, maps, weights, out_cap, out_tile,
                            interpret)


def sparse_conv_fod_ref(features, maps, weights, out_cap):
    return spconv_fod_ref(features, invert_maps(maps, out_cap), weights)
