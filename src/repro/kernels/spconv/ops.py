"""jit'd public wrappers: KernelMaps -> inverse table -> Pallas call.

Two entry points mirror the two kernels in spconv.py:

  * `sparse_conv_fod`   — baseline whole-array-resident kernel
    (`flow="pallas"`).
  * `sparse_conv_fused` — streamed feature tiles + fused epilogue
    (`flow="pallas_fused"`): derives the scalar-prefetched window schedule
    from the inverse table, pads rows/channels to the tile grid, and folds
    the `core.sparseconv.Epilogue` into the kernel flush.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.mapping import KernelMaps
from repro.core.sparseconv import Epilogue
from repro.kernels.spconv.spconv import (spconv_fod_fused_pallas,
                                         spconv_fod_pallas)
from repro.kernels.spconv.ref import spconv_fod_ref


def invert_maps(maps: KernelMaps, out_cap: int) -> jnp.ndarray:
    """(K, cap) map lists -> (K, out_cap) inverse table inv[k, j] = i.

    The v2 packed-key engine emits the inverse table directly from its
    binary-search hit positions (KernelMaps.inv) — that path is a no-op
    here, and since PR 2 it covers swapped maps too: strided v2 maps carry
    the transposed table (KernelMaps.inv_t), which `swap()` promotes to
    `inv`, so decoder transposed convs stay scatter-free.  Only v1 maps
    (and explicitly capped v2 maps, whose tables are dropped) fall back to
    the scatter: kernel mapping is 1:1 per offset (both clouds are
    coordinate sets), so the scatter is collision-free.
    """
    if maps.inv is not None and maps.inv.shape[1] == out_cap:
        return maps.inv
    k, cap = maps.in_idx.shape
    inv = jnp.full((k, out_cap), -1, jnp.int32)
    oidx = jnp.where(maps.valid, maps.out_idx, out_cap)      # OOB -> dropped
    rows = jnp.broadcast_to(jnp.arange(k, dtype=jnp.int32)[:, None],
                            (k, cap))
    return inv.at[rows.reshape(-1), oidx.reshape(-1)].set(
        maps.in_idx.reshape(-1), mode="drop")


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pad_rows(a: jnp.ndarray, rows: int, value=0) -> jnp.ndarray:
    if a.shape[0] == rows:
        return a
    pad = [(0, rows - a.shape[0])] + [(0, 0)] * (a.ndim - 1)
    return jnp.pad(a, pad, constant_values=value)


def _pad_cin(features: jnp.ndarray, weights: jnp.ndarray, cin_tile: int):
    """Zero-pad the contraction dim to a multiple of cin_tile; padded
    channels contribute exactly zero to every accumulator."""
    cin = features.shape[1]
    cin_pad = _round_up(cin, cin_tile)
    if cin_pad != cin:
        features = jnp.pad(features, ((0, 0), (0, cin_pad - cin)))
        weights = jnp.pad(weights, ((0, 0), (0, cin_pad - cin), (0, 0)))
    return features, weights


def window_schedule(inv: jnp.ndarray, n_rows: int, out_tile: int,
                    feat_tile: int):
    """Per-out-tile feature-window schedule for the streamed kernel.

    For each out tile: the range of feature row blocks its inverse-table
    slice touches.  wmap[o, w] = block id of sweep step w (clamped past the
    end so revisits cost no DMA); nwin[o] = number of live steps.  With
    features in packed-key order the inverse tables are monotone per offset
    and these ranges are tight — the paper's cache blocks.
    """
    k, m = inv.shape
    tiles = m // out_tile
    n_win = n_rows // feat_tile
    iv = inv.reshape(k, tiles, out_tile)
    valid = iv >= 0
    mins = jnp.min(jnp.where(valid, iv, n_rows), axis=(0, 2))
    maxs = jnp.max(jnp.where(valid, iv, -1), axis=(0, 2))
    has = maxs >= 0
    wlo = jnp.where(has, mins // feat_tile, 0).astype(jnp.int32)
    whi = jnp.where(has, maxs // feat_tile, 0).astype(jnp.int32)
    nwin = jnp.where(has, whi - wlo + 1, 0).astype(jnp.int32)
    sweep = jnp.arange(n_win, dtype=jnp.int32)
    wmap = jnp.clip(wlo[:, None] + sweep[None, :], 0, whi[:, None])
    return wmap, nwin


@functools.partial(jax.jit,
                   static_argnames=("out_cap", "out_tile", "interpret"))
def _sparse_conv_fod(features: jnp.ndarray, maps: KernelMaps,
                     weights: jnp.ndarray, out_cap: int,
                     out_tile: int, interpret: bool) -> jnp.ndarray:
    inv = invert_maps(maps, out_cap)
    m_pad = _round_up(out_cap, out_tile)
    inv = jnp.pad(inv, ((0, 0), (0, m_pad - out_cap)), constant_values=-1)
    out = spconv_fod_pallas(features, inv, weights, out_tile=out_tile,
                            interpret=interpret)
    return out[:out_cap]


def sparse_conv_fod(features: jnp.ndarray, maps: KernelMaps,
                    weights: jnp.ndarray, out_cap: int,
                    out_tile: int = 128,
                    interpret: bool | None = None) -> jnp.ndarray:
    """Drop-in replacement for core.sparseconv flows (flow='pallas').

    interpret=None auto-selects from the active backend: compiled on TPU,
    interpreter everywhere else (CPU validation).  Pass a bool to override.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _sparse_conv_fod(features, maps, weights, out_cap, out_tile,
                            interpret)


@functools.partial(jax.jit,
                   static_argnames=("out_cap", "out_tile", "feat_tile",
                                    "cin_tile", "relu", "interpret"))
def _sparse_conv_fused(features, maps, weights, out_cap, bias, ln_scale,
                       ln_bias, mask, residual, relu, out_tile, feat_tile,
                       cin_tile, interpret):
    n = features.shape[0]
    inv = invert_maps(maps, out_cap)
    m_pad = _round_up(out_cap, out_tile)
    inv = jnp.pad(inv, ((0, 0), (0, m_pad - out_cap)), constant_values=-1)
    feat_tile = min(feat_tile, _round_up(n, 8))
    n_pad = _round_up(n, feat_tile)
    features = _pad_rows(features, n_pad)
    if cin_tile is not None:
        features, weights = _pad_cin(features, weights, cin_tile)
    if mask is not None:
        mask = _pad_rows(mask.astype(features.dtype), m_pad)
    if residual is not None:
        residual = _pad_rows(residual, m_pad)
    wmap, nwin = window_schedule(inv, n_pad, out_tile, feat_tile)
    out = spconv_fod_fused_pallas(
        features, inv, weights, wmap, nwin, bias=bias, ln_scale=ln_scale,
        ln_bias=ln_bias, residual=residual, mask=mask, relu=relu,
        feat_tile=feat_tile, out_tile=out_tile, cin_tile=cin_tile,
        interpret=interpret)
    return out[:out_cap]


def sparse_conv_fused(features: jnp.ndarray, maps: KernelMaps,
                      weights: jnp.ndarray, out_cap: int,
                      epilogue: Epilogue | None = None,
                      feat_tile: int | None = None,
                      out_tile: int = 128, cin_tile: int | None = None,
                      interpret: bool | None = None) -> jnp.ndarray:
    """Streamed + fused FoD conv (flow='pallas_fused').

    feat_tile is the feature cache-block row count (None = whole cloud
    resident, clamped to the padded cloud size either way); out_tile the
    output-stationary tile; cin_tile optionally tiles the contraction dim
    (odd channel counts are zero-padded).  `epilogue` runs inside the
    kernel flush — see core.sparseconv.Epilogue.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    epi = epilogue or Epilogue()
    if (epi.ln_scale is None) != (epi.ln_bias is None):
        raise ValueError("Epilogue.ln_scale and ln_bias must come together")
    if feat_tile is None:
        feat_tile = _round_up(features.shape[0], 8)
    return _sparse_conv_fused(
        features, maps, weights, out_cap, epi.bias, epi.ln_scale,
        epi.ln_bias, epi.mask, epi.residual, bool(epi.relu), out_tile,
        feat_tile, cin_tile, interpret)


def sparse_conv_fod_ref(features, maps, weights, out_cap):
    return spconv_fod_ref(features, invert_maps(maps, out_cap), weights)
