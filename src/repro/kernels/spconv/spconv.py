"""Fetch-on-Demand sparse convolution kernels (PointAcc MMU+MXU, §4.2/§4.3).

TPU adaptation of the paper's dataflow:

  * output-stationary: the (out_tile, Cout) accumulator lives in VMEM scratch
    across all K kernel offsets — partial sums NEVER touch HBM (the paper's
    'eliminate the off-chip scatter of partial sums').
  * weight-stationary inner steps: the kernel-offset weights are VMEM
    resident while feature tiles stream past them (paper §4.2.2).
  * scatter-free: maps are pre-inverted per offset into `inv_idx[k, j] = i`
    (input row feeding output j under offset k, -1 if none).  Each output row
    has at most one contribution per offset (kernel-mapping is 1:1 per
    offset for coordinate-set clouds), so the MXU 'only accesses features of
    one output point in one cycle' (paper §4.3) and no scatter circuit/op is
    needed.
  * fetch-on-demand: input rows are gathered inside the kernel from the
    VMEM-resident feature block immediately before the matmul — the gathered
    matrix is never materialised in HBM (the paper's 3x DRAM saving,
    Fig. 11c).

Two kernels:

  * `spconv_fod_pallas` — the original realisation: grid (out, cin, K) with
    the whole (N, cin_tile) feature array resident per step.  Kept as the
    `flow="pallas"` baseline and for cross-checking.
  * `spconv_fod_fused_pallas` — the temporal-fusion realisation (§4.2.4):
      - streamed feature tiles: the feature array is cut into `feat_tile`
        row windows (the paper's configurable cache blocks).  A scalar-
        prefetched per-out-tile window map drives the BlockSpec index_map,
        so only the windows an output tile actually references are fetched
        (revisited clamp indices cost no new DMA) and clouds larger than
        VMEM stream with double buffering instead of failing.
      - the K-offset loop runs *inside* the kernel body, so each feature
        window moves HBM->VMEM once per output tile, not once per offset —
        a K-fold cut in feature traffic over the baseline kernel.
      - fused epilogue: the flush applies bias / layernorm / residual-add
        (from a VMEM-resident skip tile) / ReLU / row-mask before the single
        output write, so a conv+norm+activation block writes no
        pre-activation intermediate to HBM.

Window maps rely on no ordering property for correctness — every referenced
window is visited and rows are masked to their window — but when features
are stored in packed-key order (core.mapping.SortedCloud) the inverse
tables are monotone per offset, the per-tile window ranges collapse, and
the sweep touches a near-minimal set of blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro import compat

LN_EPS = 1e-6  # must match repro.nn.layernorm


def _kernel(inv_ref, feat_ref, w_ref, out_ref, acc_ref, *, n_k, n_cin):
    ci = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when((k == 0) & (ci == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    idx = inv_ref[0, :]                                   # (T,) int32
    valid = idx >= 0
    rows = jnp.take(feat_ref[...], jnp.maximum(idx, 0), axis=0)
    rows = jnp.where(valid[:, None], rows, 0.0)
    acc_ref[...] += jnp.dot(rows, w_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when((k == n_k - 1) & (ci == n_cin - 1))
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def spconv_fod_pallas(features: jnp.ndarray, inv_idx: jnp.ndarray,
                      weights: jnp.ndarray, *, out_tile: int = 128,
                      cin_tile: int | None = None,
                      interpret: bool = False) -> jnp.ndarray:
    """features (N, Cin), inv_idx (K, M) int32 (-1 = no map),
    weights (K, Cin, Cout) -> (M, Cout).

    M and N must be multiples of the tile sizes (the ops.py wrapper pads
    both M and Cin).
    """
    n, cin = features.shape
    k, m = inv_idx.shape
    cout = weights.shape[-1]
    cin_tile = cin_tile or cin
    if cin % cin_tile != 0:
        raise ValueError(
            f"cin={cin} is not a multiple of cin_tile={cin_tile}; pad the "
            "channel dim (ops.sparse_conv_fod does) or pick a divisor")
    if m % out_tile != 0:
        raise ValueError(
            f"output rows m={m} not a multiple of out_tile={out_tile}; pad "
            "the inverse table (ops.sparse_conv_fod does)")
    n_cin = cin // cin_tile

    grid = (m // out_tile, n_cin, k)

    return pl.pallas_call(
        functools.partial(_kernel, n_k=k, n_cin=n_cin),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, out_tile), lambda o, ci, kk: (kk, o)),
            pl.BlockSpec((n, cin_tile), lambda o, ci, kk: (0, ci)),
            pl.BlockSpec((1, cin_tile, cout),
                         lambda o, ci, kk: (kk, ci, 0)),
        ],
        out_specs=pl.BlockSpec((out_tile, cout), lambda o, ci, kk: (o, 0)),
        out_shape=jax.ShapeDtypeStruct((m, cout), features.dtype),
        scratch_shapes=[pltpu.VMEM((out_tile, cout), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
        name="spconv_fetch_on_demand",
    )(inv_idx, features, weights)


# ---------------------------------------------------------------------------
# fused epilogue + streamed feature tiles
# ---------------------------------------------------------------------------

def _fused_kernel(wmap_ref, nwin_ref, inv_ref, feat_ref, w_ref, *rest,
                  n_k, n_cin, n_win, feat_tile, has_bias, has_ln, has_res,
                  has_mask, relu):
    it = iter(rest)
    bias_ref = next(it) if has_bias else None
    ln_scale_ref = next(it) if has_ln else None
    ln_bias_ref = next(it) if has_ln else None
    res_ref = next(it) if has_res else None
    mask_ref = next(it) if has_mask else None
    out_ref = next(it)
    acc_ref = next(it)

    o = pl.program_id(0)
    ci = pl.program_id(1)
    wi = pl.program_id(2)

    @pl.when((ci == 0) & (wi == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Only the first nwin[o] steps present fresh windows; the remaining
    # sweep steps revisit the last block (clamped index map -> no new DMA)
    # and are skipped so no row is counted twice.
    @pl.when(wi < nwin_ref[o])
    def _compute():
        base = wmap_ref[o, wi] * feat_tile
        feat = feat_ref[...]                              # (F, cin_tile)
        for k in range(n_k):                              # static unroll
            idx = inv_ref[k, :]                           # (T,) int32
            loc = idx - base
            ok = (idx >= 0) & (loc >= 0) & (loc < feat_tile)

            @pl.when(jnp.any(ok))
            def _dot():
                rows = jnp.take(feat, jnp.clip(loc, 0, feat_tile - 1),
                                axis=0)
                rows = jnp.where(ok[:, None], rows, 0.0)
                acc_ref[...] += jnp.dot(rows, w_ref[k],
                                        preferred_element_type=jnp.float32)

    @pl.when((ci == n_cin - 1) & (wi == n_win - 1))
    def _flush():
        r = acc_ref[...]                                  # f32 (T, Cout)
        if has_bias:
            r = r + bias_ref[...]                         # (1, Cout)
        if has_ln:
            mu = jnp.mean(r, axis=1, keepdims=True)
            var = jnp.mean(jnp.square(r - mu), axis=1, keepdims=True)
            r = (r - mu) * jax.lax.rsqrt(var + LN_EPS)
            r = r * ln_scale_ref[...] + ln_bias_ref[...]
        if has_res:
            r = r + res_ref[...].astype(jnp.float32)
        if relu:
            r = jnp.maximum(r, 0.0)
        if has_mask:
            r = r * mask_ref[...].astype(jnp.float32)     # (T, 1)
        out_ref[...] = r.astype(out_ref.dtype)


def spconv_fod_fused_pallas(features: jnp.ndarray, inv_idx: jnp.ndarray,
                            weights: jnp.ndarray,
                            wmap: jnp.ndarray, nwin: jnp.ndarray, *,
                            bias: jnp.ndarray | None = None,
                            ln_scale: jnp.ndarray | None = None,
                            ln_bias: jnp.ndarray | None = None,
                            residual: jnp.ndarray | None = None,
                            mask: jnp.ndarray | None = None,
                            relu: bool = False,
                            feat_tile: int, out_tile: int = 128,
                            cin_tile: int | None = None,
                            interpret: bool = False) -> jnp.ndarray:
    """Streamed + fused FoD conv.  features (N, Cin), inv_idx (K, M),
    weights (K, Cin, Cout) -> (M, Cout).

    wmap (M/out_tile, N/feat_tile) int32 and nwin (M/out_tile,) int32 are
    the scalar-prefetched window schedule: out tile o visits feature row
    blocks wmap[o, 0..nwin[o]-1] (ops.py derives them from the inverse
    table).  Epilogue (all optional, applied in this order at flush):
    +bias (1, Cout) -> layernorm (ln_scale/ln_bias (1, Cout)) ->
    +residual (M, Cout) -> ReLU -> *mask (M, 1).
    """
    n, cin = features.shape
    k, m = inv_idx.shape
    cout = weights.shape[-1]
    cin_tile = cin_tile or cin
    if cin % cin_tile != 0:
        raise ValueError(
            f"cin={cin} is not a multiple of cin_tile={cin_tile}; pad the "
            "channel dim (ops.sparse_conv_fused does) or pick a divisor")
    if m % out_tile != 0:
        raise ValueError(
            f"output rows m={m} not a multiple of out_tile={out_tile}; pad "
            "the inverse table (ops.sparse_conv_fused does)")
    if n % feat_tile != 0:
        raise ValueError(
            f"feature rows n={n} not a multiple of feat_tile={feat_tile}; "
            "pad the features (ops.sparse_conv_fused does)")
    if (ln_scale is None) != (ln_bias is None):
        raise ValueError("ln_scale and ln_bias must be passed together")
    n_cin = cin // cin_tile
    n_win = n // feat_tile
    tiles = m // out_tile
    if wmap.shape != (tiles, n_win) or nwin.shape != (tiles,):
        raise ValueError(
            f"window schedule shapes {wmap.shape}/{nwin.shape} do not match "
            f"grid ({tiles}, {n_win})")

    has_bias = bias is not None
    has_ln = ln_scale is not None
    has_res = residual is not None
    has_mask = mask is not None

    in_specs = [
        pl.BlockSpec((k, out_tile), lambda o, ci, wi, wm, nw: (0, o)),
        pl.BlockSpec((feat_tile, cin_tile),
                     lambda o, ci, wi, wm, nw: (wm[o, wi], ci)),
        pl.BlockSpec((k, cin_tile, cout),
                     lambda o, ci, wi, wm, nw: (0, ci, 0)),
    ]
    operands = [inv_idx, features, weights]
    if has_bias:
        in_specs.append(pl.BlockSpec((1, cout),
                                     lambda o, ci, wi, wm, nw: (0, 0)))
        operands.append(bias.reshape(1, cout))
    if has_ln:
        for p in (ln_scale, ln_bias):
            in_specs.append(pl.BlockSpec((1, cout),
                                         lambda o, ci, wi, wm, nw: (0, 0)))
            operands.append(p.reshape(1, cout))
    if has_res:
        in_specs.append(pl.BlockSpec((out_tile, cout),
                                     lambda o, ci, wi, wm, nw: (o, 0)))
        operands.append(residual)
    if has_mask:
        in_specs.append(pl.BlockSpec((out_tile, 1),
                                     lambda o, ci, wi, wm, nw: (o, 0)))
        operands.append(mask.reshape(m, 1).astype(features.dtype))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(tiles, n_cin, n_win),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((out_tile, cout),
                               lambda o, ci, wi, wm, nw: (o, 0)),
        scratch_shapes=[pltpu.VMEM((out_tile, cout), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_fused_kernel, n_k=k, n_cin=n_cin, n_win=n_win,
                          feat_tile=feat_tile, has_bias=has_bias,
                          has_ln=has_ln, has_res=has_res, has_mask=has_mask,
                          relu=relu),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, cout), features.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
        name="spconv_fod_fused",
    )(wmap, nwin, *operands)
