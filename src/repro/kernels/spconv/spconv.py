"""Fetch-on-Demand sparse convolution kernel (PointAcc MMU+MXU, §4.2/§4.3).

TPU adaptation of the paper's dataflow:

  * output-stationary: the (out_tile, Cout) accumulator lives in VMEM scratch
    across all K kernel offsets — partial sums NEVER touch HBM (the paper's
    'eliminate the off-chip scatter of partial sums').
  * weight-stationary inner steps: one offset's (Cin, Cout) weight tile is
    resident per grid step (paper §4.2.2).
  * scatter-free: maps are pre-inverted per offset into `inv_idx[k, j] = i`
    (input row feeding output j under offset k, -1 if none).  Each output row
    has at most one contribution per offset (kernel-mapping is 1:1 per
    offset for coordinate-set clouds), so the MXU 'only accesses features of
    one output point in one cycle' (paper §4.3) and no scatter circuit/op is
    needed.
  * fetch-on-demand: input rows are gathered inside the kernel from the
    VMEM-resident feature block immediately before the matmul — the gathered
    matrix is never materialised in HBM (the paper's 3x DRAM saving,
    Fig. 11c).  For clouds larger than a VMEM block the wrapper tiles the
    input channel dim; point-dim tiling happens at the distribution layer.

Grid: (out_tiles, cin_tiles, K) with K innermost (arbitrary) so the output
accumulator revisits the same block while offsets stream.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro import compat


def _kernel(inv_ref, feat_ref, w_ref, out_ref, acc_ref, *, n_k, n_cin):
    ci = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when((k == 0) & (ci == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    idx = inv_ref[0, :]                                   # (T,) int32
    valid = idx >= 0
    rows = jnp.take(feat_ref[...], jnp.maximum(idx, 0), axis=0)
    rows = jnp.where(valid[:, None], rows, 0.0)
    acc_ref[...] += jnp.dot(rows, w_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when((k == n_k - 1) & (ci == n_cin - 1))
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def spconv_fod_pallas(features: jnp.ndarray, inv_idx: jnp.ndarray,
                      weights: jnp.ndarray, *, out_tile: int = 128,
                      cin_tile: int | None = None,
                      interpret: bool = False) -> jnp.ndarray:
    """features (N, Cin), inv_idx (K, M) int32 (-1 = no map),
    weights (K, Cin, Cout) -> (M, Cout).

    M and N must be multiples of the tile sizes (wrapper pads).
    """
    n, cin = features.shape
    k, m = inv_idx.shape
    cout = weights.shape[-1]
    cin_tile = cin_tile or cin
    assert cin % cin_tile == 0 and m % out_tile == 0
    n_cin = cin // cin_tile

    grid = (m // out_tile, n_cin, k)

    return pl.pallas_call(
        functools.partial(_kernel, n_k=k, n_cin=n_cin),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, out_tile), lambda o, ci, kk: (kk, o)),
            pl.BlockSpec((n, cin_tile), lambda o, ci, kk: (0, ci)),
            pl.BlockSpec((1, cin_tile, cout),
                         lambda o, ci, kk: (kk, ci, 0)),
        ],
        out_specs=pl.BlockSpec((out_tile, cout), lambda o, ci, kk: (o, 0)),
        out_shape=jax.ShapeDtypeStruct((m, cout), features.dtype),
        scratch_shapes=[pltpu.VMEM((out_tile, cout), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
        name="spconv_fetch_on_demand",
    )(inv_idx, features, weights)
