"""jit'd wrapper: pads the cache to the block multiple, handles layouts."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_decode.flash_decode import flash_decode_pallas


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit,
                   static_argnames=("softcap", "block_s", "interpret"))
def flash_decode(q, k, v, lengths, *, softcap=None, block_s: int = 256,
                 interpret: bool = True):
    """Decode attention: q (B, Hq, hd) vs cache k/v (B, S, Hkv, hd) with
    per-sequence valid lengths (B,)."""
    s = k.shape[1]
    block_s = min(block_s, _round_up(s, 128))
    pad = _round_up(s, block_s) - s
    if pad:
        cfg = [(0, 0), (0, pad), (0, 0), (0, 0)]
        k = jnp.pad(k, cfg)
        v = jnp.pad(v, cfg)
    return flash_decode_pallas(q, k, v, lengths, softcap=softcap,
                               block_s=block_s, interpret=interpret)
