"""Pure-jnp oracle for the flash-decode kernel."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_decode_ref(q, k, v, lengths, *, softcap=None, scale=None):
    """q (B, Hq, hd); k/v (B, S, Hkv, hd); lengths (B,) -> (B, Hq, hd)."""
    b, hq, hd = q.shape
    _, s, hkv, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(b, hkv, g, hd).astype(jnp.float32) * scale
    logits = jnp.einsum("bhgd,bshd->bhgs", qg, k.astype(jnp.float32))
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    valid = jnp.arange(s)[None, :] < lengths[:, None]
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, hd).astype(q.dtype)
