"""Flash-decoding kernel: single-token attention against a long KV cache.

The H1 hillclimb showed decode is dominated by KV-cache traffic; on real
TPU the remaining memory term is this kernel's to win: it streams the cache
HBM->VMEM exactly once in (block_s, hd) tiles, keeps the (G, hd) online-
softmax accumulator in VMEM, and masks invalid slots from the per-sequence
`length` operand (scalar-prefetched, so tiles beyond the current length are
skipped without reading the cache — the same pl.when tile-skip as the
prefill flash kernel).

Ring-buffer SWA caches work unchanged: every slot is valid once the ring
has wrapped, and `length` handles the warm-up phase (the wrapper passes
min(pos+1, window)).

Grid: (B * Hkv, S_tiles); GQA handled by keeping all G query heads of one
kv head in the q block (they share every kv tile).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro import compat

_NEG_INF = -1e30
_LANES = 128


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, softcap, block_s: int, n_s: int):
    bh = pl.program_id(0)
    it = pl.program_id(1)
    length = len_ref[bh]

    @pl.when(it == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(it * block_s < length)      # skip tiles beyond the length
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (G, hd)
        k = k_ref[0].astype(jnp.float32)                  # (block_s, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        kpos = it * block_s + jax.lax.broadcasted_iota(
            jnp.int32, (q.shape[0], block_s), 1)
        s = jnp.where(kpos < length, s, _NEG_INF)         # (G, block_s)

        m_prev = m_ref[...][:, :1]
        l_prev = l_ref[...][:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)                  # (block_s, hd)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(it == n_s - 1)
    def _flush():
        l = l_ref[...][:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_decode_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        lengths: jnp.ndarray, *,
                        softcap: float | None = None,
                        scale: float | None = None, block_s: int = 256,
                        interpret: bool = False) -> jnp.ndarray:
    """q (B, Hq, hd); k/v (B, S, Hkv, hd); lengths (B,) int32 -> (B, Hq, hd).

    S must be a multiple of block_s (ops.py pads the cache)."""
    b, hq, hd = q.shape
    _, s, hkv, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    assert s % block_s == 0
    n_s = s // block_s

    qg = q.reshape(b * hkv, g, hd)
    # (B, S, Hkv, hd) -> (B*Hkv, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, s, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, s, hd)
    len_bh = jnp.repeat(lengths.astype(jnp.int32), hkv)

    gs = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b * hkv, n_s),
        in_specs=[
            pl.BlockSpec((1, g, hd), lambda bh, it, L: (bh, 0, 0)),
            pl.BlockSpec((1, block_s, hd), lambda bh, it, L: (bh, it, 0)),
            pl.BlockSpec((1, block_s, hd), lambda bh, it, L: (bh, it, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, hd), lambda bh, it, L: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),
            pltpu.VMEM((g, _LANES), jnp.float32),
            pltpu.VMEM((g, _LANES), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, softcap=softcap,
                          block_s=block_s, n_s=n_s),
        grid_spec=gs,
        out_shape=jax.ShapeDtypeStruct((b * hkv, g, hd), q.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="flash_decode",
    )(len_bh, qg, kf, vf)
    return out.reshape(b, hq, hd)
