"""Pure-jnp oracle for the grouped matmul kernel."""

import jax.numpy as jnp


def grouped_matmul_ref(x, tile_eid, weights, row_tile: int = 128):
    r, cin = x.shape
    n_tiles = r // row_tile
    xt = x.reshape(n_tiles, row_tile, cin)
    wt = weights[tile_eid]                              # (n_tiles, Cin, Cout)
    out = jnp.einsum("tik,tkj->tij", xt, wt,
                     preferred_element_type=jnp.float32)
    return out.reshape(r, weights.shape[-1]).astype(x.dtype)
