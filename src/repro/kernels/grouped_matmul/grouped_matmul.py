"""Grouped (per-expert) matmul kernel — the PointAcc paradigm applied to MoE.

MoE token routing is a mapping operation in the paper's exact sense: tuples
(token i, expert e, weight W_e) play the role of (p_i, q_k, w_n).  We build
the maps with the ranking kernel (sort tokens by expert id — Mapping Unit)
and consume them with this kernel (Fetch-on-Demand — MMU/MXU):

  * tokens arrive sorted by expert, each expert segment padded to a multiple
    of the row tile, so every row tile belongs to exactly one expert;
  * the expert id per row tile is a *scalar-prefetched* operand whose value
    drives the weight BlockSpec index_map — the hardware analogue is the
    MMU's address generator consuming map metadata (paper Fig. 7 top);
  * expert weights stream HBM->VMEM only for tiles that need them
    (fetch-on-demand), tokens are read exactly once, outputs written exactly
    once — no gathered intermediate ever exists in HBM.

Grid: (row_tiles, cout_tiles, cin_tiles) with cin innermost, accumulating
output-stationary in VMEM scratch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro import compat


def _kernel(eid_ref, x_ref, w_ref, o_ref, acc_ref, *, n_ci):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(ci == n_ci - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def grouped_matmul_pallas(x: jnp.ndarray, tile_eid: jnp.ndarray,
                          weights: jnp.ndarray, *, row_tile: int = 128,
                          cin_tile: int | None = None,
                          cout_tile: int | None = None,
                          interpret: bool = False) -> jnp.ndarray:
    """x (R, Cin) rows sorted+padded by expert; tile_eid (R//row_tile,) int32;
    weights (E, Cin, Cout) -> (R, Cout)."""
    r, cin = x.shape
    e, _, cout = weights.shape
    assert r % row_tile == 0
    cin_tile = cin_tile or cin
    cout_tile = cout_tile or cout
    assert cin % cin_tile == 0 and cout % cout_tile == 0
    n_ci = cin // cin_tile

    grid = (r // row_tile, cout // cout_tile, n_ci)

    gs = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_tile, cin_tile),
                         lambda i, co, ci, eid: (i, ci)),
            pl.BlockSpec((1, cin_tile, cout_tile),
                         lambda i, co, ci, eid: (eid[i], ci, co)),
        ],
        out_specs=pl.BlockSpec((row_tile, cout_tile),
                               lambda i, co, ci, eid: (i, co)),
        scratch_shapes=[pltpu.VMEM((row_tile, cout_tile), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_kernel, n_ci=n_ci),
        grid_spec=gs,
        out_shape=jax.ShapeDtypeStruct((r, cout), x.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
        name="grouped_matmul_fod",
    )(tile_eid, x, weights)
