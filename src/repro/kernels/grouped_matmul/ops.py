"""Sorted MoE dispatch + grouped-matmul FFN (ranking-based, PointAcc-style).

The dispatch is the Mapping-Unit step: a stable `lax.sort` of assignment
expert-ids produces contiguous per-expert segments (maps), capacity-clipped
and padded to the row tile; the grouped matmul kernel consumes them
Fetch-on-Demand.  The dense one-hot dispatch (`repro.models.moe.dense`) is
the Gather-MatMul-Scatter baseline for the Fig.17-style comparison.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.grouped_matmul.grouped_matmul import grouped_matmul_pallas
from repro.kernels.grouped_matmul.ref import grouped_matmul_ref


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


class Dispatch(NamedTuple):
    """Maps from (token, choice) assignments to sorted padded rows."""
    dest_row: jnp.ndarray     # (T, topk) int32 row in sorted buffer, -1 drop
    tile_eid: jnp.ndarray     # (rows // row_tile,) int32 expert per row tile
    src_token: jnp.ndarray    # (rows,) int32 source token per row, -1 pad
    n_rows: int


def make_dispatch(expert_idx: jnp.ndarray, n_experts: int,
                  capacity: int, row_tile: int = 128) -> Dispatch:
    """expert_idx (T, topk) -> sorted segment layout.

    capacity = max tokens kept per expert (already row_tile aligned by the
    caller).  Ranking-based: one stable sort over assignments.
    """
    t, topk = expert_idx.shape
    a = t * topk
    flat_e = expert_idx.reshape(-1).astype(jnp.int32)
    flat_tok = jnp.arange(a, dtype=jnp.int32) // topk

    # Mapping Unit: sort assignments by expert id (stable keeps token order)
    s_e, s_tok, s_a = lax.sort((flat_e, flat_tok,
                                jnp.arange(a, dtype=jnp.int32)),
                               dimension=0, num_keys=1, is_stable=True)
    # position within the expert segment
    seg_start = jnp.searchsorted(s_e, jnp.arange(n_experts), side="left")
    pos = jnp.arange(a, dtype=jnp.int32) - seg_start[s_e]
    keep = pos < capacity
    dest = jnp.where(keep, s_e * capacity + pos, -1)

    # scatter dest back to (token, choice) order
    dest_row = jnp.full((a,), -1, jnp.int32).at[s_a].set(dest)
    n_rows = n_experts * capacity
    src_token = jnp.full((n_rows,), -1, jnp.int32).at[
        jnp.where(keep, dest, n_rows)].set(s_tok, mode="drop")
    tile_eid = jnp.repeat(jnp.arange(n_experts, dtype=jnp.int32),
                          capacity // row_tile)
    return Dispatch(dest_row.reshape(t, topk), tile_eid, src_token, n_rows)


def grouped_matmul(x: jnp.ndarray, tile_eid: jnp.ndarray,
                   weights: jnp.ndarray, row_tile: int = 128,
                   interpret: bool = True, use_kernel: bool = True):
    if use_kernel:
        return grouped_matmul_pallas(x, tile_eid, weights,
                                     row_tile=row_tile, interpret=interpret)
    return grouped_matmul_ref(x, tile_eid, weights, row_tile=row_tile)


def sorted_moe_ffn(x: jnp.ndarray, expert_idx: jnp.ndarray,
                   gates: jnp.ndarray, w_in: jnp.ndarray,
                   w_out: jnp.ndarray, *, capacity_factor: float = 1.25,
                   row_tile: int = 128, act=jax.nn.silu,
                   w_gate: jnp.ndarray | None = None,
                   interpret: bool = True,
                   use_kernel: bool = True) -> jnp.ndarray:
    """Full sorted-dispatch MoE FFN.

    x (T, D); expert_idx/gates (T, topk); w_in (E, D, F); w_out (E, F, D);
    optional w_gate (E, D, F) for gated (SwiGLU-style) experts.
    """
    t, d = x.shape
    e = w_in.shape[0]
    topk = expert_idx.shape[1]
    capacity = _round_up(int(t * topk * capacity_factor / e) + 1, row_tile)
    disp = make_dispatch(expert_idx, e, capacity, row_tile)

    xs = jnp.where(disp.src_token[:, None] >= 0,
                   x[jnp.maximum(disp.src_token, 0)], 0.0)    # (rows, D)
    h = grouped_matmul(xs, disp.tile_eid, w_in, row_tile, interpret,
                       use_kernel)
    if w_gate is not None:
        g = grouped_matmul(xs, disp.tile_eid, w_gate, row_tile, interpret,
                           use_kernel)
        h = act(g) * h
    else:
        h = act(h)
    y = grouped_matmul(h, disp.tile_eid, w_out, row_tile, interpret,
                       use_kernel)                            # (rows, D)

    # combine: gather each assignment's row, weight by gate, sum over topk
    picked = jnp.where(disp.dest_row[..., None] >= 0,
                       y[jnp.maximum(disp.dest_row, 0)], 0.0)  # (T,topk,D)
    return jnp.sum(picked * gates[..., None], axis=1).astype(x.dtype)
