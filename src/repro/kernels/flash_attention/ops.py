"""jit'd wrapper with custom_vjp: Pallas forward, reference-recompute
backward (training defaults to the XLA path + remat; the kernel targets
prefill/serving where no backward exists)."""

from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.flash_attention import \
    flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q, k, v, causal=True, window=None, softcap=None,
                    block=128, interpret=True):
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  softcap=softcap, block_q=block,
                                  block_k=block, interpret=interpret)


def _fwd(q, k, v, causal, window, softcap, block, interpret):
    out = flash_attention(q, k, v, causal, window, softcap, block, interpret)
    return out, (q, k, v)


def _bwd(causal, window, softcap, block, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: attention_ref(q, k, v, causal=causal, window=window,
                                      softcap=softcap), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
