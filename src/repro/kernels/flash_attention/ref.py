"""Pure-jnp oracle for the flash attention kernel (and the XLA path used by
the models / dry-run)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None,
                  softcap: float | None = None,
                  scale: float | None = None) -> jnp.ndarray:
    """q (B, Hq, Sq, D); k, v (B, Hkv, Skv, D) with GQA broadcast."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    qg = q.reshape(b, hkv, g, sq, d).astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kf)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return out.reshape(b, hq, sq, d).astype(q.dtype)
