"""Blockwise (flash) attention Pallas kernel for the LM architectures.

Features needed by the assigned pool: causal masking, sliding-window
attention (mixtral / gemma2 local layers), logit soft-capping (gemma2), and
GQA (every arch).  GQA is expressed in the grid: q is viewed as
(B*Hkv, G, Sq, D) and the kv BlockSpec index_map ignores the group dim, so
one HBM->VMEM copy of each kv tile serves all G query heads (the kv tile is
"cached" in VMEM — same reuse argument as PointAcc's configurable cache).

Online-softmax accumulators (m, l, acc) are VMEM scratch — output-stationary
across kv tiles, the same never-spill-psums dataflow as the spconv kernel.
Out-of-range kv tiles (causal / window) are skipped entirely via pl.when.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro import compat

_NEG_INF = -1e30
_LANES = 128


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int | None,
            softcap: float | None, block_q: int, block_k: int, n_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    k_start = ik * block_k

    # tile-level skip: entirely-masked kv tiles never touch the MXU
    needed = jnp.bool_(True)
    if causal:
        needed &= q_start + block_q - 1 >= k_start
    if window is not None:
        # kv tile entirely left of every query's window -> skip
        needed &= k_start + block_k - 1 >= q_start - window + 1

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (Tq, D)
        k = k_ref[0].astype(jnp.float32)                     # (Tk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)              # (Tq, Tk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...][:, :1]                           # (Tq, 1)
        l_prev = l_ref[...][:, :1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                               # (Tq, Tk)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == n_k - 1)
    def _flush():
        l = l_ref[...][:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           *, causal: bool = True,
                           window: int | None = None,
                           softcap: float | None = None,
                           scale: float | None = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False) -> jnp.ndarray:
    """q (B, Hq, Sq, D); k, v (B, Hkv, Skv, D); Hq % Hkv == 0.

    Sq % block_q == 0 and Skv % block_k == 0 (ops.py pads).
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    qg = q.reshape(b * hkv, g, sq, d)
    kf = k.reshape(b * hkv, skv, d)
    vf = v.reshape(b * hkv, skv, d)
    n_q, n_k = sq // block_q, skv // block_k
    grid = (b * hkv, g, n_q, n_k)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          window=window, softcap=softcap, block_q=block_q,
                          block_k=block_k, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bh, gg, iq, ik: (bh, gg, iq, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, gg, iq, ik: (bh, ik, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, gg, iq, ik: (bh, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bh, gg, iq, ik: (bh, gg, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv, g, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
        name="flash_attention",
    )(qg, kf, vf)
    return out.reshape(b, hq, sq, d)
