"""jit'd wrapper: pads the point dim, applies the fusion plan."""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.fusion import plan_fusion
from repro.kernels.fused_mlp.fused_mlp import fused_mlp_pallas


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("final_act", "tile_points",
                                             "interpret"))
def fused_mlp(x: jnp.ndarray, weights: Sequence[jnp.ndarray],
              biases: Sequence[jnp.ndarray], *, tile_points: int = 512,
              final_act: bool = True, interpret: bool = True) -> jnp.ndarray:
    n = x.shape[0]
    n_pad = _round_up(n, tile_points)
    xp = jnp.pad(x, ((0, n_pad - n), (0, 0)))
    out = fused_mlp_pallas(xp, tuple(weights), tuple(biases),
                           tile_points=tile_points, final_act=final_act,
                           interpret=interpret)
    return out[:n]


def fused_mlp_chain(x: jnp.ndarray, params: dict, *, final_act: bool = True,
                    budget_bytes: int | None = None,
                    interpret: bool = True) -> jnp.ndarray:
    """Apply an nn.mlp_chain parameter dict through fusion groups chosen by
    the paper's compile-time planner (core.fusion.plan_fusion)."""
    n_fcs = len(params)
    ws = [params[f"fc{i}"]["w"] for i in range(n_fcs)]
    bs = [params[f"fc{i}"].get("b", jnp.zeros(ws[i].shape[1], ws[i].dtype))
          for i in range(n_fcs)]
    widths = [ws[0].shape[0]] + [w.shape[1] for w in ws]
    kwargs = {} if budget_bytes is None else {"budget_bytes": budget_bytes}
    groups = plan_fusion(widths, **kwargs)
    h = x
    for gi, g in enumerate(groups):
        last_group = gi == len(groups) - 1
        h = fused_mlp(
            h, ws[g.start:g.start + g.n_layers],
            bs[g.start:g.start + g.n_layers],
            tile_points=g.tile_points,
            final_act=final_act or not last_group,
            interpret=interpret)
    return h
