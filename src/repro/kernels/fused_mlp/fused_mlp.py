"""Temporal layer fusion kernel (PointAcc §4.2.4, Fig. 12).

PointAcc fuses consecutive FC layers by tiling the *point* dimension (FCs
are pointwise — no halos) and keeping every inter-layer activation on-chip
in an MIR-managed stack; only group-boundary tensors touch DRAM.

TPU analogue: one Pallas kernel per fusion group.  The grid walks point-dim
tiles; all fused weights are VMEM-resident (weight-stationary); the chain
h0 -> h1 -> ... -> hL is evaluated per tile entirely in VMEM/registers, and
only hL is written back.  XLA cannot do this on its own — it never fuses
across matmuls — which is exactly why the paper's MMU exists.

The fusion *plan* (#layers per group, tile size) comes from
repro.core.fusion.plan_fusion, reproducing the paper's compile-time search.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat


def _kernel(x_ref, *refs, n_layers: int, final_act: bool):
    out_ref = refs[-1]
    w_refs = refs[:-1]
    h = x_ref[...]
    for i in range(n_layers):
        w, b = w_refs[2 * i], w_refs[2 * i + 1]
        h = jnp.dot(h, w[...], preferred_element_type=jnp.float32)
        h = h + b[...][None, :]
        if i < n_layers - 1 or final_act:
            h = jnp.maximum(h, 0.0)
    out_ref[...] = h.astype(out_ref.dtype)


def fused_mlp_pallas(x: jnp.ndarray, weights: Sequence[jnp.ndarray],
                     biases: Sequence[jnp.ndarray], *,
                     tile_points: int = 512, final_act: bool = True,
                     interpret: bool = False) -> jnp.ndarray:
    """x (N, C0); weights[i] (C_i, C_{i+1}); biases[i] (C_{i+1},).

    N must be a multiple of tile_points (ops.py pads).
    """
    n, c0 = x.shape
    n_layers = len(weights)
    assert n % tile_points == 0
    c_out = weights[-1].shape[1]

    in_specs = [pl.BlockSpec((tile_points, c0), lambda i: (i, 0))]
    operands = [x]
    for w, b in zip(weights, biases):
        in_specs.append(pl.BlockSpec(w.shape, lambda i: (0, 0)))
        in_specs.append(pl.BlockSpec(b.shape, lambda i: (0,)))
        operands.extend([w, b])

    return pl.pallas_call(
        functools.partial(_kernel, n_layers=n_layers, final_act=final_act),
        grid=(n // tile_points,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tile_points, c_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c_out), x.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
        name=f"fused_mlp_x{n_layers}",
    )(*operands)
