"""Pure-jnp oracle for the fused MLP kernel."""

import jax.numpy as jnp


def fused_mlp_ref(x, weights, biases, final_act: bool = True):
    h = x
    n = len(weights)
    for i, (w, b) in enumerate(zip(weights, biases)):
        h = jnp.dot(h, w, preferred_element_type=jnp.float32) + b
        if i < n - 1 or final_act:
            h = jnp.maximum(h, 0.0)
    return h.astype(x.dtype)
