"""Mini-MinkowskiUNet: the paper's co-designed light model (Fig. 16)."""

from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="mini-minkunet", family="pointcloud",
        n_layers=4, d_model=16,
        notes="paper §5.2.2 co-design: shallow/narrow MinkowskiUNet",
    ),
    reduced=ArchConfig(
        name="mini-minkunet", family="pointcloud",
        n_layers=4, d_model=8,
    ),
)
