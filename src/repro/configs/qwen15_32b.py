"""qwen1.5-32b [dense]: 64L d_model=5120 40H (MHA kv=40) d_ff=27392
vocab=152064 — QKV bias.  [hf:Qwen/Qwen1.5]  Full attention -> no
long_500k."""

from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="qwen1.5-32b", family="dense",
        n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40, d_ff=27392,
        vocab_size=152064, qkv_bias=True,
        notes="QKV bias",
    ),
    reduced=ArchConfig(
        name="qwen1.5-32b", family="dense",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=192,
        vocab_size=256, qkv_bias=True,
    ),
)
