"""qwen1.5-4b [dense]: 40L d_model=2560 20H (MHA kv=20) d_ff=6912
vocab=151936 — QKV bias.  [hf:Qwen/Qwen1.5]  Full attention -> no
long_500k."""

from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="qwen1.5-4b", family="dense",
        n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20, d_ff=6912,
        vocab_size=151936, qkv_bias=True,
        notes="QKV bias",
    ),
    reduced=ArchConfig(
        name="qwen1.5-4b", family="dense",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, qkv_bias=True,
    ),
)
