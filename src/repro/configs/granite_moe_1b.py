"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) d_ff=512,
MoE 32e top-8, vocab=49155.  [hf:ibm-granite/granite-3.0-1b-a400m-base]
Highest routing irregularity in the pool (top-8 of 32) — flagship target
for the PointAcc sorted dispatch.  Full attention -> no long_500k."""

from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="granite-moe-1b-a400m", family="moe",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, d_ff=512,
        vocab_size=49155,
        n_experts=32, topk=8,
        notes="32 experts top-8",
    ),
    reduced=ArchConfig(
        name="granite-moe-1b-a400m", family="moe",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=32,
        vocab_size=256, n_experts=8, topk=4,
    ),
)
