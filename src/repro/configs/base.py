"""ArchConfig dataclass + registry for the assigned architecture pool."""

from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass
from typing import Optional, Tuple

_REGISTRY: dict = {}

_ARCH_MODULES = [
    "gemma2_2b", "granite_34b", "qwen15_4b", "qwen15_32b", "jamba_52b",
    "xlstm_125m", "seamless_m4t_medium", "granite_moe_1b", "mixtral_8x7b",
    "qwen2_vl_72b", "minkunet", "mini_minkunet",
]


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense|moe|hybrid|ssm|audio|vlm|pointcloud
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: Optional[int] = None  # defaults to d_model // n_heads

    # attention details
    qkv_bias: bool = False
    attn_softcap: Optional[float] = None      # gemma2
    final_softcap: Optional[float] = None     # gemma2
    sliding_window: Optional[int] = None      # SWA width
    local_global: bool = False                # gemma2 alternating pattern
    rope_theta: float = 10000.0
    mrope: bool = False                       # qwen2-vl M-RoPE
    mrope_sections: Tuple[int, ...] = (16, 24, 24)

    # MLP
    gated_mlp: bool = True                    # SwiGLU vs plain
    act: str = "silu"

    # MoE
    n_experts: int = 0
    topk: int = 0
    moe_every: int = 1          # a MoE FFN every k-th layer (jamba: 2)

    # hybrid / ssm
    attn_every: int = 0         # jamba: 1 attention layer per this many
    ssm_type: Optional[str] = None            # "mamba" | "xlstm"
    d_state: int = 16
    d_conv: int = 4
    ssm_expand: int = 2
    slstm_every: int = 0        # xlstm: sLSTM block frequency

    # encoder-decoder (audio)
    encoder_layers: int = 0

    norm: str = "rmsnorm"                      # rmsnorm | layernorm
    sandwich_norm: bool = False                # gemma2 post-norms
    tie_embeddings: bool = False
    embed_scale: bool = False                  # gemma2 sqrt(d) embed scaling

    # shape policy / structure
    subquadratic: bool = False                 # runs long_500k
    block_pattern: int = 1                     # layers per scan body
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // \
            max(1, self.n_heads)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


def register(cfg: ArchConfig, reduced: "ArchConfig" = None):
    _REGISTRY[cfg.name] = (cfg, reduced)
    return cfg


def _load_all():
    for m in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")


def get(name: str, reduced: bool = False) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    cfg, red = _REGISTRY[name]
    return red if reduced else cfg


def list_archs():
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)
