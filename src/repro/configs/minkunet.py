"""MinkowskiUNet (the paper's own SparseConv benchmark, MinkNet(i)/(o))."""

from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="minkunet", family="pointcloud",
        n_layers=8, d_model=32, vocab_size=0,
        notes="sparse conv U-Net; enc (32,64,128,256) dec (256,128,96,96)",
    ),
    reduced=ArchConfig(
        name="minkunet", family="pointcloud",
        n_layers=4, d_model=8,
        notes="enc (8,16) dec (16,8)",
    ),
)
