"""seamless-m4t-medium [audio]: 12L d_model=1024 16H d_ff=4096 vocab=256206
— encoder-decoder, multimodal.  [arXiv:2308.11596]
Backbone only per the assignment: the speech frontend is a STUB —
input_specs() provides precomputed frame embeddings (B, S_enc, d_model).
12 encoder + 12 decoder layers; full attention -> no long_500k."""

from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="seamless-m4t-medium", family="audio",
        n_layers=12, encoder_layers=12,
        d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
        vocab_size=256206,
        norm="layernorm", gated_mlp=False, act="relu",
        notes="enc-dec, audio frontend stubbed",
    ),
    reduced=ArchConfig(
        name="seamless-m4t-medium", family="audio",
        n_layers=2, encoder_layers=2,
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, norm="layernorm", gated_mlp=False, act="relu",
    ),
)
