"""gemma2-2b [dense]: 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.
Local(4096 SWA)+global alternating attention, attn softcap 50, final logit
softcap 30, head_dim 256, GeGLU, sandwich norms.  [arXiv:2408.00118]
Alternating local/global -> local layers bound their KV at 4k; long_500k
decode runs with full-length KV only on the global layers (seq-sharded)."""

from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="gemma2-2b", family="dense",
        n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, d_ff=9216,
        vocab_size=256000, head_dim=256,
        attn_softcap=50.0, final_softcap=30.0,
        sliding_window=4096, local_global=True,
        gated_mlp=True, act="gelu", sandwich_norm=True,
        tie_embeddings=True, embed_scale=True,
        subquadratic=True, block_pattern=2,
        notes="local+global alternating, logit softcap",
    ),
    reduced=ArchConfig(
        name="gemma2-2b", family="dense",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, head_dim=16,
        attn_softcap=50.0, final_softcap=30.0,
        sliding_window=32, local_global=True,
        gated_mlp=True, act="gelu", sandwich_norm=True,
        tie_embeddings=True, subquadratic=True, block_pattern=2,
    ),
)
