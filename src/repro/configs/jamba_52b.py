"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2 — Mamba+attention 1:7 interleave, MoE every
other layer.  [arXiv:2403.19887]
Scan body = 8 layers (7 mamba + 1 attn; MoE on odd sub-layers).
Hybrid -> runs long_500k (only 4 attention layers hold KV)."""

from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="jamba-v0.1-52b", family="hybrid",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
        vocab_size=65536,
        n_experts=16, topk=2, moe_every=2,
        attn_every=8, ssm_type="mamba", d_state=16, d_conv=4, ssm_expand=2,
        subquadratic=True, block_pattern=8,
        notes="Mamba+attn 1:7 interleave, MoE 16e top-2",
    ),
    reduced=ArchConfig(
        name="jamba-v0.1-52b", family="hybrid",
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256,
        n_experts=4, topk=2, moe_every=2,
        attn_every=8, ssm_type="mamba", d_state=8, d_conv=4, ssm_expand=2,
        subquadratic=True, block_pattern=8,
    ),
)
