"""granite-34b [dense]: 88L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152 — llama-arch code model.  [arXiv:2405.04324]
Plain (non-gated) 4x MLP; MQA single kv head.  Pure full attention ->
long_500k skipped."""

from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="granite-34b", family="dense",
        n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, d_ff=24576,
        vocab_size=49152,
        gated_mlp=False, act="gelu",
        notes="llama-arch, code, MQA",
    ),
    reduced=ArchConfig(
        name="granite-34b", family="dense",
        n_layers=4, d_model=64, n_heads=8, n_kv_heads=1, d_ff=256,
        vocab_size=256, gated_mlp=False, act="gelu",
    ),
)
