"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336, MoE 8e
top-2, vocab=32000, sliding-window attention.  [arXiv:2401.04088]
SWA bounds the KV cache -> runs long_500k."""

from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="mixtral-8x7b", family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
        vocab_size=32000,
        n_experts=8, topk=2, sliding_window=4096,
        subquadratic=True,
        notes="8 experts top-2, SWA",
    ),
    reduced=ArchConfig(
        name="mixtral-8x7b", family="moe",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, n_experts=4, topk=2, sliding_window=32,
        subquadratic=True,
    ),
)
