"""xlstm-125m [ssm]: 12L d_model=768 4H d_ff=0 vocab=50304 — sLSTM + mLSTM
blocks.  [arXiv:2405.04517; unverified]  One sLSTM block per 4 layers
(7:1-style mix scaled to 12L); mLSTM uses matrix memory via chunkwise
linear attention.  SSM family -> runs long_500k."""

from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="xlstm-125m", family="ssm",
        n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, d_ff=0,
        vocab_size=50304,
        ssm_type="xlstm", slstm_every=4, ssm_expand=2,
        subquadratic=True, block_pattern=4,
        notes="sLSTM + mLSTM blocks",
    ),
    reduced=ArchConfig(
        name="xlstm-125m", family="ssm",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=0,
        vocab_size=256,
        ssm_type="xlstm", slstm_every=4, ssm_expand=2,
        subquadratic=True, block_pattern=4,
    ),
)
