"""Architecture configs: the 10 assigned archs + the paper's own models.

Use `repro.configs.get(name)` / `repro.configs.list_archs()`.
"""

from repro.configs.base import ArchConfig, get, list_archs, register

__all__ = ["ArchConfig", "get", "list_archs", "register"]
