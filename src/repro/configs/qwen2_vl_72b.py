"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — M-RoPE, dynamic resolution.  [arXiv:2409.12191]
Backbone only: the vision frontend is a STUB — input_specs() provides
precomputed patch embeddings prepended to the token stream, with 3-D
(t, h, w) M-RoPE position ids supplied as inputs.  Full attention -> no
long_500k."""

from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        name="qwen2-vl-72b", family="vlm",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568,
        vocab_size=152064, qkv_bias=True,
        mrope=True, mrope_sections=(16, 24, 24),
        notes="M-RoPE, dynamic resolution (frontend stubbed)",
    ),
    reduced=ArchConfig(
        name="qwen2-vl-72b", family="vlm",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, qkv_bias=True,
        mrope=True, mrope_sections=(2, 3, 3),
    ),
)
