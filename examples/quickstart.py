"""Quickstart: the PointAcc pipeline end to end on one synthetic scene.

  1. Mapping Unit: quantise coordinates, build kernel maps (sort-merge).
  2. MMU+MXU: run one sparse convolution in all three flows
     (Gather-MatMul-Scatter, Fetch-on-Demand, Pallas FoD kernel) and check
     they agree.
  3. The same conv through the `PointAccSession` frontend (repro.api) —
     the one-object API new code should use.
  4. Run Mini-MinkowskiUNet (the paper's co-designed model) on the scene.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.api import PointAccSession
from repro.core import mapping as M
from repro.core import sparseconv as SC
from repro.data.synthetic import lidar_scene
from repro.models import minkunet as MU

N_POINTS = 2048


def main():
    coords, mask, feats = lidar_scene(seed=0, n_points=N_POINTS, grid=48)
    pc = M.make_point_cloud(jnp.asarray(coords), jnp.asarray(mask))
    feats = jnp.asarray(feats)
    print(f"scene: {int(pc.num_valid())} voxels "
          f"(density {int(pc.num_valid()) / 48**3:.4%})")

    # --- Mapping Unit: ranking-based kernel maps -------------------------
    maps, out_pc = M.build_conv_maps(pc, kernel_size=3, stride=1)
    n_maps = int(jnp.sum(maps.valid))
    print(f"kernel maps (3^3 offsets): {n_maps} input-output pairs "
          f"({n_maps / max(int(pc.num_valid()), 1):.1f} per point)")

    # --- one sparse conv, three computation flows ------------------------
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(27, 4, 16)).astype(np.float32) * 0.2)
    y_gms = SC.gather_matmul_scatter(feats, maps, w, out_pc.capacity)
    y_fod = SC.fetch_on_demand(feats, maps, w, out_pc.capacity)
    from repro.kernels.spconv import ops as spops
    y_pal = spops.sparse_conv_fod(feats, maps, w, out_pc.capacity)
    print("flows agree (G-M-S vs FoD):",
          bool(jnp.allclose(y_gms, y_fod, atol=1e-4)))
    print("flows agree (FoD vs Pallas kernel):",
          bool(jnp.allclose(y_fod, y_pal, atol=1e-4)))

    # --- the same conv through the session frontend ----------------------
    session = PointAccSession(flow="fod")
    x = session.tensor(jnp.asarray(coords), jnp.asarray(mask), feats)
    y = session.conv(x, w)               # kernel_size inferred from w
    print("session conv agrees with raw flow:",
          bool(jnp.allclose(y.feats, y_fod * x.mask[:, None], atol=1e-4)))
    down = session.conv(x, jnp.asarray(
        np.random.default_rng(1).normal(size=(8, 4, 16)).astype(np.float32)),
        stride=2)
    print(f"strided conv: stride {x.stride} -> {down.stride}, "
          f"{int(down.num_valid())} coarse voxels "
          "(transposed convs find these maps by stride-pair lookup)")

    # --- Mini-MinkowskiUNet forward --------------------------------------
    params = MU.mini_minkunet_init(jax.random.key(0), c_in=4, n_classes=2)
    logits = MU.minkunet_forward(
        session, params, session.tensor(jnp.asarray(coords),
                                        jnp.asarray(mask), feats))
    pred = jnp.argmax(logits, -1)
    print(f"Mini-MinkowskiUNet: logits {logits.shape}, "
          f"{int(jnp.sum((pred == 1) & pc.mask))} points predicted 'object'")


if __name__ == "__main__":
    main()
